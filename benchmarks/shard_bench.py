"""Sharded morsel-parallel execution benchmark (acceptance for the
sharding PR).

Three parts:

* ``shard_speedup`` — the scan/join-heavy GCDIA (``a_shard_reg``: two
  selective scans ⋈ on ``customer_id`` → Rel2Matrix → logistic
  regression) on one m2bench database at ``--sf`` (the target is
  sf >= 200), single-stream
  engine vs. ``n_shards=4``. Each repetition constructs a fresh engine so
  neither side gets inter-buffer or exchange-cache reuse: the number is
  the honest cold end-to-end latency. The sharded and serial relations
  are compared bit-for-bit before any timing is trusted.
* ``shard_born`` — one traced 4-shard run; asserts the Rel2Matrix span
  metadata carries ``born_sharded=True, host_gather=False``, i.e. the
  generated matrix reached the GCDA kernel without a host gather.
* ``shard_serial_gate`` — a small input (sf=1) with ``n_shards=4``
  requested: the cost model must choose the single-stream plan
  (``last_shard_count == 1``) and the median latency must stay within 5%
  of an engine that never heard of sharding.

Usage: PYTHONPATH=src python -m benchmarks.run --suite shard [--sf 200]
"""
from __future__ import annotations

import statistics
import sys
import time

import numpy as np

from repro.core import cost
from repro.core.engine import GredoEngine
from repro.data import m2bench


# gradient-descent iterations for the timed GCDIA: enough to exercise the
# device handoff, small enough that the integration data path (the subject
# of this suite) dominates the fixed device compute both sides share
GD_ITERS = 8


def _col_vals(t, name):
    c = t.columns[name]
    return c.decode(c.codes) if hasattr(c, "decode") else np.asarray(c)


def _assert_same_relation(a, b) -> int:
    assert list(a.columns) == list(b.columns)
    assert a.nrows == b.nrows
    for name in a.columns:
        assert np.array_equal(_col_vals(a, name), _col_vals(b, name)), (
            f"sharded relation diverged on {name}")
    return a.nrows


def _time_pair(db, mode: str, run, repeat: int) -> tuple[float, float]:
    """Best-of cold latency, serial vs 4-shard: a fresh engine per
    repetition (no inter-buffer hits, no exchange-cache reuse — both sides
    pay their full pipeline) and the two sides interleaved within each
    repetition so neither gets a cleaner allocator/page-cache state than
    the other. Returns ``(serial_s, sharded_s)``."""
    import gc
    best = {1: float("inf"), 4: float("inf")}
    for _ in range(repeat):
        for n_shards in (1, 4):
            gc.collect()
            eng = GredoEngine(db, mode=mode, n_shards=n_shards)
            t0 = time.perf_counter()
            run(eng)
            best[n_shards] = min(best[n_shards], time.perf_counter() - t0)
            del eng
    return best[1], best[4]


def shard_speedup(sf: int, repeat: int = 3) -> list[dict]:
    db = m2bench.generate(sf=sf)
    q = m2bench.q_shard_join()
    task = m2bench.a_shard_reg()

    import jax

    # correctness anchor before timing: identical rows, identical weights
    serial_eng = GredoEngine(db, mode="gredo")
    shard_eng = GredoEngine(db, mode="gredo", n_shards=4)
    rows = _assert_same_relation(serial_eng.query(q), shard_eng.query(q))
    g0 = np.asarray(GredoEngine(db, mode="gredo").analyze(task, iters=GD_ITERS))
    g1 = np.asarray(GredoEngine(db, mode="gredo",
                                n_shards=4).analyze(task, iters=GD_ITERS))
    assert np.array_equal(g0, g1), "sharded regression weights diverged"
    k_eff = shard_eng.last_shard_count

    # analyze() returns an async device array — block so the timed section
    # covers the GCDA compute, not just host-side dispatch
    out = []
    for name, run in (
            ("gcdi_join", lambda e: e.query(q)),
            ("gcdia_reg",
             lambda e: jax.block_until_ready(e.analyze(task,
                                                       iters=GD_ITERS)))):
        s1, s4 = _time_pair(db, "gredo", run, repeat)
        out.append({
            "table": "shard_speedup", "sf": sf, "workload": name,
            "rows": int(rows), "k": int(k_eff),
            "serial_s": s1, "sharded_s": s4, "speedup": s1 / s4,
        })
    return out


def born_sharded_check(sf: int) -> list[dict]:
    db = m2bench.generate(sf=max(sf // 4, 1))
    saved = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0      # force sharding even on the reduced input
    try:
        eng = GredoEngine(db, mode="gredo", n_shards=4, telemetry=True)
        eng.analyze(m2bench.a_shard_reg())
        spans = [s for s in eng.telemetry.collector.last().spans
                 if s.name == "Rel2Matrix"]
        assert spans, "no Rel2Matrix span in the traced run"
        args = spans[0].args
        assert args.get("born_sharded") is True, args
        assert args.get("host_gather") is False, args
        return [{"table": "shard_born", "sf": sf,
                 "shards": args.get("shards"),
                 "sharding": args.get("sharding"),
                 "born_sharded": True, "host_gather": False}]
    finally:
        cost.SHARD_MIN_ROWS = saved


def serial_gate(repeat: int = 15) -> list[dict]:
    """sf=1 is far below ``cost.SHARD_MIN_ROWS``: an engine asked for 4
    shards must cost-choose the single-stream plan and pay (almost)
    nothing for having asked."""
    db = m2bench.generate(sf=1)
    q = m2bench.q_shard_join()

    def median_lat(n_shards: int) -> float:
        eng = GredoEngine(db, mode="gredo", n_shards=n_shards)
        eng.query(q)                       # warm (stats, dictionaries)
        lat = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            eng.query(q)
            lat.append(time.perf_counter() - t0)
        return statistics.median(lat)

    base = median_lat(1)
    gated = median_lat(4)
    eng = GredoEngine(db, mode="gredo", n_shards=4)
    eng.query(q)
    assert eng.last_shard_count == 1, "cost gate failed to choose serial"
    return [{"table": "shard_serial_gate", "sf": 1,
             "chosen_k": int(eng.last_shard_count),
             "serial_s": base, "gated_s": gated,
             "overhead": gated / base - 1.0}]


def run_suite(sf: int = 200, fast: bool = False) -> list[dict]:
    if fast:
        sf = min(sf, 40)
    rows = shard_speedup(sf=sf, repeat=2 if fast else 4)
    rows += born_sharded_check(sf=sf)
    rows += serial_gate(repeat=9 if fast else 15)
    return rows


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        if r["table"] == "shard_speedup":
            print(f"shard_{r['workload']}_sf{r['sf']},"
                  f"{r['sharded_s']*1e6:.1f},"
                  f"speedup_vs_serial={r['speedup']:.2f};k={r['k']};"
                  f"rows={r['rows']}")
        elif r["table"] == "shard_born":
            print(f"shard_born_sf{r['sf']},0.0,"
                  f"born_sharded={r['born_sharded']};"
                  f"host_gather={r['host_gather']};shards={r['shards']}")
        elif r["table"] == "shard_serial_gate":
            print(f"shard_serial_gate_sf1,{r['gated_s']*1e6:.1f},"
                  f"chosen_k={r['chosen_k']};"
                  f"overhead_vs_serial={r['overhead']*100:.1f}%")
            if r["overhead"] > 0.05:
                print(f"#   WARNING: gate overhead {r['overhead']*100:.1f}% "
                      f"exceeds the 5% budget", file=sys.stderr)
