"""Trace suite: telemetry smoke + disabled-path overhead guard.

Three deliverables (ISSUE 6 acceptance):

1. Run a GCDIA reuse ladder (cold A3 multiply, then the warm A2 similarity
   that shares its GCDI sub-plan) with tracing on; export the Chrome
   trace-event JSON to ``experiments/trace_gcdia.json`` and validate it —
   the spans must cover every executed operator of the DAG *including*
   inter-buffer-hit pseudo-spans.
2. Kernel roofline attribution rows from the fenced GCDA spans
   (``roofline.from_trace``): dispatch vs device-sync time, achieved
   GFLOP/s against the arithmetic-intensity-capped roof.
3. Measure the disabled-telemetry executor against a frozen replica of the
   pre-telemetry ``physical.execute`` on the same DAG. The replica is the
   honest baseline: it is byte-for-byte the old executor body, so the
   comparison isolates exactly what this PR added to the hot path (see
   ``measure_overhead`` for why walk time — wall minus internally-timed
   ``node.run`` — is the only estimator that resolves it under jax
   dispatch noise). Must stay < 2% of end-to-end query time
   (``tests/test_telemetry.py`` guards it too).
"""
from __future__ import annotations

import gc
import json
import os
import sys
import time

import numpy as np

from repro.core import GredoEngine, validate_chrome_trace
from repro.core import physical, telemetry
from repro.core.interbuffer import fingerprint, value_nbytes
from repro.data import m2bench

from . import roofline


# ---------------------------------------------------------------------------
# Pre-telemetry executor replica (the overhead baseline)
# ---------------------------------------------------------------------------


def execute_baseline(node: physical.PhysicalOp, ctx: physical.ExecContext):
    """``physical.execute`` exactly as it was before span tracing landed —
    kept verbatim so the overhead ratio measures only the telemetry gates."""
    sig = node.signature()
    if sig in ctx.memo:
        node.stats.memoized = True
        return ctx.memo[sig]
    if ctx.interbuffer is not None and node.cacheable:
        hit = ctx.interbuffer.get(fingerprint(sig))
        if hit is not None:
            node.stats.cached = True
            node.stats.rows = physical._result_rows(hit)
            node.stats.nbytes = value_nbytes(hit)
            ctx.nodes_reused += 1
            ctx.memo[sig] = hit
            return hit
    inputs = [execute_baseline(c, ctx) for c in node.children]
    t0 = time.perf_counter()
    out = node.run(ctx, *inputs)
    node.stats.seconds += time.perf_counter() - t0
    node.stats.executed = True
    node.stats.rows = physical._result_rows(out)
    if ctx.interbuffer is not None or physical.TRACK_NBYTES:
        node.stats.nbytes = value_nbytes(out)
    ctx.nodes_run += 1
    if ctx.interbuffer is not None and node.cacheable:
        est = ctx.ests.get(id(node)) if ctx.ests is not None else None
        out = ctx.interbuffer.put(fingerprint(sig), out,
                                  est_cost=None if est is None else est[1])
    ctx.memo[sig] = out
    return out


def measure_overhead(sf: int = 1, repeat: int = 30) -> dict:
    """Disabled-telemetry executor vs the pre-PR replica on the same
    gcdia-suite DAG (fresh ExecContext per run, no inter-buffer, so every
    run re-executes the full operator tree).

    End-to-end wall time cannot resolve the question: the jax dispatch in
    this DAG has ms-scale run-to-run variance while the executor walk
    costs ~100µs, so even paired min-of-N bounces ±5%. Both executors
    time ``node.run`` internally, though — wall minus the summed run()
    seconds is exactly the walk's own bookkeeping cost, with the kernel
    noise subtracted out. ``overhead_pct`` is the added walk time as a
    fraction of end-to-end query time, which is what a user pays."""
    db = m2bench.generate(sf=sf)
    eng = GredoEngine(db)
    task = m2bench.a3_multiply()
    p = eng.plan(task.integration)
    naive = physical.build_gcdia(db, p, task, mode="gredo")
    dag, _ = eng._lower(naive)

    def one(fn, inner: int = 5) -> tuple[float, float]:
        # (wall, walk) per execution, batched so µs-scale costs are
        # resolvable above the timer quantum
        run0 = physical.total_seconds(dag)
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(dag, physical.ExecContext(db))
        wall = (time.perf_counter() - t0) / inner
        run_s = (physical.total_seconds(dag) - run0) / inner
        # drain the async jax dispatch queue before the next sample — without
        # this the next sample absorbs this one's still-running device work
        telemetry.fence(out)
        return wall, wall - run_s

    for _ in range(3):                  # warm jit/caches for both
        one(execute_baseline)
        one(physical.execute)
    base, disabled = [], []
    gc.collect()
    gc.disable()    # ms-scale GC pauses land randomly on either series
    try:
        for i in range(repeat):
            if i % 2:   # alternate pair order: cancels first-runner bias
                disabled.append(one(physical.execute))
                base.append(one(execute_baseline))
            else:
                base.append(one(execute_baseline))
                disabled.append(one(physical.execute))
    finally:
        gc.enable()
    base_wall = float(min(w for w, _ in base))
    base_walk = float(np.median([k for _, k in base]))
    disabled_walk = float(np.median([k for _, k in disabled]))
    return {"table": "trace_overhead", "sf": sf, "repeat": repeat,
            "baseline_s": base_wall,
            "disabled_s": float(min(w for w, _ in disabled)),
            "baseline_walk_s": base_walk,
            "disabled_walk_s": disabled_walk,
            "overhead_pct": (disabled_walk - base_walk) / base_wall * 100.0}


# ---------------------------------------------------------------------------
# Traced GCDIA run + export
# ---------------------------------------------------------------------------


def traced_gcdia(sf: int = 1,
                 out_path: str = "experiments/trace_gcdia.json") -> list[dict]:
    db = m2bench.generate(sf=sf)
    m2bench.build_indexes(db)
    eng = GredoEngine(db, telemetry=True)
    prof_cold = eng.profile(m2bench.a3_multiply())     # cold: full DAG runs
    prof_warm = eng.profile(m2bench.a2_similarity())   # warm: shares the
                                                       # GCDI relation
    collector = eng.telemetry.collector
    doc = json.loads(collector.to_chrome_json())       # the round-trip check
    problems = validate_chrome_trace(doc)
    if problems:
        raise AssertionError(f"invalid trace export: {problems}")

    # every operator the DAG touched must be covered by a span — executed
    # ones by complete spans, reuse by cache pseudo-spans
    for prof in (prof_cold, prof_warm):
        spans = [s for s in prof.trace.spans if s.cat != "query"]
        assert spans, "trace has no operator spans"
    warm_ops = [o["op"] for o in eng.last_stats.operators   # last = warm run
                if o["executed"] or o["cached"]]
    warm_spans = [s.name for s in prof_warm.trace.spans if s.cat != "query"]
    missing = set(warm_ops) - set(warm_spans)
    if missing:
        raise AssertionError(f"operators without spans: {missing}")

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# trace -> {out_path} ({len(doc['traceEvents'])} events, "
          f"valid)", file=sys.stderr)

    rows = []
    for label, prof in (("cold_A3_multiply", prof_cold),
                        ("warm_A2_similarity", prof_warm)):
        cache_hits = sum(1 for s in prof.trace.spans if s.cat == "cache")
        rows.append({
            "table": "trace_gcdia", "sf": sf, "step": label,
            "seconds": prof.seconds,
            "spans": len(prof.trace.spans),
            "cache_pseudo_spans": cache_hits,
            "qerror_flags": len(prof.qerrors),
            "trace_file": out_path,
        })
    rows += roofline.from_trace(doc["traceEvents"])
    return rows


def run_suite(sf: int = 1, fast: bool = False) -> list[dict]:
    rows = traced_gcdia(sf=sf)
    rows.append(measure_overhead(sf=sf, repeat=10 if fast else 30))
    return rows


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        if r["table"] == "trace_gcdia":
            print(f"trace_{r['step']}_sf{r['sf']},{r['seconds']*1e6:.1f},"
                  f"spans={r['spans']};cache_spans={r['cache_pseudo_spans']};"
                  f"qerror_flags={r['qerror_flags']}")
        elif r["table"] == "kernel_roofline":
            print(f"trace_kernel_{r['op']},{r['seconds']*1e6:.1f},"
                  f"gflops={r['achieved_gflops']:.2f};"
                  f"roof_frac={r['roofline_frac']:.4f};"
                  f"sync_us={r['sync_s']*1e6:.1f}")
        elif r["table"] == "trace_overhead":
            print(f"trace_disabled_overhead,{r['disabled_s']*1e6:.1f},"
                  f"baseline_us={r['baseline_s']*1e6:.1f};"
                  f"walk_us={r['disabled_walk_s']*1e6:.1f}"
                  f"_vs_{r['baseline_walk_s']*1e6:.1f};"
                  f"overhead_pct={r['overhead_pct']:.2f}")


if __name__ == "__main__":
    print_rows(run_suite())
