"""Traversal-kernel benchmarks: host vs jit vs fused device pattern match.

On this CPU container the Pallas traversal kernel runs in interpret mode
(orders of magnitude slower than compiled TPU code), so the wall-clock
"pallas" rows here run the fused chain through its jnp oracle — the exact
compute the kernel replaces, in the same single-dispatch launch structure.
The fused flavor's CPU advantage over the per-hop jit matcher is therefore
structural and carries to TPU: one jit'd program for the whole chain with
ONE end-of-chain host sync (vs a dispatch + overflow sync per hop), and
predicate tables built through zone-map skip-scans (vs dense full-column
eval per hop). The batched rows measure launch amortization: B point
lookups advanced per launch vs B sequential dispatch sequences.

Tables: traversal_ladder (single-query latency vs start selectivity),
traversal_batched (point-lookup throughput), traversal_roofline
(achieved-vs-roof bandwidth of the DeviceMatchPattern spans, from the
engine's fenced trace export).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import GredoEngine, optimizer, physical
from repro.core.pattern import match, plan_pattern
from repro.core.pattern_jit import device_match, get_matcher
from repro.core.schema import Predicate, Query, chain_pattern
from repro.core.storage import Database, Graph, Table
from repro.kernels.traversal import ops as kops

from . import roofline

GRAPH = "Chain"
SEL_LADDER = (1e-4, 1e-3, 1e-2, 1e-1)
W_CUT = 0.2                   # edge predicate: clustered, zones prune ~80%


def make_db(sf: int = 1, seed: int = 0) -> Database:
    """Homogeneous 2-hop-able graph: n vertices, avg out-degree 8, a
    uniform vertex attribute for the selectivity ladder and a *clustered*
    edge weight (sorted, so zone maps prune the w-range predicate to a
    contiguous chunk band — the kernel's prefetch-filter showcase)."""
    rng = np.random.default_rng(seed)
    n = 20_000 * sf
    V = Table("V", {"vid": np.arange(n, dtype=np.int64),
                    "grp": (np.arange(n, dtype=np.int64) * 7919) % 10_000})
    deg = rng.poisson(8, n).clip(1, 40)
    src = np.repeat(np.arange(n), deg)
    m = len(src)
    E = Table(GRAPH, {"svid": src,
                      "tvid": rng.integers(0, n, m),
                      "w": np.linspace(0.0, 1.0, m)})
    g = Graph(GRAPH, {"V": V}, E, "V", "V")
    db = Database()
    db.add_graph(g)
    db.indexes.create(GRAPH, "w", kind="zone")          # edge zone maps
    db.indexes.create(GRAPH, "grp", label="V")          # start-vertex seed
    return db


def _pattern():
    return chain_pattern(GRAPH, ("a", "V", GRAPH, "b", "V"),
                         ("b", "V", GRAPH, "c", "V"))


def _plan(g, sel: float):
    cut = max(int(sel * 10_000), 1)
    phi = {"a": [Predicate("a.grp", "<", cut)],
           "e0": [Predicate("e0.w", "<=", W_CUT)],
           "e1": [Predicate("e1.w", "<=", W_CUT)]}
    return plan_pattern(g, _pattern(), phi, projected=set(),
                        force_reverse=False, enable_pushdown=True)


def _best(fn, repeat: int) -> float:
    fn()                                   # warm (jit compile, index build)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def latency_ladder(sf: int = 1, repeat: int = 5) -> list[dict]:
    db = make_db(sf=sf)
    g = db.graphs[GRAPH]
    rows = []
    for sel in SEL_LADDER:
        plan = _plan(g, sel)
        n_rows = match(g, plan).nrows
        host_s = _best(lambda: match(g, plan), repeat)
        jit_s = _best(lambda: device_match(g, plan, flavor="jit"), repeat)
        pal_s = _best(lambda: device_match(g, plan, flavor="pallas"), repeat)
        rows.append({
            "table": "traversal_ladder", "sf": sf, "sel": sel,
            "rows": n_rows, "host_s": host_s, "jit_s": jit_s,
            "pallas_s": pal_s,
            "pallas_vs_jit": jit_s / pal_s,
            "pallas_vs_host": host_s / pal_s,
        })
    return rows


def batched_throughput(sf: int = 1, repeat: int = 3,
                       batches=(64, 256)) -> list[dict]:
    db = make_db(sf=sf)
    g = db.graphs[GRAPH]
    matcher = get_matcher(g)
    rp, ci, ei = matcher.csr(False)
    rng = np.random.default_rng(1)
    epred = np.asarray(g.edges.col("w")) <= W_CUT
    members = [None, None]
    epreds = [epred, epred]
    cals = [None, None]
    kw = dict(capacity=1024, chunk=2048)
    n, m = g.n_vertices, g.edges.nrows
    rows = []
    for B in batches:
        starts = rng.integers(0, n, B).astype(np.int64)

        def seq_jit():
            for s in starts:
                matcher.match_chain(np.array([s]), members, epreds,
                                    initial_capacity=1024)

        def seq_fused():
            for s in starts:
                _, _, ok = kops.traverse_chain(rp, ci, ei, n, m,
                                               np.array([s]), members,
                                               epreds, cals, **kw)
                assert ok

        def batched():
            out = kops.batched_traverse(rp, ci, ei, n, m, starts, members,
                                        epreds, cals, **kw)
            assert out[3]

        seq_jit_s = _best(seq_jit, repeat)
        seq_fused_s = _best(seq_fused, repeat)
        batched_s = _best(batched, repeat)
        rows.append({
            "table": "traversal_batched", "sf": sf, "B": B,
            "seq_jit_s": seq_jit_s, "seq_fused_s": seq_fused_s,
            "batched_s": batched_s,
            "batched_qps": B / batched_s,
            "speedup_vs_seq_jit": seq_jit_s / batched_s,
            "speedup_vs_seq_fused": seq_fused_s / batched_s,
        })
    return rows


def roofline_rows(sf: int = 1) -> list[dict]:
    """Run a selective 2-hop query through the engine (the optimizer lowers
    it to DeviceMatchPattern) and attribute the fenced kernel spans against
    the TPU roofline from the Chrome trace export."""
    db = make_db(sf=sf)
    eng = GredoEngine(db, telemetry=True)
    q = Query(select=("a.vid", "c.vid"), froms=(), match=_pattern(),
              where=(Predicate("a.grp", "<", 100),
                     Predicate("e0.w", "<=", W_CUT),
                     Predicate("e1.w", "<=", W_CUT)))
    eng.query(q)
    dag = physical.explain(eng.last_dag)
    if "DeviceMatchPattern" not in dag:
        raise AssertionError("optimizer did not pick the device access path:"
                             f"\n{dag}")
    events = eng.telemetry.collector.to_chrome()["traceEvents"]
    rows = []
    for r in roofline.from_trace(events):
        if r["op"] != "DeviceMatchPattern":
            continue
        r = dict(r, table="traversal_roofline", sf=sf)
        rows.append(r)
    if not rows:
        raise AssertionError("no DeviceMatchPattern roofline rows in trace")
    return rows


def run_suite(sf: int = 1, fast: bool = False) -> list[dict]:
    repeat = 2 if fast else 5
    rows = latency_ladder(sf=sf, repeat=repeat)
    rows += batched_throughput(sf=sf, repeat=max(repeat - 1, 1),
                               batches=(64,) if fast else (64, 256))
    rows += roofline_rows(sf=sf)
    return rows


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        if r["table"] == "traversal_ladder":
            print(f"traversal_sel{r['sel']:g}_sf{r['sf']},"
                  f"{r['pallas_s']*1e6:.1f},"
                  f"host_us={r['host_s']*1e6:.1f};"
                  f"jit_us={r['jit_s']*1e6:.1f};"
                  f"pallas_vs_jit={r['pallas_vs_jit']:.2f};rows={r['rows']}")
        elif r["table"] == "traversal_batched":
            print(f"traversal_batched_B{r['B']}_sf{r['sf']},"
                  f"{r['batched_s']*1e6:.1f},"
                  f"qps={r['batched_qps']:.0f};"
                  f"vs_seq_jit={r['speedup_vs_seq_jit']:.2f};"
                  f"vs_seq_fused={r['speedup_vs_seq_fused']:.2f}")
        elif r["table"] == "traversal_roofline":
            print(f"traversal_kernel_{r['op']},{r['seconds']*1e6:.1f},"
                  f"gflops={r['achieved_gflops']:.2f};"
                  f"roof_frac={r['roofline_frac']:.5f};"
                  f"bytes={r['bytes']}")
