"""Cost-based optimizer benchmark: naive-order vs. optimized DAG latency,
plus cardinality quality on the Zipfian-skew fixture.

Part 1 (``optimizer_gain``) runs each M2Bench-style multi-join query twice
through the same engine path — once with the optimizer disabled (the naive
query-order DAG the builder emits) and once with the full rewrite pass
(DP join enumeration, semi-join siding, CSE, selection/projection
sink-down) — and reports the wall-clock ratio, the per-operator
intermediate sizes, and the root est_rows vs. actual rows.

Part 2 (``cardinality_quality``) measures the histogram-overlap join model
against the NDV-only baseline on ``m2bench.generate_skew``: root-level
q-error of the skewed 3-join query under both estimators, and the bushy DP
plan vs. the *best* left-deep plan (``join_enum="dp-leftdeep"``) on the
4-source query.

    PYTHONPATH=src python -m benchmarks.run --suite optimizer [--sf N]
"""
from __future__ import annotations

import time

from repro.core import GredoEngine, physical
from repro.data import m2bench

QUERIES = ("q_g1", "q_g2", "q_g4", "q_opt_skew")


def _best_seconds(eng, q, repeat: int) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        eng.query(q)
        best = min(best, time.perf_counter() - t0)
    return best


def _join_rows(eng) -> int:
    """Total rows flowing out of EquiJoin operators — the intermediate-size
    proxy that join reordering is supposed to shrink."""
    return sum(o["rows"] or 0 for o in eng.last_stats.operators
               if o["op"] == "EquiJoin" and o["rows"] is not None)


def optimizer_gain(sf: int = 2, repeat: int = 5) -> list[dict]:
    db = m2bench.generate(sf=sf)
    rows: list[dict] = []
    for qname in QUERIES:
        q = getattr(m2bench, qname)()
        naive_eng = GredoEngine(db, enable_optimizer=False)
        opt_eng = GredoEngine(db)
        n_rows = naive_eng.query(q).nrows
        o_rows = opt_eng.query(q).nrows
        assert n_rows == o_rows, f"optimizer changed {qname}: {n_rows} != {o_rows}"
        naive_s = _best_seconds(naive_eng, q, repeat)
        opt_s = _best_seconds(opt_eng, q, repeat)
        root_est = opt_eng.last_ests[id(opt_eng.last_dag)][0]
        report = opt_eng.last_report
        rows.append({
            "table": "optimizer_gain", "sf": sf, "query": qname,
            "rows": n_rows,
            "naive_s": naive_s, "opt_s": opt_s,
            "speedup": naive_s / max(opt_s, 1e-9),
            "naive_join_rows": _join_rows(naive_eng),
            "opt_join_rows": _join_rows(opt_eng),
            "est_root_rows": float(root_est),
            "q_error_root": max(root_est / max(n_rows, 1),
                                n_rows / max(root_est, 1e-9)),
            "rewrites": report.notes() if report else [],
        })
    return rows


def _root_qerror(eng) -> float:
    actual = max(eng.last_stats.operators[0]["rows"] or 0, 1)
    est = eng.last_ests[id(eng.last_dag)][0]
    return max(est / actual, actual / max(est, 1e-9))


def cardinality_quality(sf: int = 1, repeat: int = 3) -> list[dict]:
    """Histogram-overlap vs. NDV-only estimates on the Zipfian fixture, and
    bushy DP vs. best left-deep on the 4-source query."""
    db = m2bench.generate_skew(sf=sf)
    rows: list[dict] = []

    # -- skewed 3-join: root q-error under both join-estimate models -------
    q = m2bench.q_skew_3join()
    eng = GredoEngine(db)
    n_rows = eng.query(q).nrows
    q_hist = _root_qerror(eng)
    physical.HIST_JOIN_EST = False
    try:
        eng_ndv = GredoEngine(db)
        assert eng_ndv.query(q).nrows == n_rows
        q_ndv = _root_qerror(eng_ndv)
    finally:
        physical.HIST_JOIN_EST = True
    rows.append({
        "table": "cardinality_quality", "sf": sf, "query": "q_skew_3join",
        "rows": n_rows,
        "q_error_hist": q_hist, "q_error_ndv": q_ndv,
        "ndv_over_hist": q_ndv / max(q_hist, 1e-9),
        "seconds": _best_seconds(eng, q, repeat),
    })

    # -- 4-source bushy query: DP bushy vs best left-deep ------------------
    qb = m2bench.q_bushy_4src()
    bushy_eng = GredoEngine(db)
    ld_eng = GredoEngine(db, join_enum="dp-leftdeep")
    nb = bushy_eng.query(qb).nrows
    assert ld_eng.query(qb).nrows == nb

    def max_join_rows(e):
        return max((o["rows"] or 0) for o in e.last_stats.operators
                   if o["op"] == "EquiJoin")

    bushy_s = _best_seconds(bushy_eng, qb, repeat)
    ld_s = _best_seconds(ld_eng, qb, repeat)
    rows.append({
        "table": "cardinality_quality", "sf": sf, "query": "q_bushy_4src",
        "rows": nb,
        "bushy_selected": any(n.startswith("join-order: dp bushy")
                              for n in bushy_eng.last_stats.rewrites),
        "bushy_s": bushy_s, "best_leftdeep_s": ld_s,
        "speedup_vs_leftdeep": ld_s / max(bushy_s, 1e-9),
        "bushy_join_rows": max_join_rows(bushy_eng),
        "leftdeep_join_rows": max_join_rows(ld_eng),
        "rewrites": (bushy_eng.last_report.notes()
                     if bushy_eng.last_report else []),
    })
    return rows


def print_rows(rows: list[dict]) -> None:
    import sys
    for r in rows:
        if r.get("table") == "cardinality_quality":
            if r["query"] == "q_skew_3join":
                print(f"cardest_{r['query']}_sf{r['sf']},"
                      f"{r['seconds']*1e6:.1f},"
                      f"q_error_hist={r['q_error_hist']:.2f};"
                      f"q_error_ndv={r['q_error_ndv']:.2f};"
                      f"ndv_over_hist={r['ndv_over_hist']:.1f}")
            else:
                print(f"cardest_{r['query']}_sf{r['sf']},"
                      f"{r['bushy_s']*1e6:.1f},"
                      f"bushy_selected={r['bushy_selected']};"
                      f"speedup_vs_best_leftdeep="
                      f"{r['speedup_vs_leftdeep']:.2f};"
                      f"join_rows={r['leftdeep_join_rows']}"
                      f"->{r['bushy_join_rows']}")
            for n in r.get("rewrites", []):
                print(f"#   {n}", file=sys.stderr)
            continue
        print(f"optimizer_{r['query']}_sf{r['sf']},{r['opt_s']*1e6:.1f},"
              f"speedup_vs_naive={r['speedup']:.2f};"
              f"join_rows={r['naive_join_rows']}->{r['opt_join_rows']};"
              f"q_error_root={r['q_error_root']:.2f}")
        for n in r["rewrites"]:
            print(f"#   {n}", file=sys.stderr)


if __name__ == "__main__":
    print_rows(optimizer_gain() + cardinality_quality())
