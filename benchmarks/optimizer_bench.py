"""Cost-based optimizer benchmark: naive-order vs. optimized DAG latency.

Runs each M2Bench-style multi-join query twice through the same engine
path — once with the optimizer disabled (the naive query-order DAG the
builder emits) and once with the full rewrite pass (join reordering,
semi-join siding, CSE, selection/projection sink-down) — and reports the
wall-clock ratio, the per-operator intermediate sizes, and the root
est_rows vs. actual rows (plan-quality check).

    PYTHONPATH=src python -m benchmarks.run --suite optimizer [--sf N]
"""
from __future__ import annotations

import time

from repro.core import GredoEngine, physical
from repro.data import m2bench

QUERIES = ("q_g1", "q_g2", "q_g4", "q_opt_skew")


def _best_seconds(eng, q, repeat: int) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        eng.query(q)
        best = min(best, time.perf_counter() - t0)
    return best


def _join_rows(eng) -> int:
    """Total rows flowing out of EquiJoin operators — the intermediate-size
    proxy that join reordering is supposed to shrink."""
    return sum(o["rows"] or 0 for o in eng.last_stats.operators
               if o["op"] == "EquiJoin" and o["rows"] is not None)


def optimizer_gain(sf: int = 2, repeat: int = 5) -> list[dict]:
    db = m2bench.generate(sf=sf)
    rows: list[dict] = []
    for qname in QUERIES:
        q = getattr(m2bench, qname)()
        naive_eng = GredoEngine(db, enable_optimizer=False)
        opt_eng = GredoEngine(db)
        n_rows = naive_eng.query(q).nrows
        o_rows = opt_eng.query(q).nrows
        assert n_rows == o_rows, f"optimizer changed {qname}: {n_rows} != {o_rows}"
        naive_s = _best_seconds(naive_eng, q, repeat)
        opt_s = _best_seconds(opt_eng, q, repeat)
        root_est = opt_eng.last_ests[id(opt_eng.last_dag)][0]
        report = opt_eng.last_report
        rows.append({
            "table": "optimizer_gain", "sf": sf, "query": qname,
            "rows": n_rows,
            "naive_s": naive_s, "opt_s": opt_s,
            "speedup": naive_s / max(opt_s, 1e-9),
            "naive_join_rows": _join_rows(naive_eng),
            "opt_join_rows": _join_rows(opt_eng),
            "est_root_rows": float(root_est),
            "q_error_root": max(root_est / max(n_rows, 1),
                                n_rows / max(root_est, 1e-9)),
            "rewrites": report.notes() if report else [],
        })
    return rows


def print_rows(rows: list[dict]) -> None:
    import sys
    for r in rows:
        print(f"optimizer_{r['query']}_sf{r['sf']},{r['opt_s']*1e6:.1f},"
              f"speedup_vs_naive={r['speedup']:.2f};"
              f"join_rows={r['naive_join_rows']}->{r['opt_join_rows']};"
              f"q_error_root={r['q_error_root']:.2f}")
        for n in r["rewrites"]:
            print(f"#   {n}", file=sys.stderr)


if __name__ == "__main__":
    print_rows(optimizer_gain())
