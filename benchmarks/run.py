"""Benchmark driver: one function per paper table. Prints
``name,us_per_call,derived`` CSV rows plus a readable summary.

Usage: PYTHONPATH=src python -m benchmarks.run [--sf 1] [--fast]
                                               [--suite paper|update|all]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _update_suite(fast: bool) -> list[dict]:
    from . import update_bench
    rows = update_bench.run_suite(fast=fast)
    update_bench.print_rows(rows)
    return rows


def _gcdia_suite(sf: int) -> list[dict]:
    """Operator-level inter-buffer reuse: per-step hit rates + per-operator
    timings of the physical DAG (ISSUE 2 acceptance output)."""
    from . import m2bench_suite as m2
    rows = m2.gcdia_operator_reuse(sf=sf)
    for r in rows:
        print(f"gcdia_{r['step']}_sf{r['sf']},{r['seconds']*1e6:.1f},"
              f"hit_rate={r['hit_rate']:.2f};reused_nodes={r['nodes_reused']};"
              f"fetches={r['record_fetches']}")
        for o in r["operators"]:
            tag = ("interbuffer-hit" if o["cached"]
                   else "ran" if o["executed"] else "skipped")
            print(f"#   {o['op']:<20} {tag:<15} rows={o['rows']} "
                  f"ms={o['ms']}", file=sys.stderr)
    return rows


def _optimizer_suite(sf: int, fast: bool) -> list[dict]:
    """Cost-based optimizer: naive query-order DAG vs. rewritten DAG (DP
    join enumeration / semi-join siding / CSE / sink-down) on multi-join
    queries, plus cardinality quality on the Zipfian-skew fixture
    (histogram-overlap vs. NDV-only q-error; bushy DP vs. best left-deep).
    The rewrite overhead is ~1ms/query, so the latency win grows with --sf
    (the Makefile's bench-optimizer target uses --sf 2)."""
    from . import optimizer_bench
    repeat = 2 if fast else 5
    rows = optimizer_bench.optimizer_gain(sf=sf, repeat=repeat)
    rows += optimizer_bench.cardinality_quality(sf=sf, repeat=repeat)
    optimizer_bench.print_rows(rows)
    return rows


def _index_suite(sf: int, fast: bool) -> list[dict]:
    """Secondary-index access paths: indexed vs. full-scan latency on the
    selective fixtures, the selectivity-sweep crossover, and the write-path
    maintenance overhead. The access-path win grows with --sf (the
    Makefile's bench-index target uses --sf 80, where the point lookup's
    full scans dominate the fixed executor overhead)."""
    from . import index_bench
    rows = index_bench.run_suite(sf=sf, fast=fast)
    index_bench.print_rows(rows)
    return rows


def _trace_suite(sf: int, fast: bool) -> list[dict]:
    """Telemetry: traced GCDIA reuse ladder exported as Chrome trace-event
    JSON (schema-validated; experiments/trace_gcdia.json — open it in
    Perfetto), kernel roofline attribution from the fenced GCDA spans, and
    the disabled-telemetry overhead guard vs the pre-telemetry executor."""
    from . import trace_bench
    rows = trace_bench.run_suite(sf=sf, fast=fast)
    trace_bench.print_rows(rows)
    return rows


def _kernels_suite(sf: int, fast: bool) -> list[dict]:
    """Traversal kernel family: single-query latency ladder (host matcher vs
    per-hop jit vs fused pallas path) over start selectivity, batched
    point-lookup throughput (launch amortization across >=64 concurrent
    queries), and achieved-vs-roof bandwidth of the DeviceMatchPattern
    kernel spans from the engine's fenced trace export."""
    from . import traversal_bench
    rows = traversal_bench.run_suite(sf=sf, fast=fast)
    traversal_bench.print_rows(rows)
    return rows


def _shard_suite(sf: int, fast: bool) -> list[dict]:
    """Sharded morsel-parallel execution: single-stream vs 4-shard cold
    end-to-end latency on the scan/join-heavy GCDIA (bit-for-bit checked
    first), the born-sharded Rel2Matrix span assertion, and the small-input
    cost gate (4 shards requested, serial chosen, <=5% overhead)."""
    from . import shard_bench
    rows = shard_bench.run_suite(sf=sf, fast=fast)
    shard_bench.print_rows(rows)
    return rows


def _row_key(r: dict) -> tuple:
    """Stable identity of a bench row (table + whichever discriminator
    fields it carries) — the merged results file is sorted by this, so its
    order no longer depends on which suites ran in which sessions and
    baseline diffs stay reviewable."""
    return tuple(str(r.get(k, "")) for k in
                 ("table", "query", "task", "step", "kernel", "op", "mode",
                  "name", "sf", "n_batches", "selectivity"))


def _save(all_rows: list[dict]) -> None:
    """Merge into experiments/bench_results.json: rows of the tables just
    measured replace their previous records; other suites' rows persist.
    The merged file is written in deterministic (_row_key) order."""
    os.makedirs("experiments", exist_ok=True)
    path = "experiments/bench_results.json"
    fresh_tables = {r.get("table") for r in all_rows}
    kept: list[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                kept = [r for r in json.load(f)
                        if r.get("table") not in fresh_tables]
        except (ValueError, OSError):
            kept = []
    with open(path, "w") as f:
        json.dump(sorted(kept + all_rows, key=_row_key), f, indent=1,
                  default=str)
    print(f"# full records -> {path}", file=sys.stderr)


def _finish(all_rows: list[dict], args) -> None:
    """Common exit path for every suite: persist, then the machine-readable
    surfaces (--json rows to stdout, --save-baseline into the perf gate's
    committed baseline file)."""
    _save(all_rows)
    if args.json:
        print(json.dumps(sorted(all_rows, key=_row_key), default=str))
    if args.save_baseline:
        from . import regression
        path = regression.update_baseline([all_rows])
        print(f"# baselines -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=int, default=1)
    ap.add_argument("--fast", action="store_true",
                    help="skip the scale-factor sweep / use smoke sizes")
    ap.add_argument("--suite",
                    choices=("paper", "update", "gcdia", "optimizer",
                             "index", "trace", "kernels", "shard", "all"),
                    default="paper",
                    help="paper: GCDI/GCDA tables; update: write-path "
                         "throughput (delta store vs full rebuild); gcdia: "
                         "operator-level inter-buffer reuse (per-operator "
                         "timings + hit rates); optimizer: naive-order vs "
                         "cost-based rewritten DAG latency; index: "
                         "secondary-index access paths vs full scans; "
                         "trace: telemetry smoke — traced GCDIA with "
                         "Chrome-trace export + disabled-overhead guard; "
                         "kernels: traversal kernel family — latency "
                         "ladder, batched point lookups, kernel roofline; "
                         "shard: morsel-parallel execution — single-stream "
                         "vs 4-shard latency, born-sharded GCDA handoff, "
                         "small-input serial gate")
    ap.add_argument("--json", action="store_true",
                    help="also print the measured rows as one JSON array on "
                         "stdout (machine-readable; the CSV lines stay)")
    ap.add_argument("--save-baseline", action="store_true",
                    help="write/update experiments/bench_baselines.json "
                         "from this run's rows (the perf-regression gate's "
                         "committed reference; see benchmarks.regression)")
    args = ap.parse_args()

    from . import m2bench_suite as m2
    from .kernels_bench import kernel_microbench

    print("name,us_per_call,derived")
    all_rows: list[dict] = []

    if args.suite in ("optimizer", "all"):
        all_rows += _optimizer_suite(sf=args.sf, fast=args.fast)
        if args.suite == "optimizer":
            _finish(all_rows, args)
            return

    if args.suite in ("index", "all"):
        all_rows += _index_suite(sf=args.sf, fast=args.fast)
        if args.suite == "index":
            _finish(all_rows, args)
            return

    if args.suite in ("trace", "all"):
        all_rows += _trace_suite(sf=args.sf, fast=args.fast)
        if args.suite == "trace":
            _finish(all_rows, args)
            return

    if args.suite in ("kernels", "all"):
        all_rows += _kernels_suite(sf=args.sf, fast=args.fast)
        if args.suite == "kernels":
            _finish(all_rows, args)
            return

    if args.suite in ("shard", "all"):
        all_rows += _shard_suite(sf=args.sf, fast=args.fast)
        if args.suite == "shard":
            _finish(all_rows, args)
            return

    if args.suite in ("gcdia", "all"):
        all_rows += _gcdia_suite(sf=args.sf)
        if args.suite == "gcdia":
            _finish(all_rows, args)
            return

    if args.suite in ("update", "all"):
        all_rows += _update_suite(fast=args.fast)
        if args.suite == "update":
            _finish(all_rows, args)
            return

    # Figs. 7-8 + Fig. 10: GCDI ablation & graph workloads
    rows = m2.graph_workloads(sf=args.sf)
    all_rows += rows
    for r in rows:
        if "gredo_s" in r and "single_s" in r:
            print(f"gcdi_{r['query']}_sf{r['sf']},{r['gredo_s']*1e6:.1f},"
                  f"speedup_vs_single={r['speedup_vs_single']:.2f};"
                  f"speedup_vs_dual={r['speedup_vs_dual']:.2f};"
                  f"io_gredo={r['gredo_io']};io_single={r['single_io']}")
        elif "gredo_s" in r:
            print(f"gcdi_{r['query']}_sf{r['sf']},{r['gredo_s']*1e6:.1f},"
                  f"reachable={r.get('reachable')}")

    # Figs. 9/12: GCDA ablation
    rows = m2.gcda_ablation(sf=args.sf)
    all_rows += rows
    for r in rows:
        print(f"gcda_{r['task']}_sf{r['sf']},{r['batch_s']*1e6:.1f},"
              f"volcano_speedup={r['speedup']:.1f}")

    # §6.4 inter-buffer reuse
    rows = m2.interbuffer_reuse(sf=args.sf)
    all_rows += rows
    for r in rows:
        print(f"interbuffer_reuse_sf{r['sf']},{r['warm_s']*1e6:.1f},"
              f"reuse_speedup={r['reuse_speedup']:.0f}")

    # Table 5 flavor: scale factors
    if not args.fast:
        rows = m2.scale_factors()
        all_rows += rows
        for r in rows:
            print(f"scale_sf{r['sf']}_{r['mode']},{r['SUM_s']*1e6:.1f},"
                  f"geomean_us={r['GEOMEAN_s']*1e6:.1f}")

    # kernel microbench
    rows = kernel_microbench()
    all_rows += rows
    for r in rows:
        d = f"gflops={r.get('gflops', 0):.1f};" if "gflops" in r else ""
        print(f"kernel_{r['kernel'].split('(')[0]},{r['oracle_s']*1e6:.1f},"
              f"{d}block={r['tpu_block']}")

    _finish(all_rows, args)


if __name__ == "__main__":
    main()
