"""M2Bench-style benchmark suite (paper §7, scaled to this container).

One function per paper table/figure:
  * gcdi_ablation(sf)        — Figs. 7-8: G1-G5 + trim cases across GredoDB /
                                GredoDB-D / GredoDB-S (response time + the
                                record-fetch I/O proxy)
  * graph_workloads(sf)      — Fig. 10: pattern matching G1-G5 and
                                shortest-path G6-G8
  * gcda_ablation(sf)        — Figs. 9/12: A1-A3 batch-parallel vs volcano
                                tuple-at-a-time
  * interbuffer_reuse(sf)    — §6.4: repeated GCDIA with structural-match reuse
  * scale_factors()          — Table 5 flavor: SUM/GEOMEAN over SF 1/2/5

Times are wall-clock on this host; the paper's 104-thread Xeon numbers are
not comparable in absolute terms — the *ratios* between engine variants are
the reproduction target (see EXPERIMENTS.md).
"""
from __future__ import annotations

import statistics
import time

import jax.numpy as jnp
import numpy as np

from repro.core import GredoEngine, analytics
from repro.data import m2bench


def _timed(fn, repeat: int = 3):
    import jax
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)  # jax dispatch is async — time completion
        best = min(best, time.perf_counter() - t0)
    return best, out


def _queries():
    return [("G1", m2bench.q_g1()), ("G2", m2bench.q_g2()),
            ("G3", m2bench.q_g3()), ("G4", m2bench.q_g4()),
            ("G5", m2bench.q_g5()), ("edge_scan", m2bench.q_edge_scan()),
            ("vertex_scan", m2bench.q_vertex_scan())]


def gcdi_ablation(sf: int = 1, repeat: int = 3) -> list[dict]:
    db = m2bench.generate(sf=sf)
    rows = []
    engines = {m: GredoEngine(db, mode=m) for m in ("gredo", "dual", "single")}
    for qname, q in _queries():
        rec = {"table": "gcdi_ablation", "sf": sf, "query": qname}
        nrows = set()
        for mode, eng in engines.items():
            secs, result = _timed(lambda e=eng, qq=q: e.query(qq), repeat)
            rec[f"{mode}_s"] = secs
            rec[f"{mode}_io"] = eng.last_stats.record_fetches
            nrows.add(result.nrows)
        assert len(nrows) == 1, f"mode results disagree on {qname}: {nrows}"
        rec["rows"] = nrows.pop()
        rec["speedup_vs_single"] = rec["single_s"] / max(rec["gredo_s"], 1e-9)
        rec["speedup_vs_dual"] = rec["dual_s"] / max(rec["gredo_s"], 1e-9)
        rows.append(rec)
    return rows


def graph_workloads(sf: int = 1, repeat: int = 3) -> list[dict]:
    db = m2bench.generate(sf=sf)
    eng = GredoEngine(db)
    rows = list(gcdi_ablation(sf, repeat))
    # shortest-path G6-G8 analogues (not supported by -D/-S, as in the paper)
    rng = np.random.default_rng(0)
    n_persons = db.graphs["Follows"].vertex_tables["Persons"].nrows
    for qname, n_pairs in [("G6_sp", 8), ("G7_sp", 16), ("G8_sp", 32)]:
        src = rng.integers(0, n_persons, n_pairs)
        dst = rng.integers(0, n_persons, n_pairs)
        secs, d = _timed(lambda: eng.shortest_path(
            "Follows", "Persons", src, "Persons", dst), repeat)
        rows.append({"table": "graph_workloads", "sf": sf, "query": qname,
                     "gredo_s": secs, "reachable": int((d >= 0).sum()),
                     "pairs": n_pairs})
    return rows


def gcda_ablation(sf: int = 1, volcano_cap: int = 400,
                  iters: int = 20) -> list[dict]:
    """A1 regression / A2 similarity / A3 multiply: parallel batch operators
    vs literal tuple-at-a-time volcano execution. The volcano variant runs on
    a row-capped subset (it is O(rows x dims) *per python op*); we report
    measured per-row-iteration time for both so the ratio is size-honest."""
    db = m2bench.generate(sf=sf)
    eng = GredoEngine(db)
    r = eng.query(m2bench.q_g1())
    X, groups = analytics.random_access_matrix(
        r, "Customer.id", "t.tid", m2bench.N_TAGS)
    y = jnp.asarray(m2bench.purchase_labels(db)[groups])
    Xn, yn = np.asarray(X), np.asarray(y)
    cap = min(volcano_cap, X.shape[0])
    rows = []

    # A1 regression
    t_batch, (w, loss) = _timed(
        lambda: analytics.regression(X, y, iters=iters), repeat=1)
    t_volc, _ = _timed(
        lambda: analytics.volcano.regression(Xn[:cap], yn[:cap], iters=2),
        repeat=1)
    batch_unit = t_batch / (X.shape[0] * iters)
    volc_unit = t_volc / (cap * 2)
    rows.append({"table": "gcda_ablation", "sf": sf, "task": "A1_regression",
                 "batch_s": t_batch, "volcano_s_capped": t_volc,
                 "batch_s_per_row_iter": batch_unit,
                 "volcano_s_per_row_iter": volc_unit,
                 "speedup": volc_unit / batch_unit,
                 "rows": int(X.shape[0]), "volcano_rows": cap})

    # A2 similarity
    t_batch, S = _timed(lambda: analytics.similarity(X, X), repeat=1)
    t_volc, _ = _timed(
        lambda: analytics.volcano.similarity(Xn[:cap // 4], Xn[:cap // 4]),
        repeat=1)
    bu = t_batch / (X.shape[0] ** 2)
    vu = t_volc / ((cap // 4) ** 2)
    rows.append({"table": "gcda_ablation", "sf": sf, "task": "A2_similarity",
                 "batch_s": t_batch, "volcano_s_capped": t_volc,
                 "batch_s_per_pair": bu, "volcano_s_per_pair": vu,
                 "speedup": vu / bu, "rows": int(X.shape[0]),
                 "volcano_rows": cap // 4})

    # A3 multiply (gram)
    t_batch, Z = _timed(lambda: analytics.multiply(X, X.T), repeat=1)
    t_volc, _ = _timed(
        lambda: analytics.volcano.multiply(Xn[:cap // 4], Xn[:cap // 4].T),
        repeat=1)
    bu = t_batch / (X.shape[0] ** 2 * X.shape[1])
    vu = t_volc / ((cap // 4) ** 2 * X.shape[1])
    rows.append({"table": "gcda_ablation", "sf": sf, "task": "A3_multiply",
                 "batch_s": t_batch, "volcano_s_capped": t_volc,
                 "batch_s_per_mac": bu, "volcano_s_per_mac": vu,
                 "speedup": vu / bu, "rows": int(X.shape[0]),
                 "volcano_rows": cap // 4})
    return rows


def gcdia_operator_reuse(sf: int = 1) -> list[dict]:
    """§6.4 structural matching at *operator* granularity: one engine runs a
    sequence of GCDIA tasks over the same integration and we record, per
    step, the per-operator timings plus which DAG nodes were satisfied from
    the inter-buffer. The reuse ladder:
      1. cold A3 MULTIPLY                 — everything executes
      2. A2 SIMILARITY (same matrix gen)  — hit at RandomAccessMatrix
      3. MULTIPLY over rel2matrix         — hit at the GCDI Project root
      4. A2 after a source write          — epoch bump, everything re-runs
    """
    from repro.core.schema import AnalyticsTask, GCDIATask

    db = m2bench.generate(sf=sf)
    eng = GredoEngine(db)
    rows: list[dict] = []

    def run(step: str, task) -> None:
        hits0, miss0 = eng.interbuffer.hits, eng.interbuffer.misses
        secs, _ = _timed(lambda: eng.analyze(task), repeat=1)
        s = eng.last_stats
        hits = eng.interbuffer.hits - hits0
        misses = eng.interbuffer.misses - miss0
        rows.append({
            "table": "gcdia_operator_reuse", "sf": sf, "step": step,
            "seconds": secs, "record_fetches": s.record_fetches,
            "nodes_reused": s.nodes_reused, "root_hit": s.interbuffer_hit,
            "interbuffer_hits": hits, "interbuffer_misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
            "operators": [{"op": o["op"], "rows": o["rows"],
                           "ms": round(o["seconds"] * 1e3, 3),
                           "cached": o["cached"], "executed": o["executed"]}
                          for o in s.operators],
        })

    run("cold_A3_multiply", m2bench.a3_multiply())
    run("warm_A2_similarity_shared_matrix", m2bench.a2_similarity())
    run("warm_multiply_rel2matrix_shared_gcdi", GCDIATask(
        integration=m2bench.q_g1(),
        analytics=AnalyticsTask("MULTIPLY",
                                [("rel2matrix", ("Customer.id", "t.tid"))])))
    db.graphs["Interested_in"].insert_edges(
        {"svid": np.array([0]), "tvid": np.array([0]),
         "weight": np.array([0.5])})
    run("post_write_A2_similarity", m2bench.a2_similarity())
    return rows


def interbuffer_reuse(sf: int = 1) -> list[dict]:
    db = m2bench.generate(sf=sf)
    eng = GredoEngine(db)
    t_cold, _ = _timed(lambda: eng.analyze(m2bench.a2_similarity()), repeat=1)
    t_warm, _ = _timed(lambda: eng.analyze(m2bench.a2_similarity()), repeat=1)
    return [{"table": "interbuffer_reuse", "sf": sf, "cold_s": t_cold,
             "warm_s": t_warm, "reuse_speedup": t_cold / max(t_warm, 1e-9),
             "hits": eng.interbuffer.hits}]


def scale_factors(sfs=(1, 2, 5)) -> list[dict]:
    rows = []
    for sf in sfs:
        per_q = gcdi_ablation(sf, repeat=1)
        for mode in ("gredo", "dual", "single"):
            times = [r[f"{mode}_s"] for r in per_q]
            rows.append({"table": "scale_factors", "sf": sf, "mode": mode,
                         "SUM_s": sum(times),
                         "GEOMEAN_s": statistics.geometric_mean(times)})
    return rows
