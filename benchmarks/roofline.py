"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds-per-step per device:
    compute    = HLO_flops_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
(cost_analysis flops/bytes are per-partition in SPMD HLO; the collective
parser sums per-shard result bytes with while-loop multiplicity, 2x for
all-reduce ring cost.)

MODEL_FLOPS (useful work, global):
    LM train    6 * N_active * tokens        LM prefill  2 * N_active * tokens
    LM decode   2 * N_active * batch + 2 * kv_bytes/2 (attention reads)
    GNN train   6 * N_params * n_nodes  (convention; edge-dominated archs
                under-count — the ratio column carries the caveat)
    recsys      (6 if train else 2) * N_touched * batch

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
        [--write experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


# ---------------------------------------------------------------------------
# Analytic per-device flops: the CPU backend lowers decode matvecs without
# `dot` ops and its cost_analysis counts while bodies once, so the honest
# TPU compute term is derived from the model configs. Components that are
# REPLICATED over the 'model' axis (attention when heads % tp != 0) divide
# by dp only; sharded components divide by all devices.
# ---------------------------------------------------------------------------


def _lm_analytic_flops_dev(arch: str, shape: str, mesh: str) -> float:
    from repro import configs
    cfg = configs.get(arch).config()
    spec = configs.get(arch).SHAPES[shape]
    n_dev = 512 if mesh.startswith("2x") else 256
    tp = 16
    dp_total = n_dev // tp
    B, S = spec["batch"], spec["seq"]
    kind = spec["kind"]
    d, h, kv, dh, f, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, cfg.d_ff, cfg.vocab, cfg.n_layers)
    n_mats = 3 if cfg.mlp == "swiglu" else 2

    tokens = B * (1 if kind == "decode" else S)
    s_kv = S if kind == "decode" else S / 2          # causal average
    # per-token per-layer flop components (x2 for MAC)
    qkvo = 2 * (2 * d * h * dh + 2 * d * kv * dh)
    attn = 4 * h * dh * s_kv
    if cfg.is_moe:
        mlp = 2 * n_mats * d * f * cfg.top_k * cfg.capacity_factor
    else:
        mlp = 2 * n_mats * d * f
    head_f = 2 * d * V

    mult = 4.0 if kind == "train" else 1.0           # fwd+bwd+remat-fwd
    heads_sharded = (h % tp == 0)
    experts_sharded = (not cfg.is_moe) or cfg.n_experts % tp == 0

    f_sharded = tokens * L * mlp * mult + tokens * head_f * mult
    f_attn = tokens * L * (qkvo + attn) * mult
    dev = f_sharded / (n_dev if experts_sharded else dp_total)
    dev += f_attn / (n_dev if heads_sharded else dp_total)
    return dev


def _gnn_analytic_flops_dev(arch: str, shape: str, mesh: str) -> float:
    from repro import configs
    mod = configs.get(arch)
    spec = mod.SHAPES[shape]
    n_dev = 512 if mesh.startswith("2x") else 256
    if spec["kind"] == "molecule":
        N = spec["batch"] * spec["n_nodes"]
        E = spec["batch"] * spec["n_edges"]
    else:
        N, E = spec["n_nodes"], spec["n_edges"]
    mult = 3.0  # fwd + bwd
    if arch == "gatedgcn":
        cfg = mod.config()
        d, L = 70, cfg.n_layers
        per = L * (5 * N * d * d * 2 + 8 * E * d)
    elif arch == "pna":
        cfg = mod.config()
        d, L = 75, cfg.n_layers
        per = L * (E * (2 * d * d + d * d) * 2 + N * 13 * d * d * 2)
    elif arch == "mace":
        cfg = mod.config()
        C, L = cfg.channels, cfg.n_layers
        paths = 15
        cg_edge = E * paths * 27 * C * 2             # A-basis CG x radial
        cg_node = 2 * N * paths * 27 * C * 2         # B2 + B3 products
        radial = E * (8 * 64 + 64 * paths * C) * 2
        mix = N * 3 * C * C * 2 * 9
        per = L * (cg_edge + cg_node + radial + mix)
    else:  # equiformer_v2
        cfg = mod.config()
        C, L, dim = cfg.channels, cfg.n_layers, (cfg.l_max + 1) ** 2
        wigner = 2 * E * dim * dim * C * 2           # rotate + unrotate
        so2 = E * sum((cfg.l_max + 1 - m) ** 2 * C * C * (2 if m else 1) * 2
                      for m in range(cfg.m_max + 1)) * 2
        ffn = N * (cfg.l_max + 1) * 9 * C * C * 2
        per = L * (wigner + so2 + E * 3 * C * C * 2 + ffn)
    return per * mult / n_dev


def _recsys_analytic_flops_dev(shape: str, mesh: str) -> float:
    from repro import configs
    spec = configs.get("wide_deep").SHAPES[shape]
    n_dev = 512 if mesh.startswith("2x") else 256
    B = spec["batch"]
    d_in = 40 * 32 + 13
    mlp = (d_in * 1024 + 1024 * 512 + 512 * 256 + 256) * 2
    mult = 3.0 if spec["kind"] == "train" else 1.0
    flops = B * mlp * mult
    if spec["kind"] == "retrieval":
        flops += spec["n_candidates"] * 256 * 2 + B * 256 * 256 * 2
    return flops / n_dev


def analytic_flops_dev(rec: dict) -> float:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    kind = rec.get("kind", "")
    try:
        if kind in ("train", "prefill", "decode"):
            return _lm_analytic_flops_dev(arch, shape, mesh)
        if kind == "gnn_train":
            return _gnn_analytic_flops_dev(arch, shape, mesh)
        if kind.startswith("recsys"):
            return _recsys_analytic_flops_dev(shape, mesh)
    except Exception:
        return 0.0
    return 0.0


def model_flops(rec: dict) -> float:
    meta = rec.get("meta", {})
    kind = rec.get("kind", "")
    if kind in ("train", "prefill", "decode"):
        n = meta["n_active"]
        toks = meta["tokens"]
        if kind == "train":
            return 6.0 * n * toks
        if kind == "prefill":
            return 2.0 * n * toks
        return 2.0 * n * toks  # decode: tokens == batch
    if kind == "gnn_train":
        return 6.0 * meta["n_params"] * meta["n_nodes"]
    if kind.startswith("recsys"):
        # embedding rows touched + dense mlp per example
        dense = meta["n_params"] - 40 * 1_000_000 * 32 - 1_000_000
        touched = 40 * 32 + max(dense, 0)
        mult = 6.0 if kind == "recsys_train" else 2.0
        return mult * touched * meta.get("batch", 1)
    return 0.0


def analyze(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    # analytic compute term (CPU HLO hides matvec dots / loop trip counts);
    # dot_flops_per_device (trip-corrected HLO dots) kept as cross-check
    flops_dev = analytic_flops_dev(rec) or rec.get(
        "dot_flops_per_device", rec["flops_per_device"])
    bytes_dev = rec.get("hbm_bytes_per_device", rec["bytes_per_device"])
    coll_dev = rec["collectives"]["total_bytes"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = model_flops(rec)
    hlo_global = flops_dev * n_dev
    bound = max(t_c, t_m, t_x)
    useful_t = (mf / n_dev) / PEAK_FLOPS if mf else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("perf_variant", ""),
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0], "bound_s": bound,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "dot_flops_dev": rec.get("dot_flops_per_device", 0.0),
        "useful_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "roofline_frac": (useful_t / bound) if bound else 0.0,
    }


def from_trace(events: list[dict]) -> list[dict]:
    """Roofline attribution of GCDA kernel spans from a Chrome trace export
    (``telemetry.TraceCollector.to_chrome()["traceEvents"]``). Telemetry
    fences kernel outputs with ``block_until_ready``, so a span's duration
    is honest host+device time and splits into ``dispatch_s`` (host until
    the call returned) and ``sync_s`` (device wait); achieved FLOP/s over
    that wall time is compared against the arithmetic-intensity-capped roof
    ``min(PEAK_FLOPS, ai * HBM_BW)``."""
    rows = []
    for ev in events:
        args = ev.get("args", {})
        if ev.get("ph") != "X" or ev.get("cat") != "gcda":
            continue
        if "flops" not in args or not ev.get("dur"):
            continue
        seconds = ev["dur"] / 1e6
        flops = float(args["flops"])
        nbytes = float(args.get("bytes", 0.0))
        ai = flops / nbytes if nbytes else 0.0
        roof = min(PEAK_FLOPS, ai * HBM_BW) if ai else PEAK_FLOPS
        achieved = flops / seconds
        rows.append({
            "table": "kernel_roofline", "op": ev["name"],
            "seconds": seconds,
            "dispatch_s": args.get("dispatch_s", 0.0),
            "sync_s": args.get("sync_s", 0.0),
            "flops": flops, "bytes": nbytes,
            "arithmetic_intensity": ai,
            "achieved_gflops": achieved / 1e9,
            "roof_gflops": roof / 1e9,
            "roofline_frac": achieved / roof if roof else 0.0,
        })
    return rows


def what_would_help(row: dict) -> str:
    if row["dominant"] == "collective":
        return "cut collective bytes: bf16 collectives, reduce-scatter " \
               "instead of all-reduce, or reshard to remove the gather"
    if row["dominant"] == "memory":
        return "cut HBM traffic: fuse/smaller dtypes, shard the dominant " \
               "resident tensor (KV cache / node features) over more axes"
    return "raise MXU utilization: larger effective matmul tiles, less " \
           "remat recompute, drop replicated compute"


def load(dry_dir: str, include_variants: bool = False) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        rec = json.load(open(f))
        if not rec.get("ok"):
            continue
        if rec.get("perf_variant") and not include_variants:
            continue
        rows.append(analyze(rec))
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']}"
            f"{('/' + r['variant']) if r['variant'] else ''} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | **{r['dominant']}** "
            f"| {r['model_flops']:.3e} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_frac']:.3f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--write", default="")
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, include_variants=args.variants)
    md = to_markdown(rows)
    print(md)
    print()
    for r in rows:
        if r["roofline_frac"] < 0.05 or r["dominant"] == "collective":
            print(f"* {r['arch']}/{r['shape']}/{r['mesh']}: "
                  f"{r['dominant']}-bound, frac={r['roofline_frac']:.3f} -> "
                  + what_would_help(r))
    if args.write:
        with open(args.write, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
