"""Update-throughput benchmark: LSM delta writes vs. seed-style full rebuild.

Two write paths over the same mutation stream:
  * ``delta``   — the deltastore write path (O(batch) per write; compaction
                  only when the policy triggers);
  * ``rebuild`` — the seed behaviour: a full O(V+E) topology rebuild after
                  every batch (simulated by forcing ``compact()`` per write).

Also asserts the acceptance criterion directly: across the delta-path batch
inserts the write-cost counters charge no compaction work and the per-batch
write cost is batch-proportional (never O(V+E)).

Usage: PYTHONPATH=src python -m benchmarks.update_bench [--fast]
       (or via ``python -m benchmarks.run --suite update``)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import deltastore
from repro.core.storage import Graph, Table


def _mk_graph(n_vertices: int, n_edges: int, seed: int = 0,
              cfg: deltastore.DeltaConfig | None = None) -> Graph:
    rng = np.random.default_rng(seed)
    verts = Table("V", {"vid": np.arange(n_vertices, dtype=np.int64),
                        "attr": rng.integers(0, 100, n_vertices)})
    edges = Table("E", {"svid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
                        "tvid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
                        "w": rng.uniform(0, 1, n_edges)})
    return Graph("U", {"V": verts}, edges, "V", "V", delta_config=cfg)


def _batches(n_vertices: int, batch: int, n_batches: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        out.append({"svid": rng.integers(0, n_vertices, batch).astype(np.int64),
                    "tvid": rng.integers(0, n_vertices, batch).astype(np.int64),
                    "w": rng.uniform(0, 1, batch)})
    return out


def _query_mix(g: Graph, rng: np.ndarray) -> int:
    """A small read between writes: whole-frontier expansion over a vid
    sample (the mixed-workload part of the benchmark)."""
    _, dst, _ = g.expand(rng)
    return len(dst)


def update_throughput(n_vertices: int = 20_000, n_edges: int = 100_000,
                      batch: int = 1_000, n_batches: int = 20,
                      deletes_per_batch: int = 100) -> list[dict]:
    """Returns CSV-able rows; raises if the delta write path did rebuild-scale
    work (the acceptance assertion)."""
    rows: list[dict] = []
    mutations = _batches(n_vertices, batch, n_batches)
    probe = np.random.default_rng(9).integers(0, n_vertices, 256)

    # --- delta path -------------------------------------------------------
    g = _mk_graph(n_vertices, n_edges)     # fresh graph: counters start at 0
    base_fwd = g.fwd
    t0 = time.perf_counter()
    for i, m in enumerate(mutations):
        g.insert_edges(m)
        if deletes_per_batch:
            g.delete_edges(np.arange(i * deletes_per_batch,
                                     (i + 1) * deletes_per_batch))
    t_delta_writes = time.perf_counter() - t0
    c = g.write_counters
    total_rows = n_batches * (batch + deletes_per_batch)
    # acceptance: no O(V+E) work on the hot path ---------------------------
    assert c.compact_ops == 0 and c.compactions == 0, \
        f"delta write path compacted unexpectedly: {c.compactions}"
    assert g.fwd is base_fwd, "delta write path rebuilt the base CSR"
    per_batch_ops = c.write_ops / (2 * n_batches)
    assert per_batch_ops < 32 * batch, \
        f"write cost {per_batch_ops:.0f} ops/batch is not batch-proportional"
    t0 = time.perf_counter()
    for _ in range(5):
        _query_mix(g, probe)
    t_delta_read = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    g.compact()
    t_compact = time.perf_counter() - t0

    # --- seed-style rebuild-per-write path --------------------------------
    g2 = _mk_graph(n_vertices, n_edges)
    t0 = time.perf_counter()
    for i, m in enumerate(mutations):
        g2.insert_edges(m)
        g2.compact()                      # what the seed's _rebuild_topology did
        if deletes_per_batch:
            # compaction renumbers tids: the delta path's rows
            # [i*k, (i+1)*k) are the first k live rows here
            g2.delete_edges(np.arange(deletes_per_batch))
            g2.compact()
    t_rebuild_writes = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(5):
        _query_mix(g2, probe)
    t_rebuild_read = (time.perf_counter() - t0) / 5

    # --- correctness spot check: both paths converge to the same graph ----
    assert g.n_live_edges == g2.n_live_edges
    d1 = np.sort(g.fwd.degrees())
    d2 = np.sort(g2.fwd.degrees())
    assert np.array_equal(d1, d2), "delta and rebuild paths diverged"

    rows.append({
        "table": "update_throughput", "n_vertices": n_vertices,
        "n_edges": n_edges, "batch": batch, "n_batches": n_batches,
        "delta_writes_s": t_delta_writes, "rebuild_writes_s": t_rebuild_writes,
        "write_speedup": t_rebuild_writes / max(t_delta_writes, 1e-9),
        "delta_rows_per_s": total_rows / max(t_delta_writes, 1e-9),
        "rebuild_rows_per_s": total_rows / max(t_rebuild_writes, 1e-9),
        "delta_read_s": t_delta_read, "rebuild_read_s": t_rebuild_read,
        "compact_s": t_compact, "write_ops_per_batch": per_batch_ops,
    })
    return rows


def compaction_amortization(n_vertices: int = 20_000, n_edges: int = 100_000,
                            batch: int = 1_000, n_batches: int = 60) -> list[dict]:
    """Delta path with the default auto-compaction policy: total cost stays
    amortized even when the policy fires mid-stream."""
    g = _mk_graph(n_vertices, n_edges)     # fresh graph: counters start at 0
    t0 = time.perf_counter()
    for m in _batches(n_vertices, batch, n_batches, seed=2):
        g.insert_edges(m)
    elapsed = time.perf_counter() - t0
    c = g.write_counters
    return [{
        "table": "compaction_amortization", "n_batches": n_batches,
        "batch": batch, "total_s": elapsed,
        "compactions": c.compactions,
        "compact_ops": c.compact_ops, "write_ops": c.write_ops,
        "rows_per_s": n_batches * batch / max(elapsed, 1e-9),
    }]


def run_suite(fast: bool = False) -> list[dict]:
    if fast:
        rows = update_throughput(n_vertices=4_000, n_edges=20_000,
                                 batch=500, n_batches=6)
        rows += compaction_amortization(n_vertices=4_000, n_edges=20_000,
                                        batch=500, n_batches=15)
        return rows
    rows = update_throughput()
    rows += compaction_amortization()
    return rows


def print_rows(rows: list[dict]) -> None:
    """CSV rows for the update suite (shared with benchmarks.run)."""
    for r in rows:
        if r["table"] == "update_throughput":
            print(f"update_delta_writes,{r['delta_writes_s']*1e6:.1f},"
                  f"write_speedup={r['write_speedup']:.1f};"
                  f"delta_rows_per_s={r['delta_rows_per_s']:.0f};"
                  f"rebuild_rows_per_s={r['rebuild_rows_per_s']:.0f};"
                  f"ops_per_batch={r['write_ops_per_batch']:.0f}")
        else:
            print(f"update_amortized,{r['total_s']*1e6:.1f},"
                  f"compactions={r['compactions']};"
                  f"rows_per_s={r['rows_per_s']:.0f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="small sizes (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    print_rows(run_suite(fast=args.fast))


if __name__ == "__main__":
    main()
