"""Perf-regression gate: noise-aware committed baselines for the paper
suites.

The bench trajectory problem: ``experiments/bench_results.json`` is
overwritten per run and nothing gates on it, so a PR can silently erode the
speedups the repo exists to demonstrate. This module maintains
``experiments/bench_baselines.json`` — per-metric medians with tolerance
bands — and fails ``make bench-regression`` when a fresh measurement falls
outside its band.

Noise handling, by metric *kind*:

- ``seconds`` — absolute wall time. Machine-dependent (a CI runner is not
  the box that wrote the baseline), so the tolerance floor is generous
  (100%: only a >2x slowdown trips on seconds alone).
- ``ratio`` — machine-independent speedups (gredo vs single/dual, batch vs
  volcano, warm vs cold). These are the paper's claims and the gate's
  teeth: a regression that slows gredo *relative to its ablations* trips
  here even when absolute seconds stay inside their loose band. Ratios
  must not *drop* below ``median * (1 - tol)``.
- ``count`` — deterministic operation counts (record fetches). Near-exact
  (2% floor): an I/O regression is a plan change, not noise.

Per-metric tolerance = ``max(kind floor, 3 * observed relative spread)``
over the baseline's median-of-k samples, so metrics that are noisy *on the
baseline machine* get proportionally wider bands.

Usage::

    python -m benchmarks.regression --fast              # gate (exit 1 on regression)
    python -m benchmarks.regression --update-baseline   # re-baseline (accepted perf change)
    python -m benchmarks.regression --fast --inject-slowdown 0.05
                                                        # self-test: gate must trip
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

BASELINE_PATH = "experiments/bench_baselines.json"

# metric kind -> tolerance floor (relative)
TOL_FLOORS = {"seconds": 1.00, "ratio": 0.40, "count": 0.02}
TOL_CAP = 4.0

# table -> (identity fields, {metric field: kind}). Only the suites the
# fast gate runs; identity fields order the metric names deterministically.
SUITE_SPECS = {
    "gcdi_ablation": (("query",), {
        "gredo_s": "seconds",
        "speedup_vs_single": "ratio",
        "speedup_vs_dual": "ratio",
        "gredo_io": "count",
        "single_io": "count",
    }),
    "graph_workloads": (("query",), {
        "gredo_s": "seconds",
    }),
    "gcda_ablation": (("task",), {
        "batch_s": "seconds",
        "speedup": "ratio",
    }),
    "interbuffer_reuse": ((), {
        "cold_s": "seconds",
        "warm_s": "seconds",
        "reuse_speedup": "ratio",
    }),
}


def metrics_from_rows(rows: list[dict]) -> dict:
    """Flatten suite rows into ``{metric_name: (value, kind)}``; rows of
    tables without a spec are ignored."""
    out: dict[str, tuple[float, str]] = {}
    for r in rows:
        spec = SUITE_SPECS.get(r.get("table"))
        if spec is None:
            continue
        id_fields, fields = spec
        ident = ".".join([str(r["table"])]
                         + [str(r[k]) for k in id_fields if k in r])
        for field, kind in fields.items():
            v = r.get(field)
            if isinstance(v, (int, float)):
                out[f"{ident}.{field}"] = (float(v), kind)
    return out


def _suite_rows(sf: int) -> list[dict]:
    """One measurement pass over the gated suites (the paper's headline
    tables: GCDI ablation, GCDA ablation, inter-buffer reuse)."""
    from . import m2bench_suite as m2
    rows = list(m2.graph_workloads(sf=sf))
    rows += m2.gcda_ablation(sf=sf)
    rows += m2.interbuffer_reuse(sf=sf)
    return rows


class _Slowdown:
    """Test hook: monkeypatch ``GredoEngine.query`` with a sleep that fires
    only in gredo mode, so both the absolute-seconds and the
    speedup-vs-ablation ratio metrics regress — exactly what a real
    gredo-path regression looks like."""

    def __init__(self, seconds: float):
        from repro.core.engine import GredoEngine
        self.cls = GredoEngine
        self.orig = GredoEngine.query
        self.seconds = seconds

        def slow_query(eng, q, _orig=self.orig, _s=seconds):
            if eng.mode == "gredo":
                time.sleep(_s)
            return _orig(eng, q)

        GredoEngine.query = slow_query

    def undo(self) -> None:
        self.cls.query = self.orig


def measure(sf: int = 1, repeat: int = 3,
            slowdown: float = 0.0) -> list[dict]:
    """``repeat`` independent passes over the gated suites, each flattened
    to a metrics dict. The gate compares per-metric *medians* of these
    samples, the baseline records their spread. A discarded warmup pass
    runs first: the initial pass in a fresh process pays one-time jit
    compilation (10x+ on the GCDA batch operators), which is compile cost,
    not the execution perf this gate protects."""
    patch = _Slowdown(slowdown) if slowdown > 0 else None
    try:
        _suite_rows(sf)
        return [metrics_from_rows(_suite_rows(sf)) for _ in range(repeat)]
    finally:
        if patch is not None:
            patch.undo()


def build_baseline(samples: list[dict], sf: int = 1) -> dict:
    """Median-of-k baseline with per-metric tolerance bands."""
    names: dict[str, str] = {}
    for s in samples:
        for k, (_, kind) in s.items():
            names[k] = kind
    metrics = {}
    for name in sorted(names):
        vals = [s[name][0] for s in samples if name in s]
        kind = names[name]
        med = statistics.median(vals)
        spread = ((max(vals) - min(vals)) / max(abs(med), 1e-12)
                  if len(vals) > 1 else 0.0)
        tol = min(max(TOL_FLOORS[kind], 3.0 * spread), TOL_CAP)
        metrics[name] = {"value": round(med, 9), "kind": kind,
                         "tol": round(tol, 4),
                         "samples": [round(v, 9) for v in vals]}
    return {"version": 1, "sf": sf, "k": len(samples), "metrics": metrics}


def update_baseline(row_samples: list[list[dict]], sf: int = 1,
                    path: str = BASELINE_PATH) -> str:
    """Build a baseline from raw suite-row samples and merge it into
    ``path`` (existing metrics not re-measured are preserved). This is the
    entry point ``benchmarks.run --save-baseline`` uses."""
    samples = [metrics_from_rows(rows) for rows in row_samples]
    return update_baseline_from_samples(samples, sf, path)


def compare(fresh: dict, baseline: dict) -> tuple[list[str], list[str]]:
    """Fresh ``{name: (value, kind)}`` medians vs the committed baseline.
    Returns ``(regressions, notes)`` — non-empty regressions fail the gate.
    Higher-is-better ratios regress downward; seconds/counts upward. A
    baselined metric that vanished is a regression too (silent coverage
    loss); new unbaselined metrics are a note (run --update-baseline)."""
    regressions: list[str] = []
    notes: list[str] = []
    base_metrics = baseline.get("metrics", {})
    for name, spec in sorted(base_metrics.items()):
        base, tol, kind = spec["value"], spec["tol"], spec["kind"]
        if name not in fresh:
            regressions.append(f"{name}: baselined but not measured "
                               f"(metric vanished — re-baseline if intended)")
            continue
        v = fresh[name][0]
        if kind == "ratio":
            bound = base * (1.0 - tol)
            if v < bound:
                regressions.append(
                    f"{name}: {v:.4g} < {bound:.4g} "
                    f"(baseline {base:.4g}, tol {tol:.0%}) [ratio dropped]")
        else:
            bound = base * (1.0 + tol)
            if v > bound:
                regressions.append(
                    f"{name}: {v:.4g} > {bound:.4g} "
                    f"(baseline {base:.4g}, tol {tol:.0%}) [{kind} grew]")
    for name in sorted(fresh):
        if name not in base_metrics:
            notes.append(f"{name}: not baselined yet "
                         f"(value {fresh[name][0]:.4g})")
    return regressions, notes


def _median_sample(samples: list[dict]) -> dict:
    out: dict[str, tuple[float, str]] = {}
    names: dict[str, str] = {}
    for s in samples:
        for k, (_, kind) in s.items():
            names[k] = kind
    for name, kind in names.items():
        vals = [s[name][0] for s in samples if name in s]
        out[name] = (statistics.median(vals), kind)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sf", type=int, default=1)
    ap.add_argument("--fast", action="store_true",
                    help="single measurement pass (repeat=1); the committed "
                         "baseline's tolerance bands absorb the extra noise")
    ap.add_argument("--repeat", type=int, default=0,
                    help="measurement passes (default: 3, or 1 with --fast)")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-measure and rewrite the baseline instead of "
                         "gating (use only for accepted perf changes)")
    ap.add_argument("--inject-slowdown", type=float, default=0.0,
                    metavar="SECONDS",
                    help="self-test: sleep this long inside every "
                         "gredo-mode query; the gate is expected to trip")
    args = ap.parse_args()
    repeat = args.repeat or (1 if args.fast else 3)

    t0 = time.perf_counter()
    samples = measure(sf=args.sf, repeat=repeat,
                      slowdown=args.inject_slowdown)
    dt = time.perf_counter() - t0
    print(f"# measured {len(samples[0])} metrics x {repeat} passes "
          f"in {dt:.1f}s", file=sys.stderr)

    if args.update_baseline:
        path = update_baseline_from_samples(samples, args.sf, args.baseline)
        print(f"baseline updated -> {path}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"error: no baseline at {args.baseline} — seed it with "
              f"`python -m benchmarks.regression --update-baseline`",
              file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    fresh = _median_sample(samples)
    regressions, notes = compare(fresh, baseline)
    for n in notes:
        print(f"NOTE  {n}")
    checked = sum(1 for name in baseline.get("metrics", {}) if name in fresh)
    if regressions:
        for r in regressions:
            print(f"REGRESSION  {r}")
        print(f"FAIL: {len(regressions)} regression(s) across "
              f"{checked} gated metrics")
        return 1
    print(f"OK: {checked} gated metrics within tolerance "
          f"({len(notes)} unbaselined)")
    return 0


def update_baseline_from_samples(samples: list[dict], sf: int,
                                 path: str) -> str:
    """Write/merge a baseline doc from flattened metric samples: freshly
    measured metrics replace their old entries, metrics this run didn't
    cover (other suites) are preserved."""
    doc = build_baseline(samples, sf=sf)
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            merged = dict(old.get("metrics", {}))
            merged.update(doc["metrics"])
            doc["metrics"] = dict(sorted(merged.items()))
        except (ValueError, OSError):
            pass
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    raise SystemExit(main())
