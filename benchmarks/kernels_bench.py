"""Kernel microbenchmarks (paper §5.4 operators + LM/recsys hot paths).

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled TPU code), so the *wall-clock* rows here
benchmark the jnp oracles — the compute the kernels replace — plus the
tuple-at-a-time volcano floor; interpret-mode kernels are validated for
correctness in tests/ and their TPU block shapes recorded here."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cosine_sim import cosine_sim_ref
from repro.kernels.flash_attention import flash_attention_ref
from repro.kernels.logreg import logreg_grad_ref
from repro.kernels.matmul import matmul_ref
from repro.kernels.embedding_bag import embedding_bag_ref


def _bench(fn, *args, repeat=5):
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_j(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_microbench() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.standard_normal((1024, 512)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((512, 1024)), jnp.float32)
    t = _bench(matmul_ref, x, y)
    rows.append({"table": "kernels", "kernel": "matmul(1024x512x1024)",
                 "oracle_s": t, "gflops": 2 * 1024 * 512 * 1024 / t / 1e9,
                 "tpu_block": "bm=bn=bk=128 (MXU-aligned, 192KiB VMEM)"})
    a = jnp.asarray(rng.standard_normal((2048, 256)), jnp.float32)
    t = _bench(cosine_sim_ref, a, a)
    rows.append({"table": "kernels", "kernel": "cosine_sim(2048x2048x256)",
                 "oracle_s": t, "tpu_block": "fused rsqrt epilogue"})
    X = jnp.asarray(rng.standard_normal((8192, 256)), jnp.float32)
    yy = jnp.asarray(rng.integers(0, 2, 8192), jnp.float32)
    w = jnp.zeros(256, jnp.float32)
    t = _bench(logreg_grad_ref, X, yy, w)
    rows.append({"table": "kernels", "kernel": "logreg_grad(8192x256)",
                 "oracle_s": t, "tpu_block": "bn=512 row blocks, fused fwd+bwd"})
    q = jnp.asarray(rng.standard_normal((4, 8, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((4, 2, 256, 64)), jnp.float32)
    t = _bench(lambda q_, k_, v_: flash_attention_ref(q_, k_, v_), q, k, k)
    rows.append({"table": "kernels", "kernel": "flash_attention(4x8x256x64 GQA)",
                 "oracle_s": t, "tpu_block": "bq=bk=128, online softmax"})
    table = jnp.asarray(rng.standard_normal((100_000, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 100_000, (4096, 16)), jnp.int32)
    t = _bench(embedding_bag_ref, table, idx)
    rows.append({"table": "kernels", "kernel": "embedding_bag(4096x16, 100k x 64)",
                 "oracle_s": t, "tpu_block": "scalar-prefetch row DMA"})
    return rows
