"""Secondary-index benchmark: cost-based access paths vs. full scans.

Three parts:

* ``access_path_gain`` — the two selective m2bench fixtures
  (``q_point_lookup``, ``q_range_narrow``) on two identical databases, one
  carrying ``m2bench.build_indexes``. Reports end-to-end executor latency
  (median over prebuilt optimized DAGs, so both sides pay identical
  planning) plus the access-path-only latency (scan/select/index/match
  operator seconds), and the ``access=`` provenance lines from
  ``explain_last``.
* ``selectivity_sweep`` — the crossover curve: a synthetic 400k-row table,
  range predicates swept from 1e-4 to 0.5 selectivity, full column scan
  vs. sorted-index postings vs. zone skip-scan (clustered column). Shows
  where the full scan wins back (wide predicates) and that the optimizer's
  crossover rule tracks it.
* ``maintenance_overhead`` — the update-suite acceptance number: the
  delta-store write stream with per-batch index maintenance
  (``IndexManager.refresh_all``) vs. the bare write path; overhead must
  stay well under 20%.

Usage: PYTHONPATH=src python -m benchmarks.run --suite index [--sf N]
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import GredoEngine, physical
from repro.core.schema import Predicate
from repro.core.storage import Database, Graph, Table
from repro.data import m2bench

SCAN_OPS = ("Select", "ScanTable", "IndexScan", "IndexSelect", "MatchPattern")


def _best_exec_seconds(dag, db, repeat: int) -> float:
    """Best-of executor latency on a prebuilt DAG (min is the standard
    low-noise microbenchmark estimator; optimizer_bench does the same).
    The per-node footprint walk is disabled so both sides time the bare
    operators."""
    best = float("inf")
    physical.TRACK_NBYTES = False
    try:
        for _ in range(repeat):
            t0 = time.perf_counter()
            physical.execute(dag, physical.ExecContext(db))
            best = min(best, time.perf_counter() - t0)
    finally:
        physical.TRACK_NBYTES = True
    return best


def _scan_path_seconds(dag, db, repeat: int) -> float:
    """Accumulated seconds of the scan/select/index/match operators — the
    access-path portion of the plan (joins/projections are identical on
    both sides)."""

    def reset(n):
        n.stats.seconds = 0.0
        for c in n.children:
            reset(c)

    reset(dag)
    physical.TRACK_NBYTES = False
    try:
        for _ in range(repeat):
            physical.execute(dag, physical.ExecContext(db))
    finally:
        physical.TRACK_NBYTES = True
    return sum(o["seconds"] / repeat for o in physical.collect_stats(dag)
               if o["op"] in SCAN_OPS)


def access_path_gain(sf: int = 2, repeat: int = 15) -> list[dict]:
    db_scan = m2bench.generate(sf=sf)
    db_idx = m2bench.generate(sf=sf)
    m2bench.build_indexes(db_idx)
    pid, oid = m2bench.point_lookup_keys(db_idx)
    queries = (("q_point_lookup", m2bench.q_point_lookup(pid, oid), repeat),
               ("q_range_narrow", m2bench.q_range_narrow(),
                max(repeat // 2, 3)))
    rows: list[dict] = []
    for qname, q, rep in queries:
        e_scan, e_idx = GredoEngine(db_scan), GredoEngine(db_idx)
        r_scan, r_idx = e_scan.query(q), e_idx.query(q)
        assert r_scan.nrows == r_idx.nrows, \
            f"index changed {qname}: {r_scan.nrows} != {r_idx.nrows}"
        access = []

        def collect_access(n, seen=None):
            seen = set() if seen is None else seen
            if id(n) in seen:
                return
            seen.add(id(n))
            if getattr(n, "access", None) is not None:
                access.append(f"{n.describe()}  access={n.access}")
            for c in n.children:
                collect_access(c, seen)

        collect_access(e_idx.last_dag)
        dag_scan = e_scan.optimized_plan(q)
        dag_idx = e_idx.optimized_plan(q)
        scan_s = _best_exec_seconds(dag_scan, db_scan, rep)
        idx_s = _best_exec_seconds(dag_idx, db_idx, rep)
        scanpath_scan = _scan_path_seconds(dag_scan, db_scan, rep)
        scanpath_idx = _scan_path_seconds(dag_idx, db_idx, rep)
        rows.append({
            "table": "index_access", "sf": sf, "query": qname,
            "rows": r_scan.nrows,
            "fullscan_s": scan_s, "indexed_s": idx_s,
            "speedup": scan_s / max(idx_s, 1e-9),
            "scanpath_fullscan_s": scanpath_scan,
            "scanpath_indexed_s": scanpath_idx,
            "scanpath_speedup": scanpath_scan / max(scanpath_idx, 1e-9),
            "access": list(access),
            "rewrites": [n for n in (e_idx.last_report.notes() if
                                     e_idx.last_report else [])
                         if n.startswith("access-path")],
        })
    return rows


def selectivity_sweep(n: int = 400_000, seed: int = 3) -> list[dict]:
    """Full scan vs. sorted postings vs. zone skip-scan across predicate
    selectivities, on one synthetic table: ``key`` is a shuffled permutation
    (no clustering — postings only), ``ts`` is monotone (zones prune to the
    hit range exactly)."""
    rng = np.random.default_rng(seed)
    db = Database()
    db.add_table(Table("Sweep", {
        "key": rng.permutation(n).astype(np.int64),
        "ts": np.arange(n, dtype=np.int64),
    }))
    im = db.indexes
    im.create("Sweep", "key")               # sorted postings
    im.create("Sweep", "ts", kind="zone")   # zone maps only
    t = db.tables["Sweep"]
    rows: list[dict] = []
    for sel in (1e-4, 1e-3, 1e-2, 0.1, 0.5):
        width = max(int(n * sel), 1)
        pk = Predicate("Sweep.key", "range", 1000, 1000 + width - 1)
        pt = Predicate("Sweep.ts", "range", 1000, 1000 + width - 1)

        def best(f, reps: int = 9) -> float:
            f()
            b = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                f()
                b = min(b, time.perf_counter() - t0)
            return b

        scan_s = best(lambda: t.take(np.nonzero(t.eval_predicate(pk))[0]))
        index_s = best(lambda: t.take(np.sort(im.lookup("Sweep", pk))))
        zone_s = best(lambda: t.take(im.zone_rows("Sweep", pt)))
        rows.append({
            "table": "index_sweep", "n": n, "selectivity": sel,
            "scan_s": scan_s, "index_s": index_s, "zone_s": zone_s,
            "index_speedup": scan_s / max(index_s, 1e-9),
            "zone_speedup": scan_s / max(zone_s, 1e-9),
        })
    return rows


def maintenance_overhead(n_vertices: int = 20_000, n_edges: int = 100_000,
                         batch: int = 1_000, n_batches: int = 20) -> list[dict]:
    """The update-suite stream (insert batches + tombstone deletes) with
    per-batch index maintenance forced, vs. the bare delta write path.
    Incremental absorbs are O(delta), so the overhead stays small; the
    final lookups are asserted against full scans."""

    def mk(seed: int = 0) -> tuple[Database, Graph]:
        rng = np.random.default_rng(seed)
        verts = Table("V", {"vid": np.arange(n_vertices, dtype=np.int64),
                            "attr": rng.integers(0, 100, n_vertices)})
        edges = Table("E", {
            "svid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
            "tvid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
            "w": rng.uniform(0, 1, n_edges)})
        g = Graph("U", {"V": verts}, edges, "V", "V")
        db = Database()
        db.add_graph(g)
        return db, g

    rng = np.random.default_rng(1)
    batches = [{"svid": rng.integers(0, n_vertices, batch).astype(np.int64),
                "tvid": rng.integers(0, n_vertices, batch).astype(np.int64),
                "w": rng.uniform(0, 1, batch)} for _ in range(n_batches)]
    vbatches = [{"vid": np.arange(i * 64, (i + 1) * 64, dtype=np.int64),
                 "attr": rng.integers(0, 100, 64)} for i in range(n_batches)]

    def stream(g, im) -> tuple[float, float]:
        """Returns (total stream seconds, seconds inside index refreshes).
        Timing the maintenance inline keeps the ratio self-consistent —
        comparing two separately-run streams would let the write path's own
        run-to-run variance swamp the maintenance delta."""
        refresh_s = 0.0
        t0 = time.perf_counter()
        for i, (m, vm) in enumerate(zip(batches, vbatches)):
            g.insert_vertices("V", vm)
            g.insert_edges(m)
            g.delete_edges(np.arange(i * 50, (i + 1) * 50))
            # a record read between writes (the update suite's mixed
            # workload): the merged base ⊕ delta views the indexes absorb
            # from are materialized by the workload itself
            g.vertex_tables["V"].nrows
            g.edges.nrows
            r0 = time.perf_counter()
            im.refresh_all()
            refresh_s += time.perf_counter() - r0
        return time.perf_counter() - t0, refresh_s

    totals, refresh_totals = [], []
    for _ in range(3):      # median over fresh streams
        db, g_idx = mk()
        im = db.indexes
        im.create("U", "attr", label="V")
        im.create("U", "w")
        t, r = stream(g_idx, im)
        totals.append(t)
        refresh_totals.append(r)
    idx_s = float(np.median(totals))
    refresh_s = float(np.median(refresh_totals))
    plain_s = idx_s - refresh_s

    # correctness: maintained indexes equal full scans after the stream
    p = Predicate("V.attr", "==", 7)
    want = np.nonzero(g_idx.vertex_tables["V"].eval_predicate(p))[0]
    got = np.sort(im.lookup("U", p, label="V"))
    assert np.array_equal(np.sort(want), got), "maintained index diverged"
    pe = Predicate("E.w", ">", 0.99)
    live = g_idx.live_edge_mask()
    want_e = np.nonzero(g_idx.edges.eval_predicate(pe) & live)[0]
    assert np.array_equal(np.sort(im.lookup("U", pe)), want_e)

    overhead = idx_s / max(plain_s, 1e-9) - 1.0
    return [{
        "table": "index_maintenance", "n_batches": n_batches, "batch": batch,
        "plain_s": plain_s, "indexed_s": idx_s,
        "overhead_pct": 100.0 * overhead,
        "refreshes": sum(i.refreshes for i in im._indexes.values()),
        "rebuilds": sum(i.rebuilds for i in im._indexes.values()),
    }]


def run_suite(sf: int = 2, fast: bool = False) -> list[dict]:
    if fast:
        rows = access_path_gain(sf=sf, repeat=5)
        rows += selectivity_sweep(n=100_000)
        rows += maintenance_overhead(n_vertices=4_000, n_edges=20_000,
                                     batch=500, n_batches=6)
        return rows
    rows = access_path_gain(sf=sf)
    rows += selectivity_sweep()
    rows += maintenance_overhead()
    return rows


def print_rows(rows: list[dict]) -> None:
    import sys
    for r in rows:
        if r["table"] == "index_access":
            print(f"index_{r['query']}_sf{r['sf']},{r['indexed_s']*1e6:.1f},"
                  f"speedup_vs_fullscan={r['speedup']:.2f};"
                  f"scanpath_speedup={r['scanpath_speedup']:.2f};"
                  f"rows={r['rows']}")
            for ln in r.get("rewrites", []):
                print(f"#   {ln}", file=sys.stderr)
            for ln in r.get("access", []):
                print(f"#   {ln}", file=sys.stderr)
        elif r["table"] == "index_sweep":
            print(f"index_sweep_sel{r['selectivity']:g},{r['index_s']*1e6:.1f},"
                  f"index_speedup={r['index_speedup']:.1f};"
                  f"zone_speedup={r['zone_speedup']:.1f};"
                  f"scan_us={r['scan_s']*1e6:.1f}")
        else:
            print(f"index_maintenance,{r['indexed_s']*1e6:.1f},"
                  f"overhead_pct={r['overhead_pct']:.1f};"
                  f"refreshes={r['refreshes']};rebuilds={r['rebuilds']}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    print_rows(run_suite())
