"""Serving example: batched prefill + autoregressive decode with a KV cache
(the decode_32k dry-run cell's code path at toy scale).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models.transformer import (TransformerConfig, init_cache,
                                      init_params, forward, serve_step)


def main():
    cfg = TransformerConfig(name="serve-demo", n_layers=4, d_model=256,
                            n_heads=8, n_kv_heads=2, d_ff=512, vocab=4096,
                            dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, P, G = 4, 64, 48
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)

    prefill = jax.jit(lambda p, c, t: forward(
        p, t, cfg, cache=c, cache_lengths=jnp.zeros((B,), jnp.int32)))
    decode = jax.jit(lambda p, c, t, l: serve_step(p, c, t, l, cfg))

    cache = init_cache(cfg, B, P + G)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, prompts)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None]
    lengths = jnp.full((B,), P, jnp.int32)
    toks = [nxt]
    for _ in range(G - 1):
        logits, cache = decode(params, cache, nxt, lengths)
        nxt = jnp.argmax(logits, -1)[:, None]
        lengths = lengths + 1
        toks.append(nxt)
    jax.block_until_ready(nxt)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, 1)
    print(f"[serve] {B} requests x ({P} prompt + {G} generated) "
          f"in {dt:.2f}s ({B*G/dt:.0f} tok/s incl. compile)")
    print("[serve] continuation of request 0:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
