"""Quickstart: the paper's running example end-to-end in ~40 lines.

Builds the multi-model e-commerce scenario (relational Products/Customers,
document Orders, Interested_in property graph), runs the Fig. 1(a) GCDI
query through the optimizing engine, and executes the A1 GCDA (logistic
regression predicting yogurt purchases from interest tags).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import GredoEngine, analytics
from repro.data import m2bench


def main():
    # 1. load the multi-model database (SF=1 synthetic M2Bench scenario)
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    print("collections:", list(db.tables), "+ graphs", list(db.graphs))

    # 2. GCDI: "customers and the food tags their persons are interested in"
    q = m2bench.q_g1()
    plan = eng.plan(q)
    print("\n--- logical plan ---")
    print(plan.explain())
    print("\n--- physical plans (naive vs cost-based rewrite) ---")
    print(eng.explain(q))
    result = eng.query(q)
    print(f"\nGCDI result: {result.nrows} rows, "
          f"{eng.last_stats.seconds*1e3:.1f} ms, "
          f"{eng.last_stats.record_fetches} record fetches")

    # 3. GCDA: logistic regression — predict yogurt buyers from tag vectors
    X, groups = analytics.random_access_matrix(
        result, "Customer.id", "t.tid", m2bench.N_TAGS)
    y = m2bench.purchase_labels(db)[groups]
    w, loss = analytics.regression(X, jnp.asarray(y), iters=50)
    acc = float(((np.asarray(X) @ np.asarray(w) > 0) == (y > 0.5)).mean())
    print(f"\nGCDA (A1 REGRESSION): loss={float(loss):.4f} "
          f"train-accuracy={acc:.3f} over {X.shape[0]} customers")

    # 4. GCDA reuse: the inter-buffer answers the repeated task instantly
    eng.analyze(m2bench.a2_similarity())
    eng.analyze(m2bench.a2_similarity())
    print(f"inter-buffer hits after repeated A2: {eng.interbuffer.hits}")


if __name__ == "__main__":
    main()
