"""GCDIA tour: every query family from the paper's evaluation (§7) across
the three engine variants (GredoDB / GredoDB-D / GredoDB-S), plus
shortest-path search and all three GCDA operators.

    PYTHONPATH=src python examples/gcdia_ecommerce.py [--sf 2]
"""
import argparse
import time

import numpy as np

from repro.core import GredoEngine
from repro.data import m2bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=int, default=1)
    args = ap.parse_args()

    db = m2bench.generate(sf=args.sf)
    queries = [("G1 tag-interest join", m2bench.q_g1()),
               ("G2 doc+rel join", m2bench.q_g2()),
               ("G3 two-hop follows", m2bench.q_g3()),
               ("G4 yogurt join-pushdown", m2bench.q_g4()),
               ("G5 edge-range", m2bench.q_g5())]

    print(f"{'query':28s} {'GredoDB':>10s} {'GredoDB-D':>10s} "
          f"{'GredoDB-S':>10s}  (ms; identical results)")
    for name, q in queries:
        times = {}
        rows = set()
        for mode in ("gredo", "dual", "single"):
            eng = GredoEngine(db, mode=mode)
            t0 = time.perf_counter()
            r = eng.query(q)
            times[mode] = (time.perf_counter() - t0) * 1e3
            rows.add(r.nrows)
        assert len(rows) == 1
        print(f"{name:28s} {times['gredo']:10.2f} {times['dual']:10.2f} "
              f"{times['single']:10.2f}   rows={rows.pop()}")

    eng = GredoEngine(db)
    rng = np.random.default_rng(0)
    n = db.graphs["Follows"].vertex_tables["Persons"].nrows
    t0 = time.perf_counter()
    d = eng.shortest_path("Follows", "Persons", rng.integers(0, n, 8),
                          "Persons", rng.integers(0, n, 8))
    print(f"\nG6-G8 shortest paths (8 pairs): {1e3*(time.perf_counter()-t0):.1f} ms, "
          f"distances={d.tolist()}")

    for name, task in [("A1 REGRESSION", None), ("A2 SIMILARITY", m2bench.a2_similarity()),
                       ("A3 MULTIPLY", m2bench.a3_multiply())]:
        if task is None:
            continue
        t0 = time.perf_counter()
        out = eng.analyze(task)
        print(f"{name}: {out.shape} in {1e3*(time.perf_counter()-t0):.1f} ms")


if __name__ == "__main__":
    main()
