"""End-to-end driver: train a ~100M-parameter qwen2-family LM for a few
hundred steps with the full production substrate — deterministic data
pipeline, AdamW, gradient accumulation, async checkpointing, an injected
mid-run failure, and automatic restore-and-replay.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.data.lm import TokenStream
from repro.distributed.fault import FailureInjector
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 8L x d512 (qwen2 family: GQA + QKV bias + SwiGLU + tied)
    cfg = TransformerConfig(
        name="qwen2-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=1536, vocab=32768, qkv_bias=True, tie_embeddings=True,
        mlp="swiglu", dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params")

    stream = TokenStream(vocab=cfg.vocab, batch=4, seq=128)

    def data_at(step):
        b = stream.batch_at(step)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    trainer = Trainer(
        lambda p, b: loss_fn(p, b, cfg), params, data_at,
        TrainerConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, microbatch=2, log_every=25),
        opt_cfg=AdamWConfig(lr=1e-3),
        failure_injector=FailureInjector(fail_at=(args.steps // 2,)))
    print(f"[train_lm] training {args.steps} steps with an injected failure "
          f"at step {args.steps // 2} (watch the restart)...")
    result = trainer.run_with_restarts()
    for m in result["metrics"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"{m['seconds']*1e3:6.0f} ms{'  [straggler]' if m['straggler'] else ''}")
    first, last = result["metrics"][0]["loss"], result["metrics"][-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NOT LEARNING'}); "
          f"survived injected failure via checkpoint restore")


if __name__ == "__main__":
    main()
