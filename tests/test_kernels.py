"""Per-kernel correctness: shape/dtype sweeps, interpret-mode pallas_call vs
the pure-jnp ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cosine_sim.cosine_sim import cosine_sim
from repro.kernels.cosine_sim.ref import cosine_sim_ref
from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.logreg.logreg import logreg_grad
from repro.kernels.logreg.ref import logreg_grad_ref
from repro.kernels.matmul.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (128, 128, 128),
                                   (100, 60, 130), (257, 129, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    x = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    y = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    out = matmul(x, y, bm=32, bn=32, bk=32, interpret=True)
    ref = matmul_ref(x, y)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,d", [(64, 64, 32), (100, 50, 96), (33, 65, 17)])
def test_cosine_sweep(m, n, d):
    x = jnp.asarray(RNG.standard_normal((m, d)), jnp.float32)
    y = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    out = cosine_sim(x, y, bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_allclose(out, cosine_sim_ref(x, y), rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("n,d,bn", [(100, 16, 32), (512, 64, 128), (65, 7, 16)])
def test_logreg_sweep(n, d, bn):
    x = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    y = jnp.asarray(RNG.integers(0, 2, n), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(d) * 0.3, jnp.float32)
    g1, l1 = logreg_grad(x, y, w, bn=bn, interpret=True)
    g2, l2 = logreg_grad_ref(x, y, w)
    np.testing.assert_allclose(g1, g2, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(l1, l2, rtol=3e-4)


@pytest.mark.parametrize("b,h,hk,sq,skv,causal", [
    (2, 4, 4, 64, 64, True),      # MHA train
    (2, 8, 2, 100, 100, True),    # GQA, ragged seq
    (3, 8, 2, 1, 256, True),      # decode
    (2, 4, 2, 48, 96, False),     # bidirectional, q != kv
])
def test_flash_attention_sweep(b, h, hk, sq, skv, causal):
    q = jnp.asarray(RNG.standard_normal((b, h, sq, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hk, skv, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hk, skv, 64)), jnp.float32)
    lens = jnp.asarray(RNG.integers(max(sq, 1), skv + 1, b), jnp.int32)
    out = flash_attention(q, k, v, lens, causal=causal, bq=32, bk=32,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, lens, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("nbags,bag,V,D", [(8, 4, 64, 16), (16, 8, 500, 32)])
def test_embedding_bag_sweep(nbags, bag, V, D):
    table = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
    idx = RNG.integers(0, V, (nbags, bag)).astype(np.int32)
    idx[0, 1:] = -1
    w = jnp.asarray(RNG.random((nbags, bag)), jnp.float32)
    out = embedding_bag(table, jnp.asarray(idx), w, interpret=True)
    ref = embedding_bag_ref(table, jnp.asarray(idx), w)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-5)


def test_flash_matches_model_dense_attention():
    """Kernel agrees with the model's dense attention oracle path."""
    from repro.models.transformer import _dense_attention
    q = jnp.asarray(RNG.standard_normal((2, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 32, 16)), jnp.float32)
    lens = jnp.full((2,), 32, jnp.int32)
    out = flash_attention(q, k, v, lens, causal=True, bq=16, bk=16,
                          interpret=True)
    ref = _dense_attention(q, k, v, lens, True)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-5)
