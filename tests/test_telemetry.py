"""Telemetry layer: span tracing, Chrome-trace export, metrics registry
snapshot/delta semantics, per-graph write counters, q-error monitoring, and
the disabled-path overhead guard."""
import json
import time

import numpy as np
import pytest

from repro.core import (GredoEngine, Registry, Telemetry,
                        validate_chrome_trace, physical)
from repro.core import deltastore, telemetry
from repro.core.interbuffer import fingerprint, value_nbytes
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1)


# ---------------------------------------------------------------------------
# Span tree vs DAG shape
# ---------------------------------------------------------------------------


def _expected_shape(node, memo):
    """Mirror of the executor's visit order: a fresh node opens a span
    covering its children; a signature already executed collapses to a
    leaf pseudo-span (memo hit)."""
    sig = node.signature()
    if sig in memo:
        return (node.kind, [])
    memo.add(sig)
    return (node.kind, [_expected_shape(c, memo) for c in node.children])


@pytest.mark.parametrize("mode", ["gredo", "dual", "single"])
def test_span_tree_matches_dag_shape(db, mode):
    eng = GredoEngine(db, mode=mode, telemetry=True)
    eng.query(m2bench.q_g1())
    trace = eng.telemetry.last_trace()
    assert trace is not None
    assert trace.shape() == [_expected_shape(eng.last_dag, set())]


def test_interbuffer_hit_pseudo_span(db):
    eng = GredoEngine(db, telemetry=True)
    eng.analyze(m2bench.a3_multiply())
    eng.analyze(m2bench.a3_multiply())      # root satisfied from inter-buffer
    trace = eng.telemetry.last_trace()
    hits = [s for s in trace.spans if s.args.get("cache") == "interbuffer-hit"]
    assert hits and hits[0].name == eng.last_dag.kind
    assert eng.last_stats.interbuffer_hit


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------


def test_chrome_trace_round_trips_and_nests(db):
    eng = GredoEngine(db, telemetry=True)
    eng.analyze(m2bench.a3_multiply())
    eng.query(m2bench.q_g1())
    doc = json.loads(eng.telemetry.collector.to_chrome_json())
    assert validate_chrome_trace(doc) == []
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert events
    for tid in {e["tid"] for e in events}:
        evs = [e for e in events if e["tid"] == tid]
        # begin order == span-id order: ts must be monotonically
        # non-decreasing, and each span must end within its enclosing one
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts)
        root = evs[0]
        for e in evs[1:]:
            assert e["ts"] >= root["ts"] - 1e-6
            assert e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 0.5


def test_validator_rejects_malformed_traces():
    assert validate_chrome_trace({}) == ["missing traceEvents"]
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 0,
                            "ts": -5, "dur": 2}]}
    assert validate_chrome_trace(bad)
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0, "dur": 10},
        {"name": "b", "ph": "X", "pid": 1, "tid": 0, "ts": 5, "dur": 10}]}
    assert any("nesting" in p for p in validate_chrome_trace(overlap))


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_histogram_percentiles():
    h = telemetry.Histogram("t")
    for v in np.linspace(1e-4, 1e-1, 1000):
        h.observe(float(v))
    assert h.count == 1000
    assert h.p50 == pytest.approx(5e-2, rel=0.5)
    assert h.p50 <= h.p95 <= h.p99 <= h.max
    assert np.isnan(telemetry.Histogram("e").p99)


def test_registry_snapshot_delta_across_write_burst(db):
    eng = GredoEngine(db, telemetry=True)
    reg = eng.telemetry.registry
    g = db.graphs["Interested_in"]
    before = reg.snapshot()
    n0 = g.vertex_tables["Tags"].nrows
    for i in range(3):
        g.insert_vertices("Tags", {"tid": np.array([90000 + i]),
                                   "content": np.array([f"t{i}"]),
                                   "popularity": np.array([0.0])})
    delta = Registry.delta(before, reg.snapshot())
    assert delta["deltastore.Interested_in.write_batches"] == 3
    assert delta["deltastore.Interested_in.write_rows"] == 3
    # the other graph's counters must not move (per-graph isolation)
    assert delta.get("deltastore.Follows.write_batches", 0) == 0
    assert g.vertex_tables["Tags"].nrows == n0 + 3


def test_write_counters_per_graph(db):
    # per-graph counters are the only write-path accounting now (the
    # module-global WRITE_COUNTERS alias is gone); the registry exposes
    # them namespaced per graph
    g1 = db.graphs["Follows"]
    assert not hasattr(deltastore, "WRITE_COUNTERS")
    b0 = g1.write_counters.write_batches
    g1.insert_edges({"svid": np.array([0]), "tvid": np.array([1]),
                     "since": np.array([2020])})
    assert g1.write_counters.write_batches == b0 + 1
    eng = GredoEngine(db, telemetry=True)
    snap = eng.telemetry.registry.snapshot()
    assert snap["deltastore.Follows.write_batches"] == b0 + 1


def test_per_query_interbuffer_delta(db):
    eng = GredoEngine(db, telemetry=True)
    task = m2bench.a3_multiply()
    eng.analyze(task)
    eng.analyze(task)
    # second run: one hit, zero misses *for this query* even though the
    # cumulative counters carry the first run's misses
    assert eng.last_interbuffer_delta["hits"] == 1
    assert eng.last_interbuffer_delta["misses"] == 0
    assert eng.interbuffer.misses > 0
    out = eng.explain_last()
    assert "interbuffer (this query)" in out
    assert "(cumulative)" in out


# ---------------------------------------------------------------------------
# Q-error monitor
# ---------------------------------------------------------------------------


def test_qerror_monitor_flags_misestimate():
    mon = telemetry.QErrorMonitor(threshold=4.0, max_log=8)
    mon.start_plan()
    assert mon.record("q", "Scan", "Scan[ok]", 100, 110) < 4.0
    assert mon.record("q", "Join", "Join[bad]", 1000, 10) == 100.0
    assert len(mon.last_plan) == 1
    assert mon.last_plan[0].op == "Join"
    assert mon.worst(1)[0].q_error == 100.0
    # zero-row operators clamp instead of dividing by zero
    assert mon.record("q", "Sel", "Sel[empty]", 0, 0) == 1.0
    for i in range(20):     # bounded log keeps the worst offenders
        mon.record("q", "Op", f"Op[{i}]", 10 ** (i % 5 + 1), 1)
    assert len(mon.log) <= 8
    assert mon.worst(1)[0].q_error == 100000.0


def test_engine_records_qerrors_per_plan(db):
    tel = Telemetry(qerror_threshold=1.000001)   # flag any est != actual
    eng = GredoEngine(db, telemetry=tel)
    eng.query(m2bench.q_g4())
    assert tel.qerror.observations > 0
    assert tel.qerror.last_plan, "an exactly-estimated 4-join plan is " \
                                 "vanishingly unlikely"
    assert "q-error flags" in eng.explain_last()
    assert eng.last_registry_delta.get("qerror.observations", 0) > 0


# ---------------------------------------------------------------------------
# explain_last timing annotations (satellite: seconds + % of total, top-k)
# ---------------------------------------------------------------------------


def test_explain_last_shows_seconds_and_pct(db):
    eng = GredoEngine(db)
    eng.query(m2bench.q_g1())
    out = eng.explain_last(top=3)
    assert "ms=" in out and "pct=" in out
    assert "top 3 operators by time" in out


def test_profile_returns_trace_without_permanent_telemetry(db):
    eng = GredoEngine(db)
    assert eng.telemetry is None
    prof = eng.profile(m2bench.q_g1())
    assert eng.telemetry is None            # transient session detached
    assert prof.result.nrows > 0
    assert prof.trace is not None and prof.trace.total_seconds() > 0
    assert "total_ms=" in prof.render(top=2)
    assert prof.registry_delta.get("engine.queries") == 1


# ---------------------------------------------------------------------------
# Disabled-telemetry overhead guard
# ---------------------------------------------------------------------------


def _execute_pre_telemetry(node, ctx):
    """Frozen copy of physical.execute as it was before span tracing — the
    honest baseline for the overhead bound."""
    sig = node.signature()
    if sig in ctx.memo:
        node.stats.memoized = True
        return ctx.memo[sig]
    if ctx.interbuffer is not None and node.cacheable:
        hit = ctx.interbuffer.get(fingerprint(sig))
        if hit is not None:
            node.stats.cached = True
            node.stats.rows = physical._result_rows(hit)
            node.stats.nbytes = value_nbytes(hit)
            ctx.nodes_reused += 1
            ctx.memo[sig] = hit
            return hit
    inputs = [_execute_pre_telemetry(c, ctx) for c in node.children]
    t0 = time.perf_counter()
    out = node.run(ctx, *inputs)
    node.stats.seconds += time.perf_counter() - t0
    node.stats.executed = True
    node.stats.rows = physical._result_rows(out)
    if ctx.interbuffer is not None or physical.TRACK_NBYTES:
        node.stats.nbytes = value_nbytes(out)
    ctx.nodes_run += 1
    if ctx.interbuffer is not None and node.cacheable:
        est = ctx.ests.get(id(node)) if ctx.ests is not None else None
        out = ctx.interbuffer.put(fingerprint(sig), out,
                                  est_cost=None if est is None else est[1])
    ctx.memo[sig] = out
    return out


def test_disabled_telemetry_overhead_bounded(db):
    """trace=None must cost only pointer checks: paired min-of-N on the
    same DAG vs the pre-telemetry executor, generous CI-noise bound (the
    trace benchmark measures the honest <2% figure on quiet hardware)."""
    eng = GredoEngine(db)
    dag = eng.optimized_plan(m2bench.q_g1())
    for _ in range(3):
        _execute_pre_telemetry(dag, physical.ExecContext(db))
        physical.execute(dag, physical.ExecContext(db))
    base, new = [], []
    for _ in range(15):
        t0 = time.perf_counter()
        _execute_pre_telemetry(dag, physical.ExecContext(db))
        base.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        physical.execute(dag, physical.ExecContext(db))
        new.append(time.perf_counter() - t0)
    assert min(new) <= min(base) * 1.25


def test_trace_collector_bounded():
    coll = telemetry.TraceCollector(max_spans=10)
    for i in range(8):
        qt = coll.start_query(f"q{i}")
        for _ in range(3):
            qt.end(qt.begin("Op"))
        qt.close()
        coll.trim()
    total = sum(len(t.spans) for t in coll.traces)
    assert total <= 10 or len(coll.traces) == 1
    assert coll.dropped_spans > 0
    assert coll.last().label == "q7"    # newest trace always survives


def test_empty_histogram_summary_is_finite():
    h = telemetry.Histogram("e")
    s = h.summary()
    assert s == {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    json.dumps(s)                       # strict-JSON safe (no NaN)
    # percentile() itself still says "no data" with NaN (asserted above in
    # test_histogram_percentiles) — only the snapshot view is zero-filled


def test_registry_to_openmetrics_exposition():
    reg = Registry()
    reg.counter("engine.queries").inc(3)
    reg.gauge("pool.bytes").set(1.5)
    h = reg.histogram("engine.query_seconds")
    h.observe(0.002)
    h.observe(5.0)
    reg.register_source("ib", lambda: {"hits": 7, "rate": 0.25})
    text = reg.to_openmetrics()
    lines = text.splitlines()
    assert "# TYPE engine_queries counter" in lines
    assert "engine_queries_total 3" in lines
    assert "# TYPE pool_bytes gauge" in lines
    assert "pool_bytes 1.5" in lines
    # histogram: cumulative buckets, +Inf catch-all, sum/count
    assert "# TYPE engine_query_seconds histogram" in lines
    buckets = [l for l in lines
               if l.startswith("engine_query_seconds_bucket")]
    assert buckets[-1] == 'engine_query_seconds_bucket{le="+Inf"} 2'
    counts = [int(l.rsplit(" ", 1)[1]) for l in buckets]
    assert counts == sorted(counts)     # cumulative, monotone
    assert "engine_query_seconds_count 2" in lines
    assert any(l.startswith("engine_query_seconds_sum 5.002") for l in lines)
    # pull sources export as gauges under a sanitized namespace
    assert "ib_hits 7" in lines and "ib_rate 0.25" in lines
    assert lines[-1] == "# EOF" and text.endswith("\n")
    # names obey the OpenMetrics grammar
    for l in lines:
        if not l.startswith("#"):
            name = l.split(" ")[0].split("{")[0]
            assert telemetry.Registry._om_name(name) == name


def test_engine_openmetrics_end_to_end(db):
    eng = GredoEngine(db, telemetry=True)
    eng.query(m2bench.q_g1())
    eng.health()
    text = eng.telemetry.registry.to_openmetrics()
    assert "engine_queries_total 1" in text
    assert "health_status" in text      # health gauges ride along
    assert "flight_records 1" in text   # flight-recorder source too
