"""Delta-store write path: base ⊕ delta reads must be indistinguishable from
a from-scratch rebuilt graph (pattern.match, traversal, k-hop joins, shortest
paths), writes must stay off the O(V+E) rebuild path, and the epoch-keyed
inter-buffer must recompute GCDA results after any source mutation."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import deltastore
from repro.core.engine import GredoEngine, _match_by_joins
from repro.core.interbuffer import InterBuffer
from repro.core.pattern import match, plan_pattern, shortest_path_lengths
from repro.core.schema import (AnalyticsTask, GCDIATask, Predicate, Query,
                               chain_pattern)
from repro.core.storage import (Database, DictColumn, Graph, RaggedColumn,
                                Table, build_csr)
from repro.core import traversal

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# Helpers: build a graph, mutate it, and rebuild an oracle from scratch
# ---------------------------------------------------------------------------


def _mk_tables(seed=0, n_a=15, n_b=8, n_e=60):
    rng = np.random.default_rng(seed)
    A = {"attr": rng.integers(0, 3, n_a),
         "tag": [("x", "y", "z")[i % 3] for i in range(n_a)]}
    B = {"attr": rng.integers(0, 3, n_b)}
    E = {"svid": rng.integers(0, n_a, n_e).astype(np.int64),
         "tvid": rng.integers(0, n_b, n_e).astype(np.int64),
         "w": rng.integers(0, 10, n_e).astype(np.int64)}
    return A, B, E


def _graph_from(A, B, E, cfg=None):
    return Graph("G",
                 {"A": Table("A", {"attr": np.asarray(A["attr"]),
                                   "tag": DictColumn(values=list(A["tag"]))}),
                  "B": Table("B", {"attr": np.asarray(B["attr"])})},
                 Table("E", {k: np.asarray(v) for k, v in E.items()}),
                 "A", "B", delta_config=cfg)


def _no_compact():
    return deltastore.DeltaConfig(auto_compact=False)


def _match_rows(g, phi=None, projected=()):
    """Sorted multiset of (src vid, dst vid, edge w) bindings — edge tids are
    deliberately excluded because compaction renumbers them."""
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    plan = plan_pattern(g, pattern, {k: list(v) for k, v in (phi or {}).items()},
                        projected=set(projected))
    rel = match(g, plan)
    w = np.asarray(g.edges.col("w"))[np.asarray(rel.col("e0"))]
    rows = list(zip(np.asarray(rel.col("x")).tolist(),
                    np.asarray(rel.col("y")).tolist(), w.tolist()))
    return sorted(rows)


def _apply_script(g, script):
    """Mutate ``g`` through the delta write path, and return the equivalent
    (A, B, E, live) state for building an oracle graph from scratch."""
    A = {"attr": list(np.asarray(g.vertex_tables["A"].col("attr"))),
         "tag": list(g.vertex_tables["A"].col("tag").decode(
             g.vertex_tables["A"].col("tag").codes))}
    B = {"attr": list(np.asarray(g.vertex_tables["B"].col("attr")))}
    E = {k: list(np.asarray(g.edges.col(k))) for k in ("svid", "tvid", "w")}
    dead: set = set()
    for op, payload in script:
        if op == "ins_e":
            g.insert_edges(payload)
            for k in E:
                E[k].extend(np.asarray(payload[k]).tolist())
        elif op == "del_e":
            g.delete_edges(payload)
            dead.update(np.asarray(payload).tolist())
        elif op == "ins_vA":
            g.insert_vertices("A", payload)
            A["attr"].extend(np.asarray(payload["attr"]).tolist())
            A["tag"].extend(list(payload["tag"]))
        elif op == "ins_vB":
            g.insert_vertices("B", payload)
            B["attr"].extend(np.asarray(payload["attr"]).tolist())
        else:
            raise ValueError(op)
    live = [i for i in range(len(E["svid"])) if i not in dead]
    E_live = {k: np.asarray(v)[live] for k, v in E.items()}
    return A, B, E_live


SCRIPT = [
    ("ins_e", {"svid": np.array([0, 1, 2, 14]), "tvid": np.array([7, 0, 3, 1]),
               "w": np.array([11, 12, 13, 14])}),
    ("del_e", np.array([0, 5, 9, 61])),       # base edges + a delta edge
    ("ins_vA", {"attr": np.array([1, 2]), "tag": ["q", "x"]}),
    ("ins_vB", {"attr": np.array([0])}),
    ("ins_e", {"svid": np.array([15, 16, 3]), "tvid": np.array([8, 8, 2]),
               "w": np.array([20, 21, 22])}),  # edges touching delta vertices
    ("del_e", np.array([64])),                 # delete an edge of a delta vertex
]


@pytest.fixture()
def mutated_and_oracle():
    A, B, E = _mk_tables()
    g = _graph_from(A, B, E, cfg=_no_compact())
    A2, B2, E2 = _apply_script(g, SCRIPT)
    oracle = _graph_from(A2, B2, E2)
    assert g.delta.has_pending()  # the point: reads run over base ⊕ delta
    return g, oracle


# ---------------------------------------------------------------------------
# Read-path equivalence: delta overlay == from-scratch rebuild
# ---------------------------------------------------------------------------


def test_pattern_match_equals_rebuild(mutated_and_oracle):
    g, oracle = mutated_and_oracle
    assert _match_rows(g) == _match_rows(oracle)


def test_pattern_match_with_predicates_equals_rebuild(mutated_and_oracle):
    g, oracle = mutated_and_oracle
    phi = {"x": [Predicate("x.attr", "==", 1)],
           "e0": [Predicate("e0.w", "<=", 12)]}
    assert _match_rows(g, phi) == _match_rows(oracle, phi)
    phi = {"y": [Predicate("y.attr", "!=", 0)],
           "x": [Predicate("x.tag", "==", "q")]}  # delta-extended vocabulary
    assert _match_rows(g, phi) == _match_rows(oracle, phi)


def _lv(gr, nids):
    """nids -> comparable (label_code, vid) pairs: the delta graph appends
    new vertices after the base nid space while a rebuilt oracle lays labels
    out contiguously, so raw nids are not comparable across the two."""
    nids = np.asarray(nids)
    return list(zip(gr.vertex_label_code[nids].tolist(),
                    gr.vertex_vid_of[nids].tolist()))


def test_traversal_equals_rebuild(mutated_and_oracle):
    g, oracle = mutated_and_oracle
    for reverse in (False, True):
        s1, d1, _ = traversal.nid_to_nid(g, np.arange(g.n_vertices),
                                         reverse=reverse)
        s2, d2, _ = traversal.nid_to_nid(oracle, np.arange(oracle.n_vertices),
                                         reverse=reverse)
        assert sorted(zip(_lv(g, s1), _lv(g, d1))) == \
            sorted(zip(_lv(oracle, s2), _lv(oracle, d2)))


def test_khop_joins_equal_rebuild():
    """Two-hop homogeneous k-hop joins (the GredoDB-S TBS path) agree."""
    rng = np.random.default_rng(3)
    n, e = 12, 40
    E = {"svid": rng.integers(0, n, e).astype(np.int64),
         "tvid": rng.integers(0, n, e).astype(np.int64),
         "w": rng.integers(0, 5, e).astype(np.int64)}
    mk = lambda Ed, cfg=None: Graph(
        "H", {"A": Table("A", {"attr": np.zeros(n, np.int64)})},
        Table("E", {k: np.asarray(v) for k, v in Ed.items()}), "A", "A",
        delta_config=cfg)
    g = mk(E, _no_compact())
    g.insert_edges({"svid": np.array([0, 1]), "tvid": np.array([2, 0]),
                    "w": np.array([9, 9])})
    g.delete_edges(np.array([3, 4, 40]))
    live = [i for i in range(e) if i not in (3, 4)] + [41]
    full = {k: np.append(np.asarray(E[k]), {"svid": [0, 1], "tvid": [2, 0],
                                            "w": [9, 9]}[k]) for k in E}
    oracle = mk({k: v[live] for k, v in full.items()})
    pat = chain_pattern("H", ("x", "A", "E", "y", "A"), ("y", "A", "E", "z", "A"))

    def rows(gr):
        t = _match_by_joins(gr, pat)
        w = np.asarray(gr.edges.col("w"))
        return sorted(zip(np.asarray(t.col("x")).tolist(),
                          np.asarray(t.col("y")).tolist(),
                          np.asarray(t.col("z")).tolist(),
                          w[np.asarray(t.col("e0"))].tolist(),
                          w[np.asarray(t.col("e1"))].tolist()))

    assert rows(g) == rows(oracle)
    # and the topology engine agrees with the join engine over base ⊕ delta
    rel = match(g, plan_pattern(g, pat, {}, projected=set()))
    assert len(rel.columns["x"]) == len(rows(g))


def test_shortest_paths_equal_rebuild(mutated_and_oracle):
    g, oracle = mutated_and_oracle
    src_vids = np.repeat(np.arange(4), 3)
    dst_vids = np.tile(np.array([0, 3, 8]), 4)  # B vids incl. a delta vertex
    got = shortest_path_lengths(g, g.nid_of("A", src_vids),
                                g.nid_of("B", dst_vids))
    want = shortest_path_lengths(oracle, oracle.nid_of("A", src_vids),
                                 oracle.nid_of("B", dst_vids))
    assert np.array_equal(got, want)


def test_compaction_preserves_results_and_resets_delta(mutated_and_oracle):
    g, oracle = mutated_and_oracle
    before = _match_rows(g)
    n_live = g.n_live_edges
    g.compact()
    assert not g.delta.has_pending()
    assert g.edges.nrows == n_live  # tombstones physically dropped
    assert g.fwd.n_edges == n_live
    assert _match_rows(g) == before == _match_rows(oracle)
    # label blocks are contiguous again
    for lbl in g.labels:
        lo, hi = g.label_range(lbl)
        assert hi - lo == g.vertex_tables[lbl].nrows


def test_auto_compaction_triggers():
    A, B, E = _mk_tables()
    cfg = deltastore.DeltaConfig(min_delta_edges=8, max_delta_ratio=0.01)
    g = _graph_from(A, B, E, cfg=cfg)
    for _ in range(5):
        g.insert_edges({"svid": np.arange(3), "tvid": np.arange(3),
                        "w": np.array([1, 2, 3])})
    assert g.compactions >= 1
    assert len(g.delta.segments) <= 2  # folded into base


def test_write_path_performs_no_rebuild_work():
    """The acceptance criterion: a batch insert/delete does no O(V+E) work —
    the base CSR object is untouched and the charged write cost is
    batch-proportional, not graph-proportional."""
    rng = np.random.default_rng(1)
    n, e, b = 2000, 10000, 100
    g = Graph("G", {"A": Table("A", {"attr": np.zeros(n, np.int64)})},
              Table("E", {"svid": rng.integers(0, n, e).astype(np.int64),
                          "tvid": rng.integers(0, n, e).astype(np.int64),
                          "w": np.zeros(e, np.int64)}),
              "A", "A")
    base_fwd, base_rev = g.fwd, g.rev      # fresh graph: counters start at 0
    g.insert_edges({"svid": rng.integers(0, n, b).astype(np.int64),
                    "tvid": rng.integers(0, n, b).astype(np.int64),
                    "w": np.zeros(b, np.int64)})
    g.delete_edges(np.arange(10))
    c = g.write_counters
    assert c.compactions == 0 and c.compact_ops == 0
    assert g.fwd is base_fwd and g.rev is base_rev  # no rebuild happened
    assert c.write_ops <= 20 * b                    # O(b log b), nowhere near e
    assert g.n_live_edges == e + b - 10


# ---------------------------------------------------------------------------
# Epoch-keyed inter-buffer: writes invalidate cached GCDA results
# ---------------------------------------------------------------------------


def _analytics_db():
    db = Database()
    rng = np.random.default_rng(5)
    persons = Table("P", {"pid": np.arange(6, dtype=np.int64)})
    tags = Table("T", {"tid": np.arange(4, dtype=np.int64)})
    edges = Table("E", {"svid": rng.integers(0, 6, 12).astype(np.int64),
                        "tvid": rng.integers(0, 4, 12).astype(np.int64)})
    db.add_graph(Graph("G", {"P": persons, "T": tags}, edges, "P", "T"))
    return db


def _sim_task():
    pat = chain_pattern("G", ("p", "P", "E", "t", "T"))
    q = Query(select=("p.pid", "t.tid"), froms=(), match=pat)
    return GCDIATask(integration=q,
                     analytics=AnalyticsTask("SIMILARITY",
                                             [("random", "p.pid", "t.tid", 4)]))


def test_analyze_recomputes_after_graph_write():
    db = _analytics_db()
    eng = GredoEngine(db)
    out1 = eng.analyze(_sim_task())
    eng.analyze(_sim_task())
    assert eng.interbuffer.hits == 1  # unchanged epoch -> structural reuse
    # mutate the source graph: every new-vertex edge changes the incidence
    db.graphs["G"].insert_edges({"svid": np.array([0, 0, 0]),
                                 "tvid": np.array([3, 2, 1])})
    out2 = eng.analyze(_sim_task())
    assert eng.interbuffer.hits == 1  # epoch changed -> MISS, recomputed
    assert eng.interbuffer.misses >= 2
    assert (np.asarray(out1).shape != np.asarray(out2).shape
            or not np.allclose(np.asarray(out1), np.asarray(out2)))


def test_duplicate_and_empty_write_batches():
    A, B, E = _mk_tables()
    g = _graph_from(A, B, E, cfg=_no_compact())
    n_live = g.n_live_edges
    g.delete_edges(np.array([0, 0, 3, 3]))   # duplicates count once
    assert g.delta.n_tombstones == 2 and g.n_live_edges == n_live - 2
    e_before = g.epoch
    g.delete_edges(np.array([0]))            # re-delete is a no-op
    assert g.delta.n_tombstones == 2
    assert g.epoch == e_before               # no spurious cache invalidation
    g2 = _graph_from(A, B, E)
    g2.insert_vertices("A", {"attr": np.array([], np.int64), "tag": []})
    g2.insert_edges({"svid": np.array([], np.int64),
                     "tvid": np.array([], np.int64), "w": np.array([], np.int64)})
    g2.delete_edges(np.array([], np.int64))
    assert not g2.delta.has_pending() and g2.epoch == 0  # all no-ops


def test_compact_after_delete_advances_epoch():
    """Dropping tombstones renumbers edge tids — observable via
    tid-projecting queries — so that compaction must invalidate caches."""
    A, B, E = _mk_tables()
    g = _graph_from(A, B, E, cfg=_no_compact())
    g.insert_edges({"svid": np.array([0]), "tvid": np.array([0]),
                    "w": np.array([1])})
    e1 = g.epoch
    g.compact()                      # pure merge: tids unchanged -> no bump
    assert g.epoch == e1
    g.delete_edges(np.array([2]))
    e2 = g.epoch
    g.compact()                      # renumbering -> epoch advances
    assert g.epoch == e2 + 1


def test_device_matcher_refuses_pending_delta():
    from repro.core.pattern_jit import DevicePatternMatcher
    A, B, E = _mk_tables()
    g = _graph_from(A, B, E, cfg=_no_compact())
    g.delete_edges(np.array([0]))
    with pytest.raises(ValueError, match="pending delta"):
        DevicePatternMatcher(g)
    g.compact()
    DevicePatternMatcher(g)  # clean after an explicit compaction


def test_add_graph_replacement_invalidates_cache():
    db = _analytics_db()
    eng = GredoEngine(db)
    eng.analyze(_sim_task())
    eng.analyze(_sim_task())
    assert eng.interbuffer.hits == 1
    db2 = _analytics_db()            # same name, fresh graph (epoch 0)
    db.add_graph(db2.graphs["G"])
    eng.analyze(_sim_task())
    assert eng.interbuffer.hits == 1  # replacement bumped the epoch lineage


def test_analyze_recomputes_after_table_touch():
    db = _analytics_db()
    db.add_table(Table("R", {"k": np.arange(3)}))
    eng = GredoEngine(db)
    pat = chain_pattern("G", ("p", "P", "E", "t", "T"))
    q = Query(select=("p.pid", "t.tid"), froms=("R",), match=pat,
              where=(Predicate("R.k", ">=", 0),))
    task = GCDIATask(integration=q, analytics=AnalyticsTask(
        "SIMILARITY", [("random", "p.pid", "t.tid", 4)]))
    eng.analyze(task)
    eng.analyze(task)
    assert eng.interbuffer.hits == 1
    db.touch_table("R")
    eng.analyze(task)
    assert eng.interbuffer.hits == 1  # table epoch bump invalidates too


# ---------------------------------------------------------------------------
# Satellite regressions: inter-buffer LRU + ragged-column edge cases
# ---------------------------------------------------------------------------


def test_interbuffer_lru_no_duplicate_order_entries():
    buf = InterBuffer(capacity_bytes=1 << 20)
    m = jnp.ones((4, 4))
    for _ in range(5):
        buf.put("k", m)     # re-put must not duplicate LRU entries
    assert len(buf) == 1
    assert buf.nbytes() == int(m.size) * m.dtype.itemsize
    buf.put("k2", m)
    assert buf.get("k") is not None and buf.get("k2") is not None


def test_interbuffer_evicts_lru_and_oversized():
    one_kb = jnp.ones((256,), jnp.float32)  # 1 KiB
    buf = InterBuffer(capacity_bytes=2048)
    buf.put("a", one_kb)
    buf.put("b", one_kb)
    buf.get("a")                      # a becomes MRU
    buf.put("c", one_kb)              # evicts b (LRU), not a
    assert buf.get("b") is None and buf.get("a") is not None
    # a single entry larger than capacity must not stick around
    buf.put("huge", jnp.ones((4096,), jnp.float32))
    assert buf.nbytes() <= 2048 and buf.get("huge") is None


def test_ragged_take_and_predicates_on_empty_rows():
    r = RaggedColumn(lists=[[1, 2], [], [5]])
    t = r.take(np.array([], dtype=np.int64))     # empty selection
    assert len(t) == 0 and len(t.values) == 0
    t2 = r.take(np.array([1, 1]))                # duplicated empty row
    assert len(t2) == 2 and list(t2.lengths()) == [0, 0]
    tbl = Table("D", {"xs": RaggedColumn(lists=[[], [], []])})
    mask = tbl.eval_predicate(Predicate("D.xs", ">=", 0))
    assert list(mask) == [False, False, False]   # ANY over empty rows
    tbl2 = Table("D", {"xs": r})
    assert list(tbl2.eval_predicate(Predicate("D.xs", "==", 5))) == \
        [False, False, True]


def test_insert_promotes_numeric_dtype_like_seed_path():
    """A float batch into an int column must promote (seed np.concatenate
    semantics), not truncate to the base dtype."""
    A, B, E = _mk_tables()
    g = _graph_from(A, B, E, cfg=_no_compact())
    g.insert_vertices("A", {"attr": np.array([4.5]), "tag": ["f"]})
    merged = np.asarray(g.vertex_tables["A"].col("attr"))
    assert merged.dtype.kind == "f" and merged[-1] == 4.5


def test_dict_column_incremental_append():
    c = DictColumn(values=["b", "a", "b"])
    c2 = c.append(["a", "zz", "b", "zz"])
    assert list(c2.decode(c2.codes)) == ["b", "a", "b", "a", "zz", "b", "zz"]
    assert len(c2.vocab) == 3            # only one genuinely new value
    assert np.array_equal(c2.codes[:3], c.codes)  # existing codes untouched
    assert c.encode("zz") == -1          # original column is unaffected


def test_delta_segment_neighbors_matches_csr():
    rng = np.random.default_rng(11)
    n, e = 30, 120
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    seg = deltastore.EdgeSegment(src, dst, np.arange(e))
    csr = build_csr(n, src, dst)
    frontier = rng.integers(0, n, 10)
    pos, d1, e1 = seg.neighbors(frontier)
    s_rep, d2, e2 = csr.neighbors(frontier)
    assert sorted(zip(frontier[pos], d1, e1)) == \
        sorted(zip(s_rep, d2, e2.astype(np.int64)))
    # reverse direction == forward on the transposed edge set
    posr, dr, er = seg.neighbors(frontier, reverse=True)
    segT = deltastore.EdgeSegment(dst, src, np.arange(e))
    posf, df, ef = segT.neighbors(frontier)
    assert sorted(zip(frontier[posr], dr, er)) == sorted(zip(frontier[posf], df, ef))
