"""LM transformer: attention path equivalences, MoE invariants, decode
consistency, learning smoke."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (TransformerConfig, forward, init_cache,
                                      init_params, loss_fn, serve_step)

CFG = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab=211, qkv_bias=True, dtype=jnp.float32,
                        q_chunk=16, kv_chunk=16)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def test_chunked_equals_dense(params):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, CFG.vocab)
    l1, _ = forward(params, toks, CFG)  # chunked
    l2, _ = forward(params, toks, dataclasses.replace(CFG, attn_impl="dense"))
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_ragged_lengths_mask(params):
    """Positions beyond `lengths` must not influence earlier logits."""
    cfg = dataclasses.replace(CFG, attn_impl="dense")
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, CFG.vocab)
    toks2 = toks.at[:, 12:].set(7)  # change the padding region
    lens = jnp.array([12], jnp.int32)
    l1, _ = forward(params, toks, cfg, lengths=lens)
    l2, _ = forward(params, toks2, cfg, lengths=lens)
    np.testing.assert_allclose(l1[:, :12], l2[:, :12], rtol=1e-4, atol=1e-4)


def test_prefill_decode_equals_full(params):
    cfg = dataclasses.replace(CFG, attn_impl="dense")
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, CFG.vocab)
    nxt = jax.random.randint(jax.random.PRNGKey(4), (2, 1), 0, CFG.vocab)
    full, _ = forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    cache = init_cache(cfg, 2, 32)
    logits_p, cache = forward(params, toks, cfg, cache=cache,
                              cache_lengths=jnp.zeros(2, jnp.int32))
    np.testing.assert_allclose(logits_p, full[:, :24], rtol=2e-4, atol=2e-4)
    nl, cache = serve_step(params, cache, nxt, jnp.full(2, 24, jnp.int32), cfg)
    np.testing.assert_allclose(nl, full[:, 24], rtol=2e-4, atol=2e-4)


def test_moe_group_invariance():
    """Dispatch grouping must not change results when capacity is ample."""
    cfg1 = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                             d_ff=32, vocab=64, n_experts=4, top_k=2,
                             capacity_factor=4.0, dtype=jnp.float32,
                             moe_groups=1)
    cfg2 = dataclasses.replace(cfg1, moe_groups=4)
    p = init_params(jax.random.PRNGKey(0), cfg1)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
    l1, _ = forward(p, toks, cfg1)
    l2, _ = forward(p, toks, cfg2)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=32, vocab=64, n_experts=4, top_k=2,
                            capacity_factor=1.0, dtype=jnp.float32)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    logits, aux = forward(p, toks, cfg)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) >= 1.0  # switch aux loss lower bound is 1 at balance


def test_loss_decreases():
    cfg = dataclasses.replace(CFG, vocab=64)
    p = init_params(jax.random.PRNGKey(0), cfg)
    from repro.data.lm import TokenStream
    from repro.train.loop import Trainer, TrainerConfig
    import shutil
    shutil.rmtree("/tmp/tt_loss", ignore_errors=True)
    stream = TokenStream(vocab=64, batch=8, seq=32)

    def data_at(step):
        b = stream.batch_at(step)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    t = Trainer(lambda pp, b: loss_fn(pp, b, cfg), p, data_at,
                TrainerConfig(total_steps=25, ckpt_every=0,
                              ckpt_dir="/tmp/tt_loss", log_every=1))
    r = t.run(resume=False)
    losses = [m["loss"] for m in r["metrics"]]
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.parametrize("arch", ["olmoe_1b_7b", "granite_moe_1b_a400m",
                                  "starcoder2_3b", "qwen2_1_5b", "stablelm_3b"])
def test_full_config_param_counts(arch):
    """Published configs land in the advertised parameter bands."""
    from repro import configs
    cfg = configs.get(arch).config()
    total = cfg.param_count() / 1e9
    active = cfg.active_param_count() / 1e9
    bands = {"olmoe_1b_7b": (6.0, 8.0, 0.9, 1.6),
             "granite_moe_1b_a400m": (1.0, 1.7, 0.3, 0.6),
             "starcoder2_3b": (2.6, 3.6, 2.6, 3.6),
             "qwen2_1_5b": (1.2, 1.9, 1.2, 1.9),
             "stablelm_3b": (2.5, 3.6, 2.5, 3.6)}
    lo, hi, alo, ahi = bands[arch]
    assert lo <= total <= hi, (arch, total)
    assert alo <= active <= ahi, (arch, active)
