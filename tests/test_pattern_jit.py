"""Device-resident pattern matching == host engine (incl. overflow retry)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.pattern import match, plan_pattern
from repro.core.pattern_jit import DevicePatternMatcher
from repro.core.schema import Predicate, chain_pattern
from repro.core.storage import Graph, Table


def _mk_graph(seed, n_a=20, n_b=10, n_e=80):
    rng = np.random.default_rng(seed)
    A = Table("A", {"attr": rng.integers(0, 3, n_a)})
    B = Table("B", {"attr": rng.integers(0, 3, n_b)})
    E = Table("E", {"svid": rng.integers(0, n_a, n_e),
                    "tvid": rng.integers(0, n_b, n_e),
                    "w": rng.integers(0, 10, n_e)})
    return Graph("G", {"A": A, "B": B}, E, "A", "B")


@given(st.integers(0, 5000), st.sampled_from([None, 0, 1, 2]))
@settings(max_examples=15, deadline=None)
def test_device_match_equals_host(seed, pred):
    g = _mk_graph(seed)
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    phi = {"y": [Predicate("y.attr", "==", pred)]} if pred is not None else {}
    plan = plan_pattern(g, pattern, {k: list(v) for k, v in phi.items()},
                        projected=set(), force_reverse=False,
                        enable_pushdown=False)
    host = match(g, plan)

    m = DevicePatternMatcher(g, initial_capacity=16)  # force retry path
    lo, hi = g.label_range("A")
    blo, bhi = g.label_range("B")
    member = np.zeros(g.n_vertices, bool)
    if pred is not None:
        member[blo:bhi] = np.asarray(g.vertex_tables["B"].col("attr")) == pred
    else:
        member[blo:bhi] = True
    cols = m.match_chain(np.arange(lo, hi), [member], [None])

    host_pairs = sorted(zip(np.asarray(host.col("x")),
                            np.asarray(host.col("y"))))
    dev_pairs = sorted(zip(cols[0] - lo, cols[1] - blo))
    assert host_pairs == [(int(a), int(b)) for a, b in dev_pairs]
    assert m.recompiles >= 1  # capacity 16 must have doubled at least once
