"""Unit tests for the perf-regression gate (benchmarks.regression): metric
flattening, noise-aware tolerance bands, compare semantics, and baseline
merge. All synthetic — no benchmark suites run here."""
import json
import os

import pytest

from benchmarks import regression

pytestmark = pytest.mark.fast


ROWS = [
    {"table": "gcdi_ablation", "query": "Q1", "gredo_s": 0.010,
     "speedup_vs_single": 5.0, "speedup_vs_dual": 2.0,
     "gredo_io": 100, "single_io": 900, "sf": 1},
    {"table": "graph_workloads", "query": "G6_sp", "gredo_s": 0.020},
    {"table": "gcda_ablation", "task": "A3_multiply", "batch_s": 0.030,
     "speedup": 3.0},
    {"table": "interbuffer_reuse", "cold_s": 0.40, "warm_s": 0.10,
     "reuse_speedup": 4.0},
    {"table": "not_gated", "query": "X", "gredo_s": 99.0},
    {"table": "gcdi_ablation", "query": "Q2", "gredo_s": None},  # non-numeric
]


def test_metrics_from_rows_flattening():
    m = regression.metrics_from_rows(ROWS)
    assert m["gcdi_ablation.Q1.gredo_s"] == (0.010, "seconds")
    assert m["gcdi_ablation.Q1.speedup_vs_single"] == (5.0, "ratio")
    assert m["gcdi_ablation.Q1.gredo_io"] == (100.0, "count")
    assert m["graph_workloads.G6_sp.gredo_s"] == (0.020, "seconds")
    assert m["gcda_ablation.A3_multiply.speedup"] == (3.0, "ratio")
    assert m["interbuffer_reuse.reuse_speedup"] == (4.0, "ratio")
    assert not any(k.startswith("not_gated") for k in m)
    assert "gcdi_ablation.Q2.gredo_s" not in m        # None dropped


def _samples(*vals, kind="ratio", name="m"):
    return [{name: (v, kind)} for v in vals]


def test_build_baseline_tolerance_floor_and_spread():
    # tight samples -> the kind floor wins
    doc = regression.build_baseline(_samples(2.0, 2.0, 2.0))
    spec = doc["metrics"]["m"]
    assert spec["value"] == 2.0 and spec["kind"] == "ratio"
    assert spec["tol"] == regression.TOL_FLOORS["ratio"]
    assert spec["samples"] == [2.0, 2.0, 2.0]

    # noisy samples -> 3x relative spread beats the floor
    doc = regression.build_baseline(_samples(1.0, 2.0, 3.0))
    assert doc["metrics"]["m"]["tol"] == pytest.approx(3.0 * (2.0 / 2.0))

    # pathological spread is capped
    doc = regression.build_baseline(_samples(0.001, 10.0, 20.0))
    assert doc["metrics"]["m"]["tol"] == regression.TOL_CAP


def test_compare_directionality():
    baseline = regression.build_baseline([{
        "r": (2.0, "ratio"), "s": (1.0, "seconds"), "c": (100.0, "count"),
    }])
    # within band: ratio may grow freely, seconds/count may shrink freely
    regs, notes = regression.compare(
        {"r": (9.0, "ratio"), "s": (0.01, "seconds"), "c": (1.0, "count")},
        baseline)
    assert regs == [] and notes == []
    # ratio dropping below (1 - tol) trips; tol floor for ratio is 40%
    regs, _ = regression.compare(
        {"r": (1.0, "ratio"), "s": (1.0, "seconds"), "c": (100.0, "count")},
        baseline)
    assert len(regs) == 1 and "ratio dropped" in regs[0]
    # seconds growing past (1 + tol) trips; floor is 100% (>2x)
    regs, _ = regression.compare(
        {"r": (2.0, "ratio"), "s": (2.5, "seconds"), "c": (100.0, "count")},
        baseline)
    assert len(regs) == 1 and "seconds grew" in regs[0]
    # counts are near-exact (2% floor)
    regs, _ = regression.compare(
        {"r": (2.0, "ratio"), "s": (1.0, "seconds"), "c": (103.0, "count")},
        baseline)
    assert len(regs) == 1 and "count grew" in regs[0]


def test_compare_vanished_and_new_metrics():
    baseline = regression.build_baseline([{"old": (2.0, "ratio")}])
    regs, notes = regression.compare({"new": (1.0, "ratio")}, baseline)
    assert len(regs) == 1 and "vanished" in regs[0]
    assert len(notes) == 1 and "not baselined" in notes[0]


def test_median_sample():
    med = regression._median_sample(_samples(1.0, 5.0, 2.0))
    assert med["m"] == (2.0, "ratio")


def test_update_baseline_merges_uncovered_metrics(tmp_path):
    path = str(tmp_path / "baselines.json")
    regression.update_baseline_from_samples(
        [{"a": (1.0, "seconds"), "b": (2.0, "ratio")}], sf=1, path=path)
    # second run re-measures only "b": "a" must survive the merge
    regression.update_baseline_from_samples(
        [{"b": (3.0, "ratio")}], sf=1, path=path)
    doc = json.load(open(path))
    assert doc["metrics"]["a"]["value"] == 1.0
    assert doc["metrics"]["b"]["value"] == 3.0
    assert list(doc["metrics"]) == sorted(doc["metrics"])


def test_committed_baseline_covers_gated_suites():
    """The committed baseline must exist and carry the paper's headline
    metrics — the CI gate exits 2 (hard fail) without it."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        regression.BASELINE_PATH)
    doc = json.load(open(path))
    names = set(doc["metrics"])
    assert any(n.startswith("graph_workloads.") for n in names)
    assert any(n.startswith("gcda_ablation.") and n.endswith(".speedup")
               for n in names)
    assert "interbuffer_reuse.reuse_speedup" in names
    for spec in doc["metrics"].values():
        assert spec["kind"] in regression.TOL_FLOORS
        assert 0.0 < spec["tol"] <= regression.TOL_CAP


def test_slowdown_hook_patches_and_restores():
    from repro.core.engine import GredoEngine
    orig = GredoEngine.query
    patch = regression._Slowdown(0.001)
    try:
        assert GredoEngine.query is not orig
    finally:
        patch.undo()
    assert GredoEngine.query is orig
