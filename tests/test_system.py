"""End-to-end behaviour of the GredoDB reproduction: tri-mode agreement,
GCDIA pipeline, inter-buffer reuse, I/O-proxy ordering."""
import numpy as np
import pytest

from repro.core import GredoEngine, analytics
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1, seed=7)


QUERIES = ["q_g1", "q_g2", "q_g3", "q_g4", "q_g5", "q_edge_scan", "q_vertex_scan"]


@pytest.mark.parametrize("qname", QUERIES)
def test_tri_mode_agreement(db, qname):
    """GredoDB / GredoDB-D / GredoDB-S return identical result multisets."""
    q = getattr(m2bench, qname)()
    results = {}
    for mode in ("gredo", "dual", "single"):
        r = GredoEngine(db, mode=mode).query(q)
        key_cols = sorted(r.columns)
        rows = np.stack([np.asarray(r.col(c), dtype=np.int64)
                         if np.asarray(r.col(c)).dtype.kind in "iu"
                         else np.asarray([hash(x) for x in
                                          np.asarray(r.col(c) if not hasattr(r.col(c), 'codes') else r.col(c).codes)])
                         for c in key_cols])
        order = np.lexsort(rows)
        results[mode] = rows[:, order]
    assert np.array_equal(results["gredo"], results["dual"])
    assert np.array_equal(results["gredo"], results["single"])


def test_io_proxy_ordering(db):
    """Optimizations reduce record fetches: gredo <= dual <= single on the
    predicate-selective pattern workloads (paper Figs. 7-8 direction)."""
    for qname in ("q_g1", "q_g2", "q_g3"):
        q = getattr(m2bench, qname)()
        ios = {}
        for mode in ("gredo", "dual", "single"):
            eng = GredoEngine(db, mode=mode)
            eng.query(q)
            ios[mode] = eng.last_stats.record_fetches
        assert ios["gredo"] <= ios["dual"] <= ios["single"], (qname, ios)


def test_gcdia_pipeline(db):
    eng = GredoEngine(db)
    out = eng.analyze(m2bench.a2_similarity())
    assert out.shape[0] == out.shape[1]
    assert not np.isnan(np.asarray(out)).any()
    # diagonal of cosine self-similarity == 1
    d = np.diag(np.asarray(out))
    np.testing.assert_allclose(d, 1.0, atol=1e-3)


def test_interbuffer_reuse(db):
    eng = GredoEngine(db)
    eng.analyze(m2bench.a3_multiply())
    assert eng.interbuffer.hits == 0
    eng.analyze(m2bench.a3_multiply())
    assert eng.interbuffer.hits == 1


def test_regression_learns_signal(db):
    """A1: the paper's running example — tags predict yogurt purchase."""
    eng = GredoEngine(db)
    r = eng.query(m2bench.q_g1())
    X, groups = analytics.random_access_matrix(
        r, "Customer.id", "t.tid", m2bench.N_TAGS)
    y = m2bench.purchase_labels(db)[groups]
    import jax.numpy as jnp
    w, loss = analytics.regression(X, jnp.asarray(y), iters=50)
    acc = float(((np.asarray(X) @ np.asarray(w) > 0) == (y > 0.5)).mean())
    assert acc > max(float((y > 0.5).mean()), float((y <= 0.5).mean())) - 0.02


def test_shortest_path(db):
    eng = GredoEngine(db)
    d = eng.shortest_path("Follows", "Persons", np.arange(4),
                          "Persons", np.arange(4))
    assert np.array_equal(d, np.zeros(4))  # self-distances


def test_graph_updates(db):
    g = db.graphs["Interested_in"]
    n_edges = g.edges.nrows
    epoch0 = g.epoch
    svid = np.asarray(g.edges.col("svid"))[:2]
    g.delete_edges(np.array([0, 1]))
    # tombstone semantics: edge tids stay stable until compaction, but the
    # live count and every topology read drop the deleted edges immediately
    assert g.n_live_edges == n_edges - 2
    _, _, eids = g.expand(np.arange(g.n_vertices))
    assert len(eids) == n_edges - 2 and 0 not in eids and 1 not in eids
    g.insert_edges({"svid": svid, "tvid": np.array([0, 1]),
                    "weight": np.array([0.5, 0.6])})
    assert g.n_live_edges == n_edges
    assert g.epoch == epoch0 + 2  # every mutation advances the write epoch
    # compaction folds the delta into a fresh base; mappers stay consistent:
    # every adjacency slot maps to a real edge
    g.compact()
    assert not g.delta.has_pending()
    assert g.edges.nrows == n_edges and g.fwd.n_edges == n_edges
    assert g.fwd.edge_id.max() < g.edges.nrows
