"""Opt-in sliding-window attention (the sub-quadratic path documented for
long_500k) and the bonus GCDA dry-run cells on a small mesh."""
import os
import subprocess
import sys
import textwrap

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import TransformerConfig, init_params, forward


def test_window_attention_chunked_equals_dense():
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=96, vocab=128, dtype=jnp.float32,
                            attn_window=8, q_chunk=16, kv_chunk=16)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, 128)
    l1, _ = forward(p, toks, cfg)
    l2, _ = forward(p, toks, dataclasses.replace(cfg, attn_impl="dense"))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_window_actually_masks():
    """Tokens beyond the window must not affect the last position."""
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=32, vocab=64, dtype=jnp.float32,
                            attn_impl="dense", attn_window=4)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    toks2 = toks.at[:, :8].set(11)  # mutate tokens far outside the window
    l1, _ = forward(p, toks, cfg)
    l2, _ = forward(p, toks2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_gcda_cells_lower_on_small_mesh():
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    code = textwrap.dedent("""
        import jax
        from repro.launch.mesh import make_local_mesh
        from repro.launch.specs import build_cell
        mesh = make_local_mesh(2, 4)
        for shape in ("gcda_regression", "gcda_similarity", "gcda_multiply"):
            with mesh:
                cell = build_cell("gredo", shape, mesh)
                c = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(
                    *cell.args).compile()
                assert c.cost_analysis() is not None
        print("OK gcda cells")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
