"""Cost-based optimizer: plan-shape goldens (join reordering, semi-join
siding), CSE node counts, estimate accuracy (bounded q-error), the stats
layer feeding it (NDV / histograms / MCV counts, delta-maintained), and the
cost-aware inter-buffer admission policy."""
import numpy as np
import pytest

from repro.core import GredoEngine, InterBuffer, optimizer, physical
from repro.core.deltastore import DeltaConfig
from repro.core.schema import (AnalyticsTask, GCDIATask, JoinPred, Predicate,
                               Query, chain_pattern)
from repro.core.storage import Database, DictColumn, Graph, Table, compute_stats
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1)


def _rows_multiset(t: Table):
    cols = sorted(t.columns)
    out = []
    for i in range(t.nrows):
        row = []
        for c in cols:
            col = t.col(c)
            v = col.codes[i] if hasattr(col, "codes") else np.asarray(col)[i]
            row.append(v.item() if hasattr(v, "item") else v)
        out.append(tuple(row))
    return sorted(out)


# ---------------------------------------------------------------------------
# Plan-shape goldens: reordering + siding on the skewed 3-join query
# ---------------------------------------------------------------------------

SKEW_NAIVE = """\
Project[Customer.id, t.tid]
  EquiJoin[Product.id=Orders.product_id]
    Alias[Product]
      Select[Product.title == 'Yogurt']
        ScanTable[Product]
    EquiJoin[Orders.customer_id=Customer.id]
      Alias[Orders]
        ScanTable[Orders]
      EquiJoin[Customer.person_id=p.pid]
        Alias[Customer]
          ScanTable[Customer]
        GraphProject[Interested_in keep=p,t]
          MatchPattern[Interested_in dir=rev hops=1 pushed=t:1 deferred=-]"""

SKEW_OPTIMIZED = """\
Project[Customer.id, t.tid]
  EquiJoin[p.pid=Customer.person_id]
    GraphProject[Interested_in keep=p,t]
      MatchPattern[Interested_in dir=rev hops=1 pushed=t:1 deferred=-]
        SemiJoinMask[Persons.pid ∈ person_id]
          PruneCols[id, person_id]
            ScanTable[Customer]
    EquiJoin[Customer.id=Orders.customer_id]
      Alias[Customer]
        ^shared:PruneCols[id, person_id]
      EquiJoin[Orders.product_id=Product.id]
        Alias[Orders]
          PruneCols[customer_id, product_id]
            ScanTable[Orders]
        Alias[Product]
          PruneCols[id]
            Select[Product.title == 'Yogurt']
              ScanTable[Product]"""


def test_skewed_three_join_is_reordered(db):
    """The naive DAG follows the (deliberately bad) query order — graph ⋈
    Customer ⋈ Orders first, the selective Product filter last. The DP
    enumerator flips it to selective-first and (because siding is searched
    jointly with the order) adds the graph-side candidate mask, sharing the
    pruned Customer subtree with the join cluster."""
    eng = GredoEngine(db)
    q = m2bench.q_opt_skew()
    assert physical.explain(eng.physical_plan(q)) == SKEW_NAIVE
    assert physical.explain(eng.optimized_plan(q)) == SKEW_OPTIMIZED
    # and it is semantics-preserving
    naive = GredoEngine(db, enable_optimizer=False).query(q)
    opt = eng.query(q)
    assert _rows_multiset(naive) == _rows_multiset(opt)
    assert any(n.startswith("join-order") for n in eng.last_stats.rewrites)


def test_semi_join_siding_picks_graph_mask_on_g4(db):
    """q_g4's Customer↔pattern join: the cost model picks the graph-side
    candidate mask (Eq. 9/10), an explicit SemiJoinMask child of the match."""
    eng = GredoEngine(db)
    dag = eng.optimized_plan(m2bench.q_g4())
    rendered = physical.explain(dag)
    assert "SemiJoinMask[Persons.pid ∈ person_id]" in rendered
    assert any("semi-join" in n and "graph-side mask" in n
               for n in eng.last_report.notes())


def test_optimizer_preserves_semantics_across_workload(db):
    for qname in ("q_g1", "q_g2", "q_g3", "q_g4", "q_g5", "q_opt_skew",
                  "q_edge_scan", "q_vertex_scan"):
        q = getattr(m2bench, qname)()
        naive = GredoEngine(db, enable_optimizer=False).query(q)
        opt = GredoEngine(db).query(q)
        assert _rows_multiset(naive) == _rows_multiset(opt), qname


def test_build_side_is_the_smaller_input(db):
    """Every EquiJoin in an optimized plan puts the smaller estimated input
    on the right (build/sorted) side of the sort-merge."""
    eng = GredoEngine(db)
    dag = eng.optimized_plan(m2bench.q_opt_skew())
    ests = physical.estimate(dag, db)

    def walk(n):
        if isinstance(n, physical.EquiJoin):
            l, r = n.children
            assert ests[id(r)][0] <= ests[id(l)][0], n.describe()
        for c in n.children:
            walk(c)

    walk(dag)


# ---------------------------------------------------------------------------
# CSE
# ---------------------------------------------------------------------------


def _count_nodes(root):
    seen = set()

    def walk(n):
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            walk(c)

    walk(root)
    return len(seen)


def test_cse_unifies_duplicate_subtrees():
    """Two structurally identical Select(ScanTable) subtrees collapse into
    one shared node; the executor then runs the subtree once."""
    db = m2bench.generate(sf=1)
    ep = db.epoch_of("Customer")
    pred = Predicate("Customer.age", ">=", 30)
    a = physical.Select(physical.ScanTable("Customer", ep), [pred])
    b = physical.Select(physical.ScanTable("Customer", ep), [pred])
    jp = JoinPred("Customer.id", "Customer.id")
    join = physical.EquiJoin(jp, a, b)
    root = physical.Project(("Customer.id",), (("Customer", ep),), join)
    assert _count_nodes(root) == 6
    opt, report = optimizer.optimize(root, db)
    assert _count_nodes(opt) == 4               # one Select+Scan pair shared
    l, r = opt.children[0].children
    assert l is r
    assert any("cse" in n for n in report.notes())


def test_cse_shares_mask_and_cluster_scan(db):
    """In q_g4 the Customer subtree feeds both the semi-join mask and the
    join cluster: after CSE it is literally the same (pruned) node."""
    eng = GredoEngine(db)
    dag = eng.optimized_plan(m2bench.q_g4())
    assert "^shared:" in physical.explain(dag)
    scans = [o for o in _collect_kinds(dag, physical.ScanTable)
             if o.name == "Customer"]
    assert len(scans) == 1


def _collect_kinds(root, cls):
    out, seen = [], set()

    def walk(n):
        if id(n) in seen:
            return
        seen.add(id(n))
        if isinstance(n, cls):
            out.append(n)
        for c in n.children:
            walk(c)

    walk(root)
    return out


# ---------------------------------------------------------------------------
# Selection sink-down (physical-level pushdown, exercised on a dual-mode DAG)
# ---------------------------------------------------------------------------


def test_selection_sinks_below_joins_into_scan(db):
    """A dual-mode DAG carries table predicates as a Residual above the
    joins; optimize() sinks them into a Select directly above the scan."""
    eng = GredoEngine(db, mode="dual")
    q = m2bench.q_g2()
    naive = eng.physical_plan(q)
    assert "Residual" in physical.explain(naive)
    opt, report = optimizer.optimize(naive, db)
    rendered = physical.explain(opt)
    assert "Residual" not in rendered
    assert "Select[Orders.shipping.days <= 3]" in rendered
    assert any("sink-down" in n for n in report.notes())
    r_naive = physical.execute(naive, physical.ExecContext(db))
    r_opt = physical.execute(opt, physical.ExecContext(db))
    assert _rows_multiset(r_naive) == _rows_multiset(r_opt)


# ---------------------------------------------------------------------------
# Table-side semi-join siding (SemiJoinReduce)
# ---------------------------------------------------------------------------


def _wide_key_db(n_tbl=20_000, n_v=40, key_dom=20_000):
    """A tiny vertex set joined against a huge table whose keys mostly miss:
    masking the graph is useless (every vertex stays a candidate), while
    reducing the table by the vertex keys shrinks it ~500x."""
    rng = np.random.default_rng(0)
    db = Database()
    persons = Table("P", {"pid": np.arange(n_v, dtype=np.int64)})
    tags = Table("T", {"tid": np.arange(8, dtype=np.int64)})
    edges = Table("E", {"svid": rng.integers(0, n_v, 200).astype(np.int64),
                        "tvid": rng.integers(0, 8, 200).astype(np.int64)})
    db.add_graph(Graph("G", {"P": persons, "T": tags}, edges, "P", "T"))
    db.add_table(Table("C", {
        "id": np.arange(n_tbl, dtype=np.int64),
        "person_id": rng.integers(0, key_dom, n_tbl).astype(np.int64)}))
    q = Query(select=("C.id", "t.tid"), froms=("C",),
              match=chain_pattern("G", ("p", "P", "E", "t", "T")),
              joins=(JoinPred("C.person_id", "p.pid"),))
    return db, q


def test_semi_join_sides_onto_the_table_when_vertices_are_small():
    db, q = _wide_key_db()
    eng = GredoEngine(db)
    dag = eng.optimized_plan(q)
    rendered = physical.explain(dag)
    assert "SemiJoinReduce[person_id ∈ P.pid]" in rendered
    assert any("table-side reduce" in n for n in eng.last_report.notes())
    naive = GredoEngine(db, enable_optimizer=False).query(q)
    opt = eng.query(q)
    assert _rows_multiset(naive) == _rows_multiset(opt)
    # the reduce actually shrank the join input
    reduce_ops = [o for o in eng.last_stats.operators
                  if o["op"] == "SemiJoinReduce"]
    assert reduce_ops and reduce_ops[0]["rows"] < 20_000 / 100


# ---------------------------------------------------------------------------
# Estimate accuracy: bounded q-error on seeded data
# ---------------------------------------------------------------------------

CHECKED_KINDS = ("ScanTable", "Select", "MatchPattern", "EquiJoin",
                 "GraphProject", "Project", "VertexScan", "EdgeScan")


def test_est_rows_within_bounded_q_error(db):
    """§6.3 estimates against actuals, per operator: q-error (max of
    over/under-estimation factor) stays bounded on the seeded M2Bench data.
    Value-aware selectivity + label-aware hop expansion keep it tight."""
    worst = 0.0
    for qname in ("q_g1", "q_g2", "q_g4", "q_opt_skew", "q_vertex_scan",
                  "q_edge_scan"):
        eng = GredoEngine(db)
        eng.query(getattr(m2bench, qname)())
        ests = eng.last_ests

        def walk(n, seen):
            nonlocal worst
            if id(n) in seen:
                return
            seen.add(id(n))
            if n.kind in CHECKED_KINDS and n.stats.executed \
                    and n.stats.rows and id(n) in ests:
                est = ests[id(n)][0]
                qerr = max(est / n.stats.rows, n.stats.rows / max(est, 1e-9))
                assert qerr <= 16.0, (qname, n.describe(), est, n.stats.rows)
                worst = max(worst, qerr)
            for c in n.children:
                walk(c, seen)

        walk(eng.last_dag, set())
    assert worst < 16.0


def test_root_estimate_close_on_g1(db):
    """The end-to-end cardinality estimate of q_g1 lands within 2x."""
    eng = GredoEngine(db)
    r = eng.query(m2bench.q_g1())
    est = eng.last_ests[id(eng.last_dag)][0]
    assert 0.5 <= est / r.nrows <= 2.0


# ---------------------------------------------------------------------------
# Stats layer: NDV / MCV / histograms, delta maintenance
# ---------------------------------------------------------------------------


def test_dict_column_equality_selectivity_is_value_exact():
    col = DictColumn(values=["a"] * 90 + ["b"] * 9 + ["c"])
    s = compute_stats(col)
    assert s.ndv == 3
    assert s.selectivity(Predicate("t.x", "==", "a")) == pytest.approx(0.9)
    assert s.selectivity(Predicate("t.x", "==", "c")) == pytest.approx(0.01)
    assert s.selectivity(Predicate("t.x", "==", "nope")) == 0.0
    assert s.selectivity(Predicate("t.x", "in", ["b", "c"])) == pytest.approx(0.1)


def test_histogram_range_selectivity():
    rng = np.random.default_rng(0)
    vals = np.concatenate([rng.uniform(0, 1, 9000), rng.uniform(9, 10, 1000)])
    s = compute_stats(vals)
    # a uniform-span model would say ~10% for [0,1]; the histogram knows 90%
    frac = s.selectivity(Predicate("t.x", "range", 0.0, 1.0))
    assert 0.8 <= frac <= 1.0
    frac_hi = s.selectivity(Predicate("t.x", ">", 9.0))
    assert 0.05 <= frac_hi <= 0.15


def test_stats_maintained_across_delta_appends():
    """The merged base ⊕ delta views carry incrementally-maintained stats:
    NDV/min/max/histogram reflect appended rows without an O(base) pass."""
    g = _stats_graph()
    g.insert_vertices("A", {"v": np.array([500.0, 600.0]),
                            "tag": ["z", "x"]})
    vt = g.vertex_tables["A"]
    sv = vt.stats("v")
    assert sv.n == 12 and sv.vmax == 600.0
    assert sv.hist is not None and sv.hist.sum() == pytest.approx(12)
    st = vt.stats("tag")
    assert st.n == 12 and st.value_counts["z"] == 1
    # equality selectivity is exact on the merged view
    assert st.selectivity(Predicate("A.tag", "==", "z")) == pytest.approx(1 / 12)


def _stats_graph():
    vt = Table("A", {"v": np.arange(10, dtype=np.float64),
                     "tag": DictColumn(values=[("x", "y")[i % 2]
                                               for i in range(10)])})
    edges = Table("E", {"svid": np.arange(10, dtype=np.int64) % 5,
                        "tvid": np.arange(10, dtype=np.int64) % 7})
    return Graph("G", {"A": vt}, edges, "A", "A",
                 delta_config=DeltaConfig(auto_compact=False))


def test_live_edge_stats_consistent_with_pending_delta():
    """n_live_edges / avg_out_degree / hop_expansion track pending delta
    segments and tombstones, so the optimizer never plans against a stale
    edge count between compactions."""
    g = _stats_graph()
    e0, d0 = g.n_live_edges, g.avg_out_degree
    assert g.hop_expansion() == pytest.approx(e0 / 10)
    g.insert_edges({"svid": np.array([0, 1]), "tvid": np.array([2, 3])})
    assert g.n_live_edges == e0 + 2
    assert g.avg_out_degree == pytest.approx((e0 + 2) / 10) != d0
    assert g.hop_expansion() == pytest.approx((e0 + 2) / 10)
    g.delete_edges(np.array([0, 1, 2]))
    assert g.n_live_edges == e0 - 1
    assert g.hop_expansion(reverse=True) == pytest.approx((e0 - 1) / 10)
    # vertex inserts change the per-label fan-out denominator too
    g.insert_vertices("A", {"v": np.array([11.0]), "tag": ["x"]})
    assert g.hop_expansion() == pytest.approx((e0 - 1) / 11)


# ---------------------------------------------------------------------------
# Cost-aware inter-buffer admission
# ---------------------------------------------------------------------------


def test_admission_bypasses_cheap_bulky_entries():
    buf = InterBuffer(capacity_bytes=1 << 20, admit_cost_per_byte=1.0)
    big = np.ones((4096,), np.float32)          # 16 KiB
    assert buf.put("cheap", big, est_cost=10.0) is not None
    assert len(buf) == 0 and buf.bypasses == 1  # recompute is cheaper: bypass
    buf.put("costly", big, est_cost=1e9)
    assert len(buf) == 1 and buf.get("costly") is not None
    buf.put("unknown", big)                     # no estimate -> admitted
    assert len(buf) == 2


def test_engine_admission_threshold_bypasses_and_counts():
    """With an absurd threshold every cacheable node bypasses: no reuse on
    the repeated task, and the bypass counter surfaces in explain_last."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db, admit_cost_per_byte=1e12)
    t = GCDIATask(integration=m2bench.q_g1(),
                  analytics=AnalyticsTask(
                      "MULTIPLY", [("rel2matrix", ("Customer.id", "t.tid"))]))
    eng.analyze(t)
    assert len(eng.interbuffer) == 0 and eng.interbuffer.bypasses > 0
    eng.analyze(t)
    assert eng.interbuffer.hits == 0            # nothing was admitted
    assert "bypasses=" in eng.explain_last()


def test_default_admission_keeps_expensive_gcdi_reuse():
    """The default footprint-scaled threshold admits real GCDI/GCDA results:
    the §6.4 reuse ladder still short-circuits repeated tasks."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    t = GCDIATask(integration=m2bench.q_g1(),
                  analytics=AnalyticsTask(
                      "SIMILARITY", [("random", "Customer.id", "t.tid",
                                      m2bench.N_TAGS)]))
    eng.analyze(t)
    assert eng.interbuffer.bypasses == 0
    eng.analyze(t)
    assert eng.last_stats.interbuffer_hit
