"""Storage invariants: doc shredding, ragged/dict columns, CSR topology
(hypothesis property: CSR neighbor expansion == edge-list definition)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.storage import (CSR, Database, DictColumn, Graph,
                                RaggedColumn, Table, build_csr,
                                shred_documents)

pytestmark = pytest.mark.fast


@given(st.integers(2, 30), st.integers(0, 60), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_csr_matches_edge_list(n, e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    csr = build_csr(n, src, dst)
    assert csr.n_vertices == n and csr.n_edges == e
    # per-vertex neighbor multiset equals edge-list definition
    for v in range(n):
        got = sorted(csr.col_idx[csr.row_ptr[v]:csr.row_ptr[v + 1]])
        expect = sorted(dst[src == v])
        assert got == expect
    # edge_id maps adjacency slots back to original edge rows
    for v in range(n):
        for slot in range(csr.row_ptr[v], csr.row_ptr[v + 1]):
            eid = csr.edge_id[slot]
            assert src[eid] == v and dst[eid] == csr.col_idx[slot]


@given(st.integers(1, 20), st.integers(0, 40), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_frontier_expansion(n, e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    csr = build_csr(n, src, dst)
    frontier = rng.integers(0, n, min(n, 5))
    s_rep, d, eid = csr.neighbors(frontier)
    expect = []
    for f in frontier:
        expect += [(f, x) for x in sorted(dst[src == f])]
    assert sorted(zip(s_rep, d)) == sorted(expect)


def test_doc_shredding_paths_and_ragged():
    docs = [
        {"a": 1, "b": {"c": "x", "d": 2.5}, "tags": [1, 2]},
        {"a": 2, "b": {"c": "y"}, "tags": []},
        {"a": 3, "tags": [7]},
    ]
    t = shred_documents("D", docs)
    assert set(t.columns) == {"a", "b.c", "b.d", "tags"}
    assert np.array_equal(np.asarray(t.col("a")), [1, 2, 3])
    assert isinstance(t.col("b.c"), DictColumn)
    assert isinstance(t.col("tags"), RaggedColumn)
    assert list(t.col("tags").row(0)) == [1, 2]
    assert np.isnan(np.asarray(t.col("b.d"))[2])  # absent path -> NaN


def test_ragged_predicate_any_semantics():
    from repro.core.schema import Predicate
    t = shred_documents("D", [{"xs": [1, 5]}, {"xs": [2]}, {"xs": []}])
    mask = t.eval_predicate(Predicate("D.xs", ">=", 5))
    assert list(mask) == [True, False, False]


def test_dict_column_roundtrip():
    c = DictColumn(values=["b", "a", "b", "c"])
    assert list(c.decode(c.codes)) == ["b", "a", "b", "c"]
    assert c.encode("zzz") == -1
    taken = c.take(np.array([0, 3]))
    assert list(taken.decode(taken.codes)) == ["b", "c"]


def test_ragged_take():
    r = RaggedColumn(lists=[[1, 2], [], [3, 4, 5]])
    t = r.take(np.array([2, 0]))
    assert list(t.row(0)) == [3, 4, 5]
    assert list(t.row(1)) == [1, 2]


def test_selectivity_estimates():
    from repro.core.schema import Predicate
    t = Table("T", {"x": np.arange(100)})
    s_eq = t.stats("x").selectivity(Predicate("T.x", "==", 5))
    s_range = t.stats("x").selectivity(Predicate("T.x", "range", 0, 49))
    assert abs(s_eq - 0.01) < 1e-9
    assert 0.4 < s_range < 0.6
