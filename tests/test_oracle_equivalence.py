"""Faithfulness: the vectorized engine equals a LITERAL transcription of the
paper's pseudocode (Algorithm 1 hybrid traversal over linked-list adjacency,
Algorithm 2 stack-DFS pattern matching), on randomized multi-model instances
(hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.pattern import PatternPlan, match, plan_pattern
from repro.core.schema import Predicate, chain_pattern
from repro.core.storage import Graph, Table

import pytest

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# Literal paper structures: linked-list adjacency graph (Definition 4)
# ---------------------------------------------------------------------------


class PaperAdjacencyGraph:
    """Adjacency graph Omega = (N_s, N_t, I) with ``next`` pointers forming
    singly linked out-edge lists, built exactly as Definition 4 describes."""

    def __init__(self, n_vertices, src_nids, dst_nids):
        self.first = [None] * n_vertices          # source node -> first target
        self.t_next = [None] * len(src_nids)      # target node -> next target
        self.t_nid = list(dst_nids)               # target node -> vertex nid
        self.t_edge = list(range(len(src_nids)))  # target node -> edge tid
        for e in range(len(src_nids) - 1, -1, -1):
            s = src_nids[e]
            self.t_next[e] = self.first[s]
            self.first[s] = e

    def emit_neighbors(self, nid):
        """Algorithm 1, Case 3/4: walk the linked list, emit one at a time."""
        t = self.first[nid]
        while t is not None:
            yield self.t_nid[t], self.t_edge[t]
            t = self.t_next[t]


def paper_match(g: Graph, pattern, phi):
    """Algorithm 2, literally: candidate mapping M, DFS stack over partial
    paths, volcano-style emission — tuple at a time."""
    adj = PaperAdjacencyGraph(g.n_vertices, list(g.src_nid), list(g.dst_nid))
    chain = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
    evars = [e.var for e in pattern.edges]

    def vertex_ok(var, nid):
        lbl = pattern.vertex(var).label
        lo, hi = g.label_range(lbl)
        if not (lo <= nid < hi):
            return False
        tbl = g.vertex_tables[lbl]
        vid = nid - lo
        for p in phi.get(var, []):
            if not bool(tbl.eval_predicate(p)[vid]):
                return False
        return True

    def edge_ok(evar, eid):
        for p in phi.get(evar, []):
            if not bool(g.edges.eval_predicate(p)[eid]):
                return False
        return True

    results = []
    lo, hi = g.label_range(pattern.vertex(chain[0]).label)
    for v0 in range(lo, hi):                       # Line 9
        if not vertex_ok(chain[0], v0):
            continue
        stack = [(v0, 0, [v0], [])]                # Line 10
        while stack:                               # Line 11
            cur, i, path_v, path_e = stack.pop()   # Line 12
            if i == len(evars):                    # Line 13
                results.append(tuple(path_v) + tuple(path_e))
                continue
            for nbr, eid in adj.emit_neighbors(cur):   # hybrid traversal emit
                if vertex_ok(chain[i + 1], nbr) and edge_ok(evars[i], eid):
                    stack.append((nbr, i + 1, path_v + [nbr], path_e + [eid]))
    return results


def _vectorized_rows(g, pattern, phi):
    plan = plan_pattern(g, pattern, {k: list(v) for k, v in phi.items()},
                        projected=set())
    rel = match(g, plan)
    chain = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
    evars = [e.var for e in pattern.edges]
    rows = []
    for i in range(rel.nrows):
        vs = tuple(g.nid_of(pattern.vertex(v).label,
                            np.asarray(rel.col(v))[i]) for v in chain)
        es = tuple(int(np.asarray(rel.col(e))[i]) for e in evars)
        rows.append(vs + es)
    return rows


@st.composite
def small_instance(draw):
    n_a = draw(st.integers(2, 6))
    n_b = draw(st.integers(2, 6))
    n_edges = draw(st.integers(1, 15))
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    svid = rng.integers(0, n_a, n_edges)
    tvid = rng.integers(0, n_b, n_edges)
    attr_a = rng.integers(0, 3, n_a)
    attr_b = rng.integers(0, 3, n_b)
    w = rng.integers(0, 10, n_edges)
    return n_a, n_b, svid, tvid, attr_a, attr_b, w


@given(small_instance(),
       st.sampled_from([None, 0, 1, 2]), st.sampled_from([None, 0, 1, 2]),
       st.sampled_from([None, 3, 7]))
@settings(max_examples=40, deadline=None)
def test_match_equals_paper_pseudocode(inst, pa, pb, pe):
    n_a, n_b, svid, tvid, attr_a, attr_b, w = inst
    A = Table("A", {"attr": attr_a})
    B = Table("B", {"attr": attr_b})
    E = Table("E", {"svid": svid, "tvid": tvid, "w": w})
    g = Graph("G", {"A": A, "B": B}, E, "A", "B")
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    phi = {}
    if pa is not None:
        phi["x"] = [Predicate("x.attr", "==", pa)]
    if pb is not None:
        phi["y"] = [Predicate("y.attr", "==", pb)]
    if pe is not None:
        phi["e0"] = [Predicate("e0.w", "<=", pe)]
    expected = sorted(paper_match(g, pattern, phi))
    got = sorted(_vectorized_rows(g, pattern, phi))
    assert expected == got


@given(small_instance(), st.integers(0, 2))
@settings(max_examples=20, deadline=None)
def test_two_hop_homogeneous(inst, pred_val):
    n_a, _, svid, tvid, attr_a, _, w = inst
    # homogeneous graph A->A
    svid = svid % n_a
    tvid = tvid % n_a
    A = Table("A", {"attr": attr_a})
    E = Table("E", {"svid": svid, "tvid": tvid, "w": w})
    g = Graph("G", {"A": A}, E, "A", "A")
    pattern = chain_pattern("G", ("x", "A", "E", "y", "A"),
                            ("y", "A", "E", "z", "A"))
    phi = {"x": [Predicate("x.attr", "==", pred_val)]}
    expected = sorted(paper_match(g, pattern, phi))
    got = sorted(_vectorized_rows(g, pattern, phi))
    assert expected == got
