"""Delta-aware secondary indexes: posting/zone correctness vs. full scans,
incremental maintenance under delta appends / tombstones / compaction
(property-tested), epoch staleness detection, and the optimizer's
cost-based access-path selection (IndexScan / IndexSelect / full scan,
``access=`` provenance)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GredoEngine, physical, traversal
from repro.core.index import ZoneMap
from repro.core.schema import Predicate
from repro.core.storage import Database, DictColumn, Graph, Table
from repro.data import m2bench

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def _mk_graph_db(n_vertices=3000, n_edges=9000, seed=0, name="G"):
    rng = np.random.default_rng(seed)
    verts = Table("V", {
        "vid": np.arange(n_vertices, dtype=np.int64),
        "attr": rng.integers(0, 50, n_vertices),
        "kind": DictColumn(values=[("a", "b", "c")[i % 3]
                                   for i in range(n_vertices)]),
    })
    edges = Table("E", {
        "svid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
        "tvid": rng.integers(0, n_vertices, n_edges).astype(np.int64),
        "w": rng.uniform(0, 1, n_edges),
    })
    g = Graph(name, {"V": verts}, edges, "V", "V")
    db = Database()
    db.add_graph(g)
    return db, g


@pytest.fixture(scope="module")
def dbs():
    """(plain db, indexed db) — identical m2bench content."""
    plain = m2bench.generate(sf=1)
    indexed = m2bench.generate(sf=1)
    m2bench.build_indexes(indexed)
    return plain, indexed


def _rows_multiset(t: Table):
    cols = sorted(t.columns)
    out = []
    for i in range(t.nrows):
        row = []
        for c in cols:
            col = t.col(c)
            v = col.codes[i] if hasattr(col, "codes") else np.asarray(col)[i]
            row.append(v.item() if hasattr(v, "item") else v)
        out.append(tuple(row))
    return sorted(out)


def _scan_rows(tbl: Table, pred) -> np.ndarray:
    return np.nonzero(tbl.eval_predicate(pred))[0]


# ---------------------------------------------------------------------------
# posting structures vs. full scans
# ---------------------------------------------------------------------------


def test_sorted_index_matches_scans_on_every_op():
    db, g = _mk_graph_db()
    im = db.indexes
    im.create("G", "attr", label="V")
    tbl = g.vertex_tables["V"]
    for pred in (Predicate("v.attr", "==", 7),
                 Predicate("v.attr", "in", (3, 5, 49)),
                 Predicate("v.attr", "range", 10, 20),
                 Predicate("v.attr", "<", 5),
                 Predicate("v.attr", "<=", 5),
                 Predicate("v.attr", ">", 44),
                 Predicate("v.attr", ">=", 44)):
        got = np.sort(im.lookup("G", pred, label="V"))
        assert np.array_equal(got, _scan_rows(tbl, pred)), pred


def test_hash_index_matches_scans_and_misses_cleanly():
    db, g = _mk_graph_db()
    im = db.indexes
    idx = im.create("G", "kind", label="V")
    assert idx.kind == "hash"
    tbl = g.vertex_tables["V"]
    for pred in (Predicate("v.kind", "==", "b"),
                 Predicate("v.kind", "in", ("a", "c"))):
        got = np.sort(im.lookup("G", pred, label="V"))
        assert np.array_equal(got, _scan_rows(tbl, pred))
    assert len(im.lookup("G", Predicate("v.kind", "==", "zzz"), label="V")) == 0
    # range ops are not servable from a hash index
    assert im.lookup("G", Predicate("v.kind", ">", "a"), label="V") is None


def test_table_index_and_unsupported_column():
    db = Database()
    db.add_table(Table("T", {"k": np.arange(100, dtype=np.int64),
                             "s": DictColumn(values=[str(i % 7)
                                                     for i in range(100)])}))
    im = db.indexes
    im.create("T", "k")
    p = Predicate("T.k", "range", 10, 19)
    assert np.array_equal(np.sort(im.lookup("T", p)), np.arange(10, 20))
    with pytest.raises(ValueError):
        im.create("T", "s", kind="sorted")    # dict column can't sort-index
    with pytest.raises(ValueError):
        im.create("T", "s", kind="zone")      # ... and has no zone maps
    assert im.lookup("T", Predicate("T.missing_kind", "==", 1)) is None


# ---------------------------------------------------------------------------
# zone maps
# ---------------------------------------------------------------------------


def test_zone_maps_prune_clustered_and_handle_nan():
    vals = np.arange(10_000, dtype=np.float64)
    zm = ZoneMap(vals, chunk=1024)
    p = Predicate("T.x", "range", 2000, 2100)
    cand = zm.candidate_chunks(p)
    assert cand.sum() <= 2 and 0.0 < zm.fraction(p) < 0.3
    assert np.array_equal(zm.masked_eval(vals, p),
                          (vals >= 2000) & (vals <= 2100))
    assert np.array_equal(zm.matching_rows(vals, p),
                          np.arange(2000, 2101))
    # NaN rows never match and all-NaN chunks are never candidates
    vals2 = vals.copy()
    vals2[:1024] = np.nan
    zm2 = ZoneMap(vals2, chunk=1024)
    p2 = Predicate("T.x", "<", 5000)
    assert not zm2.candidate_chunks(p2)[0]
    assert np.array_equal(zm2.masked_eval(vals2, p2), vals2 < 5000)


def test_zone_map_extend_absorbs_partial_chunks():
    zm = ZoneMap(np.arange(1500, dtype=np.float64), chunk=1024)
    zm.extend(np.arange(1500, 2600, dtype=np.float64))
    assert zm.n == 2600 and zm.n_chunks == 3
    vals = np.arange(2600, dtype=np.float64)
    p = Predicate("T.x", ">=", 2550)
    assert np.array_equal(zm.matching_rows(vals, p), np.arange(2550, 2600))


# ---------------------------------------------------------------------------
# delta-aware maintenance: property tests under random mutation streams
# ---------------------------------------------------------------------------


@st.composite
def mutation_script(draw):
    ops = []
    for _ in range(draw(st.integers(3, 7))):
        kind = draw(st.sampled_from(("verts", "edges", "delete", "compact")))
        ops.append((kind, draw(st.integers(1, 60)), draw(st.integers(0, 10**6))))
    return ops


@settings(max_examples=20, deadline=None)
@given(mutation_script())
def test_index_equals_scan_under_random_mutations(ops):
    """Index-backed lookups ≡ full scans after every mutation: delta
    appends, tombstone deletes, and mid-sequence compactions."""
    db, g = _mk_graph_db(n_vertices=400, n_edges=1200)
    im = db.indexes
    im.create("G", "attr", label="V")
    im.create("G", "kind", label="V")
    im.create("G", "w")
    pv = Predicate("v.attr", "range", 10, 30)
    pk = Predicate("v.kind", "==", "b")
    pe = Predicate("e.w", ">", 0.8)
    for kind, size, seed in ops:
        rng = np.random.default_rng(seed)
        if kind == "verts":
            n0 = g.vertex_tables["V"].nrows
            g.insert_vertices("V", {
                "vid": np.arange(n0, n0 + size, dtype=np.int64),
                "attr": rng.integers(0, 50, size),
                "kind": [("a", "b", "c")[i % 3] for i in range(size)]})
        elif kind == "edges":
            n = g.vertex_tables["V"].nrows
            g.insert_edges({"svid": rng.integers(0, n, size).astype(np.int64),
                            "tvid": rng.integers(0, n, size).astype(np.int64),
                            "w": rng.uniform(0, 1, size)})
        elif kind == "delete":
            tids = rng.integers(0, g.edges.nrows, size)
            g.delete_edges(tids)
        else:
            g.compact()
        vt = g.vertex_tables["V"]
        assert np.array_equal(np.sort(im.lookup("G", pv, label="V")),
                              _scan_rows(vt, pv))
        assert np.array_equal(np.sort(im.lookup("G", pk, label="V")),
                              _scan_rows(vt, pk))
        live = _scan_rows(g.edges, pe)
        live = live[g.live_edge_mask()[live]]   # index is tombstone-filtered
        assert np.array_equal(np.sort(im.lookup("G", pe)), live)


def test_maintenance_is_incremental_and_rebuilds_only_at_compact():
    db, g = _mk_graph_db()
    im = db.indexes
    idx = im.create("G", "attr", label="V")
    p = Predicate("v.attr", "==", 11)
    im.lookup("G", p, label="V")
    assert idx.refreshes == 0 and idx.rebuilds == 0
    n0 = g.vertex_tables["V"].nrows
    g.insert_vertices("V", {"vid": np.arange(n0, n0 + 10, dtype=np.int64),
                            "attr": np.full(10, 11),
                            "kind": ["a"] * 10})
    got = np.sort(im.lookup("G", p, label="V"))
    assert idx.refreshes == 1 and idx.rebuilds == 0     # absorbed, not rebuilt
    assert set(range(n0, n0 + 10)) <= set(got.tolist())
    g.compact()     # pure merge: epoch unchanged -> postings stay valid
    assert np.array_equal(np.sort(im.lookup("G", p, label="V")),
                          _scan_rows(g.vertex_tables["V"], p))
    assert idx.rebuilds == 0
    # the first write after a compaction hits the base-snapshot token
    # mismatch: full rebuild (the only one), not an incremental absorb
    n1 = g.vertex_tables["V"].nrows
    g.insert_vertices("V", {"vid": np.array([n1]), "attr": np.array([11]),
                            "kind": ["b"]})
    got = np.sort(im.lookup("G", p, label="V"))
    assert idx.rebuilds == 1
    assert np.array_equal(got, _scan_rows(g.vertex_tables["V"], p))


def test_stale_epoch_is_refreshed_not_reused():
    """Epoch stamping: a bumped source epoch forces a refresh before the
    postings are read — a stale index is detected, never silently wrong."""
    db, g = _mk_graph_db()
    im = db.indexes
    idx = im.create("G", "attr", label="V")
    stamped = idx.epoch
    n0 = g.vertex_tables["V"].nrows
    g.insert_vertices("V", {"vid": np.array([n0]), "attr": np.array([49]),
                            "kind": ["c"]})
    assert db.epoch_of("G") != stamped      # write bumped the epoch
    rows = im.lookup("G", Predicate("v.attr", "==", 49), label="V")
    assert n0 in rows.tolist()              # lookup saw the refreshed index
    assert idx.epoch == db.epoch_of("G")


def test_table_replacement_rebuilds():
    db = Database()
    db.add_table(Table("T", {"k": np.arange(50, dtype=np.int64)}))
    im = db.indexes
    idx = im.create("T", "k")
    db.add_table(Table("T", {"k": np.arange(50, 100, dtype=np.int64)}))
    assert np.array_equal(im.lookup("T", Predicate("T.k", "==", 75)),
                          np.array([25]))
    assert idx.rebuilds == 1


def test_tombstoned_edges_filtered_from_postings():
    db, g = _mk_graph_db()
    im = db.indexes
    p = Predicate("e.w", ">=", 0.0)     # matches every live edge
    im.create("G", "w")
    before = im.lookup("G", p)
    g.delete_edges(np.array([0, 1, 2]))
    after = im.lookup("G", p)
    assert len(after) == len(before) - 3
    assert not ({0, 1, 2} & set(after.tolist()))


# ---------------------------------------------------------------------------
# cost-based access-path selection + the physical operators
# ---------------------------------------------------------------------------


def test_optimizer_picks_index_scan_and_reports_access(dbs):
    _, indexed = dbs
    pid, oid = m2bench.point_lookup_keys(indexed)
    eng = GredoEngine(indexed)
    eng.query(m2bench.q_point_lookup(pid, oid))
    out = eng.explain_last()
    assert "IndexScan[Customer" in out and "access=sorted" in out
    assert "IndexSelect[Orders" in out and "access=zone" in out
    assert "access=index-seed[p]" in out
    assert any(n.startswith("access-path") for n in eng.last_stats.rewrites)


def test_unservable_predicate_stays_full_scan(dbs):
    _, indexed = dbs
    # != cannot be served from postings or pruned by zone maps
    from repro.core.schema import Query
    q2 = Query(select=("Customer.id",), froms=("Customer",),
               joins=(), where=(Predicate("Customer.person_id", "!=", 3),))
    eng = GredoEngine(indexed)
    eng.query(q2)
    out = eng.explain_last()
    assert "IndexScan" not in out and "access=full-scan" in out


def test_index_and_fullscan_agree_on_fixture_queries(dbs):
    plain, indexed = dbs
    pid, oid = m2bench.point_lookup_keys(indexed)
    for q in (m2bench.q_point_lookup(pid, oid), m2bench.q_range_narrow(),
              m2bench.q_g1(), m2bench.q_g4()):
        r_plain = GredoEngine(plain).query(q)
        r_idx = GredoEngine(indexed).query(q)
        assert _rows_multiset(r_plain) == _rows_multiset(r_idx)


def test_index_seeding_reduces_record_fetches(dbs):
    plain, indexed = dbs
    pid, oid = m2bench.point_lookup_keys(indexed)
    q = m2bench.q_point_lookup(pid, oid)
    e_plain, e_idx = GredoEngine(plain), GredoEngine(indexed)
    e_plain.query(q)
    io_plain = e_plain.last_stats.record_fetches
    e_idx.query(q)
    io_idx = e_idx.last_stats.record_fetches
    assert io_idx < io_plain / 5, (io_idx, io_plain)


def test_index_scan_falls_back_when_index_dropped(dbs):
    _, indexed = dbs
    pid, oid = m2bench.point_lookup_keys(indexed)
    q = m2bench.q_point_lookup(pid, oid)
    eng = GredoEngine(indexed)
    want = _rows_multiset(eng.query(q))
    dag = eng.optimized_plan(q)     # plan carries IndexScan/IndexSelect
    im = indexed.indexes
    im.drop("Customer", "person_id")
    im.drop("Orders", "order_id")
    try:
        got = physical.execute(dag, physical.ExecContext(indexed))
        assert _rows_multiset(got) == want      # degraded to scans, not wrong
    finally:
        im.create("Customer", "person_id")
        im.create("Orders", "order_id", kind="zone")


def test_estimates_cover_index_operators(dbs):
    _, indexed = dbs
    pid, oid = m2bench.point_lookup_keys(indexed)
    dag = GredoEngine(indexed).optimized_plan(m2bench.q_point_lookup(pid, oid))
    ests = physical.estimate(dag, indexed)
    kinds = set()

    def walk(n):
        kinds.add(n.kind)
        for c in n.children:
            walk(c)

    walk(dag)
    assert "IndexScan" in kinds and "IndexSelect" in kinds
    assert all(np.isfinite(r + c) and r >= 0 and c >= 0
               for r, c in ests.values())


def test_small_labels_skip_the_index_machinery(dbs):
    """Below MIN_INDEX_ROWS a vectorized scan wins: the Tags-side range
    predicate stays on the mask-scan path even though an index exists."""
    _, indexed = dbs
    eng = GredoEngine(indexed)
    eng.query(m2bench.q_range_narrow())
    assert "access=mask-scan" in eng.explain_last()
