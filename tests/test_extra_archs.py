"""Extra pool architectures beyond the assignment (GAT: SDDMM/edge-softmax
regime; DCN-v2: low-rank cross network) — smoke + learning tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import random_feature_graph
from repro.models.dcn_v2 import DCNv2Config
from repro.models import dcn_v2
from repro.models.gnn.gat import GATConfig
from repro.models.gnn import gat


def test_gat_smoke_and_learns():
    cfg = GATConfig(n_layers=2, d_hidden=16, n_heads=4, d_in=24, n_classes=4)
    g, labels = random_feature_graph(60, 240, 24, 4, seed=3)
    p = gat.init_params(jax.random.PRNGKey(0), cfg)
    logits = gat.forward(p, g, cfg)
    assert logits.shape == (60, 4)
    assert bool(jnp.isfinite(logits).all())
    loss0 = float(gat.loss_fn(p, g, labels, cfg))
    for _ in range(8):
        gr = jax.grad(lambda pp: gat.loss_fn(pp, g, labels, cfg))(p)
        p = jax.tree.map(lambda a, b: a - 0.3 * b, p, gr)
    assert float(gat.loss_fn(p, g, labels, cfg)) < loss0


def test_gat_v1_variant():
    cfg = GATConfig(n_layers=1, d_hidden=8, n_heads=2, d_in=8, n_classes=3,
                    v2=False)
    g, labels = random_feature_graph(20, 60, 8, 3, seed=4)
    p = gat.init_params(jax.random.PRNGKey(0), cfg)
    assert bool(jnp.isfinite(gat.forward(p, g, cfg)).all())


def test_dcn_v2_smoke_and_learns():
    cfg = DCNv2Config(vocab_per_field=500, embed_dim=4, n_sparse=6,
                      n_dense=3, cross_rank=8, mlp=(16, 8))
    p = dcn_v2.init_params(jax.random.PRNGKey(0), cfg)
    batch = dcn_v2.random_batch(cfg, 128, seed=5)
    sig = (np.asarray(batch["sparse"][:, 0]) % 2).astype(np.float32)
    batch = dict(batch, labels=jnp.asarray(sig))
    loss0 = float(dcn_v2.loss_fn(p, batch, cfg))
    for _ in range(60):
        gr = jax.grad(dcn_v2.loss_fn)(p, batch, cfg)
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, gr)
    assert float(dcn_v2.loss_fn(p, batch, cfg)) < loss0 - 0.02
