"""Test-session configuration.

Registers the ``slow`` mark and installs a minimal fallback implementation
of the ``hypothesis`` API when the real package is unavailable (the tier-1
environment ships without it). The fallback draws a fixed number of
pseudo-random examples per test from a deterministic seed — no shrinking,
no database — which is enough for the property tests in this repo (they
only use ``given``/``settings`` and the ``integers``/``booleans``/
``sampled_from``/``composite`` strategies).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line(
        "markers",
        "fast: quick core-engine tier (storage/planner/physical/optimizer/"
        "cardinality) — run with `make test-fast` / `pytest -m fast`")


def _install_hypothesis_stub():
    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def composite(fn):
        def build(*args, **kwargs):
            def draw_fn(rng):
                def draw(strategy):
                    return strategy.example(rng)
                return fn(draw, *args, **kwargs)
            return _Strategy(draw_fn)
        return build

    def settings(max_examples=100, deadline=None, **_ignored):
        def deco(fn):
            fn._stub_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                conf = getattr(wrapper, "_stub_settings", None) or getattr(
                    fn, "_stub_settings", {})
                n = conf.get("max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    args = [s.example(rng) for s in strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)
            # hide the original signature so pytest does not mistake drawn
            # parameters for fixtures
            wrapper.__signature__ = inspect.Signature()
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper
        return deco

    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.booleans = booleans
    strategies_mod.sampled_from = sampled_from
    strategies_mod.composite = composite

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies_mod
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies_mod


try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
