"""RecSys model + checkpoint/fault-tolerance substrate."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.distributed.fault import FailureInjector, StepWatchdog
from repro.models import recsys


@pytest.fixture(scope="module")
def rs():
    cfg = configs.get("wide_deep").smoke_config()
    p = recsys.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, p


def test_recsys_train_improves(rs):
    cfg, p = rs
    batch = recsys.random_batch(cfg, 256, seed=1)
    # plant signal: label = f(first sparse field)
    sig = (np.asarray(batch["sparse"][:, 0]) % 2).astype(np.float32)
    batch = dict(batch, labels=jnp.asarray(sig))
    loss0 = float(recsys.loss_fn(p, batch, cfg))
    for _ in range(30):
        g = jax.grad(recsys.loss_fn)(p, batch, cfg)
        p = jax.tree.map(lambda a, gr: a - 0.5 * gr, p, g)
    loss1 = float(recsys.loss_fn(p, batch, cfg))
    assert loss1 < loss0 - 0.05


def test_retrieval_topk_matches_bruteforce(rs):
    cfg, p = rs
    batch = recsys.random_batch(cfg, 4, seed=2)
    cands = jnp.asarray(np.random.default_rng(3).standard_normal(
        (300, cfg.tower_dim)), jnp.float32)
    vals, idx = recsys.retrieval_step(p, batch["dense"], batch["sparse"],
                                      cands, cfg, top_k=10)
    q = recsys.user_tower(p, batch["dense"], batch["sparse"], cfg)
    qn = np.asarray(q) / np.linalg.norm(np.asarray(q), axis=1, keepdims=True)
    cn = np.asarray(cands) / np.linalg.norm(np.asarray(cands), axis=1,
                                            keepdims=True)
    brute = qn @ cn.T
    for b in range(4):
        expect = set(np.argsort(-brute[b])[:10].tolist())
        assert set(np.asarray(idx[b]).tolist()) == expect


def test_wide_hash_in_range(rs):
    cfg, p = rs
    batch = recsys.random_batch(cfg, 64, seed=4)
    ids = recsys._hash_cross(batch["sparse"], cfg.wide_hash)
    assert int(jnp.min(ids)) >= 0 and int(jnp.max(ids)) < cfg.wide_hash


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(5, dtype=jnp.float32),
             "nested": {"b": jnp.ones((2, 3))}, "lst": [jnp.zeros(2)]}
    cm.save(3, state, metadata={"note": "x"})
    target = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, meta = cm.restore(target)
    assert meta["step"] == 3 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"x": jnp.full((2,), s, jnp.float32)})
    steps = [s for s, _ in cm.checkpoints()]
    assert steps == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": jnp.zeros(4)}, blocking=False)
    cm.wait()
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp.npz") for n in names)
    assert any(n == "step_0000000001.npz" for n in names)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        cm.restore({"x": jnp.zeros((5,))})


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0, warmup=2)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)
    assert not wd.observe(11, 0.11)
    assert wd.straggler_steps == [10]


def test_failure_injector_fires_once():
    fi = FailureInjector(fail_at=(5,))
    fi.maybe_fail(4)
    with pytest.raises(RuntimeError):
        fi.maybe_fail(5)
    fi.maybe_fail(5)  # second pass is clean (restart can proceed)


def test_elastic_reshard_identity():
    from repro.distributed.elastic import reshard_state
    import jax.sharding as jsh
    state = {"w": jnp.arange(8.0)}
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jsh.NamedSharding(mesh, jsh.PartitionSpec())}
    out = reshard_state(state, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))
