"""Fused traversal kernel family + device access path: kernel == jnp oracle
== per-hop jit matcher == host engine (property-tested), overflow retry,
epoch-staleness discipline, optimizer lowering, runtime fallback, batched
point lookups, and roofline attribution of the kernel spans."""
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GredoEngine, optimizer, physical
from repro.core.pattern import match, plan_pattern
from repro.core.pattern_jit import (COUNTERS, DevicePatternMatcher,
                                    StaleSnapshotError, device_match,
                                    get_matcher)
from repro.core.schema import Predicate, chain_pattern
from repro.core.storage import Graph, Table
from repro.data import m2bench
from repro.kernels.traversal import ops as kops
from repro.kernels.traversal import ref as kref
from repro.kernels.traversal import traversal as kern


def _mk_graph(seed, n_a=20, n_b=10, n_e=80):
    rng = np.random.default_rng(seed)
    A = Table("A", {"attr": rng.integers(0, 3, n_a)})
    B = Table("B", {"attr": rng.integers(0, 3, n_b)})
    E = Table("E", {"svid": rng.integers(0, n_a, n_e),
                    "tvid": rng.integers(0, n_b, n_e),
                    "w": rng.integers(0, 10, n_e)})
    return Graph("G", {"A": A, "B": B}, E, "A", "B")


def _rows(t: Table):
    cols = sorted(t.columns)
    out = []
    for i in range(t.nrows):
        row = []
        for c in cols:
            col = t.col(c)
            v = col.codes[i] if hasattr(col, "codes") else np.asarray(col)[i]
            row.append(v.item() if hasattr(v, "item") else v)
        out.append(tuple(row))
    return sorted(out)


# ---------------------------------------------------------------------------
# Kernel (interpret mode) == jnp oracle, single and batched
# ---------------------------------------------------------------------------


def _random_hop_inputs(seed, n=12, chunk=8):
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, 9, n)
    row_ptr = np.zeros(n + 1, np.int64)
    row_ptr[1:] = np.cumsum(deg)
    m = int(row_ptr[-1])
    col_idx = rng.integers(0, n, m)
    edge_id = rng.permutation(m)
    member = rng.random(n) < 0.7
    edge_pred = rng.random(max(m, 1)) < 0.6
    nch = max(-(-max(m, 1) // chunk), 1)
    chunk_alive = np.ones(nch, bool)
    # kill chunks with no surviving predicate rows (what zone maps compute)
    for c in range(nch):
        if not edge_pred[c * chunk:(c + 1) * chunk].any():
            chunk_alive[c] = False
    return row_ptr, col_idx, edge_id, member, edge_pred, chunk_alive


@pytest.mark.parametrize("seed,capacity", [(0, 128), (1, 128), (2, 256)])
def test_fused_hop_kernel_matches_ref(seed, capacity):
    rp, ci, ei, mem, ep, ca = _random_hop_inputs(seed)
    rng = np.random.default_rng(seed + 100)
    n = len(rp) - 1
    C0 = 6
    frontier = np.zeros(capacity, np.int32)
    frontier[:C0] = rng.integers(0, n, C0)
    fmask = np.zeros(capacity, bool)
    fmask[:C0] = True
    kw = dict(capacity=capacity, chunk=8)
    r = kref.fused_hop_ref(rp, ci, ei, frontier, fmask, mem, ep, ca, **kw)
    k = kern.fused_hop(rp, ci, ei, frontier, fmask, mem, ep, ca,
                       interpret=True, **kw)
    for a, b in zip(r, k):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_hop_kernel_matches_ref():
    rp, ci, ei, mem, ep, ca = _random_hop_inputs(7)
    rng = np.random.default_rng(7)
    n, B, capacity = len(rp) - 1, 5, 128
    frontiers = np.zeros((B, capacity), np.int32)
    fmasks = np.zeros((B, capacity), bool)
    for q in range(B):
        c0 = rng.integers(1, 8)
        frontiers[q, :c0] = rng.integers(0, n, c0)
        fmasks[q, :c0] = True
    kw = dict(capacity=capacity, chunk=8)
    r = kref.batched_hop_ref(rp, ci, ei, frontiers, fmasks, mem, ep, ca, **kw)
    k = kern.batched_hop(rp, ci, ei, frontiers, fmasks, mem, ep, ca,
                         interpret=True, **kw)
    for a, b in zip(r, k):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Property test: host == per-hop jit == fused pallas path, including
# tombstone-then-compact write bursts and overflow-forcing capacities
# ---------------------------------------------------------------------------


@given(st.integers(0, 5000), st.sampled_from([None, 0, 1, 2]),
       st.sampled_from([None, 3, 7]), st.booleans())
@settings(max_examples=12, deadline=None)
def test_three_way_equivalence(seed, vpred, wcut, delete_some):
    g = _mk_graph(seed)
    if delete_some:
        rng = np.random.default_rng(seed + 1)
        g.delete_edges(rng.choice(g.edges.nrows, 9, replace=False))
        g.compact()     # device snapshots read base CSRs only
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    phi = {}
    if vpred is not None:
        phi["y"] = [Predicate("y.attr", "==", vpred)]
    if wcut is not None:
        phi["e0"] = [Predicate("e0.w", "<=", wcut)]
    plan = plan_pattern(g, pattern, {k: list(v) for k, v in phi.items()},
                        projected=set(), force_reverse=False,
                        enable_pushdown=True)
    host = _rows(match(g, plan))
    jit_rel, _ = device_match(g, plan, flavor="jit", initial_capacity=128)
    pal_rel, kargs = device_match(g, plan, flavor="pallas",
                                  initial_capacity=128)
    assert _rows(jit_rel) == host
    assert _rows(pal_rel) == host
    assert kargs["flops"] > 0 and kargs["bytes"] > 0


def test_pallas_kernel_path_matches_host():
    """Force the actual Pallas kernel (interpret mode on CPU) through
    device_match, not just its jnp oracle."""
    g = _mk_graph(42)
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    phi = {"y": [Predicate("y.attr", "==", 1)]}
    plan = plan_pattern(g, pattern, phi, projected=set(),
                        force_reverse=False, enable_pushdown=True)
    host = _rows(match(g, plan))
    rel, _ = device_match(g, plan, flavor="pallas", initial_capacity=128,
                          use_kernel=True)
    assert _rows(rel) == host


# ---------------------------------------------------------------------------
# Overflow retry: capacity doubling is counted per flavor and per capacity
# ---------------------------------------------------------------------------


def test_jit_overflow_retry_counts_recompiles():
    g = _mk_graph(3)
    m = DevicePatternMatcher(g, initial_capacity=16)   # frontier is 20 wide
    lo, hi = g.label_range("A")
    m.match_chain(np.arange(lo, hi), [None], [None])
    assert m.recompiles >= 1
    assert m.last_capacity > 16


def test_pallas_overflow_retry_counts_capacities():
    g = _mk_graph(4, n_e=500)          # ~500 candidates >> capacity 128
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    plan = plan_pattern(g, pattern, {}, projected=set(),
                        force_reverse=False, enable_pushdown=True)
    before = COUNTERS.retries
    rel, _ = device_match(g, plan, flavor="pallas", initial_capacity=128)
    assert COUNTERS.retries > before
    assert any(cap > 128 for cap in COUNTERS.retry_caps)
    assert _rows(rel) == _rows(match(g, plan))


# ---------------------------------------------------------------------------
# Epoch-staleness discipline: refuse on pending deltas, refresh on compaction
# ---------------------------------------------------------------------------


def test_stale_snapshot_refused_then_refreshed():
    g = _mk_graph(5)
    m = get_matcher(g)
    lo, hi = g.label_range("A")
    epoch0 = m.epoch
    g.insert_edges({"svid": np.array([0, 1]), "tvid": np.array([0, 1]),
                    "w": np.array([1, 2])})
    with pytest.raises(StaleSnapshotError):
        m.match_chain(np.arange(lo, hi), [None], [None])
    # the fused flavor refuses through the same snapshot
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    plan = plan_pattern(g, pattern, {}, projected=set(),
                        force_reverse=False, enable_pushdown=True)
    with pytest.raises(StaleSnapshotError):
        device_match(g, plan, flavor="pallas")
    g.compact()
    cols, _ = m.match_chain(np.arange(lo, hi), [None], [None])
    assert m.epoch == g.epoch > epoch0
    assert m.refreshes >= 1
    assert len(cols[0]) == g.n_live_edges     # unconstrained 1-hop == edges


# ---------------------------------------------------------------------------
# Optimizer lowering + runtime fallback + telemetry plumbing (m2bench)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1)


def test_engine_lowers_selective_chain_to_device(db):
    eng = GredoEngine(db)
    q = m2bench.q_g3()
    dag = eng.optimized_plan(q)
    rendered = physical.explain(dag)
    assert "DeviceMatchPattern" in rendered
    assert "via device-pallas" in rendered
    assert any("access-path" in n and "device-pallas" in n
               for n in eng.last_report.notes())
    opt = eng.query(q)
    optimizer.DEVICE_MATCH = False
    try:
        host = GredoEngine(db).query(q)
    finally:
        optimizer.DEVICE_MATCH = True
    assert _rows(opt) == _rows(host)


def test_runtime_fallback_on_pending_delta():
    g = _mk_graph(6)
    db1 = SimpleNamespace(graphs={"G": g})
    pattern = chain_pattern("G", ("x", "A", "E", "y", "B"))
    plan = plan_pattern(g, pattern, {}, projected=set(),
                        force_reverse=False, enable_pushdown=True)
    node = physical.DeviceMatchPattern("G", g.epoch, plan, capacity=128)
    g.insert_edges({"svid": np.array([2]), "tvid": np.array([2]),
                    "w": np.array([5])})
    out = node.run(SimpleNamespace(db=db1))
    assert node.access == "host-fallback"
    assert _rows(out) == _rows(match(g, plan))


def test_device_query_registry_delta_and_explain(db):
    eng = GredoEngine(db, telemetry=True)
    eng.query(m2bench.q_g3())
    d = eng.last_registry_delta
    assert d.get("traversal_kernels.matches", 0) >= 1
    assert d.get("traversal_kernels.kernel.launches", 0) >= 1
    txt = eng.explain_last()
    assert "traversal kernels (this query):" in txt
    assert "via device-pallas" in txt


def test_roofline_rows_from_profile_trace(db):
    from benchmarks import roofline
    eng = GredoEngine(db)
    eng.enable_telemetry()
    eng.query(m2bench.q_g3())
    events = eng.telemetry.collector.to_chrome()["traceEvents"]
    rows = [r for r in roofline.from_trace(events)
            if r["op"] == "DeviceMatchPattern"]
    assert rows, "device match span missing flops/bytes payload"
    r = rows[0]
    assert r["flops"] > 0 and r["bytes"] > 0
    assert r["achieved_gflops"] > 0 and r["roof_gflops"] > 0
    assert 0 <= r["roofline_frac"]


# ---------------------------------------------------------------------------
# Batched point lookups: one launch == B sequential single-query chains
# ---------------------------------------------------------------------------


def test_batched_traverse_matches_per_query_chains():
    g = _mk_graph(8, n_a=80, n_b=40, n_e=400)
    m = get_matcher(g)
    rp, ci, ei = m.csr(False)
    lo, hi = g.label_range("A")
    starts = np.arange(lo, min(lo + 64, hi), dtype=np.int64)
    assert len(starts) == 64
    members = [None]
    epreds = [np.asarray(g.edges.col("w")) <= 5]
    cals = [None]
    kw = dict(capacity=128, chunk=8)
    bv, be, counts, ok = kops.batched_traverse(
        rp, ci, ei, g.n_vertices, g.edges.nrows, starts, members, epreds,
        cals, **kw)
    assert ok
    for qi, s in enumerate(starts):
        sv, se, sok = kops.traverse_chain(
            rp, ci, ei, g.n_vertices, g.edges.nrows, np.array([s]),
            members, epreds, cals, **kw)
        assert sok
        k = counts[qi]
        assert len(sv[0]) == k
        for col_b, col_s in zip(bv, sv):
            np.testing.assert_array_equal(col_b[qi, :k], col_s)
        for col_b, col_s in zip(be, se):
            np.testing.assert_array_equal(col_b[qi, :k], col_s)
