"""GNN models: equivariance properties (hypothesis over random rotations),
gradient sanity, sampler static shapes, SO(3) machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.gnn import equiformer_v2 as eqv2
from repro.models.gnn import gatedgcn, mace, pna, so3
from repro.models.gnn.common import GraphBatch


def _random_graph3d(seed, n=16, e=48, n_species=8):
    rng = np.random.default_rng(seed)
    pos = rng.standard_normal((n, 3)) * 2
    src = rng.integers(0, n, e)
    dst = (src + rng.integers(1, n, e)) % n          # no self loops
    species = rng.integers(0, n_species, n)
    return pos, src, dst, species


def _rotation(seed):
    rng = np.random.default_rng(seed)
    a, b, c = rng.uniform(0, 2 * np.pi, 3)
    return so3._rot_z(a) @ so3._rot_y(b) @ so3._rot_z(c)


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_mace_rotation_invariance(gseed, rseed):
    pos, src, dst, species = _random_graph3d(gseed)
    R = _rotation(rseed)
    cfg = mace.MACEConfig(channels=8, n_species=8)
    p = mace.init_params(jax.random.PRNGKey(0), cfg)
    g1 = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                    pos=jnp.asarray(pos, jnp.float32),
                    species=jnp.asarray(species))
    g2 = GraphBatch(src=g1.src, dst=g1.dst,
                    pos=jnp.asarray(pos @ R.T, jnp.float32),
                    species=g1.species)
    e1, e2 = mace.forward(p, g1, cfg), mace.forward(p, g2, cfg)
    np.testing.assert_allclose(e1, e2, rtol=2e-3, atol=1e-4)


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_eqv2_rotation_invariance(gseed, rseed):
    pos, src, dst, species = _random_graph3d(gseed)
    R = _rotation(rseed)
    cfg = eqv2.EquiformerV2Config(n_layers=2, channels=8, l_max=4, m_max=2,
                                  n_heads=4, n_species=8)
    p = eqv2.init_params(jax.random.PRNGKey(0), cfg)
    g1 = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                    pos=jnp.asarray(pos, jnp.float32),
                    species=jnp.asarray(species))
    g2 = GraphBatch(src=g1.src, dst=g1.dst,
                    pos=jnp.asarray(pos @ R.T, jnp.float32),
                    species=g1.species)
    e1, e2 = eqv2.forward(p, g1, cfg), eqv2.forward(p, g2, cfg)
    np.testing.assert_allclose(e1, e2, rtol=2e-3, atol=1e-4)


def test_mace_translation_invariance():
    pos, src, dst, species = _random_graph3d(3)
    cfg = mace.MACEConfig(channels=8, n_species=8)
    p = mace.init_params(jax.random.PRNGKey(0), cfg)
    g1 = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                    pos=jnp.asarray(pos, jnp.float32),
                    species=jnp.asarray(species))
    g2 = GraphBatch(src=g1.src, dst=g1.dst,
                    pos=jnp.asarray(pos + np.array([1.5, -2.0, 0.3]),
                                    jnp.float32), species=g1.species)
    np.testing.assert_allclose(mace.forward(p, g1, cfg),
                               mace.forward(p, g2, cfg), rtol=1e-4)


@given(st.integers(1, 6), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_wigner_rotates_sh(l, seed):
    """D(R) Y(x) == Y(R x) for the batched jax Wigner path."""
    rng = np.random.default_rng(seed)
    a, b, c = rng.uniform(0, 2 * np.pi, 3)
    R = so3._rot_z(a) @ so3._rot_y(b) @ so3._rot_z(c)
    x = rng.standard_normal((6, 3))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    Y = np.asarray(so3.real_sph_harm(jnp.asarray(x), l))
    Yr = np.asarray(so3.real_sph_harm(jnp.asarray(x @ R.T), l))
    D = np.asarray(so3.wigner_from_rotation(
        jnp.array([a]), jnp.array([b]), jnp.array([c]), l))[0]
    np.testing.assert_allclose(Yr, Y @ D.T, atol=5e-5)


@given(st.sampled_from([(1, 1, 0), (1, 1, 2), (2, 1, 1), (2, 2, 2)]),
       st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_cg_equivariance(path, seed):
    l1, l2, l3 = path
    rng = np.random.default_rng(seed)
    a, b, c = rng.uniform(0, 2 * np.pi, 3)
    R = so3._rot_z(a) @ so3._rot_y(b) @ so3._rot_z(c)
    C = so3.real_cg(l1, l2, l3)
    D1, D2, D3 = (so3.wigner_np(l, R) for l in (l1, l2, l3))
    va = rng.standard_normal(2 * l1 + 1)
    vb = rng.standard_normal(2 * l2 + 1)
    lhs = np.einsum("i,j,ijk->k", D1 @ va, D2 @ vb, C)
    rhs = D3 @ np.einsum("i,j,ijk->k", va, vb, C)
    np.testing.assert_allclose(lhs, rhs, atol=1e-8)


# pna's degree-scaler towers make the smoke loss surface sharper than
# gatedgcn's: a 0.5 full-batch step overshoots, so each arch gets an LR in
# its stable region (one SGD step must still strictly reduce the loss)
@pytest.mark.parametrize("mod,cfgmod,lr",
                         [(gatedgcn, "gatedgcn", 0.5), (pna, "pna", 0.1)])
def test_feature_gnn_train_step(mod, cfgmod, lr):
    from repro import configs
    from repro.data.graphs import random_feature_graph
    cfg = configs.get(cfgmod).smoke_config()
    g, labels = random_feature_graph(60, 240, cfg.d_in, cfg.n_classes, seed=1)
    p = mod.init_params(jax.random.PRNGKey(0), cfg)
    loss0 = float(mod.loss_fn(p, g, labels, cfg))
    grads = jax.grad(lambda pp: mod.loss_fn(pp, g, labels, cfg))(p)
    p2 = jax.tree.map(lambda a, gr: a - lr * gr, p, grads)
    loss1 = float(mod.loss_fn(p2, g, labels, cfg))
    assert np.isfinite(loss0) and loss1 < loss0


def test_neighbor_sampler_static_shapes():
    from repro.data.graphs import NeighborSampler
    rng = np.random.default_rng(0)
    n, e = 500, 4000
    src, dst = rng.integers(0, n, e), rng.integers(0, n, e)
    x = rng.standard_normal((n, 8)).astype(np.float32)
    lab = rng.integers(0, 4, n)
    s = NeighborSampler(n, src, dst, x, lab, fanouts=(4, 3), seed=0)
    shapes = set()
    for batch in range(3):
        seeds = rng.integers(0, n, 8)
        sub, slab = s.sample(seeds)
        shapes.add((sub.n_nodes, sub.n_edges, slab.shape))
        # sampled edges must exist in the base graph (valid ones)
        em = np.asarray(sub.edge_mask) > 0
    assert len(shapes) == 1, "sampler must produce static shapes"
    nn = 8 * (1 + 4 + 12)
    assert shapes.pop() == (nn, 8 * 4 + 8 * 4 * 3, (nn,))


def test_sampled_edges_are_real():
    from repro.data.graphs import NeighborSampler
    rng = np.random.default_rng(1)
    n, e = 200, 1000
    src, dst = rng.integers(0, n, e), rng.integers(0, n, e)
    edge_set = set(zip(src.tolist(), dst.tolist()))
    x = np.zeros((n, 4), np.float32)
    lab = np.zeros(n, np.int64)
    s = NeighborSampler(n, src, dst, x, lab, fanouts=(5,), seed=0)
    seeds = rng.integers(0, n, 16)
    l1 = s._sample_layer(seeds, 5)
    for i, seed in enumerate(seeds):
        for nbr in l1[i]:
            if nbr >= 0:
                assert (int(nbr), int(seed)) in edge_set
