"""Static plan verifier (repro.core.verify): golden broken-DAG fixtures —
one deliberately ill-formed plan per rule family, asserting the verifier
rejects it with the expected rule ID — plus pinning regressions for the
invariant violations the plan sweep surfaced, and a property test that the
optimizer + shard rewrites preserve verifier-inferred schemas under random
delta/tombstone/compaction streams.
"""
from __future__ import annotations

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import cost, optimizer, physical as ph, verify
from repro.core.engine import GredoEngine
from repro.core.schema import (JoinPred, Pattern, PatternVertex, Predicate,
                               chain_pattern)
from repro.core.storage import Database, DictColumn, Graph, RaggedColumn, Table
from repro.data import m2bench

pytestmark = pytest.mark.fast

MODES = ("gredo", "dual", "single")


# ---------------------------------------------------------------------------
# fixtures: a tiny hand-built db for golden broken DAGs, m2bench for
# integration-level checks
# ---------------------------------------------------------------------------

def mini_db() -> Database:
    db = Database()
    db.add_table(Table("T", {
        "a": np.arange(8, dtype=np.int64),
        "f": np.linspace(0.0, 1.0, 8),
        "s": DictColumn(["x", "y"] * 4),
        "r": RaggedColumn([[1, 2], [3]] * 4),
    }))
    db.add_table(Table("U", {
        "k": np.arange(8, dtype=np.int64),
        "s": DictColumn(["x", "z"] * 4),
    }))
    return db


def scan(db: Database, name: str) -> ph.ScanTable:
    return ph.ScanTable(name, db.epoch_of(name))


_M2 = {}


def m2db() -> Database:
    """Shared read-only m2bench database (module-scope cache; hypothesis
    given-wrapped tests cannot take pytest fixtures)."""
    if "db" not in _M2:
        db = m2bench.generate(sf=1)
        m2bench.build_indexes(db)
        _M2["db"] = db
    return _M2["db"]


def rules_of(report: verify.VerifyReport, severity=None) -> set:
    vs = report.violations
    if severity is not None:
        vs = [v for v in vs if v.severity == severity]
    return {v.rule for v in vs}


# ---------------------------------------------------------------------------
# golden broken-DAG fixtures, one per rule
# ---------------------------------------------------------------------------

def test_vcol_unresolved_select_column():
    db = mini_db()
    bad = ph.Select(scan(db, "T"), [Predicate("T.zzz", "==", 1)])
    report = verify.verify_plan(bad, db)
    assert not report.ok
    assert "V-COL" in rules_of(report, verify.ERROR)


def test_vcol_unqualified_predicate():
    db = mini_db()
    bad = ph.Select(scan(db, "T"), [Predicate("a", "==", 1)])
    report = verify.verify_plan(bad, db)
    assert "V-COL" in rules_of(report, verify.ERROR)


def test_vcol_clean_plan_passes():
    db = mini_db()
    good = ph.Select(scan(db, "T"), [Predicate("T.a", "==", 1)])
    report = verify.verify_plan(good, db)
    assert report.ok and not report.violations


def test_vtype_string_vs_int_join_key():
    db = mini_db()
    bad = ph.EquiJoin(JoinPred("T.a", "U.s"), scan(db, "T"), scan(db, "U"))
    report = verify.verify_plan(bad, db)
    assert not report.ok
    assert "V-TYPE" in rules_of(report, verify.ERROR)


def test_vtype_int_vs_float_key_warns_only():
    db = mini_db()
    join = ph.EquiJoin(JoinPred("T.f", "U.k"), scan(db, "T"), scan(db, "U"))
    report = verify.verify_plan(join, db)
    assert report.ok                       # promotion, not a wrong answer
    assert "V-TYPE" in rules_of(report, verify.WARN)


def test_vgcda_ragged_feature_column():
    db = mini_db()
    bad = ph.Rel2Matrix(["r"], scan(db, "T"))
    report = verify.verify_plan(bad, db)
    assert "V-GCDA" in rules_of(report, verify.ERROR)


def test_vgcda_int_feature_promotion_warns():
    db = mini_db()
    m = ph.Rel2Matrix(["a"], scan(db, "T"))
    report = verify.verify_plan(m, db)
    assert report.ok
    assert "V-GCDA" in rules_of(report, verify.WARN)


def test_vgcda_regression_label_width():
    x = ph.Const(np.ones((4, 3), dtype=np.float32))
    y = ph.Const(np.ones((4, 2), dtype=np.float32))
    bad = ph.Regression(3, False, x, y)
    report = verify.verify_plan(bad, Database())
    assert "V-GCDA" in rules_of(report, verify.ERROR)


def test_vgcda_similarity_width_mismatch():
    a = ph.Const(np.ones((4, 3), dtype=np.float32))
    b = ph.Const(np.ones((4, 5), dtype=np.float32))
    report = verify.verify_plan(ph.Similarity(False, a, b), Database())
    assert "V-GCDA" in rules_of(report, verify.ERROR)


def test_vepoch_stale_scan_epoch():
    db = mini_db()
    node = scan(db, "T")
    db.touch_table("T")
    report = verify.verify_plan(node, db)
    assert "V-EPOCH" in rules_of(report, verify.ERROR)


def test_vepoch_project_vector_misses_source():
    db = mini_db()
    join = ph.EquiJoin(JoinPred("T.a", "U.k"), scan(db, "T"), scan(db, "U"))
    # epoch vector covers T only — U's writes would never invalidate a
    # cached result keyed on this vector
    bad = ph.Project(["T.a"], (("T", db.epoch_of("T")),), join)
    report = verify.verify_plan(bad, db)
    assert "V-EPOCH" in rules_of(report, verify.ERROR)
    ok = ph.Project(["T.a"], (("T", db.epoch_of("T")),
                              ("U", db.epoch_of("U"))), join)
    assert verify.verify_plan(ok, db).ok


def test_vepoch_project_vector_unknown_collection():
    db = mini_db()
    bad = ph.Project(["T.a"], (("T", db.epoch_of("T")),
                               ("Ghost", 0)), scan(db, "T"))
    report = verify.verify_plan(bad, db)
    assert "V-EPOCH" in rules_of(report, verify.ERROR)


def _two_label_graph_db() -> Database:
    """Graph whose two vertex labels share a column name at different
    dtypes — the raw material for a signature collision."""
    db = Database()
    ta = Table("A", {"v": np.arange(4, dtype=np.int64)})
    tb = Table("B", {"v": DictColumn(["x", "y", "z", "w"])})
    edges = Table("G_edges", {"svid": np.array([0, 1], dtype=np.int64),
                              "tvid": np.array([0, 1], dtype=np.int64)})
    db.add_table(Table("X", {"x": np.arange(3, dtype=np.int64)}))
    db.add_graph(Graph("G", {"A": ta, "B": tb}, edges, "A", "B"))
    return db


def test_vsig_signature_collision_across_plans():
    # GraphProject's signature params carry (keep, wanted) but not the
    # pattern, so two projections with identical params over the same child
    # can disagree on the backing label — and therefore the dtype of x.v.
    db = _two_label_graph_db()
    child = scan(db, "X")                  # yields the bound var column "x"
    gep = db.epoch_of("G")
    pat_a = Pattern("G", (PatternVertex("x", "A"),), ())
    pat_b = Pattern("G", (PatternVertex("x", "B"),), ())
    gp_a = ph.GraphProject("G", gep, pat_a, ("x",), {"x": ["v"]}, child)
    gp_b = ph.GraphProject("G", gep, pat_b, ("x",), {"x": ["v"]}, child)
    assert gp_a.signature() == gp_b.signature()
    report, sigs = verify.VerifyReport(), {}
    verify.verify_plan(gp_a, db, report, sigs)
    assert report.ok                       # first plan is internally fine
    verify.verify_plan(gp_b, db, report, sigs)
    assert "V-SIG" in rules_of(report, verify.ERROR)


def test_vsig_inplace_column_swap_detected():
    # swapping a column in place without bumping the epoch leaves equal
    # signatures pointing at different schemas — the cache-poisoning hazard
    db = mini_db()
    report, sigs = verify.VerifyReport(), {}
    verify.verify_plan(scan(db, "T"), db, report, sigs)
    t = db.tables["T"]
    t.columns["a"] = np.linspace(0.0, 1.0, 8)    # int64 -> float64, no touch
    verify.verify_plan(scan(db, "T"), db, report, sigs)
    assert "V-SIG" in rules_of(report, verify.ERROR)


def test_vshard_join_without_exchange():
    db = mini_db()
    join = ph.EquiJoin(JoinPred("T.a", "U.k"), scan(db, "T"), scan(db, "U"))
    join.shards = 2
    report = verify.verify_plan(join, db)
    assert "V-SHARD" in rules_of(report, verify.ERROR)


def test_vshard_misaligned_exchange_key():
    db = mini_db()
    ex = ph.Exchange(scan(db, "U"), key="U.s", k=2)   # partitions the wrong key
    join = ph.EquiJoin(JoinPred("T.a", "U.k"), scan(db, "T"), ex)
    join.shards = 2
    report = verify.verify_plan(join, db)
    assert "V-SHARD" in rules_of(report, verify.ERROR)


def test_vshard_aligned_exchange_passes():
    db = mini_db()
    ex = ph.Exchange(scan(db, "U"), key="U.k", k=2)
    join = ph.EquiJoin(JoinPred("T.a", "U.k"), scan(db, "T"), ex)
    join.shards = 2
    assert verify.verify_plan(join, db).ok


def test_vshard_stamp_on_non_shardable_kind():
    db = mini_db()
    node = scan(db, "T")
    node.shards = 2
    report = verify.verify_plan(node, db)
    assert "V-SHARD" in rules_of(report, verify.ERROR)


def test_vshard_exchange_outside_build_side():
    db = mini_db()
    report = verify.verify_plan(ph.Exchange(scan(db, "T"), key="T.a", k=2), db)
    assert "V-SHARD" in rules_of(report, verify.ERROR)


def _device_node(db: Database):
    """A DeviceMatchPattern as the optimizer actually lowers it (q_g3 lowers
    at sf=1), or None when lowering is off in this build."""
    eng = GredoEngine(db)
    naive = eng.physical_plan(m2bench.q_g3())
    dag, _ = optimizer.optimize(naive, db, cache=eng._opt_cache)
    for n in verify._walk(dag):
        if n.kind == "DeviceMatchPattern":
            return n
    return None


def test_vdev_capacity_below_frontier_bound():
    db = m2db()
    flag = optimizer.DEVICE_MATCH
    optimizer.DEVICE_MATCH = True
    try:
        node = _device_node(db)
    finally:
        optimizer.DEVICE_MATCH = flag
    assert node is not None, "q_g3 no longer device-lowers at sf=1"
    assert verify.verify_plan(node, db).ok
    starved = ph.DeviceMatchPattern(node.graph, node.epoch, node.pplan,
                                    access=node.access, capacity=8)
    report = verify.verify_plan(starved, db)
    assert "V-DEV" in rules_of(report, verify.ERROR)


def test_vdev_mask_children_rejected():
    db = m2db()
    flag = optimizer.DEVICE_MATCH
    optimizer.DEVICE_MATCH = True
    try:
        node = _device_node(db)
    finally:
        optimizer.DEVICE_MATCH = flag
    assert node is not None
    masked = ph.DeviceMatchPattern(node.graph, node.epoch, node.pplan,
                                   access=node.access, capacity=node.capacity)
    masked.children = (ph.SemiJoinMask(node.graph, node.epoch, "Persons",
                                       "p", "Persons.id",
                                       scan(db, "Persons")),)
    report = verify.verify_plan(masked, db)
    assert "V-DEV" in rules_of(report, verify.ERROR)


def test_vann_stale_annotation_warns():
    db = mini_db()
    node = scan(db, "T")
    node.out_cols = frozenset({"a", "ghost"})
    report = verify.verify_plan(node, db)
    assert report.ok                       # annotation drift is a WARN
    assert "V-ANN" in rules_of(report, verify.WARN)


def test_veq_retyped_root_rejected():
    db = mini_db()
    naive = scan(db, "T")
    rewritten = ph.PruneCols(scan(db, "T"), ["a", "f"])   # dropped columns
    report = verify.verify_equivalence(naive, rewritten, db)
    assert "V-EQ" in rules_of(report, verify.ERROR)
    assert verify.verify_equivalence(naive, scan(db, "T"), db).ok


# ---------------------------------------------------------------------------
# pinning regressions for real violations the sweep surfaced
# ---------------------------------------------------------------------------

def test_device_lowering_embeds_catalog_epoch_after_graph_replacement():
    # Regression: _select_match_path embedded g.epoch. After db.add_graph
    # replaces a graph, the catalog carries the old lineage forward
    # (epoch_of = lineage + g.epoch), so a raw g.epoch is stale the moment
    # a graph is re-registered — the cached-device-plan poisoning bug.
    db = m2bench.generate(sf=1)
    m2bench.build_indexes(db)
    flag = optimizer.DEVICE_MATCH
    optimizer.DEVICE_MATCH = True
    try:
        node = _device_node(db)
        assert node is not None
        g = db.graphs[node.graph]
        db.add_graph(g)                     # re-register: lineage +1
        assert db.epoch_of(node.graph) != g.epoch
        node = _device_node(db)             # re-lower against the new catalog
    finally:
        optimizer.DEVICE_MATCH = flag
    assert node is not None
    assert node.epoch == db.epoch_of(node.graph)
    assert verify.verify_plan(node, db).ok
    stale = ph.DeviceMatchPattern(node.graph, db.graphs[node.graph].epoch,
                                  node.pplan, access=node.access,
                                  capacity=node.capacity)
    report = verify.verify_plan(stale, db)
    assert "V-EPOCH" in rules_of(report, verify.ERROR)


def test_prune_columns_refreshes_out_cols_annotation():
    # Regression: _prune_columns inserted PruneCols under an Alias but left
    # the alias's pre-prune out_cols annotation in place (with_children
    # carries annotations across the clone) — every pruned plan warned V-ANN.
    db = m2db()
    eng = GredoEngine(db)
    for q in (m2bench.q_g1(), m2bench.q_g3(), m2bench.q_opt_skew()):
        report = eng.verify(q)
        assert report.ok
        assert not report.by_rule("V-ANN"), report.render()


def test_optimizer_capacity_matches_verifier_bound():
    # optimizer and verifier must derive the identical frontier bound, or
    # the verifier would reject the optimizer's own lowered plans
    db = m2db()
    flag = optimizer.DEVICE_MATCH
    optimizer.DEVICE_MATCH = True
    try:
        node = _device_node(db)
    finally:
        optimizer.DEVICE_MATCH = flag
    assert node is not None
    g = db.graphs[node.graph]
    peak = cost.device_frontier_peak(g, node.pplan)
    assert node.capacity == cost.padded_capacity(peak)


# ---------------------------------------------------------------------------
# engine integration: verify(q) across modes/shards, debug mode, explain
# ---------------------------------------------------------------------------

def test_engine_verify_all_modes_and_shards():
    db = m2db()
    floor = cost.SHARD_MIN_ROWS
    try:
        for q in (m2bench.q_g2(), m2bench.q_g3(), m2bench.q_shard_join()):
            for mode in MODES:
                for k in (1, 4):
                    cost.SHARD_MIN_ROWS = 0 if k > 1 else floor
                    report = GredoEngine(db, mode=mode, n_shards=k).verify(q)
                    assert report.ok, report.render()
    finally:
        cost.SHARD_MIN_ROWS = floor


def test_gcda_verify_flags_promotions_only():
    report = GredoEngine(m2db()).verify(m2bench.a_shard_reg())
    assert report.ok
    assert rules_of(report) == {"V-GCDA"}   # int64/float64 -> float32 WARNs


def test_debug_engine_verifies_and_matches_plain_results():
    db = m2db()
    q = m2bench.q_g3()
    plain = GredoEngine(db).query(q)
    eng = GredoEngine(db, debug=True)
    dbg = eng.query(q)
    assert eng.last_verify is not None and eng.last_verify.ok
    assert plain.nrows == dbg.nrows
    assert "== verify ==" in eng.explain_last()


def test_debug_engine_raises_on_broken_catalog():
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db, debug=True)
    q = m2bench.q_shard_join()
    eng.query(q)                            # sane baseline
    t = db.tables["Orders"]
    t.columns["customer_id"] = DictColumn(      # join key: int64 -> dict
        ["c"] * len(np.asarray(t.columns["quantity"])))
    with pytest.raises(verify.PlanVerificationError) as ei:
        eng.query(q)
    assert any(v.rule in ("V-TYPE", "V-SIG") for v in ei.value.report.errors)


def test_explain_carries_verify_lines():
    db = m2db()
    text = GredoEngine(db, debug=True).explain(m2bench.q_g3())
    assert "== verify ==" in text
    assert "verify: plan ok" in text or "verify:" in text


# ---------------------------------------------------------------------------
# property: rewrites preserve schemas under random mutation streams
# ---------------------------------------------------------------------------

_PROP = {}


def _prop_db() -> Database:
    if "db" not in _PROP:
        _PROP["db"] = m2bench.generate(sf=1)
    return _PROP["db"]


@st.composite
def _mutation_ops(draw):
    n = draw(st.integers(min_value=1, max_value=4))
    return [draw(st.sampled_from(["edges", "tombstone", "compact", "touch"]))
            for _ in range(n)]


@settings(max_examples=8, deadline=None)
@given(
    ops=_mutation_ops(),
    seed=st.integers(min_value=0, max_value=2**16),
    qname=st.sampled_from(["q_g2", "q_g3", "q_shard_join", "q_opt_skew"]),
    mode=st.sampled_from(MODES),
    shards=st.sampled_from([1, 4]),
)
def test_rewrites_preserve_schemas_under_mutation(ops, seed, qname, mode,
                                                 shards):
    db = _prop_db()
    rng = np.random.default_rng(seed)
    g = db.graphs["Interested_in"]
    for op in ops:
        if op == "edges":
            m = int(rng.integers(1, 30))
            g.insert_edges({
                "svid": rng.integers(0, 100, m).astype(np.int64),
                "tvid": rng.integers(0, m2bench.N_TAGS, m).astype(np.int64),
                "weight": rng.uniform(0.0, 1.0, m),
            })
        elif op == "tombstone":
            live = g.live_edge_ids()
            m = min(int(rng.integers(1, 20)), len(live))
            if m:
                g.delete_edges(rng.choice(live, m, replace=False))
        elif op == "compact":
            g.compact()
        elif op == "touch":
            db.touch_table("Orders")
    q = getattr(m2bench, qname)()
    floor = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0 if shards > 1 else floor
    try:
        report = GredoEngine(db, mode=mode, n_shards=shards).verify(q)
    finally:
        cost.SHARD_MIN_ROWS = floor
    # every stage type-checks against the mutated catalog, the rewrite
    # chain never retypes the root, and signatures stay coherent
    assert report.ok, report.render()
    assert not report.by_rule("V-EQ") and not report.by_rule("V-SIG")
