"""Distribution layer: multi-device GCDA, gradient compression, microbatch
equivalence, sharding-rule divisibility logic. Uses host platform devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytics
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   compress_int8, compressed_psum,
                                   decompress_int8)

MULTI = jax.device_count() >= 2


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    # error bounded by half a quantization step
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum tracks the true
    sum over steps (EF-SGD property)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512) * 0.01, jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(20):
        gc = g + err
        q, s = compress_int8(gc)
        approx = decompress_int8(q, s)
        err = gc - approx
        total = total + approx
    true_total = g * 20
    rel = float(jnp.abs(total - true_total).max() /
                (jnp.abs(true_total).max() + 1e-9))
    assert rel < 0.05


def test_adamw_matches_reference_step():
    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, weight_decay=0.0,
                      grad_clip=1e9)
    new_p, new_s = adamw_update(grads, state, params, cfg)
    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.001 * g * g
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    expect = np.asarray(params["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-3,
                               atol=2e-6)  # f32 rsqrt vs np.sqrt


def test_sharding_divisibility_rules():
    from repro.distributed import sharding as shr
    from repro.launch.mesh import make_local_mesh
    from repro import configs
    mesh = make_local_mesh(1, 1)

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = configs.get("qwen2_1_5b").config()     # 12 heads: NOT divisible
    specs = shr.lm_param_specs(cfg, FakeMesh())
    assert specs["layers"]["wq"] == jax.sharding.PartitionSpec(None, None, None)
    assert specs["layers"]["w_in"][2] == "model"  # d_ff 8960 divisible
    cfg2 = configs.get("stablelm_3b").config()   # 32 heads: divisible
    specs2 = shr.lm_param_specs(cfg2, FakeMesh())
    assert specs2["layers"]["wq"][2] == "model"


def test_zero_spec_picks_divisible_dim():
    from repro.distributed.sharding import zero_spec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    class Shaped:
        shape = (30, 3072, 128)

    s = zero_spec(P(None, None, "model"), (30, 3072, 128), FakeMesh())
    assert s == P(None, "data", "model")


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_regression_distributed_matches_local():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(jax.device_count(), 1)
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, 256), jnp.float32)
    w_d, loss_d = analytics.regression_distributed(X, y, mesh, iters=30)
    w_l, loss_l = analytics.regression(X, y, iters=30, use_kernel=False)
    np.testing.assert_allclose(np.asarray(w_d), np.asarray(w_l),
                               rtol=5e-3, atol=5e-4)


def test_microbatch_equals_full_batch():
    """Gradient accumulation is loss-equivalent to the full batch."""
    import shutil
    from repro.models.transformer import TransformerConfig, init_params, loss_fn
    from repro.train.loop import Trainer, TrainerConfig
    from repro.data.lm import TokenStream
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=32, vocab=64, dtype=jnp.float32)
    stream = TokenStream(vocab=64, batch=8, seq=16)

    def data_at(step):
        b = stream.batch_at(step)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    results = {}
    for mb in (1, 4):
        shutil.rmtree(f"/tmp/mb{mb}", ignore_errors=True)
        p = init_params(jax.random.PRNGKey(0), cfg)
        t = Trainer(lambda pp, b: loss_fn(pp, b, cfg), p, data_at,
                    TrainerConfig(total_steps=5, ckpt_every=0,
                                  ckpt_dir=f"/tmp/mb{mb}", microbatch=mb,
                                  log_every=1))
        r = t.run(resume=False)
        results[mb] = [m["loss"] for m in r["metrics"]]
    # same data, averaged grads: curves should be very close
    np.testing.assert_allclose(results[1], results[4], rtol=2e-2)
