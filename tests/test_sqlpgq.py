"""SQL/PGQ-compatible surface language: parsed queries execute identically
to hand-built ASTs."""
import numpy as np
import pytest

from repro.core import GredoEngine
from repro.core.schema import JoinPred, Predicate
from repro.core.sqlpgq import parse
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1, seed=7)


def test_parse_running_example(db):
    """The paper's Fig. 1(a) query, as text."""
    q = parse("""
        SELECT Customer.id, t.tid
        FROM Customer
        MATCH (p:Persons)-[e0:Interested_in]->(t:Tags) ON Interested_in
        WHERE t.content = 'food' AND Customer.person_id = p.pid
    """)
    assert q.select == ("Customer.id", "t.tid")
    assert q.froms == ("Customer",)
    assert q.match.graph == "Interested_in"
    assert q.joins == (JoinPred("Customer.person_id", "p.pid"),)
    assert q.where == (Predicate("t.content", "==", "food"),)
    # identical results to the hand-built AST
    eng = GredoEngine(db)
    r1 = eng.query(q)
    r2 = eng.query(m2bench.q_g1())
    assert r1.nrows == r2.nrows
    assert sorted(np.asarray(r1.col("t.tid"))) == \
        sorted(np.asarray(r2.col("t.tid")))


def test_parse_two_hop_and_ranges(db):
    q = parse("""
        SELECT a.pid, c.pid
        MATCH (a:Persons)-[e0:Follows]->(b:Persons)-[e1:Follows]->(c:Persons)
              ON Follows
        WHERE a.country = 'au' AND c.country = 'uk'
    """)
    assert len(q.match.edges) == 2
    eng = GredoEngine(db)
    assert eng.query(q).nrows == eng.query(m2bench.q_g3()).nrows


def test_parse_between_and_in(db):
    q = parse("""
        SELECT e0.weight
        MATCH (p:Persons)-[e0:Interested_in]->(t:Tags) ON Interested_in
        WHERE e0.weight BETWEEN 0.25 AND 0.75 AND t.tid IN (1, 2, 3)
    """)
    preds = {p.attr: p for p in q.where}
    assert preds["e0.weight"].op == "range"
    assert preds["t.tid"].op == "in"
    eng = GredoEngine(db)
    r = eng.query(q)
    w = np.asarray(r.col("e0.weight"))
    assert ((w >= 0.25) & (w <= 0.75)).all()


def test_parse_errors():
    with pytest.raises(SyntaxError):
        parse("SELECT x WHERE a.b ~ 3")
    with pytest.raises(SyntaxError):
        parse("SELECT a.b WHERE a.b < c.d")   # non-equality join
