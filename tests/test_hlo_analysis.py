"""HLO analysis: collective-bytes parser and trip-count-aware cost walker
(validated against programs with known flops/collectives)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes, hlo_cost


def test_scan_flops_trip_count():
    def body(x, w):
        return jnp.dot(x, w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    got = hlo_cost(c.as_text())["flops"]
    assert got == 7 * 2 * 128 ** 3


def test_nested_scan_flops():
    def inner(x, w):
        return jnp.dot(x, w), None

    def outer(x, ws):
        def step(xc, wouter):
            y, _ = jax.lax.scan(inner, xc, ws)
            return y, None
        return jax.lax.scan(step, x, jnp.arange(3.0))[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    got = hlo_cost(c.as_text())["flops"]
    assert got == 3 * 5 * 2 * 64 ** 3


def test_collective_parser_on_synthetic_hlo():
    hlo = """HloModule m

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %ar = f32[64]{0} all-reduce(%gte), channel_id=1
  ROOT %t = (s32[], f32[64]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  %ag = f32[128]{0} all-gather(%a), channel_id=9
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    cb = collective_bytes(hlo)
    # all-reduce: 64*4 bytes * 2 (ring) * 4 trips = 2048; all-gather 128*4=512
    assert cb["all-reduce"]["bytes"] == 2048
    assert cb["all-reduce"]["count"] == 4
    assert cb["all-gather"]["bytes"] == 512
    assert cb["total_bytes"] == 2560


def test_collectives_in_sharded_program():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_bytes_positive_and_bounded():
    def f(x):
        return jnp.sin(x) + 1
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    b = hlo_cost(c.as_text())["bytes"]
    assert 4096 <= b <= 64 * 4096
