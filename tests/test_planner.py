"""Planner properties: every optimization mechanism is plan-equivalence
preserving (optimized == unoptimized result multisets), pushdown decisions
behave per Fig. 6, and the rewriting rules fire on the documented shapes."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import planner
from repro.core.schema import JoinPred, Pattern, PatternVertex, Predicate, Query, chain_pattern
from repro.core.storage import Database, Graph, Table
from repro.data import m2bench

import pytest

pytestmark = pytest.mark.fast


def _rows(t: Table):
    cols = sorted(t.columns)
    out = []
    for i in range(t.nrows):
        row = []
        for c in cols:
            col = t.col(c)
            v = col.codes[i] if hasattr(col, "codes") else np.asarray(col)[i]
            row.append(int(v) if np.issubdtype(type(v), np.integer) else v)
        out.append(tuple(row))
    return sorted(out)


@st.composite
def random_db_and_query(draw):
    rng = np.random.default_rng(draw(st.integers(0, 99_999)))
    n_p = draw(st.integers(3, 10))
    n_t = draw(st.integers(2, 6))
    n_e = draw(st.integers(2, 25))
    n_c = draw(st.integers(2, 8))
    db = Database()
    persons = Table("P", {"pid": np.arange(n_p), "a": rng.integers(0, 3, n_p)})
    tags = Table("T", {"tid": np.arange(n_t), "b": rng.integers(0, 3, n_t)})
    edges = Table("E", {"svid": rng.integers(0, n_p, n_e),
                        "tvid": rng.integers(0, n_t, n_e),
                        "w": rng.integers(0, 10, n_e)})
    db.add_graph(Graph("G", {"P": persons, "T": tags}, edges, "P", "T"))
    db.add_table(Table("C", {"id": np.arange(n_c),
                             "person_id": rng.integers(0, n_p, n_c),
                             "v": rng.integers(0, 5, n_c)}))
    pat = chain_pattern("G", ("p", "P", "E", "t", "T"))
    where = []
    if draw(st.booleans()):
        where.append(Predicate("t.b", "==", draw(st.integers(0, 2))))
    if draw(st.booleans()):
        where.append(Predicate("p.a", "!=", draw(st.integers(0, 2))))
    if draw(st.booleans()):
        where.append(Predicate("e0.w", "range", 2, 8))
    if draw(st.booleans()):
        where.append(Predicate("C.v", "==", draw(st.integers(0, 4))))
    q = Query(select=("C.id", "t.tid"), froms=("C",), match=pat,
              joins=(JoinPred("C.person_id", "p.pid"),), where=tuple(where))
    return db, q


@given(random_db_and_query())
@settings(max_examples=30, deadline=None)
def test_optimizations_preserve_semantics(db_q):
    db, q = db_q
    p_opt = planner.plan(db, q, enable_opt=True)
    p_raw = planner.plan(db, q, enable_opt=False,
                         enable_pattern_pushdown=False)
    assert _rows(planner.execute(db, p_opt)) == _rows(planner.execute(db, p_raw))


def test_direction_rule_fig6():
    """Fig. 6(a)/(b): traversal starts from the predicate side."""
    db = m2bench.generate(sf=1)
    g = db.graphs["Interested_in"]
    from repro.core.pattern import plan_pattern
    pat = chain_pattern("Interested_in", ("p", "Persons", "E", "t", "Tags"))
    # predicate on target -> reverse
    plan = plan_pattern(g, pat, {"t": [Predicate("t.content", "==", "food")]},
                        projected={"p", "t"})
    assert plan.reverse
    assert "t" in plan.pushed
    # predicate on source -> forward
    plan = plan_pattern(g, pat, {"p": [Predicate("p.country", "==", "cn")]},
                        projected={"p", "t"})
    assert not plan.reverse
    assert "p" in plan.pushed


def test_inequality_deferred():
    """Fig. 6 end-vertex rule: '!=' predicates are never pushed down."""
    db = m2bench.generate(sf=1)
    g = db.graphs["Interested_in"]
    from repro.core.pattern import plan_pattern
    pat = chain_pattern("Interested_in", ("p", "Persons", "E", "t", "Tags"))
    # highly selective equality on the source fixes direction=forward, so t
    # is the END vertex where the Fig. 6 rule applies
    plan = plan_pattern(g, pat,
                        {"p": [Predicate("p.pid", "==", 5)],
                         "t": [Predicate("t.content", "!=", "food")]},
                        projected={"p", "t"})
    assert not plan.reverse
    assert plan.deferred.get("t"), "end-vertex inequality must be deferred"


def test_match_trimming_cases():
    db = m2bench.generate(sf=1)
    p1 = planner.plan(db, m2bench.q_vertex_scan())
    assert p1.match_trim == "vertex_scan"
    p2 = planner.plan(db, m2bench.q_edge_scan())
    assert p2.match_trim == "edge_scan"
    p3 = planner.plan(db, m2bench.q_g1())
    assert p3.match_trim is None


def test_projection_trimming():
    db = m2bench.generate(sf=1)
    q = m2bench.q_g1()
    p = planner.plan(db, q)
    # q_g1 projects t and joins on p: both kept, nothing else
    assert p.graph_projection == {"p", "t"}


def test_predicate_replication_across_join():
    """Mechanism 1b: equality predicate on C.person_id replicates to p.pid."""
    db = m2bench.generate(sf=1)
    pat = chain_pattern("Interested_in", ("p", "Persons", "E", "t", "Tags"))
    q = Query(select=("C.id", "t.tid"), froms=("C",), match=pat,
              joins=(JoinPred("C.person_id", "p.pid"),),
              where=(Predicate("C.person_id", "==", 5),))
    # rename Customer table alias used above
    db.tables["C"] = db.tables["Customer"]
    p = planner.plan(db, q)
    assert any("replicated" in n for n in p.notes)
    assert any(pr.attr == "p.pid" for pr in
               p.pattern_plan.pushed.get("p", []) +
               p.pattern_plan.deferred.get("p", []))


def test_join_pushdown_candidates_detected():
    """The planner's mechanism-2 decision is now purely logical: it flags
    which joins are graph↔table pushdown candidates; the cost-based siding
    (Eq. 8 vs 9/10, graph mask vs table reduce) lives in the optimizer."""
    db = m2bench.generate(sf=1)
    p = planner.plan(db, m2bench.q_g4())
    assert p.semi_join_idx == {2}            # Customer.person_id = p.pid
    assert any("join-pushdown candidate" in n for n in p.notes)
    # candidates are off with optimizations disabled (GredoDB-D ablation)
    p_raw = planner.plan(db, m2bench.q_g4(), enable_opt=False)
    assert p_raw.semi_join_idx == set()
