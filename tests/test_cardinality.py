"""Cardinality estimation: histogram/MCV join-key overlap
(``ColumnStats.join_overlap``), the Selinger DP enumerator (bushy plans on
the 4-source exemplar), per-hop label-aware graph fan-out, and the
write-epoch keying of the cross-call estimate cache."""
import numpy as np
import pytest

from repro.core import GredoEngine, optimizer, physical
from repro.core.deltastore import DeltaConfig
from repro.core.pattern import PatternPlan
from repro.core.schema import chain_pattern
from repro.core.storage import (ColumnStats, Database, DictColumn, Graph,
                                Table, compute_stats)
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def skew_db():
    return m2bench.generate_skew(sf=1)


def _qerr(est: float, actual: float) -> float:
    return max(est / max(actual, 1e-9), actual / max(est, 1e-9))


# ---------------------------------------------------------------------------
# join_overlap: the per-key / per-bucket join model
# ---------------------------------------------------------------------------


def test_join_overlap_mcv_is_exact():
    l = compute_stats(DictColumn(values=["a"] * 90 + ["b"] * 10))
    r = compute_stats(DictColumn(values=["a"] * 5 + ["c"] * 2))
    matches, how = l.join_overlap(r)
    assert matches == 90 * 5
    assert how.startswith("mcv×mcv")


def test_join_overlap_numeric_mcv_vs_histogram():
    rng = np.random.default_rng(0)
    # > MCV_CAP distincts: the big side keeps only the histogram
    big = compute_stats(rng.permutation(10_000).astype(np.float64))
    assert big.value_counts is None and big.hist is not None
    small = compute_stats(np.arange(10, dtype=np.float64))
    matches, how = small.join_overlap(big)
    # each of the 10 point keys should match ~1 of the 10k distinct rows
    assert 2.0 <= matches <= 50.0
    assert "hist" in how and "mcv" in how


def test_join_overlap_histogram_pair():
    rng = np.random.default_rng(1)
    a = compute_stats(rng.integers(0, 10_000, 10_000).astype(np.float64))
    b = compute_stats(rng.integers(0, 10_000, 10_000).astype(np.float64))
    assert a.value_counts is None and b.value_counts is None
    matches, how = a.join_overlap(b)
    # uniform keys: ~n*n/domain = 10_000 expected matches
    assert 5_000 <= matches <= 20_000
    assert how.startswith("hist[")


def test_join_overlap_none_without_distribution_falls_back_to_ndv():
    bare_l, bare_r = ColumnStats(n=100, ndv=10), ColumnStats(n=50, ndv=5)
    assert bare_l.join_overlap(bare_r) is None
    rows, how = physical.est_join_rows_detail(100, 50, bare_l, bare_r)
    assert how == "ndv" and rows == pytest.approx(100 * 50 / 10)


def test_join_overlap_matches_true_zipf_join_size(skew_db):
    """On aligned Zipf keys the MCV overlap equals the exact join size,
    while NDV containment is off by an order of magnitude."""
    c = skew_db.tables["Clicks"].stats("user_id")
    p = skew_db.tables["Purchases"].stats("user_id")
    cu = np.bincount(np.asarray(skew_db.tables["Clicks"].col("user_id")))
    pu = np.bincount(np.asarray(skew_db.tables["Purchases"].col("user_id")),
                     minlength=len(cu))
    true = float(cu @ pu[:len(cu)])
    matches, how = c.join_overlap(p)
    assert how.startswith("mcv×mcv")
    assert matches == pytest.approx(true)
    ndv_est = c.n * p.n / max(c.ndv, p.ndv)
    assert true / ndv_est > 5.0          # the regime NDV collapses in


def test_filtered_inputs_scale_the_overlap():
    """est_join_rows threads input selectivities into the bucket counts:
    half the rows on one side -> half the matches."""
    l = compute_stats(DictColumn(values=["a"] * 80 + ["b"] * 20))
    r = compute_stats(DictColumn(values=["a"] * 10))
    full = physical.est_join_rows(100, 10, l, r)
    half = physical.est_join_rows(50, 10, l, r)
    assert full == pytest.approx(800)
    assert half == pytest.approx(400)


def test_overlap_maintained_across_delta_appends():
    """The merged base ⊕ delta stats views keep exact MCV counts, so
    join_overlap stays current without an O(base) recompute."""
    vt = Table("A", {"v": np.arange(10, dtype=np.float64)})
    edges = Table("E", {"svid": np.zeros(1, dtype=np.int64),
                        "tvid": np.zeros(1, dtype=np.int64)})
    g = Graph("G", {"A": vt}, edges, "A", "A",
              delta_config=DeltaConfig(auto_compact=False))
    probe = compute_stats(np.array([3.0, 3.0]))
    before, _ = g.vertex_tables["A"].stats("v").join_overlap(probe)
    g.insert_vertices("A", {"v": np.array([3.0, 3.0, 3.0])})
    after, how = g.vertex_tables["A"].stats("v").join_overlap(probe)
    assert before == pytest.approx(2.0)      # 1 base row x 2 probe rows
    assert after == pytest.approx(8.0)       # 4 rows x 2 probe rows
    assert how.startswith("mcv×mcv")


# ---------------------------------------------------------------------------
# q-error regression on the Zipfian workload
# ---------------------------------------------------------------------------


def test_skew_query_qerror_hist_beats_ndv(skew_db):
    """Root-level q-error of the skewed 3-join query: histogram-overlap
    estimates land within 4x of the truth and beat the NDV-only baseline by
    at least 2x (observed: ~1.0 vs ~22)."""
    q = m2bench.q_skew_3join()
    eng = GredoEngine(skew_db)
    r = eng.query(q)
    q_hist = _qerr(eng.last_ests[id(eng.last_dag)][0], r.nrows)

    physical.HIST_JOIN_EST = False
    try:
        eng_ndv = GredoEngine(skew_db)
        r2 = eng_ndv.query(q)
        q_ndv = _qerr(eng_ndv.last_ests[id(eng_ndv.last_dag)][0], r2.nrows)
    finally:
        physical.HIST_JOIN_EST = True

    assert r.nrows == r2.nrows
    assert q_hist <= 4.0
    assert q_ndv >= 2.0 * q_hist


def test_skew_query_provenance_rendered(skew_db):
    """explain() names the estimate source per join (per-bucket provenance)."""
    eng = GredoEngine(skew_db)
    dag = eng.optimized_plan(m2bench.q_skew_3join())
    rendered = physical.explain(dag, db=skew_db)
    assert "est_via=mcv×mcv" in rendered


# ---------------------------------------------------------------------------
# Bushy plans: the 4-source exemplar where every left-deep order is worse
# ---------------------------------------------------------------------------

BUSHY_GOLDEN = """\
Project[SrcA.id, DstB.id]
  EquiJoin[DstB.hub=SrcA.hub]
    EquiJoin[DstB.bkey=FiltD.bkey]
      Alias[DstB]
        ScanTable[DstB]
      Alias[FiltD]
        ScanTable[FiltD]
    EquiJoin[SrcA.akey=FiltA.akey]
      Alias[SrcA]
        ScanTable[SrcA]
      Alias[FiltA]
        ScanTable[FiltA]"""


def _is_bushy(root) -> bool:
    def has_join(n):
        return isinstance(n, (physical.EquiJoin, physical.IntraFilter)) \
            or any(has_join(c) for c in n.children)

    def walk(n):
        if isinstance(n, physical.EquiJoin) and all(map(has_join, n.children)):
            return True
        return any(walk(c) for c in n.children)

    return walk(root)


def test_bushy_plan_selected_on_4_source_query(skew_db):
    eng = GredoEngine(skew_db)
    q = m2bench.q_bushy_4src()
    dag = eng.optimized_plan(q)
    assert physical.explain(dag) == BUSHY_GOLDEN
    assert _is_bushy(dag)
    assert any(n.startswith("join-order: dp bushy")
               for n in eng.last_report.notes())


def test_every_left_deep_order_is_worse(skew_db):
    """dp-leftdeep finds the *best* left-deep plan; the bushy plan still
    beats it on estimated cost and on the actual intermediate sizes, and
    returns the same rows."""
    q = m2bench.q_bushy_4src()
    cache: dict = {}
    bushy_eng = GredoEngine(skew_db)
    ld_eng = GredoEngine(skew_db, join_enum="dp-leftdeep")
    bushy_dag = bushy_eng.optimized_plan(q)
    ld_dag = ld_eng.optimized_plan(q)
    assert not _is_bushy(ld_dag)
    assert optimizer._est_cost(bushy_dag, skew_db, cache) \
        < optimizer._est_cost(ld_dag, skew_db, cache)

    r_bushy = bushy_eng.query(q)
    r_ld = ld_eng.query(q)
    assert r_bushy.nrows == r_ld.nrows

    def max_join_rows(eng):
        return max((o["rows"] or 0) for o in eng.last_stats.operators
                   if o["op"] == "EquiJoin")

    assert max_join_rows(ld_eng) > 10 * max_join_rows(bushy_eng)


def test_greedy_fallback_still_used_above_dp_cap(skew_db):
    """join_enum='greedy' (and, transitively, join graphs past the DP cap)
    goes through the smallest-intermediate-first path and stays correct."""
    q = m2bench.q_bushy_4src()
    greedy = GredoEngine(skew_db, join_enum="greedy")
    dp = GredoEngine(skew_db)
    assert greedy.query(q).nrows == dp.query(q).nrows


# ---------------------------------------------------------------------------
# Per-hop, label-aware fan-out (TableJoinMatch / MatchPattern estimates)
# ---------------------------------------------------------------------------


def _bipartite_graph(n_a=10, n_b=1000, n_e=2000, seed=0):
    rng = np.random.default_rng(seed)
    va = Table("A", {"x": np.arange(n_a, dtype=np.int64)})
    vb = Table("B", {"y": np.arange(n_b, dtype=np.int64)})
    edges = Table("E", {"svid": rng.integers(0, n_a, n_e).astype(np.int64),
                        "tvid": rng.integers(0, n_b, n_e).astype(np.int64)})
    return Graph("G", {"A": va, "B": vb}, edges, "A", "B")


def test_hop_expansion_label_override():
    g = _bipartite_graph()
    assert g.hop_expansion() == pytest.approx(200.0)             # from A
    assert g.hop_expansion(reverse=True) == pytest.approx(2.0)   # from B
    assert g.hop_expansion(label="B") == pytest.approx(2.0)
    assert g.hop_expansion(reverse=True, label="A") == pytest.approx(200.0)


def test_table_join_match_estimate_is_per_hop_label_aware():
    """A 2-hop chain whose interior vertex is the *big* label: the k-way
    join estimate must use that hop's fan-out (E/|B| = 2), not the global
    forward fan-out (E/|A| = 200)."""
    g = _bipartite_graph()
    db = Database()
    db.add_graph(g)
    pat = chain_pattern("G", ("a", "A", "E", "b", "B"),
                        ("b", "B", "E", "c", "B"))
    node = physical.TableJoinMatch("G", 0, pat, {})
    est = physical.estimate(node, db)[id(node)][0]
    assert est == pytest.approx(2000 * 2.0)      # not 2000 * 200


def test_match_pattern_estimate_is_per_hop_label_aware():
    """Same chain through the hybrid matcher: hop 1 expands from A (200x),
    hop 2 from B (2x) — the old single-scalar model compounded 200^2."""
    g = _bipartite_graph()
    db = Database()
    db.add_graph(g)
    pat = chain_pattern("G", ("a", "A", "E", "b", "B"),
                        ("b", "B", "E", "c", "B"))
    pplan = PatternPlan(pat, reverse=False, pushed={}, deferred={},
                        fetch_vars=set())
    node = physical.MatchPattern("G", 0, pplan, ())
    est = physical.estimate(node, db)[id(node)][0]
    assert est == pytest.approx(10 * 200.0 * 2.0)


def test_single_hop_reverse_estimate_unchanged():
    """The per-hop rewrite reduces to the old label-aware scalar on the
    shapes the workload actually runs (1-hop reverse on bipartite)."""
    g = _bipartite_graph()
    db = Database()
    db.add_graph(g)
    pat = chain_pattern("G", ("a", "A", "E", "b", "B"))
    pplan = PatternPlan(pat, reverse=True, pushed={}, deferred={},
                        fetch_vars=set())
    node = physical.MatchPattern("G", 0, pplan, ())
    est = physical.estimate(node, db)[id(node)][0]
    assert est == pytest.approx(1000 * g.hop_expansion(reverse=True))


# ---------------------------------------------------------------------------
# Cross-call estimate cache: keyed on source write epochs
# ---------------------------------------------------------------------------


def test_estimate_cache_invalidated_by_delta_appends():
    """A persistent optimizer cache must not serve cardinalities computed
    before a delta-store append: re-planning after insert_edges sees the
    new live-edge count."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    q = m2bench.q_g1()

    eng.optimized_plan(q)
    snap1 = eng._opt_cache["__catalog__"]
    mp1 = _find_op(eng.last_dag, physical.MatchPattern)
    rows1 = optimizer._est_rows(mp1, db, eng._opt_cache)

    g = db.graphs["Interested_in"]
    g.insert_edges({"svid": np.arange(400, dtype=np.int64),
                    "tvid": np.arange(400, dtype=np.int64) % 40,  # food tags
                    "weight": np.linspace(0, 1, 400)})

    eng.optimized_plan(q)
    snap2 = eng._opt_cache["__catalog__"]
    mp2 = _find_op(eng.last_dag, physical.MatchPattern)
    rows2 = optimizer._est_rows(mp2, db, eng._opt_cache)

    assert snap1 != snap2                      # epoch snapshot advanced
    assert rows2 > rows1                       # estimates see the new edges


def test_estimate_cache_invalidated_by_join_model_toggle():
    """Flipping HIST_JOIN_EST (the NDV-baseline switch) must also drop
    cached estimates — signatures embed epochs, not the model toggle."""
    db = m2bench.generate_skew(sf=1)
    eng = GredoEngine(db)
    q = m2bench.q_skew_3join()
    eng.optimized_plan(q)
    hist_root = eng.last_ests[id(eng.last_dag)][0]
    physical.HIST_JOIN_EST = False
    try:
        eng.optimized_plan(q)                  # same engine, same cache
        cache = dict(eng._opt_cache)
        root = eng.last_dag
        ndv_root = physical.estimate(root, db,
                                     _cache=eng._opt_cache)[id(root)][0]
    finally:
        physical.HIST_JOIN_EST = True
    assert ndv_root < hist_root / 2            # no hist estimates replayed
    assert cache["__catalog__"][1] is False


def test_shared_cache_cleared_when_catalog_moves():
    """optimizer.optimize with an explicitly shared cache reuses it while
    the catalog is unchanged and drops every entry on an epoch change
    (stale node estimates cannot survive a delta-store append)."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    cache: dict = {}
    optimizer.optimize(eng.physical_plan(m2bench.q_g1()), db, cache=cache)
    cache["__sentinel__"] = True
    # same catalog: the cache (sentinel included) survives the next call
    optimizer.optimize(eng.physical_plan(m2bench.q_g1()), db, cache=cache)
    assert cache.get("__sentinel__") is True
    db.graphs["Interested_in"].insert_edges(
        {"svid": np.array([0]), "tvid": np.array([0]),
         "weight": np.array([0.5])})
    optimizer.optimize(eng.physical_plan(m2bench.q_g1()), db, cache=cache)
    assert "__sentinel__" not in cache         # epoch moved: cache cleared
    epochs, hist_flag = cache["__catalog__"]
    assert dict(epochs)["Interested_in"] == db.epoch_of("Interested_in")
    assert hist_flag is physical.HIST_JOIN_EST


def _find_op(root, cls):
    if isinstance(root, cls):
        return root
    for c in root.children:
        hit = _find_op(c, cls)
        if hit is not None:
            return hit
    return None
