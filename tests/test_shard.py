"""Sharded morsel-parallel execution: bit-for-bit equivalence with the
serial single-stream path, per-shard stats rollup exactness, partition
plumbing, and thread-safety of the shared runtime structures.

The property test (satellite 3 of the sharding PR) drives two identical
databases through the same random delta/tombstone/compaction stream and
asserts the sharded engine (k ∈ {1,2,4,7}) returns exactly the rows the
serial engine does, in the same order, across all three ablation modes.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import cost, join as join_mod, shard, storage, telemetry
from repro.core.engine import GredoEngine
from repro.core.interbuffer import InterBuffer
from repro.core.schema import (AnalyticsTask, GCDIATask, JoinPred, Predicate,
                               Query, chain_pattern)
from repro.core.storage import (Database, DictColumn, Graph, GraphPartitions,
                                Table, TableShards, compute_stats, merge_stats,
                                shard_bounds)

pytestmark = pytest.mark.fast

MODES = ("gredo", "dual", "single")
TOPICS = ["food", "music", "sport", "code", "art"]


# ---------------------------------------------------------------------------
# fixture: a compact multi-model db (graph + two tables) built from a seed
# ---------------------------------------------------------------------------

def tiny_db(seed: int) -> Database:
    rng = np.random.default_rng(seed)
    n_p, n_t, n_c, n_o = 160, 24, 120, 1500

    persons = Table("Persons", {
        "pid": np.arange(n_p, dtype=np.int64),
        "country": DictColumn(rng.choice(["de", "fi", "jp", "us"], n_p)),
    })
    tags = Table("Tags", {
        "tid": np.arange(n_t, dtype=np.int64),
        "content": DictColumn([TOPICS[i % len(TOPICS)] for i in range(n_t)]),
    })
    n_e = 900
    edges = Table("G_edges", {
        "svid": rng.integers(0, n_p, n_e).astype(np.int64),
        "tvid": rng.integers(0, n_t, n_e).astype(np.int64),
        "weight": rng.uniform(0.0, 1.0, n_e),
    })
    g = Graph("G", {"Persons": persons, "Tags": tags}, edges,
              "Persons", "Tags")

    customer = Table("Customer", {
        "id": np.arange(n_c, dtype=np.int64),
        "person_id": rng.permutation(n_p)[:n_c].astype(np.int64),
        "age": rng.integers(18, 80, n_c).astype(np.int64),
    })
    orders = Table("Orders", {
        "order_id": np.arange(n_o, dtype=np.int64),
        "customer_id": rng.integers(0, n_c, n_o).astype(np.int64),
        "quantity": rng.integers(1, 5, n_o).astype(np.int64),
        "days": rng.integers(1, 10, n_o).astype(np.int64),
    })

    db = Database()
    db.add_graph(g)
    db.add_table(customer)
    db.add_table(orders)
    return db


def cross_model_query() -> Query:
    """Match + two joins + predicates on table, document-ish and graph vars:
    exercises Select/EquiJoin/MatchPattern (TableJoinMatch in single mode)."""
    return Query(
        select=("Customer.id", "Orders.order_id", "Orders.quantity",
                "t.tid", "p.pid"),
        froms=("Customer", "Orders"),
        match=chain_pattern("G", ("p", "Persons", "G", "t", "Tags")),
        joins=(JoinPred("Customer.person_id", "p.pid"),
               JoinPred("Orders.customer_id", "Customer.id")),
        where=(Predicate("Orders.quantity", ">=", 2),
               Predicate("t.content", "==", "food")),
    )


def _col_vals(t: Table, name: str) -> np.ndarray:
    c = t.columns[name]
    if isinstance(c, DictColumn):
        return c.decode(c.codes)
    return np.asarray(c)


def assert_tables_equal(a: Table, b: Table) -> None:
    assert list(a.columns) == list(b.columns)
    assert a.nrows == b.nrows
    for name in a.columns:
        va, vb = _col_vals(a, name), _col_vals(b, name)
        assert np.array_equal(va, vb), f"column {name} diverged"


def apply_mutation(g: Graph, op: str, rng: np.random.Generator) -> None:
    """One step of the random delta/tombstone/compaction stream. The rng is
    consumed identically for both databases, so the streams are identical."""
    if op == "edges":
        m = int(rng.integers(10, 60))
        g.insert_edges({
            "svid": rng.integers(0, 160, m).astype(np.int64),
            "tvid": rng.integers(0, 24, m).astype(np.int64),
            "weight": rng.uniform(0.0, 1.0, m),
        })
    elif op == "tombstone":
        live = g.live_edge_ids()
        m = min(int(rng.integers(5, 40)), len(live))
        if m:
            g.delete_edges(rng.choice(live, m, replace=False))
    elif op == "compact":
        g.compact()


# ---------------------------------------------------------------------------
# satellite 3: property test — sharded == serial bit-for-bit
# ---------------------------------------------------------------------------

@st.composite
def shard_scenario(draw):
    mode = draw(st.sampled_from(MODES))
    k = draw(st.sampled_from((1, 2, 4, 7)))
    seed = draw(st.integers(min_value=0, max_value=2**16))
    n_ops = draw(st.integers(min_value=1, max_value=3))
    ops = tuple(draw(st.sampled_from(("edges", "tombstone", "compact")))
                for _ in range(n_ops))
    return mode, k, seed, ops


@settings(max_examples=10, deadline=None)
@given(shard_scenario())
def test_sharded_matches_serial_under_mutation_stream(scenario):
    mode, k, seed, ops = scenario
    db_a, db_b = tiny_db(seed), tiny_db(seed)
    q = cross_model_query()
    saved = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0          # force sharding on the tiny fixture
    try:
        serial = GredoEngine(db_a, mode=mode)
        sharded = GredoEngine(db_b, mode=mode, n_shards=k)
        assert_tables_equal(serial.query(q), sharded.query(q))
        rng_a = np.random.default_rng(seed + 1)
        rng_b = np.random.default_rng(seed + 1)
        for op in ops:
            apply_mutation(db_a.graphs["G"], op, rng_a)
            apply_mutation(db_b.graphs["G"], op, rng_b)
            assert_tables_equal(serial.query(q), sharded.query(q))
        if k > 1:
            assert sharded.last_shard_count == k
    finally:
        cost.SHARD_MIN_ROWS = saved


def test_sharded_gcda_born_sharded_and_equal():
    """Rel2Matrix output must reach the GCDA kernel without a host gather
    (asserted through the sharding spec in the span metadata) and the gram
    matrix must equal the serial one bit-for-bit."""
    task = GCDIATask(
        integration=cross_model_query(),
        analytics=AnalyticsTask("MULTIPLY", [
            ("rel2matrix", ("Orders.quantity", "Orders.order_id", "t.tid"))]),
    )
    saved = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0
    try:
        serial = GredoEngine(tiny_db(7), mode="gredo")
        sharded = GredoEngine(tiny_db(7), mode="gredo", n_shards=4,
                              telemetry=True)
        ref = np.asarray(serial.analyze(task))
        got = np.asarray(sharded.analyze(task))
        assert np.array_equal(ref, got)
        spans = [s for s in sharded.telemetry.collector.last().spans
                 if s.name == "Rel2Matrix"]
        assert spans and spans[0].args.get("born_sharded") is True
        assert spans[0].args.get("host_gather") is False
        assert spans[0].args.get("shards") == 4
    finally:
        cost.SHARD_MIN_ROWS = saved


# ---------------------------------------------------------------------------
# satellite 2: shard provenance in explain + skew metrics
# ---------------------------------------------------------------------------

def test_explain_shows_shard_provenance_and_metrics():
    saved = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0
    try:
        eng = GredoEngine(tiny_db(3), mode="gredo", n_shards=4,
                          telemetry=True)
        eng.query(cross_model_query())
        txt = eng.explain_last()
        assert "shards=4" in txt
        assert "Exchange" in txt
        assert "sharded execution: k=4" in txt
        snap = eng.telemetry.registry.snapshot()
        assert snap.get("shard.morsels", 0) >= 1
        assert snap.get("shard.rows_shard_max", 0) >= snap.get(
            "shard.rows_shard_mean", 0)
        assert "shard.queue_wait_s" in snap
    finally:
        cost.SHARD_MIN_ROWS = saved


def test_exchange_partition_reused_across_queries():
    saved = cost.SHARD_MIN_ROWS
    cost.SHARD_MIN_ROWS = 0
    try:
        eng = GredoEngine(tiny_db(11), mode="gredo", n_shards=4)
        q = cross_model_query()
        eng.query(q)
        m0 = eng._shard_runtime.metrics()
        eng.query(q)
        m1 = eng._shard_runtime.metrics()
        assert m1["exchanges_reused"] > m0["exchanges_reused"]
        assert m1["exchanges_built"] == m0["exchanges_built"]
    finally:
        cost.SHARD_MIN_ROWS = saved


# ---------------------------------------------------------------------------
# tentpole internals: cost gate, hash partitions, per-shard stats rollup
# ---------------------------------------------------------------------------

def test_cost_gate_keeps_small_inputs_serial():
    assert cost.choose_shard_count(100, 4) == 1
    assert cost.choose_shard_count(cost.SHARD_MIN_ROWS * 10, 4) == 4
    assert cost.choose_shard_count(cost.SHARD_MIN_ROWS * 10, 1) == 1
    # end to end: the tiny fixture is far below SHARD_MIN_ROWS, so a 4-shard
    # engine must still choose the single-stream plan.
    eng = GredoEngine(tiny_db(5), mode="gredo", n_shards=4)
    eng.query(cross_model_query())
    assert eng.last_shard_count == 1
    assert "Exchange" not in eng.explain_last()


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**16),
       st.sampled_from((1, 2, 4, 7)), st.booleans())
def test_build_partition_probe_matches_equi_join(seed, k, as_str):
    rng = np.random.default_rng(seed)
    n_l, n_r = int(rng.integers(1, 400)), int(rng.integers(1, 400))
    lk = rng.integers(0, 50, n_l).astype(np.int64)
    rk = rng.integers(0, 50, n_r).astype(np.int64)
    if as_str:
        lt = Table("L", {"key": DictColumn([f"k{v}" for v in lk])})
        rt = Table("R", {"key": DictColumn([f"k{v}" for v in rk])})
    else:
        lt = Table("L", {"key": lk})
        rt = Table("R", {"key": rk})
    li_ref, ri_ref = join_mod.equi_join_indices(lt, "key", rt, "key")

    part = shard.build_partition(rt, "key", k)
    lkeys, lrows = join_mod._key_arrays(lt, "key")
    sh_ids = shard.hash_shard_ids(lkeys, k)
    li, ri = [], []
    for i in range(n_l):
        s = int(sh_ids[i])
        ks = part.keys[s]
        lo = int(np.searchsorted(ks, lkeys[i], "left"))
        hi = int(np.searchsorted(ks, lkeys[i], "right"))
        for p in range(lo, hi):
            li.append(lrows[i])
            ri.append(part.rows_cat[part.base[s] + p])
    assert np.array_equal(np.asarray(li, dtype=np.int64), li_ref)
    assert np.array_equal(np.asarray(ri, dtype=np.int64), ri_ref)
    assert int(part.rows_per_shard().sum()) == n_r


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**16),
       st.sampled_from((1, 2, 4, 7)))
def test_per_shard_stats_rollup_is_exact(seed, k):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 3000))
    tbl = Table("S", {
        "num": rng.integers(0, 40, n).astype(np.int64),
        "cat": DictColumn(rng.choice(["a", "b", "c", "d"], n)),
    })
    shards = TableShards(tbl, k, align=64)
    for col in ("num", "cat"):
        whole = compute_stats(tbl.columns[col])
        rolled = merge_stats([shards.shard_stats(col)[i]
                              for i in range(len(shards.bounds))])
        assert rolled.n == whole.n
        assert rolled.ndv == whole.ndv
        if whole.value_counts is not None:
            assert rolled.value_counts == whole.value_counts
        if whole.hist is not None and rolled.hist is not None:
            assert np.isclose(rolled.hist.sum(), whole.hist.sum())
            assert np.isclose(rolled.vmin, whole.vmin)
            assert np.isclose(rolled.vmax, whole.vmax)


def test_table_shards_concat_roundtrip():
    rng = np.random.default_rng(0)
    n = 999
    tbl = Table("T", {
        "a": rng.integers(0, 9, n).astype(np.int64),
        "s": DictColumn(rng.choice(["x", "y", "z"], n)),
    })
    ts = TableShards(tbl, 4, align=128)
    lo_hi = ts.bounds
    assert lo_hi[0][0] == 0 and lo_hi[-1][1] == n
    assert all(lo_hi[i][1] == lo_hi[i + 1][0] for i in range(len(lo_hi) - 1))
    cat_a = np.concatenate([_col_vals(ts.shard(i), "a")
                            for i in range(len(lo_hi))])
    cat_s = np.concatenate([_col_vals(ts.shard(i), "s")
                            for i in range(len(lo_hi))])
    assert np.array_equal(cat_a, _col_vals(tbl, "a"))
    assert np.array_equal(cat_s, _col_vals(tbl, "s"))
    assert int(np.sum(ts.rows_per_shard())) == n


def test_graph_partitions_account_for_delta_and_tombstones():
    db = tiny_db(2)
    g = db.graphs["G"]
    rng = np.random.default_rng(2)
    g.insert_edges({"svid": rng.integers(0, 160, 50).astype(np.int64),
                    "tvid": rng.integers(0, 24, 50).astype(np.int64),
                    "weight": rng.uniform(0.0, 1.0, 50)})
    g.delete_edges(g.live_edge_ids()[:30])
    parts = GraphPartitions(g, 4)
    assert int(np.sum(parts.edges_per_partition())) == g.n_live_edges
    assert int(np.sum(parts.tombstones_per_partition())) == 30
    assert parts.fresh()
    g.insert_edges({"svid": np.array([0], dtype=np.int64),
                    "tvid": np.array([0], dtype=np.int64),
                    "weight": np.array([0.5])})
    assert not parts.fresh()


def test_shard_bounds_cover_and_align():
    for n in (0, 1, 100, 4097):
        for k in (1, 2, 4, 7):
            b = shard_bounds(n, k, align=64)
            assert len(b) == k
            assert b[0][0] == 0 and b[-1][1] == n
            for (lo, hi), (lo2, _hi2) in zip(b, b[1:]):
                assert hi == lo2
                assert lo % 64 == 0 or lo == n


# ---------------------------------------------------------------------------
# satellite 1: concurrent access to InterBuffer / Registry / TraceCollector
# ---------------------------------------------------------------------------

def test_concurrent_interbuffer_registry_collector():
    ib = InterBuffer(capacity_bytes=1 << 20)
    reg = telemetry.Registry()
    col = telemetry.TraceCollector(max_spans=256)
    errors: list[BaseException] = []
    n_threads, n_iter = 8, 200

    def worker(tid: int):
        try:
            rng = np.random.default_rng(tid)
            for i in range(n_iter):
                key = f"k{tid % 4}:{i % 8}"
                ib.put(key, rng.standard_normal(32), est_cost=1.0)
                ib.get(key)
                ib.get(f"k{(tid + 1) % 4}:{i % 8}")
                reg.counter("t.ops").inc()
                reg.histogram("t.lat").observe(float(i))
                qt = col.start_query(f"q{tid}")
                qt.instant("tick", i=i)
                col.trim()
        except BaseException as e:   # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errors
    snap = reg.snapshot()
    assert snap["t.ops"] == n_threads * n_iter
    assert snap["t.lat.count"] == n_threads * n_iter
    assert col.last() is not None
