"""Physical operator DAG: plan-shape snapshots per engine mode, operator-
level inter-buffer reuse (structural plan matching at the node level), and
the capacity-doubling incremental merged record views."""
import numpy as np
import pytest

from repro.core import GredoEngine, physical
from repro.core.schema import AnalyticsTask, GCDIATask
from repro.core.storage import Graph, Table
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1)


# ---------------------------------------------------------------------------
# Plan-shape snapshots: explain(dag) golden strings per ablation mode.
# ``physical_plan`` is the *naive* lowering (query-order joins, no semi-join
# siding) — the optimized shapes live in tests/test_optimizer.py.
# ---------------------------------------------------------------------------

GOLDEN = {
    ("q_g1", "gredo"): """\
Project[Customer.id, t.tid]
  EquiJoin[Customer.person_id=p.pid]
    Alias[Customer]
      ScanTable[Customer]
    GraphProject[Interested_in keep=p,t]
      MatchPattern[Interested_in dir=rev hops=1 pushed=t:1 deferred=-]""",
    ("q_g1", "dual"): """\
Project[Customer.id, t.tid]
  EquiJoin[Customer.person_id=p.pid]
    Alias[Customer]
      ScanTable[Customer]
    GraphProject[Interested_in keep=e0,p,t]
      MatchPattern[Interested_in dir=fwd hops=1 pushed=- deferred=t:1]""",
    ("q_g1", "single"): """\
Project[Customer.id, t.tid]
  EquiJoin[Customer.person_id=p.pid]
    Alias[Customer]
      ScanTable[Customer]
    GraphProject[Interested_in keep=e0,p,t]
      TableJoinMatch[Interested_in hops=1]""",
    ("q_g4", "gredo"): """\
Project[Customer.id, t.tid]
  EquiJoin[Customer.person_id=p.pid]
    EquiJoin[Orders.customer_id=Customer.id]
      EquiJoin[Product.id=Orders.product_id]
        Alias[Product]
          Select[Product.title == 'Yogurt']
            ScanTable[Product]
        Alias[Orders]
          ScanTable[Orders]
      Alias[Customer]
        ScanTable[Customer]
    GraphProject[Interested_in keep=p,t]
      MatchPattern[Interested_in dir=rev hops=1 pushed=- deferred=-]""",
    ("q_vertex_scan", "gredo"): """\
Project[t.tid]
  GraphProject[Interested_in keep=t]
    VertexScan[Interested_in.t]""",
    ("q_edge_scan", "gredo"): """\
Project[e0.weight]
  GraphProject[Interested_in keep=e0]
    EdgeScan[Interested_in.e0]""",
}


@pytest.mark.parametrize("qname,mode", sorted(GOLDEN))
def test_plan_shape_snapshot(db, qname, mode):
    eng = GredoEngine(db, mode=mode)
    q = getattr(m2bench, qname)()
    assert physical.explain(eng.physical_plan(q)) == GOLDEN[(qname, mode)]


def test_engine_explain_renders_pre_and_post_rewrite(db):
    """In full-system mode engine.explain shows both DAGs with estimates;
    the ablation variants render the single (naive == executed) plan."""
    out = GredoEngine(db).explain(m2bench.q_g1())
    assert "naive DAG (pre-rewrite)" in out
    assert "optimized DAG (post-rewrite)" in out
    assert "est_rows=" in out and "est_cost=" in out
    assert "== rewrites ==" in out
    out_dual = GredoEngine(db, mode="dual").explain(m2bench.q_g1())
    assert "pre-rewrite" not in out_dual and "est_rows=" in out_dual


def test_explain_last_shows_est_vs_actual_and_counters(db):
    eng = GredoEngine(db)
    eng.query(m2bench.q_g1())
    out = eng.explain_last()
    assert "rows=" in out and "est_rows=" in out    # actual next to estimate
    assert "interbuffer: hits=" in out and "bypasses=" in out


def test_every_mode_executes_through_the_dag(db):
    """All three ablation variants run the same executor: the DAG result
    matches engine.query and per-operator stats are populated."""
    q = m2bench.q_g1()
    for mode in ("gredo", "dual", "single"):
        eng = GredoEngine(db, mode=mode)
        r = eng.query(q)
        ops = [o["op"] for o in eng.last_stats.operators]
        assert ops[0] == "Project" and "GraphProject" in ops
        executed = [o for o in eng.last_stats.operators if o["executed"]]
        assert executed and all(o["seconds"] >= 0 for o in executed)
        assert r.nrows == eng.last_stats.operators[0]["rows"]


def test_cost_estimates_cover_every_operator(db):
    """§6.3 cost-model annotation: every node of every mode's plan gets a
    finite, non-negative (est_rows, est_cost) pair, rendered by explain."""
    for qname in ("q_g1", "q_g4", "q_vertex_scan", "q_edge_scan"):
        q = getattr(m2bench, qname)()
        for mode in ("gredo", "dual", "single"):
            dag = GredoEngine(db, mode=mode).physical_plan(q)
            ests = physical.estimate(dag, db)
            assert ests and all(r >= 0 and c >= 0 and np.isfinite(r + c)
                                for r, c in ests.values())
            rendered = physical.explain(dag, db=db)
            assert "est_cost=" in rendered and "est_rows=" in rendered


def test_node_signatures_embed_epochs_and_structure(db):
    eng = GredoEngine(db)
    s1 = eng.physical_plan(m2bench.q_g1()).signature()
    s2 = eng.physical_plan(m2bench.q_g1()).signature()
    assert s1 == s2  # deterministic across builds
    assert eng.physical_plan(m2bench.q_g2()).signature() != s1
    # a different mode produces a structurally different plan
    assert GredoEngine(db, mode="single").physical_plan(
        m2bench.q_g1()).signature() != s1


# ---------------------------------------------------------------------------
# Operator-level inter-buffer reuse (§6.4 structural matching, per node)
# ---------------------------------------------------------------------------


def _task(op, inputs):
    return GCDIATask(integration=m2bench.q_g1(),
                     analytics=AnalyticsTask(op, inputs))


def test_changed_analytics_op_reuses_gcdi_relation():
    """A repeated GCDIA with a *different* analytics op (and different matrix
    generation) skips GCDI re-execution: the shared GCDI root hits the
    inter-buffer by node signature."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    eng.analyze(_task("MULTIPLY", [("rel2matrix", ("Customer.id", "t.tid"))]))
    assert eng.interbuffer.hits == 0
    fetches_cold = eng.last_stats.record_fetches
    assert fetches_cold > 0

    eng.analyze(_task("SIMILARITY",
                      [("random", "Customer.id", "t.tid", m2bench.N_TAGS)]))
    assert eng.interbuffer.hits == 1          # hit at the GCDI Project node
    assert eng.last_stats.record_fetches == 0  # GCDI never re-executed
    by_op = {o["op"]: o for o in eng.last_stats.operators}
    assert by_op["Project"]["cached"] and not by_op["Project"]["executed"]
    assert not by_op["MatchPattern"]["executed"]
    assert by_op["Similarity"]["executed"]
    assert eng.last_stats.nodes_reused == 1
    assert "interbuffer-hit" in eng.explain_last()


def test_epoch_bump_invalidates_mid_plan_reuse():
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    eng.analyze(_task("MULTIPLY", [("rel2matrix", ("Customer.id", "t.tid"))]))
    db.graphs["Interested_in"].insert_edges(
        {"svid": np.array([0]), "tvid": np.array([1]),
         "weight": np.array([0.5])})
    eng.analyze(_task("SIMILARITY",
                      [("random", "Customer.id", "t.tid", m2bench.N_TAGS)]))
    assert eng.interbuffer.hits == 0          # every signature changed
    assert eng.last_stats.record_fetches > 0  # GCDI re-executed
    by_op = {o["op"]: o for o in eng.last_stats.operators}
    assert by_op["Project"]["executed"] and not by_op["Project"]["cached"]


def test_identical_task_hits_at_the_root():
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db)
    t = _task("SIMILARITY", [("random", "Customer.id", "t.tid", m2bench.N_TAGS)])
    out1 = eng.analyze(t)
    out2 = eng.analyze(t)
    assert eng.interbuffer.hits == 1
    assert eng.last_stats.interbuffer_hit    # whole-result reuse at the root
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_shared_subplans_execute_once(db):
    """The Customer scan feeds both the semi-join mask and the join cluster;
    signature memoization must run it once per execution."""
    eng = GredoEngine(db)
    eng.query(m2bench.q_g1())
    scans = [o for o in eng.last_stats.operators if o["op"] == "ScanTable"]
    assert len(scans) == 1  # collect_stats reports shared nodes once


# ---------------------------------------------------------------------------
# Incremental merged record views (capacity-doubling column buffers)
# ---------------------------------------------------------------------------


def _small_graph():
    from repro.core.deltastore import DeltaConfig
    from repro.core.storage import DictColumn, RaggedColumn
    rng = np.random.default_rng(0)
    vt = Table("A", {"attr": rng.integers(0, 5, 10).astype(np.int64),
                     "tag": DictColumn(values=[("x", "y")[i % 2] for i in range(10)]),
                     "xs": RaggedColumn(lists=[[i, i + 1] for i in range(10)])})
    edges = Table("E", {"svid": rng.integers(0, 10, 30).astype(np.int64),
                        "tvid": rng.integers(0, 10, 30).astype(np.int64),
                        "w": rng.uniform(0, 1, 30)})
    return Graph("G", {"A": vt}, edges, "A", "A",
                 delta_config=DeltaConfig(auto_compact=False))


def test_merged_views_append_only_the_delta_tail():
    g = _small_graph()
    g.insert_edges({"svid": np.array([0]), "tvid": np.array([1]),
                    "w": np.array([0.5])})
    e1 = g.edges
    merger = g._edge_merger
    assert merger is not None and merger._cached_runs == 1
    assert g.edges is e1                      # cached until the next write
    g.insert_edges({"svid": np.array([2]), "tvid": np.array([3]),
                    "w": np.array([0.7])})
    e2 = g.edges
    assert g._edge_merger is merger           # same buffers, tail appended
    assert merger._cached_runs == 2
    assert e2.nrows == 32
    np.testing.assert_allclose(np.asarray(e2.col("w"))[-2:], [0.5, 0.7])
    # base prefix identical to the first merged view
    np.testing.assert_array_equal(np.asarray(e2.col("svid"))[:31],
                                  np.asarray(e1.col("svid")))


def test_merged_vertex_views_all_column_kinds():
    g = _small_graph()
    base_tags = list(g.vertex_tables["A"].col("tag").decode(
        g.vertex_tables["A"].col("tag").codes))
    g.insert_vertices("A", {"attr": np.array([7]), "tag": ["z"],
                            "xs": [[99, 100]]})
    g.insert_vertices("A", {"attr": np.array([8]), "tag": ["x"],
                            "xs": [[]]})
    vt = g.vertex_tables["A"]
    assert vt.nrows == 12
    assert list(np.asarray(vt.col("attr"))[-2:]) == [7, 8]
    tags = list(vt.col("tag").decode(vt.col("tag").codes))
    assert tags == base_tags + ["z", "x"]
    assert len(vt.col("tag").vocab) == 3      # one genuinely new value
    assert list(vt.col("xs").row(10)) == [99, 100]
    assert list(vt.col("xs").row(11)) == []
    # one merger per label, reused across write/read cycles
    assert g._vt_mergers["A"]._cached_runs == 2


def test_ragged_merge_promotes_float_into_int_values():
    """np.concatenate semantics for ragged columns too: a float row into an
    int-valued RaggedColumn must promote, not truncate."""
    g = _small_graph()   # xs base values are ints
    g.insert_vertices("A", {"attr": np.array([1]), "tag": ["x"],
                            "xs": [[1.5, 2.5]]})
    xs = g.vertex_tables["A"].col("xs")
    assert np.asarray(xs.values).dtype.kind == "f"
    np.testing.assert_allclose(xs.row(10), [1.5, 2.5])
    np.testing.assert_allclose(xs.row(0), [0, 1])  # base rows intact


def test_merged_views_survive_compaction_cycle():
    g = _small_graph()
    g.insert_edges({"svid": np.array([0, 1]), "tvid": np.array([1, 2]),
                    "w": np.array([0.5, 0.6])})
    before = np.asarray(g.edges.col("w")).copy()
    g.compact()
    assert g._edge_merger is None             # fresh base, merger reset
    np.testing.assert_allclose(np.asarray(g.edges.col("w")), before)
    g.insert_edges({"svid": np.array([3]), "tvid": np.array([4]),
                    "w": np.array([0.9])})
    assert g.edges.nrows == 33                # post-compaction merging works
