"""Multi-device behaviour of the beyond-paper distribution features, run in
subprocesses with 8 host platform devices (XLA device count is fixed at
process start, so these cannot run in the main pytest process)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_distributed_regression_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import analytics
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(8, 1)
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((512, 24)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 2, 512), jnp.float32)
        w_d, _ = analytics.regression_distributed(X, y, mesh, iters=40)
        w_l, _ = analytics.regression(X, y, iters=40, use_kernel=False)
        np.testing.assert_allclose(np.asarray(w_d), np.asarray(w_l),
                                   rtol=5e-3, atol=5e-4)
        print("OK distributed regression")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_seq_sharded_decode_matches_dense():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models.transformer import (TransformerConfig, init_params,
                                              forward, init_cache, serve_step)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=2, d_ff=96, vocab=128,
                                dtype=jnp.float32, attn_impl="dense")
        cfg_d = dataclasses.replace(cfg, mesh=mesh, mesh_dp=("data",),
                                    kv_seq_shard="model")
        p = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, 128)
        nxt = jax.random.randint(jax.random.PRNGKey(2), (4, 1), 0, 128)
        with mesh:
            cache = jax.tree.map(lambda c: jax.device_put(
                c, NamedSharding(mesh, P(None, "data", None, "model", None))),
                init_cache(cfg, 4, 32))
            _, cache = forward(p, toks, cfg_d, cache=cache,
                               cache_lengths=jnp.zeros(4, jnp.int32))
            nl, _ = serve_step(p, cache, nxt, jnp.full(4, 24, jnp.int32), cfg_d)
        cache2 = init_cache(cfg, 4, 32)
        _, cache2 = forward(p, toks, cfg, cache=cache2,
                            cache_lengths=jnp.zeros(4, jnp.int32))
        nl2, _ = serve_step(p, cache2, nxt, jnp.full(4, 24, jnp.int32), cfg)
        np.testing.assert_allclose(np.asarray(nl), np.asarray(nl2),
                                   rtol=3e-4, atol=3e-4)
        print("OK seq-sharded decode")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_shard_map_moe_matches_local():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.models.transformer import TransformerConfig, init_params, forward
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                                n_kv_heads=2, d_ff=16, vocab=64, n_experts=8,
                                top_k=2, capacity_factor=4.0,
                                dtype=jnp.float32, moe_groups=2)
        cfg_sm = dataclasses.replace(cfg, mesh=mesh, mesh_dp=("data",),
                                     moe_ep_axis="model", moe_impl="shard_map")
        p = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 64)
        l1, _ = forward(p, toks, cfg)
        with mesh:
            l2, _ = jax.jit(lambda pp, tt: forward(pp, tt, cfg_sm))(p, toks)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-4, atol=5e-4)
        print("OK shard_map moe")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_retrieval_matches_bruteforce():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import recsys
        from repro import configs
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = configs.get("wide_deep").smoke_config()
        p = recsys.init_params(jax.random.PRNGKey(0), cfg)
        batch = recsys.random_batch(cfg, 2, seed=5)
        cands = jnp.asarray(np.random.default_rng(6).standard_normal(
            (512, cfg.tower_dim)), jnp.float32)
        v0, i0 = recsys.retrieval_step(p, batch["dense"], batch["sparse"],
                                       cands, cfg, top_k=16)
        with mesh:
            v1, i1 = jax.jit(lambda *a: recsys.retrieval_step_distributed(
                *a, cfg, mesh, top_k=16))(p, batch["dense"], batch["sparse"],
                                          cands.astype(jnp.bfloat16))
        for b in range(2):
            overlap = len(set(np.asarray(i0[b]).tolist())
                          & set(np.asarray(i1[b]).tolist())) / 16
            assert overlap >= 0.85, overlap
        print("OK distributed retrieval")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_gcda_multiply_on_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import analytics
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(2, 4)
        rng = np.random.default_rng(1)
        X = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        Y = jnp.asarray(rng.standard_normal((32, 48)), jnp.float32)
        Z = analytics.multiply(X, Y, mesh=mesh)
        np.testing.assert_allclose(np.asarray(Z), np.asarray(X) @ np.asarray(Y),
                                   rtol=1e-4, atol=1e-4)
        S = analytics.similarity(X, X, mesh=mesh)
        assert S.shape == (64, 64)
        print("OK gcda mesh ops")
    """)
    assert "OK" in out
