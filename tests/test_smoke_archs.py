"""Required deliverable (f): per assigned architecture, instantiate a REDUCED
same-family config and run one forward/train step on CPU, asserting output
shapes and absence of NaNs. The FULL configs are exercised via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs

LM_ARCHS = ["olmoe_1b_7b", "granite_moe_1b_a400m", "starcoder2_3b",
            "qwen2_1_5b", "stablelm_3b"]
GNN_FEATURE_ARCHS = ["gatedgcn", "pna"]
GNN_EQUIV_ARCHS = ["mace", "equiformer_v2"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as tfm
    cfg = configs.get(arch).smoke_config()
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # one train step
    loss, nll = tfm.loss_fn(params, {"tokens": toks, "labels": toks}, cfg)
    grads = jax.grad(lambda p: tfm.loss_fn(
        p, {"tokens": toks, "labels": toks}, cfg)[0])(params)
    assert np.isfinite(float(loss))
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads))
    # decode step
    cache = tfm.init_cache(cfg, 2, 24)
    _, cache = tfm.forward(params, toks, cfg, cache=cache,
                           cache_lengths=jnp.zeros(2, jnp.int32))
    nl, _ = tfm.serve_step(params, cache, toks[:, :1],
                           jnp.full(2, 16, jnp.int32), cfg)
    assert nl.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(nl).all())


@pytest.mark.parametrize("arch", GNN_FEATURE_ARCHS)
def test_gnn_feature_smoke(arch):
    from repro.data.graphs import random_feature_graph
    mod_cfg = configs.get(arch)
    cfg = mod_cfg.smoke_config()
    if arch == "gatedgcn":
        from repro.models.gnn import gatedgcn as mod
    else:
        from repro.models.gnn import pna as mod
    g, labels = random_feature_graph(40, 160, cfg.d_in, cfg.n_classes)
    p = mod.init_params(jax.random.PRNGKey(0), cfg)
    logits = mod.forward(p, g, cfg)
    assert logits.shape == (40, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    loss = mod.loss_fn(p, g, labels, cfg)
    grads = jax.grad(lambda pp: mod.loss_fn(pp, g, labels, cfg))(p)
    assert np.isfinite(float(loss))
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), grads))


@pytest.mark.parametrize("arch", GNN_EQUIV_ARCHS)
def test_gnn_equivariant_smoke(arch):
    from repro.data.graphs import random_molecule_batch
    cfg = configs.get(arch).smoke_config()
    if arch == "mace":
        from repro.models.gnn import mace as mod
    else:
        from repro.models.gnn import equiformer_v2 as mod
    g, energies = random_molecule_batch(4, 8, 20, n_species=cfg.n_species)
    p = mod.init_params(jax.random.PRNGKey(0), cfg)
    pred = mod.forward(p, g, cfg)
    assert pred.shape == (4,)
    assert bool(jnp.isfinite(pred).all())
    loss = mod.loss_fn(p, g, energies, cfg)
    grads = jax.grad(lambda pp: mod.loss_fn(pp, g, energies, cfg))(p)
    assert np.isfinite(float(loss))
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), grads))


def test_recsys_smoke():
    from repro.models import recsys
    cfg = configs.get("wide_deep").smoke_config()
    p = recsys.init_params(jax.random.PRNGKey(0), cfg)
    batch = recsys.random_batch(cfg, 32)
    scores = recsys.serve_step(p, batch["dense"], batch["sparse"], cfg)
    assert scores.shape == (32,)
    assert bool(jnp.isfinite(scores).all())
    loss = recsys.loss_fn(p, batch, cfg)
    grads = jax.grad(recsys.loss_fn)(p, batch, cfg)
    assert np.isfinite(float(loss))
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), grads))


def test_registry_covers_all_cells():
    cells = list(configs.all_cells(include_skipped=True))
    assert len(cells) == 40, f"expected 40 assigned cells, got {len(cells)}"
    skipped = [c for c in cells if c[2].get("skip")]
    assert len(skipped) == 5  # long_500k for the 5 full-attention LMs
    runnable = list(configs.all_cells())
    assert len(runnable) == 35
