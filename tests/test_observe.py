"""Tests for repro.core.observe: flight recorder (ring, triggers, dumps),
health rules, workload capture & replay, and the observer's disabled-path
overhead bound."""
import json
import os
import tempfile
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import observe, telemetry, verify
from repro.core.engine import GredoEngine
from repro.core.storage import DictColumn
from repro.data import m2bench

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def db():
    return m2bench.generate(sf=1)


# =========================================================================
# flight recorder: capture + ring bound
# =========================================================================

def test_flight_record_captured_per_query(db):
    eng = GredoEngine(db)          # observer is default-on, telemetry off
    eng.query(m2bench.q_g1())
    assert eng.observer is not None and len(eng.observer.ring) == 1
    rec = eng.observer.ring[-1]
    assert rec.kind == "query" and rec.mode == "gredo"
    assert len(rec.plan_fingerprint) == 16
    assert rec.operators and rec.seconds > 0
    assert rec.label.startswith("query")
    # telemetry off: record still exists, but no spans / registry delta
    assert rec.spans == [] and rec.registry_delta == {}
    json.dumps(rec.to_json())      # records are JSON-shaped by construction


def test_ring_is_bounded(db):
    fr = observe.FlightRecorder(ring=4, auto_dump=False)
    eng = GredoEngine(db, observe=fr)
    for _ in range(7):
        eng.query(m2bench.q_edge_scan())
    assert len(fr.ring) == 4
    assert fr.seq == 7
    assert fr.metrics()["records"] == 7.0


def test_observe_false_opts_out(db):
    eng = GredoEngine(db, observe=False)
    eng.query(m2bench.q_edge_scan())
    assert eng.observer is None
    assert "== health ==" not in eng.explain_last()


# =========================================================================
# triggers + dump contents
# =========================================================================

def test_slo_breach_dump_has_fingerprint_spans_and_registry_delta(db):
    with tempfile.TemporaryDirectory() as tmp:
        fr = observe.FlightRecorder(default_slo=1e-9, dump_dir=tmp)
        eng = GredoEngine(db, telemetry=True, observe=fr)
        eng.query(m2bench.q_g1())
        assert fr.trigger_counts.get("slo-breach") == 1
        assert len(fr.dump_paths) == 1
        doc = json.load(open(fr.dump_paths[0]))
        assert doc["trigger"] == "slo-breach"
        rec = doc["record"]
        # the acceptance triple: plan fingerprint, span tree, registry delta
        assert len(rec["plan_fingerprint"]) == 16
        assert rec["spans"] and all("name" in s and "parent" in s
                                    for s in rec["spans"])
        assert rec["registry_delta"]
        assert doc["ring"] and doc["trigger_counts"]["slo-breach"] == 1
        assert os.path.basename(fr.dump_paths[0]).startswith("flight_")


def test_per_template_slo_only_fires_on_named_template(db):
    with tempfile.TemporaryDirectory() as tmp:
        eng = GredoEngine(db, observe=observe.FlightRecorder(
            slo={"nonexistent-template": 1e-9}, dump_dir=tmp))
        eng.query(m2bench.q_g1())
        assert eng.observer.trigger_counts == {}
        label = eng.observer.ring[-1].label
        eng2 = GredoEngine(db, observe=observe.FlightRecorder(
            slo={label: 1e-9}, dump_dir=tmp))
        eng2.query(m2bench.q_g1())
        assert eng2.observer.trigger_counts == {"slo-breach": 1}


def test_qerror_trigger_fires_when_monitor_flags(db):
    # threshold 1.0 flags every estimate (q-error >= 1 by definition)
    fr = observe.FlightRecorder(auto_dump=False)
    eng = GredoEngine(db, telemetry=telemetry.Telemetry(qerror_threshold=1.0),
                      observe=fr)
    eng.query(m2bench.q_g1())
    rec = fr.ring[-1]
    assert "qerror" in rec.triggers
    assert rec.qerrors and {"op", "est_rows", "actual_rows",
                            "q_error"} <= set(rec.qerrors[0])


def test_verify_error_dumps_failing_plan_and_report():
    db = m2bench.generate(sf=1)
    with tempfile.TemporaryDirectory() as tmp:
        fr = observe.FlightRecorder(dump_dir=tmp)
        eng = GredoEngine(db, debug=True, observe=fr)
        q = m2bench.q_shard_join()
        eng.query(q)                            # sane baseline
        t = db.tables["Orders"]
        t.columns["customer_id"] = DictColumn(  # join key: int64 -> dict
            ["c"] * len(np.asarray(t.columns["quantity"])))
        with pytest.raises(verify.PlanVerificationError):
            eng.query(q)
        assert fr.trigger_counts.get("verify-error") == 1
        path = fr.dump_paths[-1]
        assert "verify-error" in os.path.basename(path)
        doc = json.load(open(path))
        rec = doc["record"]
        assert rec["kind"] == "verify" and rec["verify"]
        assert len(rec["plan_fingerprint"]) == 16
        # the healthy baseline query is still in the dumped ring
        assert any(r["kind"] == "query" for r in doc["ring"])


def test_kernel_retry_storm_trigger(db):
    fr = observe.FlightRecorder(auto_dump=False, retry_storm=2)
    eng = GredoEngine(db, observe=fr)
    eng.query(m2bench.q_g1())
    assert "kernel-retry-storm" not in fr.ring[-1].triggers
    # simulate >= 2 overflow retries landing within one query
    fr._retries0 -= 5
    rec = fr.observe(eng)
    assert "kernel-retry-storm" in rec.triggers


def test_interbuffer_collapse_trigger(db):
    fr = observe.FlightRecorder(auto_dump=False)
    eng = GredoEngine(db, observe=fr)
    fr.hit_peak = 1.0            # as if an earlier epoch ran hot
    eng.analyze(m2bench.a3_multiply(), iters=2)   # cold: all misses
    rec = fr.ring[-1]
    assert rec.kind == "analyze"
    assert rec.interbuffer["misses"] > 0
    assert "interbuffer-collapse" in rec.triggers


def test_latency_anomaly_after_warmup():
    fr = observe.FlightRecorder(auto_dump=False, warmup=3,
                                anomaly_floor_s=0.0, anomaly_factor=4.0)

    def rec(seconds):
        fr.begin("t")            # syncs the kernel-retry baseline
        r = observe.QueryRecord(
            seq=fr.seq, ts=time.time(), label="t", kind="query",
            mode="gredo", plan_fingerprint="0" * 16, seconds=seconds,
            shard_count=1, operators=[], interbuffer={}, registry_delta={},
            qerrors=[], verify=[], spans=[], triggers=[])
        fr.seq += 1
        return fr._evaluate(r, None)

    for _ in range(3):
        assert "latency-anomaly" not in rec(0.01)
    assert "latency-anomaly" not in rec(0.02)      # within 4x of ewma
    assert "latency-anomaly" in rec(1.0)           # 4x ewma, past warmup


def test_max_dumps_throttles_incident_storms(db):
    with tempfile.TemporaryDirectory() as tmp:
        fr = observe.FlightRecorder(default_slo=0.0, dump_dir=tmp,
                                    max_dumps=2)
        eng = GredoEngine(db, observe=fr)
        for _ in range(5):
            eng.query(m2bench.q_edge_scan())
        assert len(fr.dump_paths) == 2
        assert len(os.listdir(tmp)) == 2
        assert fr.dumps_suppressed == 3
        assert fr.trigger_counts["slo-breach"] == 5
        assert fr.metrics()["dumps_suppressed"] == 3.0


def test_flight_metrics_exported_through_registry(db):
    eng = GredoEngine(db, telemetry=True)
    eng.query(m2bench.q_edge_scan())
    snap = eng.telemetry.registry.snapshot()
    assert snap["flight.records"] == 1.0
    assert "flight.dumps" in snap


# =========================================================================
# health rules
# =========================================================================

def test_health_report_all_rules_on_quiet_engine(db):
    eng = GredoEngine(db)
    eng.query(m2bench.q_g1())
    rep = eng.health()
    assert rep.status in (observe.OK, observe.WARN, observe.CRITICAL)
    assert len(rep.checks) == len(observe._HEALTH_RULES)
    assert "== health ==" in eng.explain_last()
    assert any("status:" in line for line in rep.render())


def test_health_rules_on_synthetic_snapshots():
    rep = observe.evaluate_health({"qerror.observations": 100,
                                   "qerror.flagged": 60})
    assert rep.status == observe.CRITICAL
    by = {c.name: c for c in rep.checks}
    assert by["qerror_drift"].level == observe.CRITICAL

    rep = observe.evaluate_health({"qerror.observations": 100,
                                   "qerror.flagged": 30})
    assert {c.name: c for c in rep.checks}["qerror_drift"].level \
        == observe.WARN

    rep = observe.evaluate_health({"shard.shard_partitions": 8,
                                   "shard.rows_shard_mean": 1.0,
                                   "shard.rows_shard_max": 20.0})
    assert {c.name: c for c in rep.checks}["shard_skew"].level \
        == observe.CRITICAL

    rep = observe.evaluate_health({"index.T/c.lookups": 100.0,
                                   "index.T/c.refreshes": 30.0})
    assert {c.name: c for c in rep.checks}["index_churn"].level \
        == observe.WARN

    rep = observe.evaluate_health({"traversal_kernels.matches": 10,
                                   "traversal_kernels.retries": 15})
    assert {c.name: c for c in rep.checks}["kernel_retries"].level \
        == observe.CRITICAL

    # under-evidence rules stay ok with a "(need N)" note
    rep = observe.evaluate_health({})
    assert rep.status == observe.OK
    assert all("need" in c.detail or "no " in c.detail.lower()
               for c in rep.checks)


def test_health_gauges_land_in_registry(db):
    eng = GredoEngine(db, telemetry=True)
    eng.query(m2bench.q_edge_scan())
    rep = eng.health()
    snap = eng.telemetry.registry.snapshot()
    assert snap["health.status"] == float(observe._LEVELS.index(rep.status))
    for c in rep.checks:
        assert snap[f"health.{c.name}"] == float(
            observe._LEVELS.index(c.level))


def test_health_slo_rule_uses_recorder_ewma(db):
    fr = observe.FlightRecorder(auto_dump=False, default_slo=1e-9)
    eng = GredoEngine(db, observe=fr)
    eng.query(m2bench.q_g1())
    rep = eng.health()
    by = {c.name: c for c in rep.checks}
    assert by["latency_slo"].level == observe.CRITICAL
    assert rep.status == observe.CRITICAL


# =========================================================================
# serialization round trips
# =========================================================================

def test_query_round_trip_through_json():
    for ctor in (m2bench.q_g1, m2bench.q_g3, m2bench.q_shard_join,
                 m2bench.q_point_lookup, m2bench.q_range_narrow,
                 m2bench.q_edge_scan):
        q = ctor()
        d = json.loads(json.dumps(observe.query_to_dict(q)))
        assert observe.query_from_dict(d) == q


def test_task_round_trip_through_json():
    for ctor in (m2bench.a3_multiply, m2bench.a2_similarity,
                 m2bench.a_shard_reg):
        t = ctor()
        d = json.loads(json.dumps(observe.task_to_dict(t)))
        assert observe.task_from_dict(d) == t


def test_result_fingerprint_is_content_addressed(db):
    eng = GredoEngine(db)
    a = observe.result_fingerprint(eng.query(m2bench.q_g1()))
    b = observe.result_fingerprint(eng.query(m2bench.q_g1()))
    c = observe.result_fingerprint(eng.query(m2bench.q_edge_scan()))
    assert a == b and a != c and len(a) == 16
    # arrays: dtype participates in the hash
    x = np.arange(8, dtype=np.int64)
    assert observe.result_fingerprint(x) \
        != observe.result_fingerprint(x.astype(np.float64))


# =========================================================================
# workload capture & replay
# =========================================================================

def _capture_workload(path, mode="gredo"):
    """Run a scripted interleaved query/mutation stream, recording it."""
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db, mode=mode)
    g = db.graphs["Interested_in"]
    with eng.record(path) as rec:
        eng.query(m2bench.q_g1())
        g.insert_edges({"svid": np.array([0, 1, 2], dtype=np.int64),
                        "tvid": np.array([1, 2, 3], dtype=np.int64),
                        "weight": np.array([0.5, 0.25, 0.75])})
        eng.query(m2bench.q_g1())              # sees the new edges
        live = g.live_edge_ids()
        g.delete_edges(np.asarray(live[:2]))
        eng.analyze(m2bench.a3_multiply(), iters=3)
        db.touch_table("Orders")
        eng.query(m2bench.q_edge_scan())
        assert rec.events >= 7                 # header + 6 ops + mutations
    return db, eng


def test_capture_replay_bit_for_bit():
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "workload.jsonl")
        db, eng = _capture_workload(path)
        events = [json.loads(l) for l in open(path)]
        assert events[0]["kind"] == "header" and events[0]["mode"] == "gredo"
        kinds = [e["kind"] for e in events[1:]]
        assert kinds.count("query") == 3 and kinds.count("analyze") == 1
        assert "insert_edges" in kinds and "delete_edges" in kinds \
            and "touch_table" in kinds
        # every query event carries a fingerprint and the epochs it saw
        for e in events[1:]:
            if e["kind"] in ("query", "analyze"):
                assert len(e["fp"]) == 16 and e["epochs"]

        db2 = m2bench.generate(sf=1)
        rep = observe.replay(db2, path, strict=True)
        assert rep.ok
        assert (rep.queries, rep.analytics, rep.mutations) == (3, 1, 3)
        # the replayed database converged to the same write state
        for name, g in db.graphs.items():
            g2 = db2.graphs[name]
            assert g2.epoch == g.epoch
            assert g2.write_counters.metrics() == g.write_counters.metrics()
        for name in db.tables:
            assert db2.epoch_of(name) == db.epoch_of(name)


def test_replay_strict_raises_on_divergence():
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "workload.jsonl")
        _capture_workload(path)
        lines = open(path).read().splitlines()
        for i, line in enumerate(lines):       # tamper with one captured fp
            ev = json.loads(line)
            if ev["kind"] == "query":
                ev["fp"] = "0" * 16
                lines[i] = json.dumps(ev)
                break
        open(path, "w").write("\n".join(lines) + "\n")
        with pytest.raises(observe.ReplayMismatch):
            observe.replay(m2bench.generate(sf=1), path, strict=True)
        rep = observe.replay(m2bench.generate(sf=1), path, strict=False)
        assert not rep.ok and len(rep.mismatches) == 1


def test_recorder_detaches_listeners_on_exit(db):
    eng = GredoEngine(db)
    with tempfile.TemporaryDirectory() as tmp:
        with eng.record(os.path.join(tmp, "w.jsonl")):
            assert eng._recorder is not None
            assert all(g.listeners for g in db.graphs.values())
            assert db.listeners
    assert eng._recorder is None
    assert all(not g.listeners for g in db.graphs.values())
    assert not db.listeners


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       mode=st.sampled_from(["gredo", "dual", "single"]))
def test_capture_replay_property(seed, mode):
    """Replay reproduces identical result relations and write-state deltas
    under random query/mutation interleavings, in every execution mode."""
    rng = np.random.default_rng(seed)
    steps = [["q_g1", "q_edge_scan", "q_vertex_scan", "edges", "tombstone",
              "analyze"][rng.integers(0, 6)] for _ in range(6)]
    db = m2bench.generate(sf=1)
    eng = GredoEngine(db, mode=mode)
    g = db.graphs["Interested_in"]
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "w.jsonl")
        with eng.record(path):
            for op in steps:
                if op == "edges":
                    m = int(rng.integers(1, 20))
                    g.insert_edges({
                        "svid": rng.integers(0, 100, m).astype(np.int64),
                        "tvid": rng.integers(0, m2bench.N_TAGS,
                                             m).astype(np.int64),
                        "weight": rng.uniform(0.0, 1.0, m)})
                elif op == "tombstone":
                    live = g.live_edge_ids()
                    m = min(int(rng.integers(1, 10)), len(live))
                    if m:
                        g.delete_edges(rng.choice(live, m, replace=False))
                elif op == "analyze":
                    eng.analyze(m2bench.a3_multiply(), iters=2)
                else:
                    eng.query(getattr(m2bench, op)())
        db2 = m2bench.generate(sf=1)
        rep = observe.replay(db2, path, strict=True)   # fp-checked per event
        assert rep.ok
        assert rep.queries + rep.analytics + rep.mutations >= len(steps)
        for name, src in db.graphs.items():
            dst = db2.graphs[name]
            assert dst.epoch == src.epoch
            assert dst.write_counters.metrics() \
                == src.write_counters.metrics()


# =========================================================================
# overhead bound: observer on vs. off
# =========================================================================

def test_observer_disabled_overhead_bounded(db):
    q = m2bench.q_edge_scan()
    on = GredoEngine(db)                   # observer on (default), tracing off
    off = GredoEngine(db, observe=False)
    for _ in range(3):                     # warm plan caches + JIT
        on.query(q)
        off.query(q)
    t_on, t_off = [], []
    for _ in range(15):
        t0 = time.perf_counter()
        off.query(q)
        t_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        on.query(q)
        t_on.append(time.perf_counter() - t0)
    # generous CI-noise bound; the honest figure on quiet hardware is <2%
    assert min(t_on) <= min(t_off) * 1.25
