"""Continuous batching == isolated greedy decoding, with mid-flight slot
refill (ragged request lengths)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (TransformerConfig, forward, init_cache,
                                      init_params, serve_step)
from repro.serving import ContinuousBatcher, Request

CFG = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab=97, dtype=jnp.float32,
                        attn_impl="dense")


def _standalone_greedy(params, prompt, max_new):
    P = len(prompt)
    cache = init_cache(CFG, 1, 128)
    logits, cache = forward(params, jnp.asarray(prompt, jnp.int32)[None], CFG,
                            cache=cache,
                            cache_lengths=jnp.zeros((1,), jnp.int32))
    out = [int(jnp.argmax(logits[0, P - 1]))]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(max_new - 1):
        logits, cache = serve_step(params, cache,
                                   jnp.asarray([[out[-1]]], jnp.int32),
                                   lengths, CFG)
        out.append(int(jnp.argmax(logits[0])))
        lengths = lengths + 1
    return out


def test_continuous_batching_matches_standalone():
    params = init_params(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 97, rng.integers(4, 20)),
                    max_new=int(rng.integers(3, 10)))
            for i in range(7)]
    batcher = ContinuousBatcher(params, CFG, n_slots=3, max_len=128)
    completions = batcher.serve(list(reqs))
    assert [c.rid for c in completions] == list(range(7))
    for req, comp in zip(reqs, completions):
        expect = _standalone_greedy(params, req.prompt, req.max_new)
        assert comp.tokens == expect, (req.rid, comp.tokens, expect)
    # continuous refill actually happened: more prefills than slots
    assert batcher.stats["prefills"] == 7
    assert max(batcher.stats["slot_occupancy"]) == 3


def test_eos_frees_slot_early():
    params = init_params(jax.random.PRNGKey(1), CFG)
    prompt = np.arange(5) % 97
    ref = _standalone_greedy(params, prompt, 16)
    eos = ref[2]  # force early stop at the 3rd generated token
    batcher = ContinuousBatcher(params, CFG, n_slots=2, max_len=128)
    comp = batcher.serve([Request(rid=0, prompt=prompt, max_new=16,
                                  eos_id=eos)])[0]
    assert comp.tokens[-1] == eos
    assert len(comp.tokens) <= 16
