"""Repo-specific AST lint pass over ``src/repro``.

Generic linters don't know this codebase's failure modes; these rules each
pin a bug class that has actually bitten (or nearly bitten) the engine:

========  ==================================================================
rule      what it flags
========  ==================================================================
GDL001    new module-global mutable state (dict/list/set displays or
          constructor calls bound at module scope). The ``WRITE_COUNTERS``
          bug class: shared mutable globals silently couple engines and
          break per-graph isolation. Exemption: ``__all__``.
GDL002    host-device sync points outside the fenced telemetry span:
          ``block_until_ready`` calls anywhere outside
          ``repro/core/telemetry.py`` (which owns the fence), and
          ``np.asarray``/``np.array`` on values inside the ``run()`` hot
          path of a GCDA operator (whose inputs are device arrays — a
          silent transfer + sync per call).
GDL003    lock acquisition while already holding a lock in the same
          function (a ``with <lock>`` or ``.acquire()`` nested inside
          another ``with <lock>`` body). The PR-8 InterBuffer/Registry
          deadlock class: nested acquisition orders deadlock under
          morsel-parallel execution.
GDL004    bare ``except:`` — swallows ``KeyboardInterrupt``/``SystemExit``
          and masks real planner bugs as silent fallbacks.
GDL005    mutable default arguments (``def f(x=[])``) — call-to-call state
          leakage.
========  ==================================================================

Findings print as ``path:line: RULE message``. A baseline file
(``lint_baseline.json``) records accepted pre-existing findings keyed by
``(rule, path, enclosing scope, source line)`` — stable across unrelated
line drift — and CI fails only on findings *not* in the baseline.

CLI::

    python -m repro.analysis.lint [paths...] \
        [--baseline lint_baseline.json] [--write-baseline]

Exit status 1 when new (non-baselined) findings exist.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import sys
from pathlib import Path
from typing import Optional

MUTABLE_CONSTRUCTORS = frozenset({"dict", "list", "set", "defaultdict",
                                  "OrderedDict", "Counter", "deque",
                                  "bytearray"})
MUTABLE_DISPLAYS = (ast.Dict, ast.List, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp)
GDL001_EXEMPT_NAMES = frozenset({"__all__"})

# kind strings of physical operators whose run() consumes device arrays —
# np.asarray there is a hidden device->host transfer + sync
GCDA_OP_KINDS = frozenset({"Rel2Matrix", "RandomAccessMatrix", "Const",
                           "MatMul", "Similarity", "Regression"})

# telemetry owns the one sanctioned block_until_ready (the span fence)
GDL002_EXEMPT_FILES = frozenset({"repro/core/telemetry.py"})

LOCK_NAME_HINTS = ("lock",)     # attribute/variable names treated as locks


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str       # repo-relative, forward slashes
    line: int
    scope: str      # dotted enclosing scope ("<module>", "Class.method")
    snippet: str    # stripped source line (baseline key component)
    message: str

    def key(self) -> tuple:
        return (self.rule, self.path, self.scope, self.snippet)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _call_name(node: ast.AST) -> Optional[str]:
    """Trailing name of a call target: ``foo()`` -> foo, ``a.b.foo()`` -> foo."""
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute):
            return f.attr
    return None


def _is_lock_expr(node: ast.AST) -> bool:
    """Does this with-context / call target look like a lock? Matches bare
    names and attributes whose final component contains 'lock'
    (``self._lock``, ``self._pool_lock``, ``registry.lock``)."""
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return name is not None and any(h in name.lower()
                                    for h in LOCK_NAME_HINTS)


def _is_mutable_value(node: ast.AST) -> bool:
    if isinstance(node, MUTABLE_DISPLAYS):
        return True
    cn = _call_name(node)
    return cn in MUTABLE_CONSTRUCTORS


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[Finding] = []
        self.scope: list[str] = []        # class/function name stack
        self.func_depth = 0
        self.class_depth = 0
        self.lock_depth = 0               # with-lock nesting in this function
        self.gcda_run_depth = 0           # inside a GCDA operator's run()
        self.class_kinds: list[Optional[str]] = []   # kind= of class stack

    # -- plumbing --

    def _scope_name(self) -> str:
        return ".".join(self.scope) if self.scope else "<module>"

    def _snippet(self, node: ast.AST) -> str:
        i = getattr(node, "lineno", 1) - 1
        return self.lines[i].strip() if 0 <= i < len(self.lines) else ""

    def add(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(rule, self.path,
                                     getattr(node, "lineno", 1),
                                     self._scope_name(),
                                     self._snippet(node), message))

    # -- scope tracking --

    def visit_ClassDef(self, node: ast.ClassDef):
        kind = None
        for stmt in node.body:
            if (isinstance(stmt, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "kind"
                            for t in stmt.targets)
                    and isinstance(stmt.value, ast.Constant)):
                kind = stmt.value.value
        self.scope.append(node.name)
        self.class_depth += 1
        self.class_kinds.append(kind)
        self.generic_visit(node)
        self.class_kinds.pop()
        self.class_depth -= 1
        self.scope.pop()

    def _visit_func(self, node):
        for default in list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]:
            if _is_mutable_value(default):
                self.add("GDL005", default,
                         f"mutable default argument in {node.name}() — "
                         f"shared across calls; default to None instead")
        in_gcda_run = (node.name == "run" and self.class_kinds
                       and self.class_kinds[-1] in GCDA_OP_KINDS)
        self.scope.append(node.name)
        self.func_depth += 1
        outer_locks = self.lock_depth
        self.lock_depth = 0               # lock nesting is per-function
        if in_gcda_run:
            self.gcda_run_depth += 1
        self.generic_visit(node)
        if in_gcda_run:
            self.gcda_run_depth -= 1
        self.lock_depth = outer_locks
        self.func_depth -= 1
        self.scope.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # -- GDL001: module-global mutable state --

    def _check_global_assign(self, node, targets, value):
        if self.func_depth or self.class_depth or value is None:
            return
        names = [t.id for t in targets if isinstance(t, ast.Name)]
        if names and all(n in GDL001_EXEMPT_NAMES for n in names):
            return
        if _is_mutable_value(value):
            what = ", ".join(names) or "<target>"
            self.add("GDL001", node,
                     f"module-global mutable state ({what}) — the "
                     f"WRITE_COUNTERS bug class; scope it to an instance "
                     f"or make it immutable")

    def visit_Assign(self, node: ast.Assign):
        self._check_global_assign(node, node.targets, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self._check_global_assign(node, [node.target], node.value)
        self.generic_visit(node)

    # -- GDL002: host syncs outside the telemetry fence --

    def visit_Call(self, node: ast.Call):
        cn = _call_name(node)
        if cn == "block_until_ready" and self.path not in GDL002_EXEMPT_FILES:
            self.add("GDL002", node,
                     "block_until_ready outside repro/core/telemetry.py — "
                     "host-device sync belongs behind the fenced telemetry "
                     "span (telemetry.fence)")
        elif (cn in ("asarray", "array") and self.gcda_run_depth
              and isinstance(node.func, ast.Attribute)
              and isinstance(node.func.value, ast.Name)
              and node.func.value.id == "np"):
            self.add("GDL002", node,
                     "np.asarray/np.array inside a GCDA operator's run() — "
                     "silently transfers the device array to host and "
                     "syncs; keep the hot path device-resident")
        if cn == "acquire" and self.lock_depth and \
                isinstance(node.func, ast.Attribute) and \
                _is_lock_expr(node.func):
            self.add("GDL003", node,
                     "lock.acquire() while already holding a lock — the "
                     "PR-8 nested-acquisition deadlock class")
        self.generic_visit(node)

    # -- GDL003: nested lock acquisition --

    def visit_With(self, node: ast.With):
        lockish = sum(1 for item in node.items
                      if _is_lock_expr(item.context_expr))
        if lockish and self.lock_depth:
            self.add("GDL003", node,
                     "nested `with <lock>` while already holding a lock in "
                     "this function — acquisition orders deadlock under "
                     "morsel-parallel execution (the PR-8 bug class)")
        self.lock_depth += lockish
        self.generic_visit(node)
        self.lock_depth -= lockish

    # -- GDL004: bare except --

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if node.type is None:
            self.add("GDL004", node,
                     "bare `except:` — catches KeyboardInterrupt/SystemExit "
                     "and masks planner bugs; name the exception")
        self.generic_visit(node)


def lint_file(path: Path, root: Path) -> list[Finding]:
    resolved = path.resolve()
    try:
        rel = resolved.relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    # baseline keys (and the GDL002 exemption) are src-relative
    if rel.startswith("src/"):
        rel = rel[len("src/"):]
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError) as exc:
        return [Finding("GDL000", rel, getattr(exc, "lineno", 1) or 1,
                        "<module>", "", f"unparseable: {exc}")]
    linter = _Linter(rel, source)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths: list[Path], root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for p in paths:
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f, root))
    return findings


# ---------------------------------------------------------------------------
# baseline handling
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> list:
    if not path.exists():
        return []
    return [tuple(k) for k in json.loads(path.read_text())]


def save_baseline(path: Path, findings: list[Finding]) -> None:
    keys = sorted(f.key() for f in findings)
    path.write_text(json.dumps(keys, indent=2) + "\n")


def split_by_baseline(findings: list[Finding], baseline: list
                      ) -> tuple[list[Finding], list[Finding]]:
    """Partition into (new, baselined). Baseline keys are a multiset: two
    identical findings need two baseline entries."""
    pool: dict[tuple, int] = {}
    for k in baseline:
        pool[k] = pool.get(k, 0) + 1
    new, old = [], []
    for f in findings:
        k = f.key()
        if pool.get(k, 0) > 0:
            pool[k] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


def main(argv: Optional[list] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    write = "--write-baseline" in args
    if write:
        args.remove("--write-baseline")
    baseline_path = Path("lint_baseline.json")
    if "--baseline" in args:
        i = args.index("--baseline")
        baseline_path = Path(args[i + 1])
        del args[i:i + 2]
    root = Path.cwd()
    paths = [Path(a) for a in args] or [Path("src/repro")]

    findings = lint_paths(paths, root)
    if write:
        save_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0
    new, old = split_by_baseline(findings, load_baseline(baseline_path))
    for f in new:
        print(f.render())
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    summary = " ".join(f"{r}={n}" for r, n in sorted(counts.items())) or "none"
    print(f"lint: {len(new)} new, {len(old)} baselined ({summary})")
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
