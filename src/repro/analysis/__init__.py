"""Repo-wide static analysis: the AST lint pass (``repro.analysis.lint``)
and the plan-verification sweep (``repro.analysis.verify_sweep``). Both run
in CI — ``make lint`` / ``make verify-plans``."""
