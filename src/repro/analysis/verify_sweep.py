"""Plan-verification sweep: statically verify every m2bench query and GCDIA
task across {gredo, dual, single} × shards ∈ {1, 4} × device lowering
on/off — the CI gate that no plan-mutating layer (optimizer, shard
rewriter, device lowering) emits an ill-typed DAG.

Every combination runs ``GredoEngine.verify`` (naive build → optimizer →
shard rewrite, schema-checked at each stage plus cross-stage V-SIG/V-EQ
checks; see ``repro.core.verify``). ERROR-severity violations fail the
sweep; WARNs (silent float32 promotions at the matrix boundary, runtime
fallbacks) are tallied in the report. Results land in
``experiments/verify_sweep.json`` — uploaded as a CI artifact on failure.

Notes on coverage:

* ``cost.SHARD_MIN_ROWS`` is forced to 0 for the shards=4 leg (same trick
  as the CI equivalence step) — at sweep scale the cost gate would
  otherwise always choose serial plans and the shard invariants (V-SHARD)
  would never be exercised.
* ``a1_regression`` is excluded: its task spec has a single ``random``
  input and ``physical.build_gcdia`` rejects REGRESSION with fewer than two
  matrices at build time (the benchmark drives it manually with external
  labels) — there is no plan to verify.

CLI::

    python -m repro.analysis.verify_sweep [--sf N] [--out FILE]

Exit status 1 when any combination has ERROR-severity violations.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.core import cost, optimizer
from repro.core.engine import GredoEngine
from repro.data import m2bench

MODES = ("gredo", "dual", "single")
SHARD_COUNTS = (1, 4)
DEVICE = (True, False)


def _registry(sf: int):
    """(label, db, query-or-task) combinations of the sweep. Index-backed
    access paths are part of plan space, so the main db gets its secondary
    indexes before planning."""
    db = m2bench.generate(sf=sf)
    m2bench.build_indexes(db)
    pid, oid = m2bench.point_lookup_keys(db)
    skew = m2bench.generate_skew(sf=sf)
    entries = [
        ("q_g1", db, m2bench.q_g1()),
        ("q_g2", db, m2bench.q_g2()),
        ("q_g3", db, m2bench.q_g3()),
        ("q_g4", db, m2bench.q_g4()),
        ("q_g5", db, m2bench.q_g5()),
        ("q_edge_scan", db, m2bench.q_edge_scan()),
        ("q_vertex_scan", db, m2bench.q_vertex_scan()),
        ("q_opt_skew", db, m2bench.q_opt_skew()),
        ("q_point_lookup", db, m2bench.q_point_lookup(pid, oid)),
        ("q_range_narrow", db, m2bench.q_range_narrow()),
        ("q_shard_join", db, m2bench.q_shard_join()),
        ("q_skew_3join", skew, m2bench.q_skew_3join()),
        ("q_bushy_4src", skew, m2bench.q_bushy_4src()),
        # a1_regression excluded: single-input REGRESSION never builds a DAG
        ("a2_similarity", db, m2bench.a2_similarity()),
        ("a3_multiply", db, m2bench.a3_multiply()),
        ("a_shard_reg", db, m2bench.a_shard_reg()),
    ]
    return entries


def run_sweep(sf: int = 1) -> dict:
    rows = []
    n_err = n_warn = 0
    shard_floor = cost.SHARD_MIN_ROWS
    device_flag = optimizer.DEVICE_MATCH
    try:
        for label, db, q in _registry(sf):
            for mode in MODES:
                for k in SHARD_COUNTS:
                    # sweep scale is tiny; drop the serial-execution cost
                    # floor so k=4 actually exercises the shard rewriter
                    cost.SHARD_MIN_ROWS = 0 if k > 1 else shard_floor
                    for device in DEVICE:
                        optimizer.DEVICE_MATCH = device
                        eng = GredoEngine(db, mode=mode, n_shards=k)
                        report = eng.verify(q)
                        n_err += len(report.errors)
                        n_warn += len(report.warnings)
                        rows.append({
                            "query": label, "mode": mode, "shards": k,
                            "device": device, "ok": report.ok,
                            "errors": [v.render() for v in report.errors],
                            "warnings": [v.render() for v in report.warnings],
                        })
    finally:
        cost.SHARD_MIN_ROWS = shard_floor
        optimizer.DEVICE_MATCH = device_flag
    failed = [r for r in rows if not r["ok"]]
    return {"combinations": len(rows), "failed": len(failed),
            "errors": n_err, "warnings": n_warn, "rows": rows}


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    sf, out = 1, Path("experiments/verify_sweep.json")
    if "--sf" in args:
        i = args.index("--sf")
        sf = int(args[i + 1])
    if "--out" in args:
        i = args.index("--out")
        out = Path(args[i + 1])
    doc = run_sweep(sf=sf)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    for r in doc["rows"]:
        if not r["ok"]:
            head = f"{r['query']} mode={r['mode']} k={r['shards']} " \
                   f"device={r['device']}:"
            print(head)
            for line in r["errors"]:
                print(f"  {line}")
    print(f"verify sweep: {doc['combinations']} plan combinations, "
          f"{doc['failed']} failed, {doc['errors']} error(s), "
          f"{doc['warnings']} warning(s) -> {out}")
    return 1 if doc["failed"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
