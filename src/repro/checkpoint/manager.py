"""Fault-tolerant checkpointing.

* Atomic: writes to ``step_N.tmp`` then ``os.replace`` -> a crash mid-save
  never corrupts the latest checkpoint.
* Async: ``save(..., blocking=False)`` snapshots to host then writes on a
  background thread, overlapping I/O with the next training steps.
* Rotating: keeps the newest ``keep`` checkpoints.
* Elastic: checkpoints are stored as host (fully-replicated) arrays keyed by
  pytree path, so ``restore`` can re-shard onto ANY mesh topology — the
  restart path after resizing the cluster (see distributed.elastic).
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, blocking: bool = True,
             metadata: Optional[dict] = None) -> None:
        # snapshot to host *now* (cheap on CPU; device->host copy on TPU)
        flat = _flatten(state)
        meta = dict(metadata or {})
        meta["step"] = int(step)

        def write():
            tmp = os.path.join(self.directory, f"step_{step:010d}.tmp.npz")
            final = os.path.join(self.directory, f"step_{step:010d}.npz")
            with open(tmp, "wb") as f:
                np.savez(f, __meta__=json.dumps(meta), **flat)
            os.replace(tmp, final)  # atomic publish
            self._rotate()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self) -> None:
        ckpts = self.checkpoints()
        for step, path in ckpts[:-self.keep]:
            try:
                os.remove(path)
            except OSError:
                pass

    # --------------------------------------------------------------- restore
    def checkpoints(self) -> list[tuple[int, str]]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.npz", name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.directory, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ckpts = self.checkpoints()
        return ckpts[-1][0] if ckpts else None

    def restore(self, target: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> tuple[Pytree, dict]:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). With ``shardings``, leaves are device_put onto
        the (possibly different) mesh — the elastic-restart path."""
        ckpts = dict((s, p) for s, p in self.checkpoints())
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        step = step if step is not None else max(ckpts)
        with np.load(ckpts[step], allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            flat = {k: z[k] for k in z.files if k != "__meta__"}

        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, meta
