"""Delta-aware secondary indexes + access-path infrastructure (paper §4/§6).

The paper's predicate-aware traversal (pillar 1) assumes selective
predicates cost less than full scans, but the scan-based RecordAM pays
O(n) per predicate regardless of selectivity. This module supplies the
missing access paths, per ``Database`` via one :class:`IndexManager`:

* **hash/dict equality indexes** — value -> sorted row-id postings. Dict
  columns reuse their int32 codes (postings grouped by code, O(1) point
  lookup through the existing vocabulary index);
* **sorted indexes** — an argsort permutation over the base rows plus
  ``searchsorted`` range probes (equality is a zero-width range);
* **zone maps** — per-chunk min/max/non-null counts over numeric columns
  (base chunks and appended delta runs alike) powering skip-scans: chunks
  whose [min, max] cannot satisfy a predicate are never read;
* **composite (label, attr) vertex indexes** — the same structures over a
  graph's per-label vertex tables, keyed ``(graph, label, column)``, so
  ``pattern.match`` seeds candidate sets from postings instead of
  full-label masks (the graph side of topology+attribute traversal).

Every index is **delta-aware**: reads over LSM-buffered collections
(:mod:`repro.core.deltastore`) see base ⊕ delta, so an index must too.
The base structures are immutable; rows appended since the last refresh
land in a small re-sorted *tail* (postings = base ⊕ sorted delta tail),
tombstoned edges are filtered at lookup time, and a compaction — the only
event that can reorder or renumber rows — forces a rebuild. Staleness is
detected, never guessed: each index carries the write **epoch** and a base
snapshot token of its source collection; a lookup against a bumped epoch
refreshes (or rebuilds) first.

The optimizer (:func:`repro.core.optimizer.optimize`) makes the cost-based
access-path choice per scan — postings lookup vs. zone skip-scan vs. full
scan — using the existing :class:`~repro.core.storage.ColumnStats`
selectivities, and ``explain``/``explain_last`` report the decision as
``access=`` per operator.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .cost import ZONE_CHUNK
from .storage import Database, DictColumn, Graph, Table, _scalar_cmp

EQ_OPS = ("==", "in")
RANGE_OPS = ("==", "in", "<", "<=", ">", ">=", "range")


# ---------------------------------------------------------------------------
# Zone maps: per-chunk min/max/non-null for skip-scans
# ---------------------------------------------------------------------------


def _chunk_stats(vals: np.ndarray) -> tuple[float, float, int]:
    """(min, max, non-null count) of one chunk; all-null chunks get the
    (+inf, -inf) sentinel so no predicate ever selects them."""
    if vals.dtype.kind == "f":
        vals = vals[np.isfinite(vals)]
    if vals.size == 0:
        return np.inf, -np.inf, 0
    return float(vals.min()), float(vals.max()), int(vals.size)


class ZoneMap:
    """Chunked min/max/non-null summaries of one numeric column. The row
    space is the merged (base ⊕ delta) row order: ``extend`` absorbs
    appended delta runs by completing the trailing partial chunk (min/max
    combine associatively — no re-read of old values) and chunking the
    rest. ``masked_eval`` is the skip-scan: the predicate is evaluated only
    on candidate chunks, everything else stays False without being read."""

    def __init__(self, values: np.ndarray, chunk: int = ZONE_CHUNK):
        self.chunk = int(chunk)
        self.n = 0
        self._mins: list[float] = []
        self._maxs: list[float] = []
        self._nonnull: list[int] = []
        self._arrays = None     # cached (mins, maxs, nonnull) ndarrays
        self.extend(values)

    def _chunk_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._arrays is None:
            self._arrays = (np.asarray(self._mins), np.asarray(self._maxs),
                            np.asarray(self._nonnull))
        return self._arrays

    @property
    def n_chunks(self) -> int:
        return len(self._mins)

    def extend(self, values: np.ndarray) -> None:
        vals = np.asarray(values)
        if vals.size == 0:
            return
        i = 0
        part = self.n % self.chunk
        if part:
            fill = min(self.chunk - part, len(vals))
            mn, mx, nn = _chunk_stats(vals[:fill])
            self._mins[-1] = min(self._mins[-1], mn)
            self._maxs[-1] = max(self._maxs[-1], mx)
            self._nonnull[-1] += nn
            i = fill
        for start in range(i, len(vals), self.chunk):
            mn, mx, nn = _chunk_stats(vals[start:start + self.chunk])
            self._mins.append(mn)
            self._maxs.append(mx)
            self._nonnull.append(nn)
        self.n += len(vals)
        self._arrays = None

    def candidate_chunks(self, pred) -> np.ndarray:
        """Boolean per chunk: can any row of the chunk satisfy ``pred``?"""
        mins, maxs, nonnull = self._chunk_arrays()
        op, v = pred.op, pred.value
        if op == "==":
            cand = (mins <= v) & (maxs >= v)
        elif op == "in":
            cand = np.zeros(len(mins), dtype=bool)
            for val in pred.value:
                cand |= (mins <= val) & (maxs >= val)
        elif op == "<":
            cand = mins < v
        elif op == "<=":
            cand = mins <= v
        elif op == ">":
            cand = maxs > v
        elif op == ">=":
            cand = maxs >= v
        elif op == "range":
            cand = (maxs >= v) & (mins <= pred.value2)
        else:   # "!=" and friends: zones cannot prune
            cand = np.ones(len(mins), dtype=bool)
        return cand & (nonnull > 0)

    def fraction(self, pred) -> float:
        """Fraction of rows living in candidate chunks — the exact price of
        the skip-scan, fed to the optimizer's access-path costing."""
        if self.n == 0:
            return 0.0
        cand = self.candidate_chunks(pred)
        rows = 0
        for ci in np.nonzero(cand)[0]:
            rows += min(self.chunk, self.n - ci * self.chunk)
        return rows / self.n

    def _candidate_runs(self, pred) -> list[tuple[int, int]]:
        """Row ranges of candidate chunks, consecutive chunks coalesced."""
        cand = np.nonzero(self.candidate_chunks(pred))[0]
        runs: list[tuple[int, int]] = []
        i = 0
        while i < len(cand):
            j = i
            while j + 1 < len(cand) and cand[j + 1] == cand[j] + 1:
                j += 1
            runs.append((int(cand[i]) * self.chunk,
                         min((int(cand[j]) + 1) * self.chunk, self.n)))
            i = j + 1
        return runs

    def masked_eval(self, values: np.ndarray, pred) -> np.ndarray:
        """Exact predicate mask over all rows, reading candidate chunks
        only (consecutive candidates are evaluated as one slice)."""
        mask = np.zeros(self.n, dtype=bool)
        for a, b in self._candidate_runs(pred):
            mask[a:b] = _scalar_cmp(np.asarray(values[a:b]), pred)
        return mask

    def matching_rows(self, values: np.ndarray, pred) -> np.ndarray:
        """Row ids satisfying ``pred`` — the skip-scan without the O(n)
        output mask: only candidate chunks are read or written."""
        hits = [a + np.nonzero(_scalar_cmp(np.asarray(values[a:b]), pred))[0]
                for a, b in self._candidate_runs(pred)]
        return (np.concatenate(hits) if hits else np.zeros(0, dtype=np.int64))


# ---------------------------------------------------------------------------
# Posting structures: base (immutable) ⊕ sorted delta tail
# ---------------------------------------------------------------------------


class _SortedPostings:
    """Sorted index over a numeric column: base = one argsort permutation
    over the build-time rows, tail = delta rows. Absorbing a run is an O(b)
    buffer append (the write path never sorts); the tail settles — one
    argsort over the accumulated delta — lazily on the next lookup, so a
    write burst pays a single amortized sort instead of one per batch."""

    def __init__(self, values: np.ndarray):
        vals = np.asarray(values)
        self.perm = np.argsort(vals, kind="stable")
        self.keys = vals[self.perm]
        self.tail_rows = np.zeros(0, dtype=np.int64)
        self.tail_keys = np.zeros(0, dtype=vals.dtype if vals.size else np.float64)
        self._pending: list[tuple[np.ndarray, int]] = []

    def absorb(self, values: np.ndarray, row0: int) -> None:
        # copy: the run is often a view into a growable merged-column
        # buffer, and the tail must stay valid across later reallocations
        self._pending.append((np.array(values), row0))

    def _settle(self) -> None:
        if not self._pending:
            return
        vals = np.concatenate([self.tail_keys]
                              + [np.asarray(v) for v, _ in self._pending])
        rows = np.concatenate([self.tail_rows]
                              + [np.arange(r0, r0 + len(v), dtype=np.int64)
                                 for v, r0 in self._pending])
        self._pending = []
        order = np.argsort(vals, kind="stable")
        self.tail_keys = vals[order]
        self.tail_rows = rows[order]

    def _slice(self, lo_val, hi_val, lo_side: str, hi_side: str) -> np.ndarray:
        self._settle()
        lo = 0 if lo_val is None else int(np.searchsorted(self.keys, lo_val, lo_side))
        hi = len(self.keys) if hi_val is None \
            else int(np.searchsorted(self.keys, hi_val, hi_side))
        base = self.perm[lo:hi]
        if not len(self.tail_keys):     # the common no-pending-delta case
            return base
        tlo = 0 if lo_val is None \
            else int(np.searchsorted(self.tail_keys, lo_val, lo_side))
        thi = len(self.tail_keys) if hi_val is None \
            else int(np.searchsorted(self.tail_keys, hi_val, hi_side))
        return np.concatenate([base, self.tail_rows[tlo:thi]])

    def lookup(self, pred) -> Optional[np.ndarray]:
        op, v = pred.op, pred.value
        if op == "==":
            return self._slice(v, v, "left", "right")
        if op == "in":
            hits = [self._slice(val, val, "left", "right") for val in pred.value]
            return (np.unique(np.concatenate(hits)) if hits
                    else np.zeros(0, dtype=np.int64))
        if op == "range":
            return self._slice(v, pred.value2, "left", "right")
        if op == "<":
            return self._slice(None, v, "left", "left")
        if op == "<=":
            return self._slice(None, v, "left", "right")
        if op == ">":
            return self._slice(v, None, "right", "right")
        if op == ">=":
            return self._slice(v, None, "left", "right")
        return None


class _HashPostings:
    """Equality index over a dictionary-encoded column: base postings are
    row ids grouped by code (counting sort), the delta tail is kept sorted
    by code — settled lazily, like :class:`_SortedPostings`. Point lookups
    reuse ``DictColumn.encode`` — O(1) through the vocabulary hash, then
    two binary searches."""

    def __init__(self, col: DictColumn):
        codes = np.asarray(col.codes)
        self.n_codes = len(col.vocab)
        self.order = np.argsort(codes, kind="stable")
        sorted_codes = codes[self.order]
        self.starts = np.searchsorted(sorted_codes, np.arange(self.n_codes + 1))
        self.tail_codes = np.zeros(0, dtype=np.int64)
        self.tail_rows = np.zeros(0, dtype=np.int64)
        self._pending: list[tuple[np.ndarray, int]] = []

    def absorb(self, codes: np.ndarray, row0: int) -> None:
        self._pending.append((np.array(codes, dtype=np.int64), row0))

    def _settle(self) -> None:
        if not self._pending:
            return
        codes = np.concatenate([self.tail_codes]
                               + [c for c, _ in self._pending])
        rows = np.concatenate([self.tail_rows]
                              + [np.arange(r0, r0 + len(c), dtype=np.int64)
                                 for c, r0 in self._pending])
        self._pending = []
        order = np.argsort(codes, kind="stable")
        self.tail_codes = codes[order]
        self.tail_rows = rows[order]

    def _rows_of_code(self, code: int) -> np.ndarray:
        self._settle()
        base = (self.order[self.starts[code]:self.starts[code + 1]]
                if 0 <= code < self.n_codes else np.zeros(0, dtype=np.int64))
        if not len(self.tail_codes):    # the common no-pending-delta case
            return base
        lo = int(np.searchsorted(self.tail_codes, code, "left"))
        hi = int(np.searchsorted(self.tail_codes, code, "right"))
        return np.concatenate([base, self.tail_rows[lo:hi]])

    def lookup(self, pred, col: DictColumn) -> Optional[np.ndarray]:
        if pred.op == "==":
            return self._rows_of_code(col.encode(pred.value))
        if pred.op == "in":
            hits = [self._rows_of_code(col.encode(v)) for v in pred.value]
            return (np.unique(np.concatenate(hits)) if hits
                    else np.zeros(0, dtype=np.int64))
        return None


# ---------------------------------------------------------------------------
# Index sources: where the rows come from, and when they moved
# ---------------------------------------------------------------------------


class _TableSource:
    """A relational/document collection. Tables mutate by wholesale
    replacement (``add_table``) or opaque in-place edits (``touch_table``),
    so any epoch change forces a rebuild — there is no delta tail to
    absorb."""

    incremental = False

    def __init__(self, db: Database, name: str):
        self.db = db
        self.name = name

    def table(self) -> Table:
        return self.db.tables[self.name]

    def epoch(self) -> int:
        return self.db.epoch_of(self.name)

    def token(self):
        return id(self.db.tables[self.name])

    def live_filter(self, rows: np.ndarray) -> np.ndarray:
        return rows


class _VertexSource:
    """One label's vertex table of a graph: merged base ⊕ delta rows in
    stable order, so appends absorb incrementally; a compaction (the only
    row reorder) is detected via the compaction counter and rebuilds."""

    incremental = True

    def __init__(self, db: Database, gname: str, label: str):
        self.db = db
        self.gname = gname
        self.label = label

    @property
    def g(self) -> Graph:
        return self.db.graphs[self.gname]

    def table(self) -> Table:
        return self.g.vertex_tables[self.label]

    def epoch(self) -> int:
        return self.db.epoch_of(self.gname)

    def token(self):
        # graph identity + compaction count: a compaction reorders rows,
        # and a whole-graph replacement under the same name swaps the
        # object — both invalidate the base snapshot
        return (id(self.g), self.g.compactions)

    def live_filter(self, rows: np.ndarray) -> np.ndarray:
        return rows      # vertices are never tombstoned


class _EdgeSource(_VertexSource):
    """A graph's edge record table. Edge tids are stable between
    compactions (tombstoned rows stay in place), so postings remain valid
    across deletes — lookups filter through the live-edge bitmap instead."""

    def __init__(self, db: Database, gname: str):
        super().__init__(db, gname, "__edges__")

    def table(self) -> Table:
        return self.g.edges

    def live_filter(self, rows: np.ndarray) -> np.ndarray:
        g = self.g
        if not g.delta.n_tombstones or rows.size == 0:
            return rows
        return rows[g.live_edge_mask()[rows]]


# ---------------------------------------------------------------------------
# ColumnIndex: one (collection, column) with epoch-stamped maintenance
# ---------------------------------------------------------------------------


class ColumnIndex:
    """Secondary index over one column: kind-specific postings + zone maps
    (numeric columns), epoch-stamped against the source collection.

    ``refresh`` is the single maintenance entry point, called before every
    lookup: same epoch -> nothing; epoch bumped with the base snapshot
    intact -> absorb the appended tail rows in O(delta); base snapshot
    changed (compaction / table replacement) -> rebuild. A stale index is
    therefore *impossible to read* — the stamp is checked, not trusted."""

    def __init__(self, source, column: str, kind: str = "auto"):
        self.source = source
        self.column = column
        self.kind = kind
        self.lookups = 0
        self.refreshes = 0
        self.rebuilds = -1      # the initial _build is not a rebuild
        self._build()

    # ---- construction / maintenance ----
    def _build(self) -> None:
        tbl = self.source.table()
        col = tbl.columns[self.column]
        if self.kind == "auto":
            self.kind = "hash" if isinstance(col, DictColumn) else "sorted"
        self.postings = None
        self.zones = None
        if isinstance(col, DictColumn):
            if self.kind != "hash":
                raise ValueError(f"{self.kind} index needs a numeric column; "
                                 f"{self.column} is dictionary-encoded")
            self.postings = _HashPostings(col)
        else:
            vals = np.asarray(col)
            if vals.dtype.kind not in "ifub":
                raise ValueError(f"cannot index non-scalar column {self.column}")
            if self.kind == "sorted":
                self.postings = _SortedPostings(vals)
            self.zones = ZoneMap(vals.astype(np.float64, copy=False))
        self._col = col
        self.n_rows = tbl.nrows
        self.epoch = self.source.epoch()
        self.token = self.source.token()
        self.rebuilds += 1

    def refresh(self) -> None:
        ep = self.source.epoch()
        if ep == self.epoch:
            return
        tbl = self.source.table()
        if (self.source.token() != self.token or not self.source.incremental
                or tbl.nrows < self.n_rows):
            self._build()
            return
        if tbl.nrows > self.n_rows:
            col = tbl.columns[self.column]
            if isinstance(col, DictColumn):
                if self.postings is not None:
                    self.postings.absorb(np.asarray(col.codes)[self.n_rows:],
                                         self.n_rows)
            else:
                run = np.asarray(col)[self.n_rows:]
                if self.postings is not None:
                    self.postings.absorb(run, self.n_rows)
                if self.zones is not None:
                    self.zones.extend(run.astype(np.float64, copy=False))
            self._col = col
            self.n_rows = tbl.nrows
        self.epoch = ep
        self.refreshes += 1

    # ---- reads ----
    def serves(self, op: str) -> bool:
        if self.postings is None:
            return False
        return op in (EQ_OPS if self.kind == "hash" else RANGE_OPS)

    def lookup(self, pred) -> Optional[np.ndarray]:
        """Row ids matching ``pred`` (tombstone-filtered), or None when the
        predicate is not servable from the postings."""
        self.refresh()
        if not self.serves(pred.op):
            return None
        self.lookups += 1
        if self.kind == "hash":
            rows = self.postings.lookup(pred, self._col)
        else:
            rows = self.postings.lookup(pred)
        if rows is None:
            return None
        return self.source.live_filter(np.asarray(rows, dtype=np.int64))

    def zone_fraction(self, pred) -> Optional[float]:
        """Candidate-row fraction a zone skip-scan would read, or None when
        the column has no zone maps / the op cannot be pruned."""
        self.refresh()
        if self.zones is None or pred.op not in RANGE_OPS:
            return None
        return self.zones.fraction(pred)

    def zone_mask(self, pred) -> Optional[np.ndarray]:
        """Exact predicate mask over all rows via the chunk skip-scan
        (tombstones are *not* applied — the mask mirrors eval_predicate)."""
        self.refresh()
        if self.zones is None or pred.op not in RANGE_OPS:
            return None
        self.lookups += 1
        return self.zones.masked_eval(np.asarray(self._col), pred)

    def zone_rows(self, pred) -> Optional[np.ndarray]:
        """Matching row ids via the chunk skip-scan (tombstone-filtered)."""
        self.refresh()
        if self.zones is None or pred.op not in RANGE_OPS:
            return None
        self.lookups += 1
        rows = self.zones.matching_rows(np.asarray(self._col), pred)
        return self.source.live_filter(rows)

    def describe(self) -> str:
        z = f"+zones[{self.zones.n_chunks}]" if self.zones is not None else ""
        return (f"{self.kind}{z} rows={self.n_rows} epoch={self.epoch} "
                f"refreshes={self.refreshes} rebuilds={self.rebuilds}")


# ---------------------------------------------------------------------------
# IndexManager: the per-Database catalog of secondary indexes
# ---------------------------------------------------------------------------


class IndexManager:
    """All secondary indexes of one :class:`Database`. Keys are
    ``(collection, label, column)`` — ``label`` names a graph vertex table
    (the composite (label, attr) index), ``label=None`` on a graph indexes
    the edge record table, and tables ignore it. Graphs carrying indexes
    get a backref (``graph._index_manager``) so the traversal layer can
    seed candidate sets without threading the Database through."""

    def __init__(self, db: Database):
        self.db = db
        self._indexes: dict[tuple, ColumnIndex] = {}

    def _key(self, name: str, column: str, label: Optional[str]) -> tuple:
        return (name, label if name in self.db.graphs else None, column)

    def create(self, name: str, column: str, kind: str = "auto",
               label: Optional[str] = None) -> ColumnIndex:
        if name in self.db.tables:
            source = _TableSource(self.db, name)
        elif name in self.db.graphs:
            source = (_VertexSource(self.db, name, label) if label is not None
                      else _EdgeSource(self.db, name))
            self.db.graphs[name]._index_manager = self
        else:
            raise KeyError(name)
        idx = ColumnIndex(source, column, kind)
        self._indexes[self._key(name, column, label)] = idx
        return idx

    def drop(self, name: str, column: str, label: Optional[str] = None) -> None:
        self._indexes.pop(self._key(name, column, label), None)

    def get(self, name: str, column: str,
            label: Optional[str] = None) -> Optional[ColumnIndex]:
        return self._indexes.get(self._key(name, column, label))

    def lookup(self, name: str, pred,
               label: Optional[str] = None) -> Optional[np.ndarray]:
        """Matching row ids of ``pred.column`` in the named collection, or
        None when no index serves it (caller falls back to the scan)."""
        idx = self.get(name, pred.column, label)
        return None if idx is None else idx.lookup(pred)

    def zone_fraction(self, name: str, pred,
                      label: Optional[str] = None) -> Optional[float]:
        idx = self.get(name, pred.column, label)
        return None if idx is None else idx.zone_fraction(pred)

    def zone_mask(self, name: str, pred,
                  label: Optional[str] = None) -> Optional[np.ndarray]:
        idx = self.get(name, pred.column, label)
        return None if idx is None else idx.zone_mask(pred)

    def zone_rows(self, name: str, pred,
                  label: Optional[str] = None) -> Optional[np.ndarray]:
        idx = self.get(name, pred.column, label)
        return None if idx is None else idx.zone_rows(pred)

    def refresh_all(self) -> None:
        """Force maintenance of every index now (normally lazy-on-lookup);
        the update-suite benchmark charges maintenance per write batch."""
        for idx in self._indexes.values():
            idx.refresh()

    def stats(self) -> dict:
        return {"/".join(str(p) for p in k if p is not None): idx.describe()
                for k, idx in sorted(self._indexes.items(),
                                     key=lambda kv: str(kv[0]))}

    def metrics(self) -> dict:
        """Flat numeric counters per index (telemetry registry source):
        ``<collection>[/<label>]/<column>.{lookups,refreshes,rebuilds}``.
        Maintenance stays lazy — this reads stamps, it never refreshes."""
        out: dict[str, int] = {}
        for k, idx in sorted(self._indexes.items(), key=lambda kv: str(kv[0])):
            base = "/".join(str(p) for p in k if p is not None)
            out[f"{base}.lookups"] = idx.lookups
            out[f"{base}.refreshes"] = idx.refreshes
            out[f"{base}.rebuilds"] = idx.rebuilds
        return out

    def __len__(self):
        return len(self._indexes)
