"""Dual storage engine (paper §4), TPU-adapted.

* Unified record storage: columnar struct-of-arrays tables (NF² via ragged
  (values, offsets) pairs for multi-valued attributes). Strings are
  dictionary-encoded (int32 codes + vocabulary) so every column the execution
  engine touches is a dense numeric array — the TPU analogue of JSONB fields.
* Document shredding: each JSON path used by queries becomes a column
  ("a.b.c"); arrays become ragged columns. This replaces per-record JSONB
  parsing with one-time columnarization (same spirit as JSON tiles).
* Topology storage: CSR adjacency (forward + reverse) replacing the paper's
  singly-linked adjacency graph; nidMap/vertexMap/edgeMap are dense index
  arrays (O(1) ``take`` — the tid-based RecordAM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Columns
# ---------------------------------------------------------------------------


class DictColumn:
    """Dictionary-encoded string column: int32 codes into ``vocab``."""

    __slots__ = ("codes", "vocab", "_index")

    def __init__(self, values: Iterable[str] | None = None, codes=None, vocab=None):
        if values is not None:
            vocab, codes = np.unique(np.asarray(list(values), dtype=object), return_inverse=True)
            self.vocab = vocab
            self.codes = codes.astype(np.int32)
        else:
            self.codes = np.asarray(codes, dtype=np.int32)
            self.vocab = np.asarray(vocab, dtype=object)
        self._index: Optional[dict] = None

    def encode(self, value: str) -> int:
        """Map a string to its code (-1 if absent)."""
        if self._index is None:
            self._index = {v: i for i, v in enumerate(self.vocab)}
        return self._index.get(value, -1)

    def decode(self, codes) -> np.ndarray:
        return self.vocab[np.asarray(codes)]

    def take(self, idx) -> "DictColumn":
        return DictColumn(codes=self.codes[idx], vocab=self.vocab)

    def __len__(self):
        return len(self.codes)

    @property
    def dtype(self):
        return np.dtype(object)


class RaggedColumn:
    """Multi-valued (NF²) column: flat ``values`` + ``offsets`` (len n+1)."""

    __slots__ = ("values", "offsets")

    def __init__(self, lists: Iterable[Iterable] | None = None, values=None, offsets=None):
        if lists is not None:
            lists = [np.asarray(l) for l in lists]
            self.offsets = np.zeros(len(lists) + 1, dtype=np.int64)
            np.cumsum([len(l) for l in lists], out=self.offsets[1:])
            self.values = (np.concatenate(lists) if lists else np.zeros(0))
        else:
            self.values = np.asarray(values)
            self.offsets = np.asarray(offsets, dtype=np.int64)

    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def take(self, idx) -> "RaggedColumn":
        idx = np.asarray(idx)
        lens = self.lengths()[idx]
        out_off = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lens, out=out_off[1:])
        # gather: for each row, slice values[offsets[i]:offsets[i+1]]
        starts = np.repeat(self.offsets[idx], lens)
        within = np.arange(out_off[-1]) - np.repeat(out_off[:-1], lens)
        return RaggedColumn(values=self.values[starts + within], offsets=out_off)

    def row(self, i: int) -> np.ndarray:
        return self.values[self.offsets[i]:self.offsets[i + 1]]

    def __len__(self):
        return len(self.offsets) - 1


Column = Any  # np.ndarray | DictColumn | RaggedColumn


def _col_len(c: Column) -> int:
    return len(c)


def _col_take(c: Column, idx) -> Column:
    if isinstance(c, (DictColumn, RaggedColumn)):
        return c.take(idx)
    return np.asarray(c)[idx]


# ---------------------------------------------------------------------------
# Column statistics for the cost model (§6.3: selectivity estimation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnStats:
    n: int
    ndv: int               # number of distinct values
    vmin: Any = None
    vmax: Any = None

    def selectivity(self, pred) -> float:
        """Standard System-R style estimates under attribute independence."""
        if self.n == 0:
            return 0.0
        if pred.op == "==":
            return 1.0 / max(self.ndv, 1)
        if pred.op == "!=":
            return 1.0 - 1.0 / max(self.ndv, 1)
        if pred.op == "in":
            return min(1.0, len(pred.value) / max(self.ndv, 1))
        if self.vmin is None or self.vmax is None or self.vmax == self.vmin:
            return 1.0 / 3.0
        span = float(self.vmax) - float(self.vmin)
        if pred.op == "range":
            return min(1.0, max(0.0, (float(pred.value2) - float(pred.value)) / span))
        if pred.op in ("<", "<="):
            return min(1.0, max(0.0, (float(pred.value) - float(self.vmin)) / span))
        return min(1.0, max(0.0, (float(self.vmax) - float(pred.value)) / span))


def compute_stats(col: Column) -> ColumnStats:
    if isinstance(col, DictColumn):
        return ColumnStats(n=len(col), ndv=len(col.vocab))
    if isinstance(col, RaggedColumn):
        vals = col.values
        ndv = len(np.unique(vals)) if len(vals) else 0
        return ColumnStats(n=len(col), ndv=ndv)
    col = np.asarray(col)
    if col.size == 0:
        return ColumnStats(0, 0)
    if col.dtype.kind in "if":
        return ColumnStats(len(col), int(len(np.unique(col))), col.min(), col.max())
    return ColumnStats(len(col), int(len(np.unique(col))))


# ---------------------------------------------------------------------------
# Tables (unified record storage)
# ---------------------------------------------------------------------------


class Table:
    """Columnar table. Row index == tid (paper: tuple identifier; tid-based
    RecordAM == ``take`` on row indices)."""

    def __init__(self, name: str, columns: dict[str, Column]):
        self.name = name
        self.columns = dict(columns)
        lens = {k: _col_len(v) for k, v in self.columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(f"ragged table {name}: {lens}")
        self.nrows = next(iter(lens.values())) if lens else 0
        self._stats: dict[str, ColumnStats] = {}

    def col(self, name: str) -> Column:
        return self.columns[name]

    def stats(self, name: str) -> ColumnStats:
        if name not in self._stats:
            self._stats[name] = compute_stats(self.columns[name])
        return self._stats[name]

    def take(self, idx) -> "Table":
        return Table(self.name, {k: _col_take(v, idx) for k, v in self.columns.items()})

    def eval_predicate(self, pred) -> np.ndarray:
        """Vectorized predicate mask (the scan-based RecordAM's filter)."""
        col = self.columns[pred.column]
        if isinstance(col, DictColumn):
            if pred.op == "==":
                return col.codes == col.encode(pred.value)
            if pred.op == "!=":
                return col.codes != col.encode(pred.value)
            if pred.op == "in":
                codes = np.array([col.encode(v) for v in pred.value])
                return np.isin(col.codes, codes)
            # range predicates on strings: decode-free compare via vocab order
            vals = col.vocab[col.codes]
        elif isinstance(col, RaggedColumn):
            # predicate over a multi-valued attribute: ANY semantics
            hit = _scalar_cmp(col.values, pred)
            seg = np.repeat(np.arange(len(col)), col.lengths())
            out = np.zeros(len(col), dtype=bool)
            np.logical_or.at(out, seg, hit)
            return out
        else:
            vals = np.asarray(col)
        return _scalar_cmp(vals, pred)

    def __repr__(self):
        return f"Table({self.name}, rows={self.nrows}, cols={list(self.columns)})"


def _scalar_cmp(vals: np.ndarray, pred) -> np.ndarray:
    op, v = pred.op, pred.value
    if op == "==":
        return vals == v
    if op == "!=":
        return vals != v
    if op == "<":
        return vals < v
    if op == "<=":
        return vals <= v
    if op == ">":
        return vals > v
    if op == ">=":
        return vals >= v
    if op == "range":
        return (vals >= v) & (vals <= pred.value2)
    if op == "in":
        return np.isin(vals, np.asarray(list(v)))
    raise ValueError(op)


# ---------------------------------------------------------------------------
# Document collections: JSON shredding
# ---------------------------------------------------------------------------


def shred_documents(name: str, docs: list[dict]) -> Table:
    """Shred a JSON document collection into a columnar Table. Every leaf
    path becomes a column named "a.b"; lists of scalars become RaggedColumns;
    missing values are filled with NaN / "" (absent-path semantics)."""
    paths: dict[str, list] = {}

    def walk(prefix: str, obj, row: dict):
        if isinstance(obj, dict):
            for k, v in obj.items():
                walk(f"{prefix}.{k}" if prefix else k, v, row)
        else:
            row[prefix] = obj

    rows = []
    for d in docs:
        row: dict = {}
        walk("", d, row)
        rows.append(row)
        for k in row:
            paths.setdefault(k, None)

    columns: dict[str, Column] = {}
    for path in paths:
        vals = [r.get(path) for r in rows]
        sample = next((v for v in vals if v is not None), None)
        if isinstance(sample, list):
            columns[path] = RaggedColumn(lists=[v if v is not None else [] for v in vals])
        elif isinstance(sample, str):
            columns[path] = DictColumn(values=[v if v is not None else "" for v in vals])
        elif isinstance(sample, bool):
            columns[path] = np.array([bool(v) for v in vals])
        elif isinstance(sample, int) and all(v is not None for v in vals):
            columns[path] = np.array(vals, dtype=np.int64)
        else:
            columns[path] = np.array(
                [np.nan if v is None else float(v) for v in vals], dtype=np.float64)
    return Table(name, columns)


# ---------------------------------------------------------------------------
# Graph model + topology storage (paper Definitions 3-4, TPU-adapted to CSR)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency: for source nid ``s``, its out-
    neighbors are ``col_idx[row_ptr[s]:row_ptr[s+1]]`` and the corresponding
    edge tids are ``edge_id[row_ptr[s]:row_ptr[s+1]]``."""

    row_ptr: np.ndarray   # (n_vertices+1,) int64
    col_idx: np.ndarray   # (n_edges,) int32 target nids
    edge_id: np.ndarray   # (n_edges,) int32 edge tids

    @property
    def n_vertices(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.col_idx)

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def neighbors(self, frontier: np.ndarray):
        """Vectorized whole-frontier expansion (the CSR analogue of walking
        the paper's linked adjacency lists). Returns (src_rep, dst, eid)."""
        frontier = np.asarray(frontier)
        deg = self.row_ptr[frontier + 1] - self.row_ptr[frontier]
        total = int(deg.sum())
        src_rep = np.repeat(frontier, deg)
        starts = np.repeat(self.row_ptr[frontier], deg)
        out_off = np.zeros(len(frontier) + 1, dtype=np.int64)
        np.cumsum(deg, out=out_off[1:])
        pos = starts + (np.arange(total) - np.repeat(out_off[:-1], deg))
        return src_rep, self.col_idx[pos], self.edge_id[pos]


def build_csr(n_vertices: int, src: np.ndarray, dst: np.ndarray) -> CSR:
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_vertices)
    row_ptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(row_ptr=row_ptr,
               col_idx=dst_s.astype(np.int32),
               edge_id=order.astype(np.int32))


class Graph:
    """Property graph G = (Omega, V, E, L) with uniform edge label.

    * ``vertex_tables``: label -> Table (records; row index == vid)
    * ``edges``: Table with structural keys ``svid``,``tvid`` (+ labels
      ``slabel``,``tlabel`` as table names) and property columns.
    * Topology (Omega): global nid space = concatenation of vertex tables in
      ``labels`` order. ``fwd``/``rev`` CSRs; mappers are dense arrays:
        - nid_base[label] + vid == nid          (nidMap)
        - vertex_label_of[nid], vertex_vid_of[nid]  (vertexMap)
        - CSR.edge_id == edgeMap (edge tid per adjacency slot)
    """

    def __init__(self, name: str, vertex_tables: dict[str, Table], edges: Table,
                 src_label: str, dst_label: str):
        self.name = name
        self.vertex_tables = dict(vertex_tables)
        self.edges = edges
        self.labels = list(vertex_tables)
        self.src_label = src_label
        self.dst_label = dst_label

        self.nid_base: dict[str, int] = {}
        base = 0
        for lbl in self.labels:
            self.nid_base[lbl] = base
            base += vertex_tables[lbl].nrows
        self.n_vertices = base

        self.vertex_label_code = np.zeros(base, dtype=np.int8)
        self.vertex_vid_of = np.zeros(base, dtype=np.int64)
        for i, lbl in enumerate(self.labels):
            b, n = self.nid_base[lbl], vertex_tables[lbl].nrows
            self.vertex_label_code[b:b + n] = i
            self.vertex_vid_of[b:b + n] = np.arange(n)

        src_nid = self.nid_base[src_label] + np.asarray(edges.col("svid"))
        dst_nid = self.nid_base[dst_label] + np.asarray(edges.col("tvid"))
        self.src_nid, self.dst_nid = src_nid, dst_nid
        self.fwd = build_csr(base, src_nid, dst_nid)
        self.rev = build_csr(base, dst_nid, src_nid)

    # ---- mapping structures (paper §4.2) ----
    def nid_of(self, label: str, vids: np.ndarray) -> np.ndarray:
        return self.nid_base[label] + np.asarray(vids)

    def vids_of(self, nids: np.ndarray) -> np.ndarray:
        return self.vertex_vid_of[np.asarray(nids)]

    def label_range(self, label: str) -> tuple[int, int]:
        b = self.nid_base[label]
        return b, b + self.vertex_tables[label].nrows

    @property
    def avg_out_degree(self) -> float:
        return self.fwd.n_edges / max(self.n_vertices, 1)

    # ---- updates (paper §4.4; staged insertion protocol) ----
    def insert_vertices(self, label: str, rows: dict[str, np.ndarray]) -> None:
        """Vertex-only batch insertion: records first (RecordAM), then fresh
        nids; adjacency untouched (paper's vertex-only fast path)."""
        tbl = self.vertex_tables[label]
        ncols = {}
        for k, c in tbl.columns.items():
            new = rows[k]
            if isinstance(c, DictColumn):
                merged = np.concatenate([c.vocab[c.codes], np.asarray(new, dtype=object)])
                ncols[k] = DictColumn(values=merged)
            else:
                ncols[k] = np.concatenate([np.asarray(c), np.asarray(new)])
        self.vertex_tables[label] = Table(tbl.name, ncols)
        self._rebuild_topology()

    def insert_edges(self, rows: dict[str, np.ndarray]) -> None:
        ncols = {}
        for k, c in self.edges.columns.items():
            new = rows[k]
            if isinstance(c, DictColumn):
                merged = np.concatenate([c.vocab[c.codes], np.asarray(new, dtype=object)])
                ncols[k] = DictColumn(values=merged)
            else:
                ncols[k] = np.concatenate([np.asarray(c), np.asarray(new)])
        self.edges = Table(self.edges.name, ncols)
        self._rebuild_topology()

    def delete_edges(self, edge_tids: np.ndarray) -> None:
        keep = np.ones(self.edges.nrows, dtype=bool)
        keep[np.asarray(edge_tids)] = False
        self.edges = self.edges.take(np.nonzero(keep)[0])
        self._rebuild_topology()

    def _rebuild_topology(self):
        # Incremental CSR append is possible; for clarity we rebuild — the
        # mappers stay consistent by construction (the paper's consistency
        # requirement between record and topology storage).
        self.__init__(self.name, self.vertex_tables, self.edges,
                      self.src_label, self.dst_label)


# ---------------------------------------------------------------------------
# Database catalog
# ---------------------------------------------------------------------------


class Database:
    """The unified store: relational tables, shredded document collections,
    and graphs, one namespace (paper Fig. 2(a))."""

    def __init__(self):
        self.tables: dict[str, Table] = {}
        self.graphs: dict[str, Graph] = {}

    def add_table(self, t: Table):
        self.tables[t.name] = t

    def add_documents(self, name: str, docs: list[dict]):
        self.tables[name] = shred_documents(name, docs)

    def add_graph(self, g: Graph):
        self.graphs[g.name] = g

    def collection(self, name: str):
        if name in self.tables:
            return self.tables[name]
        if name in self.graphs:
            return self.graphs[name]
        raise KeyError(name)
