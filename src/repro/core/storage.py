"""Dual storage engine (paper §4), TPU-adapted.

* Unified record storage: columnar struct-of-arrays tables (NF² via ragged
  (values, offsets) pairs for multi-valued attributes). Strings are
  dictionary-encoded (int32 codes + vocabulary) so every column the execution
  engine touches is a dense numeric array — the TPU analogue of JSONB fields.
* Document shredding: each JSON path used by queries becomes a column
  ("a.b.c"); arrays become ragged columns. This replaces per-record JSONB
  parsing with one-time columnarization (same spirit as JSON tiles).
* Topology storage: CSR adjacency (forward + reverse) replacing the paper's
  singly-linked adjacency graph; nidMap/vertexMap/edgeMap are dense index
  arrays (O(1) ``take`` — the tid-based RecordAM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Columns
# ---------------------------------------------------------------------------


def encode_batch(values: list, index: dict, n_vocab: int
                 ) -> tuple[np.ndarray, list]:
    """Encode a batch of values against an existing vocab ``index``, giving
    fresh codes (from ``n_vocab`` up) to unseen values. Mutates ``index``;
    returns (int32 codes, newly-seen values in first-appearance order). The
    single encoding loop shared by ``DictColumn.append`` and the
    delta store's incremental merged views (``deltastore.ColumnMerger``)."""
    codes = np.empty(len(values), dtype=np.int32)
    fresh: list = []
    for i, v in enumerate(values):
        c = index.get(v)
        if c is None:
            c = n_vocab + len(fresh)
            index[v] = c
            fresh.append(v)
        codes[i] = c
    return codes, fresh


class DictColumn:
    """Dictionary-encoded string column: int32 codes into ``vocab``."""

    __slots__ = ("codes", "vocab", "_index")

    def __init__(self, values: Iterable[str] | None = None, codes=None, vocab=None):
        if values is not None:
            vocab, codes = np.unique(np.asarray(list(values), dtype=object), return_inverse=True)
            self.vocab = vocab
            self.codes = codes.astype(np.int32)
        else:
            self.codes = np.asarray(codes, dtype=np.int32)
            self.vocab = np.asarray(vocab, dtype=object)
        self._index: Optional[dict] = None

    def encode(self, value: str) -> int:
        """Map a string to its code (-1 if absent)."""
        if self._index is None:
            self._index = {v: i for i, v in enumerate(self.vocab)}
        return self._index.get(value, -1)

    def decode(self, codes) -> np.ndarray:
        return self.vocab[np.asarray(codes)]

    def append(self, values) -> "DictColumn":
        """Extend with new rows, growing the vocabulary incrementally: only
        unseen values get new codes and only the new rows are encoded — no
        decode + re-unique round trip over the existing column."""
        values = list(values)
        if self._index is None:
            self._index = {v: i for i, v in enumerate(self.vocab)}
        index = dict(self._index)   # this column stays unaffected
        new_codes, vocab_ext = encode_batch(values, index, len(self.vocab))
        vocab = (np.concatenate([self.vocab, np.asarray(vocab_ext, dtype=object)])
                 if vocab_ext else self.vocab)
        out = DictColumn(codes=np.concatenate([self.codes, new_codes]), vocab=vocab)
        out._index = index
        return out

    def take(self, idx) -> "DictColumn":
        return DictColumn(codes=self.codes[idx], vocab=self.vocab)

    def __len__(self):
        return len(self.codes)

    @property
    def dtype(self):
        return np.dtype(object)


class RaggedColumn:
    """Multi-valued (NF²) column: flat ``values`` + ``offsets`` (len n+1)."""

    __slots__ = ("values", "offsets")

    def __init__(self, lists: Iterable[Iterable] | None = None, values=None, offsets=None):
        if lists is not None:
            lists = [np.asarray(l) for l in lists]
            self.offsets = np.zeros(len(lists) + 1, dtype=np.int64)
            np.cumsum([len(l) for l in lists], out=self.offsets[1:])
            self.values = (np.concatenate(lists) if lists else np.zeros(0))
        else:
            self.values = np.asarray(values)
            self.offsets = np.asarray(offsets, dtype=np.int64)

    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def take(self, idx) -> "RaggedColumn":
        idx = np.asarray(idx)
        # per-row lengths of the selected rows only — O(|idx|), not O(n)
        lens = self.offsets[idx + 1] - self.offsets[idx]
        out_off = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lens, out=out_off[1:])
        # gather: for each row, slice values[offsets[i]:offsets[i+1]]
        starts = np.repeat(self.offsets[idx], lens)
        within = np.arange(out_off[-1]) - np.repeat(out_off[:-1], lens)
        return RaggedColumn(values=self.values[starts + within], offsets=out_off)

    def row(self, i: int) -> np.ndarray:
        return self.values[self.offsets[i]:self.offsets[i + 1]]

    def __len__(self):
        return len(self.offsets) - 1


Column = Any  # np.ndarray | DictColumn | RaggedColumn


def _col_len(c: Column) -> int:
    return len(c)


def _col_take(c: Column, idx) -> Column:
    if isinstance(c, (DictColumn, RaggedColumn)):
        return c.take(idx)
    return np.asarray(c)[idx]


# ---------------------------------------------------------------------------
# Column statistics for the cost model (§6.3: selectivity estimation)
# ---------------------------------------------------------------------------

N_HIST_BUCKETS = 32     # equi-width histogram resolution for numeric columns
MCV_CAP = 4096          # keep exact per-value counts up to this many distincts


@dataclasses.dataclass
class ColumnStats:
    """Per-column statistics: row count, NDV, min/max, an equi-width
    histogram for numeric columns, and exact per-value counts for
    dictionary-encoded columns (most-common-value statistics). The optimizer
    keys join ordering and semi-join siding off ``ndv``; ``selectivity`` is
    value-aware when the per-value counts are present."""

    n: int
    ndv: int                              # number of distinct values
    vmin: Any = None
    vmax: Any = None
    hist: Optional[np.ndarray] = None     # bucket counts (equi-width)
    edges: Optional[np.ndarray] = None    # len(hist)+1 bucket boundaries
    value_counts: Optional[dict] = None   # value -> exact row count

    def _has_hist(self) -> bool:
        return (self.hist is not None and self.edges is not None
                and len(self.edges) > 1 and float(self.hist.sum()) > 0)

    def _bucket_ndvs(self) -> np.ndarray:
        """Estimated distinct values per histogram bucket: NDV distributed
        proportionally to bucket mass (and capped by the bucket count)."""
        total = float(self.hist.sum())
        nd = self.ndv * self.hist / total
        return np.minimum(np.maximum(nd, 0.0), np.maximum(self.hist, 0.0))

    def join_overlap(self, other: "ColumnStats"
                     ) -> Optional[tuple[float, str]]:
        """Expected equi-join matches |L ⋈ R| between the two *base* columns
        (every row on both sides): Σ_k count_L(k) · count_R(k) over the join
        keys k. Returns ``(matches, provenance)`` or ``None`` when neither
        side carries a key distribution (caller falls back to NDV
        containment).

        * exact when both sides keep per-value MCV counts;
        * point-mass × per-bucket density when one side has numeric MCV
          counts and the other an equi-width histogram;
        * per-bucket-pair overlap under a uniform-within-bucket containment
          assumption when only histograms are available.

        Memoized per partner: the optimizer's join enumerator probes the
        same key pair once per candidate split, and an MCV sum is O(ndv).
        The memo key embeds both row/NDV counts, so in-place stat extension
        (delta appends) can never serve a stale overlap.
        """
        memo = self.__dict__.setdefault("_overlap_memo", {})
        key = (id(other), self.n, self.ndv, other.n, other.ndv)
        ent = memo.get(key)
        if ent is not None and ent[0] is other:
            return ent[1]
        out = self._join_overlap(other)
        if len(memo) > 8:
            memo.clear()
        memo[key] = (other, out)    # holding ``other`` pins its id
        return out

    def _join_overlap(self, other: "ColumnStats"
                      ) -> Optional[tuple[float, str]]:
        if self.n == 0 or other.n == 0:
            return 0.0, "empty"
        a_mcv, b_mcv = self.value_counts is not None, other.value_counts is not None
        if a_mcv and b_mcv:
            small, big = ((self.value_counts, other.value_counts)
                          if len(self.value_counts) <= len(other.value_counts)
                          else (other.value_counts, self.value_counts))
            m = float(sum(c * big.get(v, 0) for v, c in small.items()))
            return m, (f"mcv×mcv[{len(self.value_counts)}"
                       f"×{len(other.value_counts)}]")
        # numeric MCV point masses against the other side's histogram
        if a_mcv and self.vmin is not None and other._has_hist():
            return _points_vs_hist(self.value_counts, other), "mcv×hist"
        if b_mcv and other.vmin is not None and self._has_hist():
            return _points_vs_hist(other.value_counts, self), "hist×mcv"
        if self._has_hist() and other._has_hist():
            return (_hist_overlap(self, other),
                    f"hist[{len(self.hist)}×{len(other.hist)}]")
        return None

    def eq_fraction(self, value) -> float:
        """Fraction of rows equal to ``value`` (exact when MCV counts are
        kept, System-R 1/ndv otherwise)."""
        if self.n == 0:
            return 0.0
        if self.value_counts is not None:
            return self.value_counts.get(value, 0) / self.n
        return 1.0 / max(self.ndv, 1)

    def _cdf(self, x: float) -> float:
        """P(col <= x) from the histogram (linear within a bucket)."""
        e, h = self.edges, self.hist
        total = h.sum()
        if total == 0:
            return 0.0
        if x <= e[0]:
            return 0.0
        if x >= e[-1]:
            return 1.0
        i = int(np.searchsorted(e, x, side="right")) - 1
        i = min(i, len(h) - 1)
        width = e[i + 1] - e[i]
        frac_in = (x - e[i]) / width if width > 0 else 1.0
        return float(h[:i].sum() + h[i] * frac_in) / float(total)

    def range_fraction(self, lo, hi) -> float:
        """Fraction of rows in [lo, hi], histogram-backed when available."""
        if self.hist is not None and self.edges is not None and len(self.edges) > 1:
            return max(0.0, self._cdf(float(hi)) - self._cdf(float(lo)))
        if self.vmin is None or self.vmax is None or self.vmax == self.vmin:
            return 1.0 / 3.0
        span = float(self.vmax) - float(self.vmin)
        return min(1.0, max(0.0, (float(hi) - float(lo)) / span))

    def selectivity(self, pred) -> float:
        """System-R style estimates, upgraded with MCV counts (equality on
        dictionary columns is exact) and equi-width histograms (range).
        Always clamped to [0, 1]."""
        return min(1.0, max(0.0, self._selectivity(pred)))

    def _selectivity(self, pred) -> float:
        if self.n == 0:
            return 0.0
        if pred.op == "==":
            return self.eq_fraction(pred.value)
        if pred.op == "!=":
            return 1.0 - self.eq_fraction(pred.value)
        if pred.op == "in":
            if self.value_counts is not None:
                return sum(self.value_counts.get(v, 0)
                           for v in pred.value) / self.n
            return len(pred.value) / max(self.ndv, 1)
        try:
            if pred.op == "range":
                return self.range_fraction(pred.value, pred.value2)
            if pred.op in ("<", "<="):
                lo = self.vmin if self.vmin is not None else pred.value
                return self.range_fraction(lo, pred.value)
            hi = self.vmax if self.vmax is not None else pred.value
            return self.range_fraction(pred.value, hi)
        except (TypeError, ValueError):
            return 1.0 / 3.0

    # ---- incremental maintenance (delta-store appends) ----
    def extend_numeric(self, run: np.ndarray) -> None:
        """Absorb appended numeric values in O(|run| + buckets): min/max and
        histogram update exactly (re-binning old counts proportionally when
        the value range grows); NDV extrapolates with the observed
        distinctness ratio, so key-like columns keep growing while
        low-cardinality columns stay put."""
        run = np.asarray(run, dtype=np.float64)
        n_add = len(run)                 # n counts rows, like compute_stats
        if n_add == 0:
            return
        run = run[np.isfinite(run)]      # values feed min/max/hist/MCV only
        if run.size == 0:
            self.n += n_add
            return
        if self.n == 0 or self.ndv == 0:
            # empty/all-NaN base: seed from the run (a 0 distinctness ratio
            # would otherwise freeze ndv at 0 forever)
            n_rows = self.n + n_add
            fresh = _numeric_stats(run, n_rows)
            self.n, self.ndv = n_rows, fresh.ndv
            self.vmin, self.vmax = fresh.vmin, fresh.vmax
            self.hist, self.edges = fresh.hist, fresh.edges
            self.value_counts = fresh.value_counts
            return
        self.n += n_add
        if self.value_counts is not None:
            # exact per-value counts (and therefore exact NDV) survive the
            # append; drop to estimates only past the MCV cap
            u, c = np.unique(run, return_counts=True)
            for v, k in zip(u.tolist(), c.tolist()):
                self.value_counts[v] = self.value_counts.get(v, 0) + k
            if len(self.value_counts) > MCV_CAP:
                self.value_counts = None
            else:
                self.ndv = len(self.value_counts)
        if self.value_counts is None:
            n_old = self.n - n_add
            ratio = min(1.0, self.ndv / max(n_old, 1))
            self.ndv = min(self.n,
                           self.ndv + max(int(round(len(run) * ratio)), 0))
        rmin, rmax = float(run.min()), float(run.max())
        vmin = rmin if self.vmin is None else min(float(self.vmin), rmin)
        vmax = rmax if self.vmax is None else max(float(self.vmax), rmax)
        if self.hist is None or self.edges is None:
            self.vmin, self.vmax = vmin, vmax
            return
        if vmin < self.edges[0] or vmax > self.edges[-1]:
            new_edges = np.linspace(vmin, vmax if vmax > vmin else vmin + 1.0,
                                    len(self.hist) + 1)
            self.hist = _rebin(self.hist, self.edges, new_edges)
            self.edges = new_edges
        self.hist = self.hist + np.histogram(run, bins=self.edges)[0]
        self.vmin, self.vmax = vmin, vmax


def _rebin(counts: np.ndarray, old_edges: np.ndarray,
           new_edges: np.ndarray) -> np.ndarray:
    """Redistribute equi-width histogram counts onto new bucket boundaries,
    assigning each old bucket's mass proportionally to its overlap."""
    out = np.zeros(len(new_edges) - 1, dtype=np.float64)
    for i in range(len(counts)):
        lo, hi = old_edges[i], old_edges[i + 1]
        width = hi - lo
        if counts[i] == 0:
            continue
        if width <= 0:
            j = min(int(np.searchsorted(new_edges, lo, "right")) - 1, len(out) - 1)
            out[max(j, 0)] += counts[i]
            continue
        for j in range(len(out)):
            ov = min(hi, new_edges[j + 1]) - max(lo, new_edges[j])
            if ov > 0:
                out[j] += counts[i] * (ov / width)
    return out


def _points_vs_hist(vc: dict, hstats: ColumnStats) -> float:
    """Expected matches of exact point masses against a histogram side: each
    key lands in one bucket and matches ``bucket_rows / bucket_ndv`` rows
    (uniform key distribution within the bucket)."""
    e, h = hstats.edges, hstats.hist
    nd = hstats._bucket_ndvs()
    m = 0.0
    for v, c in vc.items():
        try:
            x = float(v)
        except (TypeError, ValueError):
            continue            # non-numeric key cannot hit a numeric bucket
        if x < e[0] or x > e[-1]:
            continue
        j = min(max(int(np.searchsorted(e, x, "right")) - 1, 0), len(h) - 1)
        m += c * h[j] / max(nd[j], 1.0)
    return float(m)


def _hist_overlap(a: ColumnStats, b: ColumnStats) -> float:
    """Expected matches per overlapping equi-width bucket pair: within each
    overlap region both sides are assumed uniform over their in-region
    distincts, and the side with more distincts defines the key domain
    (System-R containment, applied per region instead of globally)."""
    nda, ndb = a._bucket_ndvs(), b._bucket_ndvs()
    m = 0.0
    for i in range(len(a.hist)):
        lo_a, hi_a = float(a.edges[i]), float(a.edges[i + 1])
        wa = hi_a - lo_a
        if a.hist[i] <= 0 or wa <= 0:
            continue
        j = max(int(np.searchsorted(b.edges, lo_a, "right")) - 1, 0)
        for j in range(j, len(b.hist)):
            lo_b, hi_b = float(b.edges[j]), float(b.edges[j + 1])
            if lo_b >= hi_a:
                break
            wb = hi_b - lo_b
            ov = min(hi_a, hi_b) - max(lo_a, lo_b)
            if b.hist[j] <= 0 or wb <= 0 or ov <= 0:
                continue
            ca = a.hist[i] * ov / wa          # rows of each side in region
            cb = b.hist[j] * ov / wb
            da = nda[i] * ov / wa             # distincts of each side there
            db = ndb[j] * ov / wb
            m += ca * cb / max(da, db, 1.0)
    return float(m)


def _numeric_stats(vals: np.ndarray, n_rows: int) -> ColumnStats:
    finite = vals[np.isfinite(vals)] if vals.dtype.kind == "f" else vals
    if finite.size == 0:
        return ColumnStats(n_rows, 0)
    u, c = np.unique(finite, return_counts=True)
    vmin, vmax = float(u[0]), float(u[-1])
    hist, edges = np.histogram(
        finite, bins=N_HIST_BUCKETS,
        range=(vmin, vmax if vmax > vmin else vmin + 1.0))
    vc = None
    if len(u) <= MCV_CAP:
        vc = {u[i].item(): int(c[i]) for i in range(len(u))}
    return ColumnStats(n_rows, int(len(u)), vmin, vmax,
                       hist.astype(np.float64), edges, vc)


def dict_stats(n: int, vocab: np.ndarray, counts: np.ndarray) -> ColumnStats:
    """ColumnStats of a dictionary-encoded column from its (vocab, per-code
    counts) — the single MCV construction shared by cold ``compute_stats``
    and the delta store's incrementally-maintained merged-view stats."""
    vc = None
    if len(vocab) <= MCV_CAP:
        vc = {vocab[i]: int(counts[i]) for i in range(len(vocab))}
    return ColumnStats(n=n, ndv=int((counts > 0).sum()), value_counts=vc)


def compute_stats(col: Column) -> ColumnStats:
    if isinstance(col, DictColumn):
        counts = np.bincount(col.codes, minlength=len(col.vocab))
        return dict_stats(len(col), col.vocab, counts)
    if isinstance(col, RaggedColumn):
        vals = np.asarray(col.values)
        if vals.size and vals.dtype.kind in "ifu":
            # value-level stats: n counts flat values, so predicate fractions
            # stay in [0, 1] (a lower-bound proxy for ANY-row selectivity)
            return _numeric_stats(vals, len(vals))
        ndv = len(np.unique(vals)) if len(vals) else 0
        return ColumnStats(n=len(col), ndv=ndv)
    col = np.asarray(col)
    if col.size == 0:
        return ColumnStats(0, 0)
    if col.dtype.kind in "ifu":
        return _numeric_stats(col, len(col))
    uniq = np.unique(col)
    return ColumnStats(len(col), int(len(uniq)))


def merge_stats(parts: list[ColumnStats]) -> ColumnStats:
    """Additive rollup of per-shard :class:`ColumnStats` into one global
    object — the cardinality model the optimizer consumes when a table is
    partitioned. Row counts and min/max always combine exactly. While every
    shard keeps exact per-value counts (ndv <= MCV_CAP after the merge),
    the rollup is *bit-exact* against ``compute_stats`` on the unpartitioned
    column: value counts sum, NDV is recounted from the merged map, and the
    numeric histogram is rebuilt from the merged keys weighted by their
    counts — the same binning ``_numeric_stats`` applies to the raw values.
    Past the MCV cap the NDV falls back to a containment bound and
    histograms re-bin onto union edges (approximate, like ``extend_numeric``)."""
    parts = [p for p in parts if p is not None]
    if not parts:
        return ColumnStats(0, 0)
    n = int(sum(p.n for p in parts))
    mins = [p.vmin for p in parts if p.vmin is not None]
    maxs = [p.vmax for p in parts if p.vmax is not None]
    vmin = min(mins) if mins else None
    vmax = max(maxs) if maxs else None
    if all(p.value_counts is not None or p.ndv == 0 for p in parts):
        vc: dict = {}
        for p in parts:
            for v, c in (p.value_counts or {}).items():
                vc[v] = vc.get(v, 0) + c
        if len(vc) <= MCV_CAP:
            ndv = sum(1 for c in vc.values() if c > 0)
            hist = edges = None
            if vmin is not None and vc:
                try:
                    keys = np.array([float(v) for v in vc])
                    weights = np.array([vc[v] for v in vc], dtype=np.float64)
                    hist, edges = np.histogram(
                        keys, bins=N_HIST_BUCKETS, weights=weights,
                        range=(vmin, vmax if vmax > vmin else vmin + 1.0))
                    hist = hist.astype(np.float64)
                except (TypeError, ValueError):
                    hist = edges = None
            return ColumnStats(n, ndv, vmin, vmax, hist, edges, vc)
    # some shard overflowed the MCV cap: approximate rollup
    ndv = int(min(n, sum(p.ndv for p in parts)))
    hparts = [p for p in parts if p._has_hist()]
    hist = edges = None
    if hparts and vmin is not None:
        edges = np.linspace(vmin, vmax if vmax > vmin else vmin + 1.0,
                            N_HIST_BUCKETS + 1)
        hist = np.zeros(N_HIST_BUCKETS, dtype=np.float64)
        for p in hparts:
            hist += _rebin(p.hist, p.edges, edges)
    return ColumnStats(n, ndv, vmin, vmax, hist, edges, None)


# ---------------------------------------------------------------------------
# Tables (unified record storage)
# ---------------------------------------------------------------------------


class Table:
    """Columnar table. Row index == tid (paper: tuple identifier; tid-based
    RecordAM == ``take`` on row indices)."""

    def __init__(self, name: str, columns: dict[str, Column]):
        self.name = name
        self.columns = dict(columns)
        lens = {k: _col_len(v) for k, v in self.columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(f"ragged table {name}: {lens}")
        self.nrows = next(iter(lens.values())) if lens else 0
        self._stats: dict[str, ColumnStats] = {}

    def col(self, name: str) -> Column:
        return self.columns[name]

    def stats(self, name: str) -> ColumnStats:
        if name not in self._stats:
            self._stats[name] = compute_stats(self.columns[name])
        return self._stats[name]

    def take(self, idx) -> "Table":
        return Table(self.name, {k: _col_take(v, idx) for k, v in self.columns.items()})

    def eval_predicate(self, pred, rows=None) -> np.ndarray:
        """Vectorized predicate mask (the scan-based RecordAM's filter).
        With ``rows`` the predicate is evaluated on that row subset only
        (mask aligns with ``rows``) — the point-evaluation path index
        lookups and deferred predicates use to avoid O(n) column scans."""
        col = self.columns[pred.column]
        if rows is not None:
            col = _col_take(col, np.asarray(rows))
        if isinstance(col, DictColumn):
            if pred.op == "==":
                return col.codes == col.encode(pred.value)
            if pred.op == "!=":
                return col.codes != col.encode(pred.value)
            if pred.op == "in":
                codes = np.array([col.encode(v) for v in pred.value])
                return np.isin(col.codes, codes)
            # range predicates on strings: decode-free compare via vocab order
            vals = col.vocab[col.codes]
        elif isinstance(col, RaggedColumn):
            # predicate over a multi-valued attribute: ANY semantics
            hit = _scalar_cmp(col.values, pred)
            seg = np.repeat(np.arange(len(col)), col.lengths())
            out = np.zeros(len(col), dtype=bool)
            np.logical_or.at(out, seg, hit)
            return out
        else:
            vals = np.asarray(col)
        return _scalar_cmp(vals, pred)

    def __repr__(self):
        return f"Table({self.name}, rows={self.nrows}, cols={list(self.columns)})"


def _scalar_cmp(vals: np.ndarray, pred) -> np.ndarray:
    op, v = pred.op, pred.value
    if op == "==":
        return vals == v
    if op == "!=":
        return vals != v
    if op == "<":
        return vals < v
    if op == "<=":
        return vals <= v
    if op == ">":
        return vals > v
    if op == ">=":
        return vals >= v
    if op == "range":
        return (vals >= v) & (vals <= pred.value2)
    if op == "in":
        return np.isin(vals, np.asarray(list(v)))
    raise ValueError(op)


# ---------------------------------------------------------------------------
# Document collections: JSON shredding
# ---------------------------------------------------------------------------


def shred_documents(name: str, docs: list[dict]) -> Table:
    """Shred a JSON document collection into a columnar Table. Every leaf
    path becomes a column named "a.b"; lists of scalars become RaggedColumns;
    missing values are filled with NaN / "" (absent-path semantics)."""
    paths: dict[str, list] = {}

    def walk(prefix: str, obj, row: dict):
        if isinstance(obj, dict):
            for k, v in obj.items():
                walk(f"{prefix}.{k}" if prefix else k, v, row)
        else:
            row[prefix] = obj

    rows = []
    for d in docs:
        row: dict = {}
        walk("", d, row)
        rows.append(row)
        for k in row:
            paths.setdefault(k, None)

    columns: dict[str, Column] = {}
    for path in paths:
        vals = [r.get(path) for r in rows]
        sample = next((v for v in vals if v is not None), None)
        if isinstance(sample, list):
            columns[path] = RaggedColumn(lists=[v if v is not None else [] for v in vals])
        elif isinstance(sample, str):
            columns[path] = DictColumn(values=[v if v is not None else "" for v in vals])
        elif isinstance(sample, bool):
            columns[path] = np.array([bool(v) for v in vals])
        elif isinstance(sample, int) and all(v is not None for v in vals):
            columns[path] = np.array(vals, dtype=np.int64)
        else:
            columns[path] = np.array(
                [np.nan if v is None else float(v) for v in vals], dtype=np.float64)
    return Table(name, columns)


# ---------------------------------------------------------------------------
# Graph model + topology storage (paper Definitions 3-4, TPU-adapted to CSR)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency: for source nid ``s``, its out-
    neighbors are ``col_idx[row_ptr[s]:row_ptr[s+1]]`` and the corresponding
    edge tids are ``edge_id[row_ptr[s]:row_ptr[s+1]]``."""

    row_ptr: np.ndarray   # (n_vertices+1,) int64
    col_idx: np.ndarray   # (n_edges,) int32 target nids
    edge_id: np.ndarray   # (n_edges,) int32 edge tids

    @property
    def n_vertices(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.col_idx)

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def neighbors(self, frontier: np.ndarray):
        """Vectorized whole-frontier expansion (the CSR analogue of walking
        the paper's linked adjacency lists). Returns (src_rep, dst, eid)."""
        from .deltastore import expand_runs
        frontier = np.asarray(frontier)
        deg = self.row_ptr[frontier + 1] - self.row_ptr[frontier]
        pos, slots = expand_runs(self.row_ptr[frontier], deg)
        return frontier[pos], self.col_idx[slots], self.edge_id[slots]


def build_csr(n_vertices: int, src: np.ndarray, dst: np.ndarray) -> CSR:
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_vertices)
    row_ptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(row_ptr=row_ptr,
               col_idx=dst_s.astype(np.int32),
               edge_id=order.astype(np.int32))


class _VertexTableView:
    """Mapping view over a graph's vertex tables: ``g.vertex_tables[label]``
    returns the base table when the label has no pending delta rows, else a
    lazily merged (and cached) base ⊕ delta table."""

    def __init__(self, g: "Graph"):
        self._g = g

    def __getitem__(self, label: str) -> Table:
        return self._g.vertex_table(label)

    def __iter__(self):
        return iter(self._g.labels)

    def __len__(self):
        return len(self._g.labels)

    def __contains__(self, label):
        return label in self._g.labels

    def keys(self):
        return list(self._g.labels)

    def items(self):
        return [(lbl, self[lbl]) for lbl in self._g.labels]

    def values(self):
        return [self[lbl] for lbl in self._g.labels]


class Graph:
    """Property graph G = (Omega, V, E, L) with uniform edge label.

    * ``vertex_tables``: label -> Table (records; row index == vid)
    * ``edges``: Table with structural keys ``svid``,``tvid`` (+ labels
      ``slabel``,``tlabel`` as table names) and property columns.
    * Topology (Omega): global nid space = concatenation of vertex tables in
      ``labels`` order. ``fwd``/``rev`` CSRs; mappers are dense arrays:
        - nid_base[label] + vid == nid          (nidMap)
        - vertex_label_of[nid], vertex_vid_of[nid]  (vertexMap)
        - CSR.edge_id == edgeMap (edge tid per adjacency slot)

    Mutations are O(batch): they land in ``self.delta`` (an LSM-style
    write-ahead layer — see :mod:`repro.core.deltastore`), reads consult
    base ⊕ delta (``expand``/lazy table views), and ``compact`` folds the
    delta into a fresh base. ``epoch`` increments on every logical mutation
    and keys the inter-buffer so cached GCDA results never go stale.
    Between compactions, delta vertices occupy nids appended after the base
    label blocks; compaction restores the contiguous label-block layout.
    """

    def __init__(self, name: str, vertex_tables: dict[str, Table], edges: Table,
                 src_label: str, dst_label: str,
                 delta_config: Optional["deltastore.DeltaConfig"] = None):
        from . import deltastore
        self.name = name
        self.src_label = src_label
        self.dst_label = dst_label
        self.epoch = 0
        self.compactions = 0
        self.last_compact_seconds = 0.0
        self.write_counters = deltastore.WriteCounters()
        # mutation listeners: fn(graph, op, payload) called after each
        # successful write (workload capture — see repro.core.observe)
        self.listeners: list = []
        self.delta_config = delta_config or deltastore.DeltaConfig()
        self._set_base(dict(vertex_tables), edges)

    def _set_base(self, vertex_tables: dict[str, Table], edges: Table) -> None:
        """Install a fresh base snapshot (initial build and compaction).
        The only O(V+E) path: builds CSRs, mappers, and resets the delta."""
        from . import deltastore
        self._base_vertex_tables = vertex_tables
        self._base_edges = edges
        self.labels = list(vertex_tables)
        self._label_code = {lbl: i for i, lbl in enumerate(self.labels)}

        self.nid_base: dict[str, int] = {}
        base = 0
        for lbl in self.labels:
            self.nid_base[lbl] = base
            base += vertex_tables[lbl].nrows
        self._n_base_vertices = base
        self._base_label_rows = {lbl: vertex_tables[lbl].nrows for lbl in self.labels}

        vlc = np.zeros(base, dtype=np.int8)
        vvo = np.zeros(base, dtype=np.int64)
        for i, lbl in enumerate(self.labels):
            b, n = self.nid_base[lbl], vertex_tables[lbl].nrows
            vlc[b:b + n] = i
            vvo[b:b + n] = np.arange(n)
        self._vlc = deltastore.Growable(vlc)
        self._vvo = deltastore.Growable(vvo)

        src_nid = self.nid_base[self.src_label] + np.asarray(edges.col("svid"))
        dst_nid = self.nid_base[self.dst_label] + np.asarray(edges.col("tvid"))
        self._src_nid = deltastore.Growable(src_nid.astype(np.int64))
        self._dst_nid = deltastore.Growable(dst_nid.astype(np.int64))
        self.fwd = build_csr(base, src_nid, dst_nid)
        self.rev = build_csr(base, dst_nid, src_nid)
        self._n_base_edges = edges.nrows

        self.delta = deltastore.GraphDelta(edges.nrows)
        self._edge_merger = None
        self._vt_mergers: dict[str, "deltastore.TableMerger"] = {}
        self.vertex_tables = _VertexTableView(self)

    # ---- merged (base ⊕ delta) record views ----
    # Backed by capacity-doubling column buffers (deltastore.TableMerger):
    # the first merge after a compaction pays one O(base) copy, every later
    # write/read cycle appends only the delta tail — O(batch), not O(base).
    def vertex_table(self, label: str) -> Table:
        runs = self.delta.vertex_rows.get(label)
        if not runs:
            return self._base_vertex_tables[label]
        from . import deltastore
        merger = self._vt_mergers.get(label)
        if merger is None:
            merger = self._vt_mergers[label] = deltastore.TableMerger(
                self._base_vertex_tables[label])
        return merger.table(runs)

    @property
    def edges(self) -> Table:
        """Edge record table including pending delta rows (row index == edge
        tid; tombstoned rows stay in place until compaction)."""
        if not self.delta.n_new_edges:
            return self._base_edges
        from . import deltastore
        if self._edge_merger is None:
            self._edge_merger = deltastore.TableMerger(self._base_edges)
        return self._edge_merger.table(self.delta.edge_rows)

    # ---- mapping structures (paper §4.2) ----
    @property
    def n_vertices(self) -> int:
        return self._n_base_vertices + self.delta.n_new_vertices_total

    @property
    def vertex_label_code(self) -> np.ndarray:
        return self._vlc.view()

    @property
    def vertex_vid_of(self) -> np.ndarray:
        return self._vvo.view()

    @property
    def src_nid(self) -> np.ndarray:
        return self._src_nid.view()

    @property
    def dst_nid(self) -> np.ndarray:
        return self._dst_nid.view()

    def nid_of(self, label: str, vids) -> np.ndarray:
        vids = np.asarray(vids)
        base_rows = self._base_label_rows[label]
        if self.delta.n_new_vertices.get(label, 0) == 0 or vids.size == 0 \
                or int(np.max(vids)) < base_rows:
            return self.nid_base[label] + vids
        flat = np.atleast_1d(vids).astype(np.int64)
        out = np.empty(len(flat), dtype=np.int64)
        in_base = flat < base_rows
        out[in_base] = self.nid_base[label] + flat[in_base]
        new_nids = self.delta.label_new_nids(label)
        out[~in_base] = new_nids[flat[~in_base] - base_rows]
        return out.reshape(vids.shape) if vids.ndim else out[0]

    def vids_of(self, nids: np.ndarray) -> np.ndarray:
        return self.vertex_vid_of[np.asarray(nids)]

    def label_range(self, label: str) -> tuple[int, int]:
        """Contiguous nid range of the label's BASE block (delta vertices of
        the label, if any, live past ``_n_base_vertices`` — use
        ``label_nids`` for the full set)."""
        b = self.nid_base[label]
        return b, b + self._base_label_rows[label]

    def label_nids(self, label: str) -> np.ndarray:
        """All nids of a label, base block first then delta vertices in
        insertion order (matches the merged vertex table's row order)."""
        lo, hi = self.label_range(label)
        new = self.delta.label_new_nids(label)
        base = np.arange(lo, hi, dtype=np.int64)
        return base if new is None else np.concatenate([base, new])

    def label_code_of(self, label: str) -> int:
        return self._label_code[label]

    @property
    def n_live_edges(self) -> int:
        return self._n_base_edges + self.delta.n_new_edges - self.delta.n_tombstones

    def live_edge_mask(self) -> np.ndarray:
        """Boolean mask over the edge-tid space (== ``edges.nrows``) that is
        False for tombstoned edges."""
        return self.delta.live_edge_mask()

    def live_edge_ids(self) -> np.ndarray:
        if not self.delta.n_tombstones:
            return np.arange(self._n_base_edges + self.delta.n_new_edges)
        return np.nonzero(self.delta.live_edge_mask())[0]

    @property
    def avg_out_degree(self) -> float:
        return self.n_live_edges / max(self.n_vertices, 1)

    def hop_expansion(self, reverse: bool = False,
                      label: Optional[str] = None) -> float:
        """Label-aware per-hop fan-out: live edges per vertex of the label a
        traversal expands *from* (src label forward, dst label reverse, or an
        explicit ``label`` override for per-hop estimates on mixed-label
        chains). On bipartite graphs this differs from ``avg_out_degree`` by
        the label size ratio, which is exactly the error the global average
        makes on reverse traversals. Consistent with pending delta segments:
        both the live-edge count and the merged vertex tables include the
        delta."""
        if label is None:
            label = self.dst_label if reverse else self.src_label
        return self.n_live_edges / max(self.vertex_tables[label].nrows, 1)

    # ---- base ⊕ delta topology reads ----
    def expand(self, frontier: np.ndarray, reverse: bool = False
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Whole-frontier adjacency expansion over base CSR ⊕ delta segments
        minus tombstones. Returns (pos, dst_nid, edge_tid) with ``pos``
        indexing into ``frontier``; output is grouped by frontier position."""
        frontier = np.asarray(frontier, dtype=np.int64)
        csr = self.rev if reverse else self.fwd
        d = self.delta
        delta_free = not d.segments and not d.n_tombstones
        in_base = frontier < self._n_base_vertices
        if delta_free and (frontier.size == 0 or in_base.all()):
            return _csr_expand(csr, frontier)

        parts = []
        if in_base.all():
            parts.append(_csr_expand(csr, frontier))
        else:
            idx = np.nonzero(in_base)[0]
            pos, dst, eid = _csr_expand(csr, frontier[idx])
            parts.append((idx[pos], dst, eid))
        for seg in d.segments:
            parts.append(seg.neighbors(frontier, reverse=reverse))
        if len(parts) == 1:
            pos, dst, eid = parts[0]  # already grouped by frontier position
            if d.n_tombstones:
                keep = d.live_mask_for(eid)
                pos, dst, eid = pos[keep], dst[keep], eid[keep]
            return pos, dst, eid
        pos = np.concatenate([p[0] for p in parts])
        dst = np.concatenate([p[1] for p in parts])
        eid = np.concatenate([p[2] for p in parts])
        if d.n_tombstones:
            keep = d.live_mask_for(eid)
            pos, dst, eid = pos[keep], dst[keep], eid[keep]
        order = np.argsort(pos, kind="stable")
        return pos[order], dst[order], eid[order]

    # ---- updates (paper §4.4 staged insertion, LSM-buffered) ----
    def _charge_write(self, **ops) -> None:
        """Charge write/compaction cost to this graph's counters (surfaced
        through the registry as ``deltastore.<graph>.<field>``)."""
        self.write_counters.bump(**ops)

    def _notify(self, op: str, payload: dict) -> None:
        for fn in self.listeners:
            fn(self, op, payload)

    def insert_vertices(self, label: str, rows: dict[str, np.ndarray]) -> None:
        """Vertex-only batch insertion: records buffered (RecordAM deferred
        to the lazy merge), fresh nids appended after the base nid space;
        adjacency untouched (the paper's vertex-only fast path). O(batch)."""
        base = self._base_vertex_tables[label]
        cols = {k: np.asarray(rows[k]) if not isinstance(base.columns[k], RaggedColumn)
                else rows[k] for k in base.columns}
        lens = {len(v) for v in cols.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged insert batch for {label}: "
                             f"{ {k: len(v) for k, v in cols.items()} }")
        n_new = lens.pop()
        if n_new == 0:
            return
        start = self._n_base_vertices + self.delta.n_new_vertices_total
        nids = np.arange(start, start + n_new, dtype=np.int64)
        vid0 = self._base_label_rows[label] + self.delta.n_new_vertices.get(label, 0)
        self.delta.buffer_vertices(label, cols, nids)
        self._vlc.append(np.full(n_new, self._label_code[label], dtype=np.int8))
        self._vvo.append(np.arange(vid0, vid0 + n_new, dtype=np.int64))
        self.epoch += 1
        self._charge_write(write_batches=1, write_rows=n_new, write_ops=n_new)
        self._notify("insert_vertices", {"label": label, "rows": cols})
        self._maybe_compact()

    def insert_edges(self, rows: dict[str, np.ndarray]) -> None:
        """Edge batch insertion: records buffered, topology absorbed as one
        immutable delta-CSR segment (forward + reverse). O(batch log batch)."""
        from . import deltastore
        cols = {k: rows[k] for k in self._base_edges.columns}
        svid = np.asarray(cols["svid"])
        tvid = np.asarray(cols["tvid"])
        n_new = len(svid)
        if n_new == 0:
            return
        src_nid = np.atleast_1d(self.nid_of(self.src_label, svid)).astype(np.int64)
        dst_nid = np.atleast_1d(self.nid_of(self.dst_label, tvid)).astype(np.int64)
        eid0 = self._n_base_edges + self.delta.n_new_edges
        eids = np.arange(eid0, eid0 + n_new, dtype=np.int64)
        seg = deltastore.EdgeSegment(src_nid, dst_nid, eids)
        self.delta.buffer_edges(cols, seg)
        self._src_nid.append(src_nid)
        self._dst_nid.append(dst_nid)
        self.epoch += 1
        self._charge_write(
            write_batches=1, write_rows=n_new,
            write_ops=n_new * max(int(np.ceil(np.log2(max(n_new, 2)))), 1))
        self._notify("insert_edges", {"rows": cols})
        self._maybe_compact()

    def delete_edges(self, edge_tids: np.ndarray) -> None:
        """Edge deletion: tombstone bitmap only — edge tids stay stable and
        the record rows remain in place until compaction. O(batch)."""
        tids = np.asarray(edge_tids)
        if len(tids) == 0:
            return
        fresh = self.delta.tombstone_edges(tids)
        if fresh == 0:
            return  # idempotent re-delete: content (and epoch) unchanged
        self.epoch += 1
        self._charge_write(write_batches=1, write_rows=fresh,
                           write_ops=len(tids))
        self._notify("delete_edges", {"edge_tids": tids})
        self._maybe_compact()

    # ---- compaction (the amortized rebuild) ----
    def _maybe_compact(self) -> None:
        from . import deltastore
        if deltastore.should_compact(self.delta_config, self.delta,
                                     self._n_base_edges):
            self.compact()

    def compact(self) -> None:
        """Fold the delta into a fresh base: merge record runs, drop
        tombstoned edge rows (renumbering edge tids), rebuild CSRs and
        mappers. Restores contiguous label-block nid layout. Pure merges
        leave the epoch alone (content and tids unchanged), but dropping
        tombstones renumbers edge tids, which IS observable through
        tid-projecting queries — so that case advances the epoch."""
        import time
        if not self.delta.has_pending():
            return
        t0 = time.perf_counter()
        renumbered = self.delta.n_tombstones > 0
        vt = {lbl: self.vertex_table(lbl) for lbl in self.labels}
        edges = self.edges
        if renumbered:
            edges = edges.take(np.nonzero(self.delta.live_edge_mask())[0])
        self._set_base(vt, edges)
        if renumbered:
            self.epoch += 1
        self.compactions += 1
        self.last_compact_seconds = time.perf_counter() - t0
        self._charge_write(
            compactions=1,
            compact_ops=self._n_base_vertices + self._n_base_edges)

    def _rebuild_topology(self):
        """Deprecated alias kept for API compatibility: the full rebuild now
        only happens inside ``compact``."""
        self.compact()


def _csr_expand(csr: CSR, frontier: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR.neighbors variant returning frontier *positions* instead of
    repeated source nids (callers join path prefixes through positions)."""
    from .deltastore import expand_runs
    deg = csr.row_ptr[frontier + 1] - csr.row_ptr[frontier]
    pos, slots = expand_runs(csr.row_ptr[frontier], deg)
    return pos, csr.col_idx[slots].astype(np.int64), csr.edge_id[slots].astype(np.int64)


# ---------------------------------------------------------------------------
# Partitioned views (sharded execution; see docs/architecture.md)
# ---------------------------------------------------------------------------


def _col_slice(c: Column, lo: int, hi: int) -> Column:
    """Zero-copy contiguous row slice of a column (shards never re-gather)."""
    if isinstance(c, DictColumn):
        return DictColumn(codes=c.codes[lo:hi], vocab=c.vocab)
    if isinstance(c, RaggedColumn):
        off = c.offsets
        return RaggedColumn(values=c.values[off[lo]:off[hi]],
                            offsets=off[lo:hi + 1] - off[lo])
    return np.asarray(c)[lo:hi]


def shard_bounds(n: int, k: int, align: int = 1) -> list[tuple[int, int]]:
    """K contiguous [lo, hi) row blocks covering ``n`` rows, with block
    boundaries rounded up to multiples of ``align`` (zone-chunk alignment:
    a zone-map chunk never straddles two shards, so per-shard zone pruning
    stays exact). Trailing shards may be empty when n < k*align."""
    k = max(int(k), 1)
    step = -(-n // k)                       # ceil
    if align > 1:
        step = -(-step // align) * align    # round up to the alignment
    bounds = []
    for i in range(k):
        lo = min(i * step, n)
        hi = min(lo + step, n)
        bounds.append((lo, hi))
    return bounds


class TableShards:
    """Contiguous row-block partitioning of one :class:`Table`: per-shard
    column slices (zero-copy), per-shard :class:`ColumnStats`, and the
    additive :func:`merge_stats` rollup. ``install_stats`` places the merged
    rollup into the table's stats cache, so the optimizer's cardinality
    model reads shard-rolled statistics through the unchanged
    ``Table.stats`` API. Boundaries are zone-chunk aligned (``align``)."""

    def __init__(self, table: Table, k: int, align: int = 2048):
        self.table = table
        self.k = max(int(k), 1)
        self.bounds = shard_bounds(table.nrows, self.k, align)

    def shard(self, i: int) -> Table:
        lo, hi = self.bounds[i]
        return Table(f"{self.table.name}#{i}",
                     {n: _col_slice(c, lo, hi)
                      for n, c in self.table.columns.items()})

    def shard_stats(self, col: str) -> list[ColumnStats]:
        return [compute_stats(_col_slice(self.table.columns[col], lo, hi))
                for lo, hi in self.bounds]

    def merged_stats(self, col: str) -> ColumnStats:
        return merge_stats(self.shard_stats(col))

    def install_stats(self, col: str) -> ColumnStats:
        s = self.merged_stats(col)
        self.table._stats[col] = s
        return s

    def rows_per_shard(self) -> list[int]:
        return [hi - lo for lo, hi in self.bounds]


class GraphPartitions:
    """Contiguous nid-block partitioning of one :class:`Graph`'s topology.
    Each partition sees a zero-copy CSR window (``csr_block``), the
    per-partition sub-runs of every pending delta segment
    (``delta_views`` — two binary searches per segment, no copies), and its
    share of the tombstone bitmap — so O(batch) writes and epoch stamping
    are preserved per partition: the partitioning is a *view*, rebuilt lazily
    (``fresh``) when the graph's epoch moves past the stamped one."""

    def __init__(self, g: Graph, k: int):
        self.graph = g
        self.k = max(int(k), 1)
        self.epoch = g.epoch
        self.bounds = shard_bounds(g.n_vertices, self.k)

    def fresh(self) -> bool:
        return self.epoch == self.graph.epoch

    def csr_block(self, i: int, reverse: bool = False
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Partition i's window of the base CSR: ``(row_ptr, col_idx,
        edge_id, nid_lo)`` where ``row_ptr`` spans the block's vertices
        (absolute slot offsets — slice ``col_idx``/``edge_id`` with them)."""
        csr = self.graph.rev if reverse else self.graph.fwd
        lo, hi = self.bounds[i]
        lo = min(lo, csr.n_vertices)
        hi = min(hi, csr.n_vertices)
        rp = csr.row_ptr[lo:hi + 1]
        s0, s1 = (int(rp[0]), int(rp[-1])) if len(rp) else (0, 0)
        return rp, csr.col_idx[s0:s1], csr.edge_id[s0:s1], lo

    def delta_views(self, i: int, reverse: bool = False) -> list:
        lo, hi = self.bounds[i]
        return [seg.range_view(lo, hi, reverse=reverse)
                for seg in self.graph.delta.segments]

    def edges_per_partition(self) -> list[int]:
        """Live base+delta edge counts per partition (skew diagnostics)."""
        out = []
        live = self.graph.delta.live_edge_mask()
        for i in range(self.k):
            rp, _, eid, _ = self.csr_block(i)
            n = int(live[eid].sum()) if len(eid) else 0
            for _, _, deid in self.delta_views(i):
                if len(deid):
                    n += int(live[deid].sum())
            out.append(n)
        return out

    def tombstones_per_partition(self) -> list[int]:
        out = []
        live = self.graph.delta.live_edge_mask()
        for i in range(self.k):
            _, _, eid, _ = self.csr_block(i)
            n = int((~live[eid]).sum()) if len(eid) else 0
            for _, _, deid in self.delta_views(i):
                if len(deid):
                    n += int((~live[deid]).sum())
            out.append(n)
        return out


# ---------------------------------------------------------------------------
# Database catalog
# ---------------------------------------------------------------------------


class Database:
    """The unified store: relational tables, shredded document collections,
    and graphs, one namespace (paper Fig. 2(a))."""

    def __init__(self):
        self.tables: dict[str, Table] = {}
        self.graphs: dict[str, Graph] = {}
        self._table_epochs: dict[str, int] = {}
        self._index_manager = None      # created lazily by ``indexes``
        # mutation listeners: fn(op, name) called on touch_table (workload
        # capture — see repro.core.observe)
        self.listeners: list = []

    @property
    def indexes(self):
        """The database's secondary-index catalog (one
        :class:`repro.core.index.IndexManager`, created on first access)."""
        if self._index_manager is None:
            from .index import IndexManager
            self._index_manager = IndexManager(self)
        return self._index_manager

    def add_table(self, t: Table):
        if t.name in self.tables:
            self._table_epochs[t.name] = self._table_epochs.get(t.name, 0) + 1
        self.tables[t.name] = t

    def add_documents(self, name: str, docs: list[dict]):
        self.add_table(shred_documents(name, docs))

    def add_graph(self, g: Graph):
        if g.name in self.graphs:
            # replacing a graph resets its own epoch counter: carry the old
            # lineage forward so cached GCDA results are invalidated
            self._table_epochs[g.name] = self.epoch_of(g.name) + 1
        self.graphs[g.name] = g

    def touch_table(self, name: str) -> None:
        """Signal an in-place mutation of a relational/document collection
        (bumps its epoch so dependent cached GCDA results are invalidated)."""
        self._table_epochs[name] = self._table_epochs.get(name, 0) + 1
        for fn in self.listeners:
            fn("touch_table", name)

    def epoch_of(self, name: str) -> int:
        """Write epoch of a collection. Graphs track their own epoch; the
        epoch-base entry accounts for whole-graph replacement."""
        if name in self.graphs:
            return self._table_epochs.get(name, 0) + self.graphs[name].epoch
        return self._table_epochs.get(name, 0)

    def collection(self, name: str):
        if name in self.tables:
            return self.tables[name]
        if name in self.graphs:
            return self.graphs[name]
        raise KeyError(name)
