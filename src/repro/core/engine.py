"""GredoEngine — the unified query processing engine facade (paper Fig. 2).

GCDI: parse(SFMW AST) -> plan (optimizer §6.2) -> execute (operators §5).
GCDA: materialize matrices into the inter-buffer -> invoke parallel
analytical operators -> reuse via structural plan matching (§6.4).

``mode`` selects the ablation variant (§7.2):
  * "gredo"   — full system (operators + optimizations)      [GredoDB]
  * "dual"    — topology traversal, no pushdown/optimization  [GredoDB-D]
  * "single"  — no topology store: matches run as edge-table
                equi-joins in the relational engine           [GredoDB-S]
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import analytics, join as join_mod, pattern as pattern_mod, planner
from .interbuffer import InterBuffer, fingerprint
from .schema import AnalyticsTask, GCDIATask, Pattern, Query
from .storage import Database, Graph, Table
from . import traversal


@dataclasses.dataclass
class ExecStats:
    plan_notes: list
    seconds: float
    record_fetches: int
    cpu_ops: int
    interbuffer_hit: bool = False
    # write-path observability: pending-delta state of the matched graph
    # (segments / delta_edges / delta_vertices / tombstones) + lifetime
    # compaction counters (see repro.core.deltastore)
    delta: dict = dataclasses.field(default_factory=dict)
    compactions: int = 0


class GredoEngine:
    def __init__(self, db: Database, mode: str = "gredo",
                 interbuffer_bytes: int = 2 << 30):
        assert mode in ("gredo", "dual", "single")
        self.db = db
        self.mode = mode
        self.interbuffer = InterBuffer(interbuffer_bytes)
        self.last_stats: Optional[ExecStats] = None

    # ------------------------------------------------------------------ GCDI
    def plan(self, q: Query) -> planner.GCDIPlan:
        enable_opt = self.mode == "gredo"
        return planner.plan(self.db, q, enable_opt=enable_opt,
                            enable_pattern_pushdown=enable_opt)

    def query(self, q: Query) -> Table:
        traversal.COUNTERS.reset()
        t0 = time.perf_counter()
        if self.mode == "single":
            result = self._execute_single_engine(q)
            notes = ["single-engine: match via edge-table equi-joins"]
        else:
            p = self.plan(q)
            result = planner.execute(self.db, p)
            notes = p.notes
        self.last_stats = ExecStats(
            plan_notes=notes, seconds=time.perf_counter() - t0,
            record_fetches=traversal.COUNTERS.record_fetches,
            cpu_ops=traversal.COUNTERS.cpu_ops)
        if q.match is not None:
            g = self.db.graphs[q.match.graph]
            self.last_stats.delta = g.delta.stats()
            self.last_stats.compactions = g.compactions
        return result

    def _epoch_signature(self, q: Query) -> tuple:
        """Write epochs of every collection the GCDI task reads — part of the
        inter-buffer key, so any mutation of a source graph/table invalidates
        dependent cached GCDA matrices."""
        names = list(q.froms)
        if q.match is not None:
            names.append(q.match.graph)
        return tuple((n, self.db.epoch_of(n)) for n in names)

    def _execute_single_engine(self, q: Query) -> Table:
        """GredoDB-S: translate the match into multi-way joins over the edge
        table (the TBS strategy §2.2) then run the rest of the plan."""
        if q.match is None:
            p = planner.plan(self.db, q, enable_opt=False)
            return planner.execute(self.db, p)
        g = self.db.graphs[q.match.graph]
        rel = _match_by_joins(g, q.match)
        # wrap: substitute the join-produced graph-relation for the match,
        # then evaluate the pattern predicates post-hoc (no pushdown in TBS)
        p = planner.plan(self.db, q, enable_opt=False)
        deferred = p.pattern_plan.deferred if p.pattern_plan else {}
        orig_match = pattern_mod.match
        pattern_mod.match = lambda *_a, **_k: pattern_mod.apply_deferred(
            g, q.match, rel, deferred)
        try:
            return planner.execute(self.db, p)
        finally:
            pattern_mod.match = orig_match

    # ------------------------------------------------------------------ GCDA
    def analyze(self, task: GCDIATask, *, use_kernel: bool | None = None,
                iters: int = 100):
        """Run a full GCDIA: GCDI -> G (matrix gen) -> A (parallel op)."""
        key = fingerprint(task.integration, task.analytics.op,
                          task.analytics.inputs, self.mode,
                          self._epoch_signature(task.integration))
        cached = self.interbuffer.get(key)
        if cached is not None:
            if self.last_stats:
                self.last_stats.interbuffer_hit = True
            return cached
        gcdi_result = self.query(task.integration)
        mats = []
        for spec in task.analytics.inputs:
            kind = spec[0]
            if kind == "rel2matrix":
                mats.append(analytics.rel2matrix(gcdi_result, spec[1]))
            elif kind == "random":
                m, _ = analytics.random_access_matrix(
                    gcdi_result, spec[1], spec[2], spec[3])
                mats.append(m)
            elif kind == "const":
                mats.append(jnp.asarray(spec[1]))
            else:
                raise ValueError(kind)
        op = task.analytics.op
        if op == "MULTIPLY":
            rhs = mats[1] if len(mats) > 1 else mats[0].T  # Gram product default
            out = analytics.multiply(mats[0], rhs, use_kernel=use_kernel)
        elif op == "SIMILARITY":
            out = analytics.similarity(mats[0], mats[1] if len(mats) > 1 else mats[0],
                                       use_kernel=use_kernel)
        elif op == "REGRESSION":
            labels = mats[1].reshape(-1) if len(mats) > 1 else None
            if labels is None:
                raise ValueError("REGRESSION needs (features, labels)")
            out = analytics.regression(mats[0], labels, iters=iters,
                                       use_kernel=use_kernel)[0]
        else:
            raise ValueError(op)
        return self.interbuffer.put(key, out)

    # ------------------------------------------------------- graph utilities
    def shortest_path(self, graph: str, src_label: str, src_vids, dst_label: str,
                      dst_vids) -> np.ndarray:
        g = self.db.graphs[graph]
        return pattern_mod.shortest_path_lengths(
            g, g.nid_of(src_label, src_vids), g.nid_of(dst_label, dst_vids))


def _match_by_joins(g: Graph, pat: Pattern) -> Table:
    """TBS-style pattern matching: k-hop pattern == k-way self-join of the
    edge table on svid/tvid (index-accelerated in AgensGraph; sort-merge
    here). No topology store, no pushdown — intermediate results grow
    multiplicatively, which is exactly the §2.2 critique."""
    chain_vars = [pat.vertices[0].var] + [e.dst for e in pat.edges]
    edge_vars = [e.var for e in pat.edges]
    if not edge_vars:  # vertex-only pattern: full vertex scan
        var = pat.vertices[0].var
        n = g.vertex_tables[pat.vertex(var).label].nrows
        traversal.COUNTERS.record_fetches += n
        return Table("join0", {var: np.arange(n)})
    from .deltastore import expand_runs
    live = g.live_edge_ids()  # tombstoned edges never join
    svid = np.asarray(g.edges.col("svid"))
    tvid = np.asarray(g.edges.col("tvid"))
    if g.delta.n_tombstones:  # only copy-filter when something is dead
        svid, tvid = svid[live], tvid[live]
    traversal.COUNTERS.record_fetches += 2 * len(svid) * max(len(edge_vars), 1)

    cols = {chain_vars[0]: svid, edge_vars[0]: live, chain_vars[1]: tvid}
    cur = Table("join0", cols)
    # the edge table is static across hops: sort once, probe per hop
    order = np.argsort(svid, kind="stable")
    svid_s = svid[order]
    for h in range(1, len(edge_vars)):
        # join cur.tail == edges.svid
        tail = np.asarray(cur.col(chain_vars[h]))
        lo = np.searchsorted(svid_s, tail, "left")
        hi = np.searchsorted(svid_s, tail, "right")
        l_rep, pos = expand_runs(lo, hi - lo)
        total = len(pos)
        traversal.COUNTERS.cpu_ops += total
        traversal.COUNTERS.record_fetches += total
        rows = order[pos]
        ncols = {k: np.asarray(v)[l_rep] for k, v in cur.columns.items()}
        ncols[edge_vars[h]] = live[rows]
        ncols[chain_vars[h + 1]] = tvid[rows]
        cur = Table(f"join{h}", ncols)
    return cur
