"""GredoEngine — the unified query processing engine facade (paper Fig. 2).

GCDI: parse(SFMW AST) -> plan (optimizer §6.2) -> physical DAG -> execute.
GCDA: the same DAG grows matrix-generation and analytical-operator nodes;
intermediate results are materialized in the inter-buffer keyed by node
*signatures* (structural plan matching §6.4), so a repeated GCDIA with a
different analytics op reuses the GCDI relation and matrices mid-plan.

``mode`` selects the ablation variant (§7.2):
  * "gredo"   — full system (operators + optimizations)      [GredoDB]
  * "dual"    — topology traversal, no pushdown/optimization  [GredoDB-D]
  * "single"  — no topology store: matches run as edge-table
                equi-joins in the relational engine           [GredoDB-S]

All three modes execute through the same physical executor — they differ
only in the plan shape the builder emits (``physical.build_gcdi``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from . import join as join_mod, optimizer as optimizer_mod
from . import pattern as pattern_mod, physical, planner
from .interbuffer import InterBuffer
from .schema import GCDIATask, Query
from .storage import Database, Table
from . import traversal

# moved to repro.core.join; alias kept for existing importers
_match_by_joins = join_mod.match_by_joins


@dataclasses.dataclass
class ExecStats:
    plan_notes: list
    seconds: float
    record_fetches: int
    cpu_ops: int
    interbuffer_hit: bool = False
    # per-operator rows/bytes/seconds of the executed physical DAG
    # (pre-order; see physical.collect_stats)
    operators: list = dataclasses.field(default_factory=list)
    # optimizer rewrite log (join reordering, semi-join siding, CSE, ...)
    rewrites: list = dataclasses.field(default_factory=list)
    # inter-buffer reuse below the root: # of DAG nodes satisfied from cache
    nodes_reused: int = 0
    # write-path observability: pending-delta state of the matched graph
    # (segments / delta_edges / delta_vertices / tombstones) + lifetime
    # compaction counters (see repro.core.deltastore)
    delta: dict = dataclasses.field(default_factory=dict)
    compactions: int = 0


class GredoEngine:
    def __init__(self, db: Database, mode: str = "gredo",
                 interbuffer_bytes: int = 2 << 30,
                 enable_optimizer: bool = True,
                 admit_cost_per_byte: float = 0.05,
                 join_enum: str = "dp"):
        assert mode in ("gredo", "dual", "single")
        assert join_enum in ("dp", "dp-leftdeep", "greedy")
        self.db = db
        self.mode = mode
        self.enable_optimizer = enable_optimizer
        self.join_enum = join_enum
        self.interbuffer = InterBuffer(interbuffer_bytes,
                                       admit_cost_per_byte=admit_cost_per_byte)
        # §6.3 estimate memo shared across this engine's planner invocations;
        # keyed on the catalog write-epoch snapshot inside optimize(), so a
        # delta-store append invalidates every cached cardinality (and the
        # plan decisions that would have been built on them)
        self._opt_cache: dict = {}
        self.last_stats: Optional[ExecStats] = None
        self.last_dag: Optional[physical.PhysicalOp] = None
        self.last_naive_dag: Optional[physical.PhysicalOp] = None
        self._last_ests: Optional[dict] = None
        self.last_report: Optional[optimizer_mod.OptReport] = None

    @property
    def last_ests(self) -> Optional[dict]:
        """§6.3 estimates of the most recent DAG, computed lazily — GCDI
        queries don't pay the estimate walk unless explain_last (or a
        caller) actually reads it. analyze() fills it eagerly because the
        inter-buffer admission consumes the estimates during execution."""
        if self._last_ests is None and self.last_dag is not None:
            self._last_ests = physical.estimate(self.last_dag, self.db)
        return self._last_ests

    # ------------------------------------------------------------------ GCDI
    def plan(self, q: Query) -> planner.GCDIPlan:
        enable_opt = self.mode == "gredo"
        return planner.plan(self.db, q, enable_opt=enable_opt,
                            enable_pattern_pushdown=enable_opt)

    def physical_plan(self, q: Query) -> physical.PhysicalOp:
        """Lower a GCDI task to its *naive* physical DAG (pre-rewrite)."""
        return physical.build_gcdi(self.db, self.plan(q), mode=self.mode)

    def optimized_plan(self, q: Query) -> physical.PhysicalOp:
        """The DAG the engine actually executes (post-rewrite in gredo
        mode; identical to ``physical_plan`` otherwise). Updates the whole
        ``last_*`` family consistently, so a following ``explain_last``
        describes this plan (unexecuted: estimates only, no actuals)."""
        naive = self.physical_plan(q)
        dag, report = self._lower(naive)
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = None
        return dag

    def _lower(self, dag: physical.PhysicalOp):
        """Apply the cost-based optimizer in full-system mode. The ablation
        variants (-D / -S) run the naive DAG, as in the paper."""
        if self.mode == "gredo" and self.enable_optimizer:
            return optimizer_mod.optimize(dag, self.db, cache=self._opt_cache,
                                          join_enum=self.join_enum)
        return dag, None

    def query(self, q: Query) -> Table:
        traversal.COUNTERS.reset()
        t0 = time.perf_counter()
        p = self.plan(q)
        naive = physical.build_gcdi(self.db, p, mode=self.mode)
        dag, report = self._lower(naive)
        ctx = physical.ExecContext(self.db)
        result = physical.execute(dag, ctx)
        notes = list(p.notes)
        if self.mode == "single" and q.match is not None:
            notes.insert(0, "single-engine: match via edge-table equi-joins")
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = None
        self.last_stats = ExecStats(
            plan_notes=notes, seconds=time.perf_counter() - t0,
            record_fetches=traversal.COUNTERS.record_fetches,
            cpu_ops=traversal.COUNTERS.cpu_ops,
            operators=physical.collect_stats(dag),
            rewrites=report.notes() if report else [])
        self._attach_delta_stats(q)
        return result

    def explain(self, q: Query) -> str:
        """Pre- and post-rewrite operator DAGs with §6.3 estimates per
        operator (run the query and use ``explain_last`` for est_rows next
        to actual rows)."""
        naive = self.physical_plan(q)
        dag, report = self._lower(naive)
        if report is None:
            return physical.explain(naive, db=self.db)
        lines = ["== naive DAG (pre-rewrite) ==",
                 physical.explain(naive, db=self.db),
                 "== optimized DAG (post-rewrite) ==",
                 physical.explain(dag, db=self.db),
                 "== rewrites =="]
        lines += ["  " + n for n in report.notes()]
        return "\n".join(lines)

    def explain_last(self) -> str:
        """Pre/post-rewrite plans of the most recent execution, the executed
        DAG annotated with actual rows/bytes/seconds *and* the cost-model
        est_rows/est_cost per operator, plus inter-buffer counters."""
        if self.last_dag is None:
            return "(nothing executed yet)"
        lines = []
        if self.last_naive_dag is not None and self.last_report is not None:
            lines += ["== naive DAG (pre-rewrite) ==",
                      physical.explain(self.last_naive_dag, db=self.db),
                      "== executed DAG (post-rewrite, actual vs. estimated) =="]
        lines.append(physical.explain(self.last_dag, stats=True,
                                      ests=self.last_ests))
        if self.last_report is not None:
            lines.append("== rewrites ==")
            lines += ["  " + n for n in self.last_report.notes()]
        lines.append(f"interbuffer: {self.interbuffer.counters()}")
        return "\n".join(lines)

    def _attach_delta_stats(self, q: Query) -> None:
        if q.match is not None and self.last_stats is not None:
            g = self.db.graphs[q.match.graph]
            self.last_stats.delta = g.delta.stats()
            self.last_stats.compactions = g.compactions

    # ------------------------------------------------------------------ GCDA
    def analyze(self, task: GCDIATask, *, use_kernel: bool | None = None,
                iters: int = 100):
        """Run a full GCDIA: GCDI -> G (matrix gen) -> A (parallel op), as
        one physical DAG. Cacheable operators (the GCDI relation, generated
        matrices, analytics outputs) are keyed in the inter-buffer by node
        signature; signatures embed source write epochs, so reuse survives
        exactly until a source collection mutates."""
        traversal.COUNTERS.reset()
        t0 = time.perf_counter()
        p = self.plan(task.integration)
        naive = physical.build_gcdia(self.db, p, task, mode=self.mode,
                                     use_kernel=use_kernel, iters=iters)
        dag, report = self._lower(naive)
        ests = physical.estimate(dag, self.db)
        ctx = physical.ExecContext(self.db, interbuffer=self.interbuffer,
                                   ests=ests)
        out = physical.execute(dag, ctx)
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = ests
        self.last_stats = ExecStats(
            plan_notes=list(p.notes), seconds=time.perf_counter() - t0,
            record_fetches=traversal.COUNTERS.record_fetches,
            cpu_ops=traversal.COUNTERS.cpu_ops,
            interbuffer_hit=dag.stats.cached,
            operators=physical.collect_stats(dag),
            rewrites=report.notes() if report else [],
            nodes_reused=ctx.nodes_reused)
        self._attach_delta_stats(task.integration)
        return out

    # ------------------------------------------------------- graph utilities
    def shortest_path(self, graph: str, src_label: str, src_vids, dst_label: str,
                      dst_vids) -> np.ndarray:
        g = self.db.graphs[graph]
        return pattern_mod.shortest_path_lengths(
            g, g.nid_of(src_label, src_vids), g.nid_of(dst_label, dst_vids))
