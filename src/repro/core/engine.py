"""GredoEngine — the unified query processing engine facade (paper Fig. 2).

GCDI: parse(SFMW AST) -> plan (optimizer §6.2) -> physical DAG -> execute.
GCDA: the same DAG grows matrix-generation and analytical-operator nodes;
intermediate results are materialized in the inter-buffer keyed by node
*signatures* (structural plan matching §6.4), so a repeated GCDIA with a
different analytics op reuses the GCDI relation and matrices mid-plan.

``mode`` selects the ablation variant (§7.2):
  * "gredo"   — full system (operators + optimizations)      [GredoDB]
  * "dual"    — topology traversal, no pushdown/optimization  [GredoDB-D]
  * "single"  — no topology store: matches run as edge-table
                equi-joins in the relational engine           [GredoDB-S]

All three modes execute through the same physical executor — they differ
only in the plan shape the builder emits (``physical.build_gcdi``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from . import join as join_mod, optimizer as optimizer_mod
from . import observe as observe_mod
from . import pattern as pattern_mod, physical, planner
from . import telemetry as telemetry_mod
from . import verify as verify_mod
from .interbuffer import InterBuffer
from .schema import GCDIATask, Query
from .storage import Database, Table
from . import traversal

# moved to repro.core.join; alias kept for existing importers
_match_by_joins = join_mod.match_by_joins


@dataclasses.dataclass
class ExecStats:
    plan_notes: list
    seconds: float
    record_fetches: int
    cpu_ops: int
    interbuffer_hit: bool = False
    # per-operator rows/bytes/seconds of the executed physical DAG
    # (pre-order; see physical.collect_stats)
    operators: list = dataclasses.field(default_factory=list)
    # optimizer rewrite log (join reordering, semi-join siding, CSE, ...)
    rewrites: list = dataclasses.field(default_factory=list)
    # inter-buffer reuse below the root: # of DAG nodes satisfied from cache
    nodes_reused: int = 0
    # write-path observability: pending-delta state of the matched graph
    # (segments / delta_edges / delta_vertices / tombstones) + lifetime
    # compaction counters (see repro.core.deltastore)
    delta: dict = dataclasses.field(default_factory=dict)
    compactions: int = 0


@dataclasses.dataclass
class Profile:
    """What ``GredoEngine.profile`` returns: the query result plus every
    telemetry view of that one execution."""

    result: object
    trace: Optional["telemetry_mod.QueryTrace"]
    registry_delta: dict                # per-query metric deltas
    qerrors: list                       # flagged MisEstimates of this plan
    seconds: float

    def render(self, top: int = 0) -> str:
        lines = [self.trace.render(top=top) if self.trace is not None else ""]
        if self.qerrors:
            lines.append("== q-error flags ==")
            lines += [f"  {m!r}" for m in self.qerrors]
        return "\n".join(l for l in lines if l)


class GredoEngine:
    def __init__(self, db: Database, mode: str = "gredo",
                 interbuffer_bytes: int = 2 << 30,
                 enable_optimizer: bool = True,
                 admit_cost_per_byte: float = 0.05,
                 join_enum: str = "dp",
                 telemetry: "bool | telemetry_mod.Telemetry | None" = None,
                 n_shards: int = 1,
                 debug: bool = False,
                 observe: "bool | observe_mod.FlightRecorder" = True):
        assert mode in ("gredo", "dual", "single")
        assert join_enum in ("dp", "dp-leftdeep", "greedy")
        self.db = db
        self.mode = mode
        # debug mode: statically verify every plan (naive, post-optimizer,
        # post-shard-rewrite) before execution and raise
        # PlanVerificationError on ERROR-severity violations; explain output
        # grows `verify:` lines. See repro.core.verify for the rule catalog.
        self.debug = debug
        self.last_verify: Optional[verify_mod.VerifyReport] = None
        # morsel-parallel sharded execution (repro.core.shard). n_shards is
        # the *requested* shard count; the §6.3 sharded cost model may still
        # choose serial execution per query (small dominant inputs) — the
        # actual per-query choice lands in ``last_shard_count``.
        self.n_shards = max(int(n_shards), 1)
        self._shard_runtime = None
        self.last_shard_count = 1
        self.enable_optimizer = enable_optimizer
        self.join_enum = join_enum
        self.interbuffer = InterBuffer(interbuffer_bytes,
                                       admit_cost_per_byte=admit_cost_per_byte)
        # §6.3 estimate memo shared across this engine's planner invocations;
        # keyed on the catalog write-epoch snapshot inside optimize(), so a
        # delta-store append invalidates every cached cardinality (and the
        # plan decisions that would have been built on them)
        self._opt_cache: dict = {}
        self.last_stats: Optional[ExecStats] = None
        self.last_dag: Optional[physical.PhysicalOp] = None
        self.last_naive_dag: Optional[physical.PhysicalOp] = None
        self._last_ests: Optional[dict] = None
        self.last_report: Optional[optimizer_mod.OptReport] = None
        # flight recorder (repro.core.observe): always-on bounded ring of
        # recent query records with trigger-driven auto-dump; pass a shared
        # FlightRecorder to pool SLO state across engines, or observe=False
        # to opt out entirely. Built before telemetry so enable_telemetry
        # can register it as the `flight` registry source.
        self.observer: Optional[observe_mod.FlightRecorder] = None
        if observe:
            self.observer = (observe
                             if isinstance(observe, observe_mod.FlightRecorder)
                             else observe_mod.FlightRecorder())
        self._recorder: Optional[observe_mod.WorkloadRecorder] = None
        self._last_label = ""
        # telemetry (off by default — the hot path then only pays
        # `trace is None` checks). `telemetry=True` builds a fresh session;
        # passing a Telemetry instance shares a registry across engines.
        self.telemetry: Optional[telemetry_mod.Telemetry] = None
        if telemetry:
            self.enable_telemetry(telemetry if not isinstance(telemetry, bool)
                                  else None)
        # per-query inter-buffer counter delta (cheap: 6 ints), kept even
        # with telemetry off so explain_last never shows cumulative drift
        self.last_interbuffer_delta: dict = {}
        self.last_registry_delta: dict = {}
        self._pre_snapshot: dict = {}

    # ------------------------------------------------------------- telemetry
    def _metric_sources(self) -> dict:
        """The subsystem pull-sources this engine exposes, namespace -> fn.
        ``enable_telemetry`` registers them on the session registry;
        ``metrics_snapshot`` reads them directly when telemetry is off."""
        db = self.db

        def _graph_writes() -> dict:
            out: dict[str, float] = {}
            for name, g in db.graphs.items():
                for k, v in g.write_counters.metrics().items():
                    out[f"{name}.{k}"] = v
            return out

        def _index_counters() -> dict:
            im = getattr(db, "_index_manager", None)
            return im.metrics() if im is not None else {}

        def _shard_metrics() -> dict:
            rt = self._shard_runtime
            return rt.metrics() if rt is not None else {}

        from . import pattern_jit
        sources = {"interbuffer": self.interbuffer.metrics,
                   "deltastore": _graph_writes,
                   "index": _index_counters,
                   "traversal_kernels": pattern_jit.metrics,
                   "shard": _shard_metrics}
        if self.observer is not None:
            sources["flight"] = self.observer.metrics
        return sources

    def enable_telemetry(self, session: Optional["telemetry_mod.Telemetry"]
                         = None) -> "telemetry_mod.Telemetry":
        """Attach (or build) a telemetry session and register this engine's
        subsystems as registry sources: inter-buffer admission, per-graph
        delta-store write counters, secondary-index maintenance, traversal
        kernels, shard runtime, and the flight recorder."""
        tel = session if session is not None else telemetry_mod.Telemetry()
        for ns, fn in self._metric_sources().items():
            tel.registry.register_source(ns, fn)
        self.telemetry = tel
        return tel

    def metrics_snapshot(self) -> dict:
        """Flat ``ns.key -> number`` view of every subsystem metric. With a
        telemetry session attached this is the registry snapshot (includes
        engine counters/histograms and q-error figures); without one it
        reads the subsystem sources directly — health checks work either
        way."""
        if self.telemetry is not None:
            return self.telemetry.registry.snapshot()
        out: dict[str, float] = {}
        for ns, fn in self._metric_sources().items():
            for k, v in fn().items():
                out[f"{ns}.{k}"] = v
        return out

    def health(self) -> "observe_mod.HealthReport":
        """Evaluate the observability rule table (repro.core.observe) over
        the current metrics snapshot and the flight recorder's latency
        EWMAs. With telemetry attached, the verdicts are also exported as
        ``health.*`` gauges (0=ok 1=warn 2=critical) so OpenMetrics scrapes
        carry them."""
        report = observe_mod.evaluate_health(self.metrics_snapshot(),
                                             self.observer)
        if self.telemetry is not None:
            for k, v in report.as_metrics().items():
                self.telemetry.registry.gauge(k).set(v)
        return report

    def record(self, path: str) -> "observe_mod.WorkloadRecorder":
        """Capture this engine's interleaved query/mutation stream to JSONL
        for deterministic offline replay::

            with eng.record("experiments/workload.jsonl"):
                eng.query(q); g.insert_edges(rows); eng.analyze(task)
            observe.replay(fresh_db, "experiments/workload.jsonl")
        """
        return observe_mod.WorkloadRecorder(self, path)

    def profile(self, q: "Query | GCDIATask", **kw) -> Profile:
        """Run one GCDI query / GCDIA task with tracing on (temporarily
        enabling telemetry if the engine has none) and return the result
        together with its trace, per-query metric deltas, and q-error
        flags."""
        transient = self.telemetry is None
        tel = self.telemetry or self.enable_telemetry()
        try:
            result = (self.analyze(q, **kw) if isinstance(q, GCDIATask)
                      else self.query(q, **kw))
            return Profile(result=result, trace=tel.collector.last(),
                           registry_delta=dict(self.last_registry_delta),
                           qerrors=list(tel.qerror.last_plan),
                           seconds=self.last_stats.seconds)
        finally:
            if transient:
                self.telemetry = None

    @property
    def last_ests(self) -> Optional[dict]:
        """§6.3 estimates of the most recent DAG, computed lazily — GCDI
        queries don't pay the estimate walk unless explain_last (or a
        caller) actually reads it. analyze() fills it eagerly because the
        inter-buffer admission consumes the estimates during execution."""
        if self._last_ests is None and self.last_dag is not None:
            self._last_ests = physical.estimate(self.last_dag, self.db)
        return self._last_ests

    # ------------------------------------------------------------------ GCDI
    def plan(self, q: Query) -> planner.GCDIPlan:
        enable_opt = self.mode == "gredo"
        return planner.plan(self.db, q, enable_opt=enable_opt,
                            enable_pattern_pushdown=enable_opt)

    def physical_plan(self, q: Query) -> physical.PhysicalOp:
        """Lower a GCDI task to its *naive* physical DAG (pre-rewrite)."""
        return physical.build_gcdi(self.db, self.plan(q), mode=self.mode)

    def optimized_plan(self, q: Query) -> physical.PhysicalOp:
        """The DAG the engine actually executes (post-rewrite in gredo
        mode; identical to ``physical_plan`` otherwise). Updates the whole
        ``last_*`` family consistently, so a following ``explain_last``
        describes this plan (unexecuted: estimates only, no actuals)."""
        naive = self.physical_plan(q)
        dag, report = self._lower(naive)
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = None
        return dag

    def _lower(self, dag: physical.PhysicalOp):
        """Apply the cost-based optimizer in full-system mode. The ablation
        variants (-D / -S) run the naive DAG, as in the paper."""
        if self.mode == "gredo" and self.enable_optimizer:
            return optimizer_mod.optimize(dag, self.db, cache=self._opt_cache,
                                          join_enum=self.join_enum)
        return dag, None

    # ---------------------------------------------------- static verification
    def _verify_stages(self, naive: physical.PhysicalOp,
                       optimized: Optional[physical.PhysicalOp],
                       sharded: Optional[physical.PhysicalOp]
                       ) -> verify_mod.VerifyReport:
        """Run the static plan verifier over every rewrite stage of one
        plan: each stage's DAG is schema-checked against the live catalog,
        signatures are checked for coherence *across* stages (V-SIG: the
        inter-buffer spans them), and each rewrite boundary is checked for
        type equivalence (V-EQ: rewrites may reorder, never retype)."""
        report = verify_mod.VerifyReport()
        sigs: dict = {}
        verify_mod.verify_plan(naive, self.db, report, sigs)
        prev, prev_label = naive, "naive"
        for dag, label in ((optimized, "optimizer"), (sharded, "shard")):
            if dag is None or dag is prev:
                continue
            verify_mod.verify_plan(dag, self.db, report, sigs)
            verify_mod.verify_equivalence(prev, dag, self.db,
                                          f"{prev_label}->{label}", report)
            prev, prev_label = dag, label
        self.last_verify = report
        return report

    def verify(self, q: "Query | GCDIATask") -> verify_mod.VerifyReport:
        """Statically verify the plan this engine would run for ``q`` —
        naive build, optimizer rewrite, and shard rewrite — without
        executing anything. Returns the report (``report.ok`` means no
        ERROR-severity violations; WARNs flag silent promotions and runtime
        fallbacks)."""
        if isinstance(q, GCDIATask):
            p = self.plan(q.integration)
            naive = physical.build_gcdia(self.db, p, q, mode=self.mode)
        else:
            naive = self.physical_plan(q)
        dag, _ = self._lower(naive)
        sharded = None
        if self.n_shards > 1:
            from . import shard as shard_mod
            sharded, k = shard_mod.prepare_plan(dag, self.db, self.n_shards)
            if k <= 1:
                sharded = None
        return self._verify_stages(naive, dag, sharded)

    def _debug_verify(self, naive, dag, final) -> None:
        if not self.debug:
            return
        report = self._verify_stages(naive, dag if dag is not naive else None,
                                     final if final is not dag else None)
        if not report.ok:
            if self.observer is not None:
                # capture the failing plan + report before the exception
                # unwinds (the query never reaches _finish_query)
                self.observer.record_verify_error(self, self._last_label,
                                                  naive, report)
            raise verify_mod.PlanVerificationError(report)

    def _shard_plan(self, dag: physical.PhysicalOp
                    ) -> tuple[physical.PhysicalOp, Optional[object]]:
        """Rewrite the post-optimizer DAG for morsel-parallel execution when
        ``n_shards > 1`` *and* the sharded cost model picks k > 1 for this
        query's dominant input. Returns ``(dag, shard_runtime-or-None)``."""
        self.last_shard_count = 1
        if self.n_shards <= 1:
            return dag, None
        from . import shard as shard_mod
        dag2, k = shard_mod.prepare_plan(dag, self.db, self.n_shards)
        self.last_shard_count = k
        if k <= 1:
            return dag, None
        if self._shard_runtime is None:
            self._shard_runtime = shard_mod.ShardRuntime(self.n_shards)
        return dag2, self._shard_runtime

    def query(self, q: Query) -> Table:
        traversal.COUNTERS.reset()
        trace, ib0 = self._begin_query(f"query[{','.join(q.source_names())}]")
        t0 = time.perf_counter()
        p = self.plan(q)
        naive = physical.build_gcdi(self.db, p, mode=self.mode)
        dag, report = self._lower(naive)
        opt_dag = dag
        dag, shard_rt = self._shard_plan(dag)
        self._debug_verify(naive, opt_dag, dag)
        ctx = physical.ExecContext(self.db, trace=trace,
                                   fence_device=self._fence_device(),
                                   shard=shard_rt)
        result = physical.execute(dag, ctx)
        notes = list(p.notes)
        if self.mode == "single" and q.match is not None:
            notes.insert(0, "single-engine: match via edge-table equi-joins")
        if self.last_shard_count > 1:
            notes.append(f"sharded execution: k={self.last_shard_count}")
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = None
        self.last_stats = ExecStats(
            plan_notes=notes, seconds=time.perf_counter() - t0,
            record_fetches=traversal.COUNTERS.record_fetches,
            cpu_ops=traversal.COUNTERS.cpu_ops,
            operators=physical.collect_stats(dag),
            rewrites=report.notes() if report else [])
        self._attach_delta_stats(q)
        self._finish_query(trace, ctx, ib0)
        if self._recorder is not None:
            self._recorder.log_query(q, result, self.last_stats.seconds)
        return result

    def explain(self, q: Query) -> str:
        """Pre- and post-rewrite operator DAGs with §6.3 estimates per
        operator (run the query and use ``explain_last`` for est_rows next
        to actual rows)."""
        naive = self.physical_plan(q)
        dag, report = self._lower(naive)
        if report is None:
            lines = [physical.explain(naive, db=self.db)]
        else:
            lines = ["== naive DAG (pre-rewrite) ==",
                     physical.explain(naive, db=self.db),
                     "== optimized DAG (post-rewrite) ==",
                     physical.explain(dag, db=self.db),
                     "== rewrites =="]
            lines += ["  " + n for n in report.notes()]
        if self.debug:
            vr = self._verify_stages(naive, dag if dag is not naive else None,
                                     None)
            lines.append("== verify ==")
            lines += (["  " + l for l in vr.render()]
                      or ["  verify: plan ok (no violations)"])
        return "\n".join(lines)

    def explain_last(self, top: int = 0) -> str:
        """Pre/post-rewrite plans of the most recent execution, the executed
        DAG annotated with actual rows/bytes/seconds, the operator's share
        of total plan time, *and* the cost-model est_rows/est_cost per
        operator, plus inter-buffer counters (this query's delta, then the
        engine-lifetime cumulative figures). ``top > 0`` appends the k
        hottest operators sorted by wall seconds."""
        if self.last_dag is None:
            return "(nothing executed yet)"
        lines = []
        if self.last_naive_dag is not None and self.last_report is not None:
            lines += ["== naive DAG (pre-rewrite) ==",
                      physical.explain(self.last_naive_dag, db=self.db),
                      "== executed DAG (post-rewrite, actual vs. estimated) =="]
        lines.append(physical.explain(self.last_dag, stats=True,
                                      ests=self.last_ests, top=top))
        if self.last_report is not None:
            lines.append("== rewrites ==")
            lines += ["  " + n for n in self.last_report.notes()]
        if self.debug and self.last_verify is not None:
            lines.append("== verify ==")
            lines += (["  " + l for l in self.last_verify.render()]
                      or ["  verify: plan ok (no violations)"])
        if self.last_interbuffer_delta:
            d = self.last_interbuffer_delta
            lines.append("interbuffer (this query): "
                         + " ".join(f"{k}={d[k]:+g}" for k in
                                    ("hits", "misses", "bypasses", "evictions")
                                    if k in d))
        lines.append(f"interbuffer: {self.interbuffer.counters()} (cumulative)")
        tk = {k.split(".", 1)[1]: v
              for k, v in self.last_registry_delta.items()
              if k.startswith("traversal_kernels.") and v}
        if tk:
            lines.append("traversal kernels (this query): "
                         + " ".join(f"{k}={v:+g}"
                                    for k, v in sorted(tk.items())))
        if self.last_shard_count > 1:
            sm = {k.split(".", 1)[1]: v
                  for k, v in self.last_registry_delta.items()
                  if k.startswith("shard.") and v}
            lines.append(f"sharded execution: k={self.last_shard_count}"
                         + ("".join(f" {k}={v:+g}"
                                    for k, v in sorted(sm.items()))))
        if self.telemetry is not None and self.telemetry.qerror.last_plan:
            lines.append("== q-error flags ==")
            lines += [f"  {m!r}" for m in self.telemetry.qerror.last_plan]
        if self.observer is not None:
            lines.append("== health ==")
            lines += ["  " + l for l in self.health().render()]
        return "\n".join(lines)

    def _attach_delta_stats(self, q: Query) -> None:
        if q.match is not None and self.last_stats is not None:
            g = self.db.graphs[q.match.graph]
            self.last_stats.delta = g.delta.stats()
            self.last_stats.compactions = g.compactions

    # ---------------------------------------------------- telemetry plumbing
    def _fence_device(self) -> bool:
        return self.telemetry is not None and self.telemetry.fence_device

    def _begin_query(self, label: str):
        """Open the per-query observability window: an inter-buffer counter
        snapshot (always — 6 ints), the flight recorder's pre-query marks,
        and with telemetry on, a registry snapshot plus a fresh trace."""
        ib0 = self.interbuffer.metrics()
        self._last_label = label
        if self.observer is not None:
            self.observer.begin(label)
        tel = self.telemetry
        if tel is None:
            return None, ib0
        self._pre_snapshot = tel.registry.snapshot()
        tel.qerror.start_plan()
        return tel.collector.start_query(label), ib0

    def _finish_query(self, trace, ctx: physical.ExecContext,
                      ib0: dict, kind: str = "query") -> None:
        self.last_interbuffer_delta = telemetry_mod.Registry.delta(
            ib0, self.interbuffer.metrics())
        tel = self.telemetry
        if tel is None:
            # flight-recorder capture happens even without telemetry — the
            # record then carries plan fingerprint + operator stats +
            # inter-buffer delta (no span tree / registry delta).
            if self.observer is not None:
                self.observer.observe(self, kind=kind)
            return
        seconds = self.last_stats.seconds
        if trace is not None:
            trace.close(seconds=seconds, nodes_run=ctx.nodes_run,
                        nodes_reused=ctx.nodes_reused)
            tel.collector.trim()    # re-check the span bound now that this
                                    # query's spans are all recorded
        reg = tel.registry
        reg.counter("engine.queries").inc()
        reg.histogram("engine.query_seconds").observe(seconds)
        label = trace.label if trace is not None else "query"
        if self.last_report is not None:
            for rule, n in self.last_report.rule_counts().items():
                reg.counter(f"optimizer.rewrites.{rule}").inc(n)
        ests = self.last_ests or {}
        seen: set[int] = set()

        def walk(n: physical.PhysicalOp) -> None:
            if id(n) in seen:
                return
            seen.add(id(n))
            acc = getattr(n, "access", None)
            if acc is not None and (n.stats.executed or n.stats.cached):
                reg.counter(f"optimizer.access.{acc}").inc()
            est = ests.get(id(n))
            if n.stats.executed and est is not None and n.stats.rows is not None:
                tel.qerror.record(label, n.kind, n.describe(),
                                  est[0], n.stats.rows)
            for c in n.children:
                walk(c)

        walk(self.last_dag)
        self.last_registry_delta = telemetry_mod.Registry.delta(
            self._pre_snapshot, reg.snapshot())
        if self.observer is not None:
            self.observer.observe(self, kind=kind)

    # ------------------------------------------------------------------ GCDA
    def analyze(self, task: GCDIATask, *, use_kernel: bool | None = None,
                iters: int = 100):
        """Run a full GCDIA: GCDI -> G (matrix gen) -> A (parallel op), as
        one physical DAG. Cacheable operators (the GCDI relation, generated
        matrices, analytics outputs) are keyed in the inter-buffer by node
        signature; signatures embed source write epochs, so reuse survives
        exactly until a source collection mutates."""
        traversal.COUNTERS.reset()
        trace, ib0 = self._begin_query(f"gcdia:{task.analytics.op}")
        t0 = time.perf_counter()
        p = self.plan(task.integration)
        naive = physical.build_gcdia(self.db, p, task, mode=self.mode,
                                     use_kernel=use_kernel, iters=iters)
        dag, report = self._lower(naive)
        opt_dag = dag
        dag, shard_rt = self._shard_plan(dag)
        self._debug_verify(naive, opt_dag, dag)
        ests = physical.estimate(dag, self.db)
        ctx = physical.ExecContext(self.db, interbuffer=self.interbuffer,
                                   ests=ests, trace=trace,
                                   fence_device=self._fence_device(),
                                   shard=shard_rt)
        out = physical.execute(dag, ctx)
        self.last_dag = dag
        self.last_naive_dag = naive
        self.last_report = report
        self._last_ests = ests
        notes = list(p.notes)
        if self.last_shard_count > 1:
            notes.append(f"sharded execution: k={self.last_shard_count}")
        self.last_stats = ExecStats(
            plan_notes=notes, seconds=time.perf_counter() - t0,
            record_fetches=traversal.COUNTERS.record_fetches,
            cpu_ops=traversal.COUNTERS.cpu_ops,
            interbuffer_hit=dag.stats.cached,
            operators=physical.collect_stats(dag),
            rewrites=report.notes() if report else [],
            nodes_reused=ctx.nodes_reused)
        self._attach_delta_stats(task.integration)
        self._finish_query(trace, ctx, ib0, kind="analyze")
        if self._recorder is not None:
            self._recorder.log_analyze(task, out, iters=iters,
                                       use_kernel=use_kernel,
                                       seconds=self.last_stats.seconds)
        return out

    # ------------------------------------------------------- graph utilities
    def shortest_path(self, graph: str, src_label: str, src_vids, dst_label: str,
                      dst_vids) -> np.ndarray:
        g = self.db.graphs[graph]
        return pattern_mod.shortest_path_lengths(
            g, g.nid_of(src_label, src_vids), g.nid_of(dst_label, dst_vids))
