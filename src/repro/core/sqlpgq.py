"""SQL/PGQ-compatible query surface (paper §6.1: "user queries are expressed
in an SQL/PGQ-compatible language"). A small recursive-descent parser from
SFMW text to the core Query AST:

    SELECT Customer.id, t.tid
    FROM Customer
    MATCH (p:Persons)-[e0:Interested_in]->(t:Tags) ON Interested_in
    WHERE t.content = 'food' AND Customer.person_id = p.pid

Equality between two column references becomes a cross-model JoinPred;
column-op-literal becomes a Predicate (=, <>, !=, <, <=, >, >=,
BETWEEN..AND.., IN (...)). Patterns are vertex-edge chains with labels.
"""
from __future__ import annotations

import re

from .schema import (JoinPred, Pattern, PatternEdge, PatternVertex,
                     Predicate, Query)

_TOKEN = re.compile(r"""
    \s*(?:
      (?P<num>-?\d+\.\d+|-?\d+)
    | (?P<str>'[^']*')
    | (?P<op><=|>=|<>|!=|=|<|>)
    | (?P<arrow>->)
    | (?P<punct>[(),\[\]:\-])
    | (?P<word>[A-Za-z_][\w.]*)
    )""", re.X)

KEYWORDS = frozenset(
    {"SELECT", "FROM", "MATCH", "WHERE", "ON", "AND", "BETWEEN", "IN"})


def _tokenize(text: str):
    out = []
    pos = 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if not m:
            if text[pos:].strip() == "":
                break
            raise SyntaxError(f"bad token at: {text[pos:pos+20]!r}")
        pos = m.end()
        for kind in ("num", "str", "op", "arrow", "punct", "word"):
            v = m.group(kind)
            if v is not None:
                if kind == "word" and v.upper() in KEYWORDS:
                    out.append(("kw", v.upper()))
                else:
                    out.append((kind, v))
                break
    out.append(("eof", ""))
    return out


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def peek(self):
        return self.toks[self.i]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind, value=None):
        k, v = self.next()
        if k != kind or (value is not None and v != value):
            raise SyntaxError(f"expected {kind} {value or ''}, got {k} {v!r}")
        return v

    def accept(self, kind, value=None):
        k, v = self.peek()
        if k == kind and (value is None or v == value):
            self.i += 1
            return True
        return False

    # ---------------- grammar ----------------
    def query(self) -> Query:
        self.expect("kw", "SELECT")
        select = [self.expect("word")]
        while self.accept("punct", ","):
            select.append(self.expect("word"))

        froms = []
        if self.accept("kw", "FROM"):
            froms.append(self.expect("word"))
            while self.accept("punct", ","):
                froms.append(self.expect("word"))

        match = None
        if self.accept("kw", "MATCH"):
            match = self.pattern()

        joins, where = [], []
        if self.accept("kw", "WHERE"):
            self.condition(joins, where)
            while self.accept("kw", "AND"):
                self.condition(joins, where)

        return Query(select=tuple(select), froms=tuple(froms), match=match,
                     joins=tuple(joins), where=tuple(where))

    def pattern(self) -> Pattern:
        vertices, edges = [], []
        seen = {}

        def vertex():
            self.expect("punct", "(")
            var = self.expect("word")
            self.expect("punct", ":")
            label = self.expect("word")
            self.expect("punct", ")")
            if var not in seen:
                seen[var] = PatternVertex(var, label)
                vertices.append(seen[var])
            return var

        src = vertex()
        while self.peek() == ("punct", "-"):
            self.expect("punct", "-")
            self.expect("punct", "[")
            evar = self.expect("word")
            self.expect("punct", ":")
            elabel = self.expect("word")
            self.expect("punct", "]")
            self.expect("arrow")
            dst = vertex()
            edges.append(PatternEdge(evar, elabel, src, dst))
            src = dst

        graph = edges[0].label if edges else vertices[0].label
        if self.accept("kw", "ON"):
            graph = self.expect("word")
        return Pattern(graph, tuple(vertices), tuple(edges))

    def condition(self, joins: list, where: list):
        lhs = self.expect("word")
        if self.accept("kw", "BETWEEN"):
            lo = self.value()
            self.expect("kw", "AND")
            hi = self.value()
            where.append(Predicate(lhs, "range", lo, hi))
            return
        if self.accept("kw", "IN"):
            self.expect("punct", "(")
            vals = [self.value()]
            while self.accept("punct", ","):
                vals.append(self.value())
            self.expect("punct", ")")
            where.append(Predicate(lhs, "in", tuple(vals)))
            return
        op = self.expect("op")
        op = {"=": "==", "<>": "!="}.get(op, op)
        kind, val = self.peek()
        if kind == "word":  # column = column  ->  cross-model join
            self.next()
            if op != "==":
                raise SyntaxError("only equality joins are supported")
            joins.append(JoinPred(lhs, val))
        else:
            where.append(Predicate(lhs, op, self.value()))

    def value(self):
        kind, v = self.next()
        if kind == "num":
            return float(v) if "." in v else int(v)
        if kind == "str":
            return v[1:-1]
        raise SyntaxError(f"expected literal, got {kind} {v!r}")


def parse(text: str) -> Query:
    """Parse an SFMW query string into the core Query AST."""
    p = _Parser(_tokenize(text))
    q = p.query()
    p.expect("eof")
    return q
