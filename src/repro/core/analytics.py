"""Parallel GCDA operators (paper §5.4, Table 3) + matrix generation.

* Matrix generation: ``rel2matrix`` (local access — columnar reads, no
  tuple-at-a-time scan) and ``random_access_matrix`` (aggregate multi-valued
  attributes from qualifying records into multi-hot / count features).
* Analytical operators: MULTIPLY / SIMILARITY / REGRESSION, block-tiled
  Pallas kernels; optionally distributed with ``shard_map`` over a device
  mesh (the paper's worker threads -> mesh shards).
* ``volcano`` submodule: a literal tuple-at-a-time volcano implementation of
  the same operators — the ablation baseline (GredoDB-S / GredoDB-D rely on
  volcano-model execution for GCDA in §7.2).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.cosine_sim.ops import cosine_sim as _cosine_op
from ..kernels.logreg.ops import logreg_grad as _logreg_op
from ..kernels.matmul.ops import matmul as _matmul_op
from .storage import DictColumn, RaggedColumn, Table

_ON_TPU = jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Matrix generation (G in Eq. 5)
# ---------------------------------------------------------------------------


def rel2matrix(table: Table, columns: Sequence[str]) -> jax.Array:
    """REL2MATRIX: local access — assemble numeric columns into an (n, k)
    matrix straight from columnar storage (bypasses row iteration)."""
    cols = []
    for c in columns:
        col = table.col(c)
        if isinstance(col, DictColumn):
            cols.append(col.codes.astype(np.float32))
        else:
            cols.append(np.asarray(col, dtype=np.float32))
    return jnp.asarray(np.stack(cols, axis=1))


def rel2matrix_sharded(table: Table, columns: Sequence[str], k: int
                       ) -> tuple[jax.Array, dict]:
    """Born-sharded REL2MATRIX: each contiguous row block is cast to float32
    and staged to the device independently, then the blocks are concatenated
    *device-side* — the downstream GCDA kernels (MatMul / Similarity /
    Regression) consume the result without a host gather. Values are
    bit-identical to :func:`rel2matrix` (same per-element float32 cast, same
    row order). With more than one device the blocks land on a 1-D ``data``
    mesh via :class:`NamedSharding`; on a single device the block layout
    still avoids materializing the full host-side matrix at once.

    Returns ``(matrix, spec)`` where ``spec`` is the sharding provenance the
    executor attaches to the operator's trace span (``born_sharded``,
    ``host_gather``, ``shards``, ``sharding``)."""
    from .storage import shard_bounds
    cols = [table.col(c) for c in columns]
    blocks = []
    rows_per_block = []
    for lo, hi in shard_bounds(table.nrows, k):
        if lo >= hi:
            continue
        parts = []
        for col in cols:
            if isinstance(col, DictColumn):
                parts.append(col.codes[lo:hi].astype(np.float32))
            else:
                parts.append(np.asarray(col)[lo:hi].astype(np.float32))
        blocks.append(jnp.asarray(np.stack(parts, axis=1)))
        rows_per_block.append(hi - lo)
    if not blocks:
        mat = jnp.zeros((0, len(columns)), dtype=jnp.float32)
    elif len(blocks) == 1:
        mat = blocks[0]
    else:
        mat = jnp.concatenate(blocks, axis=0)
    devices = jax.devices()
    if len(devices) > 1 and len(blocks) > 1:
        ndev = min(len(devices), len(blocks))
        mesh = Mesh(np.array(devices[:ndev]), ("data",))
        mat = jax.device_put(mat, NamedSharding(mesh, P("data", None)))
        sharding = f"NamedSharding(mesh=data:{ndev}, spec=P('data', None))"
    else:
        plat = devices[0].platform if devices else "cpu"
        sharding = f"blocks={len(blocks)} device={plat}"
    spec = {"born_sharded": True, "host_gather": False,
            "shards": int(k), "sharding": sharding,
            "rows_per_block": rows_per_block}
    return mat, spec


def random_access_matrix(table: Table, group_col: str, value_col: str,
                         n_features: int, mode: str = "multi_hot"
                         ) -> tuple[jax.Array, np.ndarray]:
    """Random access — aggregate (multi-valued) attributes of qualifying
    records into per-group feature rows. Returns (matrix, group_ids): row i
    holds the multi-hot / count vector of ``value_col`` over group i."""
    groups = np.asarray(table.col(group_col))
    vcol = table.col(value_col)
    if isinstance(vcol, RaggedColumn):
        rows = np.repeat(groups, vcol.lengths())
        vals = np.asarray(vcol.values)
    else:
        rows = groups
        vals = np.asarray(vcol)
    uniq, row_idx = np.unique(rows, return_inverse=True)
    mat = np.zeros((len(uniq), n_features), dtype=np.float32)
    ok = (vals >= 0) & (vals < n_features)
    np.add.at(mat, (row_idx[ok], vals[ok].astype(np.int64)), 1.0)
    if mode == "multi_hot":
        mat = np.minimum(mat, 1.0)
    return jnp.asarray(mat), uniq


# ---------------------------------------------------------------------------
# Analytical operators (A in Eq. 5): block-parallel Pallas execution
# ---------------------------------------------------------------------------


def flops_estimate(op: str, shapes: Sequence[Sequence[int]],
                   iters: int = 1) -> float:
    """Analytic floating-point work of one analytical-operator execution,
    from its input shapes — the kernel-span payload telemetry attaches and
    ``benchmarks/roofline.py`` compares against the hardware roofline.
    ``op`` is a physical-operator kind ("MatMul" / "Similarity" /
    "Regression"); unknown ops and degenerate shapes cost 0."""
    shapes = [tuple(int(d) for d in s) for s in shapes]
    if not shapes or len(shapes[0]) != 2:
        return 0.0
    m, k = shapes[0]
    if op == "MatMul":
        n = shapes[1][1] if len(shapes) > 1 and len(shapes[1]) == 2 else m
        return 2.0 * m * k * n
    if op == "Similarity":
        # fused cosine: the dot products plus both norm reductions
        n = shapes[1][0] if len(shapes) > 1 and len(shapes[1]) == 2 else m
        return 3.0 * m * k * n
    if op == "Regression":
        # per iteration: forward matvec + gradient matvec over (m, k)
        return 4.0 * m * k * max(iters, 1)
    return 0.0


def multiply(x: jax.Array, y: jax.Array, *, mesh: Optional[Mesh] = None,
             use_kernel: bool | None = None) -> jax.Array:
    """MULTIPLY: Z = X·Y via the tiled MXU kernel; with a mesh, Z tiles are
    sharded (i over 'data', j over 'model') and each shard runs the local
    kernel — the distributed form of the paper's block scheduler."""
    if mesh is None:
        return _matmul_op(x, y, use_kernel=use_kernel)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(None, "model")))
    return jax.jit(jnp.dot, out_shardings=NamedSharding(mesh, P("data", "model")))(xs, ys)


def similarity(x: jax.Array, y: jax.Array, *, mesh: Optional[Mesh] = None,
               use_kernel: bool | None = None) -> jax.Array:
    """SIMILARITY: pairwise cosine scores via the fused kernel."""
    if mesh is None:
        return _cosine_op(x, y, use_kernel=use_kernel)
    from ..kernels.cosine_sim import cosine_sim_ref
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    ys = jax.device_put(y, NamedSharding(mesh, P("model", None)))
    return jax.jit(cosine_sim_ref,
                   out_shardings=NamedSharding(mesh, P("data", "model")))(xs, ys)


def regression(x: jax.Array, y: jax.Array, *, iters: int = 100,
               lr: float = 0.5, l2: float = 1e-4,
               use_kernel: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """REGRESSION: train a logistic-regression model with the fused
    gradient kernel inside a lax loop. Returns (weights, final loss)."""
    n, d = x.shape
    w0 = jnp.zeros((d,), jnp.float32)

    import functools

    @functools.partial(jax.jit, static_argnames=("n_iters",))
    def run(x_, y_, w_, n_iters):
        def step(_, carry):
            w, _ = carry
            g, loss = _logreg_op(x_, y_, w, use_kernel=use_kernel)
            return w - lr * (g + l2 * w), loss

        return jax.lax.fori_loop(0, n_iters, step, (w_, jnp.float32(0)))

    return run(x, y, w0, iters)


def regression_distributed(x: jax.Array, y: jax.Array, mesh: Mesh, *,
                           iters: int = 50, lr: float = 0.5, l2: float = 1e-4
                           ) -> tuple[jax.Array, jax.Array]:
    """Data-parallel REGRESSION: rows sharded over 'data'; each shard
    computes its partial gradient, one psum per iteration (the paper's
    "aggregating contributions from each partition in parallel")."""
    from jax.experimental.shard_map import shard_map
    from ..kernels.logreg import logreg_grad_ref

    n, d = x.shape
    ndev = mesh.shape["data"]
    pad = (-n) % ndev
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad))

    @jax.jit
    def run(xs, ys):
        def local_grad(xs_, ys_, w):
            z = xs_ @ w
            p = jax.nn.sigmoid(z)
            gpart = xs_.T @ (p - ys_)
            lpart = jnp.sum(jax.nn.softplus(z) - ys_ * z)
            g = jax.lax.psum(gpart, "data") / n
            loss = jax.lax.psum(lpart, "data") / n
            return g, loss

        sharded = shard_map(local_grad, mesh=mesh,
                            in_specs=(P("data", None), P("data"), P()),
                            out_specs=(P(), P()))

        def step(carry, _):
            w, _ = carry
            g, loss = sharded(xs, ys, w)
            return (w - lr * (g + l2 * w), loss), None

        (w, loss), _ = jax.lax.scan(step, (jnp.zeros((d,), jnp.float32),
                                           jnp.float32(0)), None, length=iters)
        return w, loss

    return run(xp, yp)


# ---------------------------------------------------------------------------
# Volcano baseline: tuple-at-a-time GCDA (ablation §7.2)
# ---------------------------------------------------------------------------


class volcano:
    """Literal tuple-at-a-time execution of the same analytics — each value
    flows through a Python-level iterator chain (the paper's criticism:
    excessive iterator invocations, function-call overhead, no batching)."""

    @staticmethod
    def rel2matrix(table: Table, columns: Sequence[str]) -> np.ndarray:
        out = []
        for i in range(table.nrows):          # tuple at a time
            row = []
            for c in columns:
                col = table.col(c)
                v = col.codes[i] if isinstance(col, DictColumn) else np.asarray(col)[i]
                row.append(float(v))
            out.append(row)
        return np.asarray(out, dtype=np.float32)

    @staticmethod
    def multiply(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        m, k = x.shape
        k2, n = y.shape
        z = np.zeros((m, n), dtype=np.float32)
        for i in range(m):
            for j in range(n):
                acc = 0.0
                for l in range(k):
                    acc += float(x[i, l]) * float(y[l, j])
                z[i, j] = acc
        return z

    @staticmethod
    def similarity(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        m, n = x.shape[0], y.shape[0]
        out = np.zeros((m, n), dtype=np.float32)
        for i in range(m):
            for j in range(n):
                dot = nx = ny = 0.0
                for l in range(x.shape[1]):
                    dot += float(x[i, l]) * float(y[j, l])
                    nx += float(x[i, l]) ** 2
                    ny += float(y[j, l]) ** 2
                out[i, j] = dot / max((nx ** 0.5) * (ny ** 0.5), 1e-12)
        return out

    @staticmethod
    def regression(x: np.ndarray, y: np.ndarray, iters: int = 100,
                   lr: float = 0.5, l2: float = 1e-4) -> tuple[np.ndarray, float]:
        n, d = x.shape
        w = np.zeros(d, dtype=np.float64)
        loss = 0.0
        for _ in range(iters):
            g = np.zeros(d, dtype=np.float64)
            loss = 0.0
            for i in range(n):                 # tuple at a time
                z = 0.0
                for l in range(d):
                    z += float(x[i, l]) * w[l]
                p = 1.0 / (1.0 + np.exp(-z))
                err = p - float(y[i])
                for l in range(d):
                    g[l] += err * float(x[i, l])
                loss += np.logaddexp(0.0, z) - float(y[i]) * z
            w -= lr * (g / n + l2 * w)
        return w.astype(np.float32), float(loss / n)
