"""GredoDB core: unified multi-model storage, graph-centric operators,
GCDI optimizer, and parallel GCDA (the paper's contribution)."""
from .engine import GredoEngine
from .interbuffer import InterBuffer
from .schema import (AnalyticsTask, GCDIATask, JoinPred, Pattern, Predicate,
                     Query, chain_pattern)
from .storage import Database, Graph, Table, shred_documents

__all__ = [
    "GredoEngine", "InterBuffer", "Database", "Graph", "Table",
    "shred_documents", "Query", "Pattern", "Predicate", "JoinPred",
    "AnalyticsTask", "GCDIATask", "chain_pattern",
]
