"""GredoDB core: unified multi-model storage, graph-centric operators,
GCDI optimizer, and parallel GCDA (the paper's contribution)."""
from .engine import GredoEngine, Profile
from .interbuffer import InterBuffer
from .observe import (FlightRecorder, HealthReport, ReplayMismatch,
                      WorkloadRecorder, evaluate_health, replay)
from .schema import (AnalyticsTask, GCDIATask, JoinPred, Pattern, Predicate,
                     Query, chain_pattern)
from .storage import Database, Graph, Table, shred_documents
from .telemetry import (QErrorMonitor, QueryTrace, Registry, Telemetry,
                        TraceCollector, default_registry,
                        validate_chrome_trace)

__all__ = [
    "GredoEngine", "Profile", "InterBuffer", "Database", "Graph", "Table",
    "shred_documents", "Query", "Pattern", "Predicate", "JoinPred",
    "AnalyticsTask", "GCDIATask", "chain_pattern",
    "Telemetry", "Registry", "TraceCollector", "QueryTrace", "QErrorMonitor",
    "default_registry", "validate_chrome_trace",
    "FlightRecorder", "HealthReport", "WorkloadRecorder", "ReplayMismatch",
    "evaluate_health", "replay",
]
