"""Cost-based optimizer: stats-driven rewrites of the physical operator DAG.

Sits between the planner and the executor. ``planner.plan`` makes the
*logical* decisions (predicate assignment and pushdown, match trimming,
projection trimming), ``physical.build_gcdi`` lowers them to a *naive* DAG
(clusters join in query order, graph↔table joins stay post-match), and
:func:`optimize` is the single physical rewrite pass:

1. **Selection sink-down** — residual σ predicates move below the joins,
   into the ``Select`` above the owning ``ScanTable`` (or onto the graph
   cluster), so joins see fewer rows.
2. **Column pruning** — base-table columns never referenced above the scan
   (projection, join keys, residual predicates) are dropped right after the
   pushed selections (projection sink-down into the scan).
3. **Semi-join siding (Eq. 8 → 9/10)** — for each candidate graph↔table
   join the §6.3 cost model compares three sidings: keep the post-match
   equi-join, mask the graph's candidate vertices (``SemiJoinMask`` into
   ``MatchPattern``), or reduce the table by the vertex keys
   (``SemiJoinReduce``) — build on the smaller input.
4. **Join reordering** — EquiJoin clusters re-merge greedily,
   smallest-estimated-intermediate first, using NDV-based join cardinality
   (``physical.est_join_rows``); the smaller side of every join becomes the
   build (right) side of the sort-merge.
5. **Common-subexpression elimination** — structurally identical subtrees
   (equal node signatures) collapse to one shared node, so the DAG walks,
   caches, and reports them once.

All rewrites are plan-equivalence preserving: selections and semi-joins
commute with equi-joins, and equi-joins commute/associate. The estimates
come from the live column statistics (NDV, equi-width histograms, MCV
counts) via :func:`physical.estimate`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import physical as ph
from .planner import _graph_join_side
from .storage import Database


@dataclasses.dataclass
class OptReport:
    """What the rewrite pass did, plus the §6.3 cost totals before/after."""

    rewrites: list = dataclasses.field(default_factory=list)
    est_cost_before: float = 0.0
    est_cost_after: float = 0.0

    def add(self, rule: str, detail: str) -> None:
        self.rewrites.append(f"{rule}: {detail}")

    def notes(self) -> list:
        out = list(self.rewrites)
        out.append(f"est_cost {self.est_cost_before:.3g} -> "
                   f"{self.est_cost_after:.3g}")
        return out


def optimize(root: ph.PhysicalOp, db: Database
             ) -> tuple[ph.PhysicalOp, OptReport]:
    """Rewrite a physical DAG (GCDI or full GCDIA) against the §6.3 cost
    model. Returns ``(new_root, report)``; the input DAG is not mutated."""
    report = OptReport()
    cache: dict = {}    # shared estimate memo across the rewrite passes
    report.est_cost_before = _est_cost(root, db, cache)
    proj = _find_kind(root, ph.Project)
    if proj is not None and getattr(proj, "logical", None) is not None:
        new_proj = _optimize_gcdi(proj, db, report, cache)
        if new_proj is not proj:
            root = _replace(root, {id(proj): new_proj})
    root, merged = _cse(root)
    if merged:
        report.add("cse", f"unified {merged} duplicate subtree(s)")
    report.est_cost_after = _est_cost(root, db, cache)
    return root, report


# ---------------------------------------------------------------------------
# DAG surgery helpers
# ---------------------------------------------------------------------------


def _find_kind(node: ph.PhysicalOp, cls) -> Optional[ph.PhysicalOp]:
    if isinstance(node, cls):
        return node
    for c in node.children:
        hit = _find_kind(c, cls)
        if hit is not None:
            return hit
    return None


def _replace(node: ph.PhysicalOp, mapping: dict) -> ph.PhysicalOp:
    """Memoized rebuild substituting ``mapping[id(old)] -> new`` subtrees;
    shared nodes stay shared."""
    memo = dict(mapping)

    def walk(n: ph.PhysicalOp) -> ph.PhysicalOp:
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(walk(c) for c in n.children)
        out = n if all(a is b for a, b in zip(kids, n.children)) \
            else n.with_children(*kids)
        memo[id(n)] = out
        return out

    return walk(node)


def _cse(root: ph.PhysicalOp) -> tuple[ph.PhysicalOp, int]:
    """Collapse structurally identical subtrees (same signature) into one
    shared node instance, bottom-up. Already-shared nodes are walked once
    (per-object memo), so ``merged`` counts genuine duplicates only."""
    seen: dict = {}     # signature -> canonical node
    memo: dict = {}     # id(original) -> rewritten/canonical node
    merged = 0

    def walk(n: ph.PhysicalOp) -> ph.PhysicalOp:
        nonlocal merged
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(walk(c) for c in n.children)
        cand = n if all(a is b for a, b in zip(kids, n.children)) \
            else n.with_children(*kids)
        sig = cand.signature()
        if sig in seen:
            if seen[sig] is not cand:
                merged += 1
            cand = seen[sig]
        else:
            seen[sig] = cand
        memo[id(n)] = cand
        return cand

    return walk(root), merged


def _est_rows(node: ph.PhysicalOp, db: Database, cache: dict) -> float:
    return ph.estimate(node, db, _cache=cache)[id(node)][0]


def _est_cost(node: ph.PhysicalOp, db: Database, cache: dict) -> float:
    return ph.estimate(node, db, _cache=cache)[id(node)][1]


# ---------------------------------------------------------------------------
# The GCDI rewrite pipeline (runs on the Project subtree)
# ---------------------------------------------------------------------------


def _optimize_gcdi(proj: ph.PhysicalOp, db: Database,
                   report: OptReport, cache: dict) -> ph.PhysicalOp:
    p = proj.logical
    q = p.query
    pattern = q.match

    node = proj.children[0]
    residual = []
    if isinstance(node, ph.Residual):
        residual = list(node.preds)
        node = node.children[0]

    # -- extract the join tree: cluster leaves + the full join predicate set
    leaves: list[ph.PhysicalOp] = []

    def collect(n: ph.PhysicalOp) -> None:
        if isinstance(n, (ph.EquiJoin, ph.IntraFilter)):
            for c in n.children:
                collect(c)
        else:
            leaves.append(n)

    collect(node)

    # -- pass 1: selection sink-down --------------------------------------
    leaves, residual = _sink_selections(leaves, residual, report)

    # -- pass 2: column pruning (projection sink-down into the scans) ------
    leaves = _prune_columns(leaves, db, q, residual, report)

    # -- pass 3: cost-based semi-join siding (Eq. 8 -> 9/10) ---------------
    if pattern is not None and p.semi_join_idx:
        leaves = _side_semi_joins(leaves, db, p, report, cache)

    # -- pass 4: greedy join reordering ------------------------------------
    current = _reorder_joins(leaves, db, q, pattern, residual, report, cache)

    if residual:
        current = ph.Residual(residual, current)
    return proj.with_children(current)


def _leaf_cols(leaf: ph.PhysicalOp) -> frozenset:
    return getattr(leaf, "out_cols", frozenset())


def _table_leaf(leaf: ph.PhysicalOp) -> Optional[ph.Alias]:
    return leaf if isinstance(leaf, ph.Alias) else None


def _sink_selections(leaves: list, residual: list, report: OptReport
                     ) -> tuple[list, list]:
    """Move residual σ predicates below the joins: into the Select above the
    owning table scan, or as a filter on the owning cluster."""
    leaves = list(leaves)
    kept: list = []
    for pred in residual:
        target = None
        for li, leaf in enumerate(leaves):
            if ph._static_has_col(_leaf_cols(leaf), pred.attr):
                target = li
                break
        if target is None:
            kept.append(pred)
            continue
        leaf = leaves[target]
        alias = _table_leaf(leaf)
        if alias is not None and pred.collection == alias.name:
            inner = alias.children[0]
            if isinstance(inner, ph.Select):
                inner = ph.Select(inner.children[0], list(inner.preds) + [pred])
            else:
                inner = ph.Select(inner, [pred])
            new_leaf = alias.with_children(inner)
            report.add("sink-down", f"{pred!r} -> Select[{alias.name}]")
        else:
            new_leaf = ph.Residual([pred], leaf)
            new_leaf.out_cols = _leaf_cols(leaf)
            report.add("sink-down", f"{pred!r} -> {leaf.kind} cluster")
        leaves[target] = new_leaf
    return leaves, kept


def _needed_columns(q, coll: str, residual: list) -> set:
    """Bare column names of ``coll`` referenced above its scan."""
    need: set = set()
    for a in q.select:
        c, _, col = a.partition(".")
        if c == coll and col:
            need.add(col)
    for jp in q.joins:
        for side in (jp.left, jp.right):
            c, _, col = side.partition(".")
            if c == coll and col:
                need.add(col)
    for pred in residual:
        if pred.collection == coll:
            need.add(pred.column)
    return need


def _prune_columns(leaves: list, db: Database, q, residual: list,
                   report: OptReport) -> list:
    leaves = list(leaves)
    for li, leaf in enumerate(leaves):
        alias = _table_leaf(leaf)
        if alias is None or alias.name not in db.tables:
            continue
        have = set(db.tables[alias.name].columns)
        need = _needed_columns(q, alias.name, residual) & have
        if not need or need >= have:
            continue
        pruned = ph.PruneCols(alias.children[0], tuple(sorted(need)))
        leaves[li] = alias.with_children(pruned)
        report.add("prune", f"{alias.name}: keep {sorted(need)} "
                            f"of {len(have)} column(s)")
    return leaves


def _side_semi_joins(leaves: list, db: Database, p, report: OptReport,
                     cache: dict) -> list:
    """Eq. 8 -> 9/10 with cost-based *siding*: per candidate graph↔table
    join, compare (A) post-match join only, (B) graph-side candidate mask,
    (C) table-side reduction by vertex keys — apply the cheapest."""
    from . import cost as cost_mod

    q = p.query
    pattern = q.match
    g = db.graphs[pattern.graph]
    gep = db.epoch_of(pattern.graph)
    vset = {v.var for v in pattern.vertices}

    graph_i = next((i for i, l in enumerate(leaves)
                    if _find_kind(l, ph.MatchPattern) is not None), None)
    if graph_i is None:
        return leaves
    leaves = list(leaves)

    for i in sorted(p.semi_join_idx):
        jp = q.joins[i]
        side = _graph_join_side(q, vset, jp)
        if side is None:
            continue
        tbl_attr, var_attr = side
        tcoll, tcol = tbl_attr.split(".", 1)
        vvar, vcol = var_attr.split(".", 1)
        label = pattern.vertex(vvar).label
        tbl_i = next((ti for ti, l in enumerate(leaves)
                      if _table_leaf(l) is not None
                      and _table_leaf(l).name == tcoll), None)
        if tbl_i is None:
            continue
        alias = leaves[tbl_i]
        tbl_subtree = alias.children[0]
        mp = _find_kind(leaves[graph_i], ph.MatchPattern)

        def mp_cost_excl_tables(node: ph.MatchPattern) -> float:
            """Match cost with every mask's *table* subtree excluded — the
            table scans execute once under any siding (they feed the final
            equi-joins regardless), so no option gets charged for them."""
            c = _est_cost(node, db, cache)
            seen: set = set()
            for m in node.children:
                t = m.children[0]
                if id(t) not in seen:   # shared subtrees are counted once
                    seen.add(id(t))
                    c -= _est_cost(t, db, cache)
            return c

        n_t = _est_rows(tbl_subtree, db, cache)
        est_match = _est_rows(mp, db, cache)

        # (A) keep the post-match equi-join
        cost_a = mp_cost_excl_tables(mp) + cost_mod.cost_join(est_match, n_t)

        # (B) graph-side mask shrinking the candidate vertex set
        mask = ph.SemiJoinMask(pattern.graph, gep, label, vcol, tcol,
                               tbl_subtree)
        mask.ocol_src = ("table", tcoll, tcol)
        mp_b = mp.with_children(*mp.children, mask)
        mp_b.mask_vars = tuple(mp.mask_vars) + (vvar,)
        est_match_b = _est_rows(mp_b, db, cache)
        cost_b = (mp_cost_excl_tables(mp_b)
                  + cost_mod.cost_join(est_match_b, n_t))

        # (C) table-side reduction by the vertex keys
        reduce_node = ph.SemiJoinReduce(pattern.graph, gep, label, vcol,
                                        tcol, tbl_subtree)
        reduce_node.ocol_src = ("table", tcoll, tcol)
        n_t_c = _est_rows(reduce_node, db, cache)
        cost_c = (mp_cost_excl_tables(mp)
                  + _est_cost(reduce_node, db, cache)
                  - _est_cost(tbl_subtree, db, cache)
                  + cost_mod.cost_join(est_match, n_t_c))

        best = min(cost_a, cost_b, cost_c)
        if best == cost_b:
            leaves[graph_i] = _replace(leaves[graph_i], {id(mp): mp_b})
            report.add("semi-join", f"join#{i} ({jp}): graph-side mask on "
                       f"{vvar} — cost {cost_b:.3g} < post-match {cost_a:.3g}")
        elif best == cost_c:
            leaves[tbl_i] = alias.with_children(reduce_node)
            report.add("semi-join", f"join#{i} ({jp}): table-side reduce of "
                       f"{tcoll} — cost {cost_c:.3g} < post-match {cost_a:.3g}")
        else:
            report.add("semi-join", f"join#{i} ({jp}): kept post-match "
                       f"(cost {cost_a:.3g} <= {min(cost_b, cost_c):.3g})")
    return leaves


def _reorder_joins(leaves: list, db: Database, q, pattern, residual: list,
                   report: OptReport, cache: dict) -> ph.PhysicalOp:
    """Greedy smallest-intermediate-first re-merge of the join clusters."""
    clusters = [{"node": leaf, "cols": set(_leaf_cols(leaf)),
                 "rows": _est_rows(leaf, db, cache)} for leaf in leaves]
    pending = [(i, jp, (ph._key_source(q, pattern, jp.left),
                        ph._key_source(q, pattern, jp.right)))
               for i, jp in enumerate(q.joins)]
    order: list[int] = []

    def find(attr: str) -> Optional[int]:
        for ci, c in enumerate(clusters):
            if ph._static_has_col(c["cols"], attr):
                return ci
        return None

    def apply_intra(ci: int) -> None:
        """Fold every pending predicate now internal to cluster ``ci``."""
        for item in list(pending):
            i, jp, ks = item
            li, ri = find(jp.left), find(jp.right)
            if li == ri == ci:
                node = ph.IntraFilter(jp, clusters[ci]["node"])
                node.key_src = ks
                ndv = max((float(s.ndv) for s in map(
                    lambda src: ph.resolve_key_stats(db, src), ks)
                    if s is not None), default=3.0)
                clusters[ci]["node"] = node
                clusters[ci]["rows"] /= max(
                    min(ndv, max(clusters[ci]["rows"], 1.0)), 1.0)
                pending.remove(item)
                order.append(i)

    for ci in range(len(clusters)):
        apply_intra(ci)

    while pending:
        best = None
        for item in pending:
            i, jp, ks = item
            li, ri = find(jp.left), find(jp.right)
            if li is None or ri is None or li == ri:
                continue
            ls, rs = (ph.resolve_key_stats(db, s) for s in ks)
            est = ph.est_join_rows(clusters[li]["rows"], clusters[ri]["rows"],
                                   ls, rs)
            if best is None or (est, i) < (best[0], best[1]):
                best = (est, i, item, li, ri)
        if best is None:
            break   # remaining predicates span unreachable clusters
        est, i, item, li, ri = best
        _, jp, ks = item
        pending.remove(item)
        lc, rc = clusters[li], clusters[ri]
        # build-side selection: the smaller estimated input becomes the
        # right (sorted/build) side of the sort-merge equi-join
        if lc["rows"] < rc["rows"]:
            jp = type(jp)(jp.right, jp.left)
            ks = (ks[1], ks[0])
            lc, rc = rc, lc
        join = ph.EquiJoin(jp, lc["node"], rc["node"])
        join.key_src = ks
        keep, drop = min(li, ri), max(li, ri)
        clusters[keep] = {"node": join, "cols": lc["cols"] | rc["cols"],
                          "rows": est}
        del clusters[drop]
        order.append(i)
        apply_intra(keep)

    if len(clusters) > 1:
        # same covering rule as the builder, including its loud failure on a
        # genuinely disconnected query — clusters are never dropped silently
        current = ph.pick_connected_cluster(
            [(c["node"], c["cols"]) for c in clusters],
            list(q.select) + [pr.attr for pr in residual])
    else:
        current = clusters[0]["node"]

    if order != sorted(order):
        report.add("join-order", f"{order} (query order {sorted(order)})")
    return current
