"""Cost-based optimizer: stats-driven rewrites of the physical operator DAG.

Sits between the planner and the executor. ``planner.plan`` makes the
*logical* decisions (predicate assignment and pushdown, match trimming,
projection trimming), ``physical.build_gcdi`` lowers them to a *naive* DAG
(clusters join in query order, graph↔table joins stay post-match), and
:func:`optimize` is the single physical rewrite pass:

1. **Selection sink-down** — residual σ predicates move below the joins,
   into the ``Select`` above the owning ``ScanTable`` (or onto the graph
   cluster), so joins see fewer rows.
2. **Column pruning** — base-table columns never referenced above the scan
   (projection, join keys, residual predicates) are dropped right after the
   pushed selections (projection sink-down into the scan).
3. **Join enumeration with semi-join siding (Eq. 8 → 9/10)** — a
   Selinger-style dynamic program over the connected subsets of the join
   graph (≤ :data:`MAX_DP_RELATIONS` relations; greedy
   smallest-intermediate-first above) produces **bushy** ``EquiJoin`` trees
   costed with distribution-aware join cardinalities
   (``physical.est_join_rows``: per-key / per-bucket overlap of the two key
   distributions, NDV containment only as fallback). The §6.3 semi-join
   siding choices — post-match equi-join vs. graph-side ``SemiJoinMask``
   vs. table-side ``SemiJoinReduce`` — are enumerated *inside* the same
   search (every siding configuration gets its own enumeration and the
   cheapest whole plan wins), not greedily in a separate pass. The smaller
   side of every join becomes the build (right) side of the sort-merge.
4. **Common-subexpression elimination** — structurally identical subtrees
   (equal node signatures) collapse to one shared node, so the DAG walks,
   caches, and reports them once.

All rewrites are plan-equivalence preserving: selections and semi-joins
commute with equi-joins, and equi-joins commute/associate. The estimates
come from the live column statistics (NDV, equi-width histograms, MCV
counts) via :func:`physical.estimate`; a caller-held estimate cache is
keyed on the catalog's write-epoch snapshot, so estimates cached across
queries are invalidated by any delta-store append.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from . import cost as cost_mod
from . import physical as ph
from . import verify as verify_mod
from .planner import _graph_join_side
from .storage import Database

MAX_DP_RELATIONS = 8     # DP over connected subsets up to this many leaves
MAX_SIDING_ENUM = 3      # joint 3^k siding enumeration up to k candidates
MAX_CACHE_ENTRIES = 50_000   # estimate-cache size backstop

DEVICE_MATCH = True              # consider device access paths for patterns
DEVICE_MAX_FRONTIER = float(1 << 18)   # skip device lowering past this peak


@dataclasses.dataclass
class OptReport:
    """What the rewrite pass did, plus the §6.3 cost totals before/after."""

    rewrites: list = dataclasses.field(default_factory=list)
    est_cost_before: float = 0.0
    est_cost_after: float = 0.0

    def add(self, rule: str, detail: str) -> None:
        self.rewrites.append(f"{rule}: {detail}")

    def notes(self) -> list:
        out = list(self.rewrites)
        out.append(f"est_cost {self.est_cost_before:.3g} -> "
                   f"{self.est_cost_after:.3g}")
        return out

    def rule_counts(self) -> dict:
        """Rewrites applied per rule name — what the engine feeds into its
        ``optimizer.rewrites.<rule>`` telemetry counters."""
        counts: dict[str, int] = {}
        for note in self.rewrites:
            rule = note.split(":", 1)[0].strip().replace(" ", "_")
            counts[rule] = counts.get(rule, 0) + 1
        return counts


def optimize(root: ph.PhysicalOp, db: Database, cache: Optional[dict] = None,
             join_enum: str = "dp") -> tuple[ph.PhysicalOp, OptReport]:
    """Rewrite a physical DAG (GCDI or full GCDIA) against the §6.3 cost
    model. Returns ``(new_root, report)``; the input DAG is not mutated.

    ``cache`` may be a caller-held estimate memo reused across calls (the
    engine keeps one per instance); it is keyed on the catalog write-epoch
    snapshot and cleared whenever any source collection mutated, so stale
    cardinalities can never steer a plan. ``join_enum`` selects the
    enumerator: ``"dp"`` (bushy Selinger DP, the default), ``"dp-leftdeep"``
    (DP restricted to left-deep trees — the measurable baseline), or
    ``"greedy"`` (smallest-intermediate-first)."""
    report = OptReport()
    if cache is None:
        cache = {}
    # snapshot = every collection's write epoch + the join-estimate model
    # toggle: node signatures embed the epochs but not HIST_JOIN_EST, so a
    # flag flip must also drop estimates cached under the other model
    snap = (ph.catalog_epochs(db), ph.HIST_JOIN_EST)
    if cache.get("__catalog__") != snap or len(cache) > MAX_CACHE_ENTRIES:
        cache.clear()
        cache["__catalog__"] = snap
    report.est_cost_before = _est_cost(root, db, cache)
    proj = _find_kind(root, ph.Project)
    if proj is not None and getattr(proj, "logical", None) is not None:
        new_proj = _optimize_gcdi(proj, db, report, cache, join_enum)
        if new_proj is not proj:
            root = _replace(root, {id(proj): new_proj})
    root, merged = _cse(root)
    if merged:
        report.add("cse", f"unified {merged} duplicate subtree(s)")
    report.est_cost_after = _est_cost(root, db, cache)
    # refresh the schema annotations the rewrites invalidated (pruned
    # columns, re-sided semi-joins, replaced access paths)
    verify_mod.annotate_out_cols(root, db)
    return root, report


# ---------------------------------------------------------------------------
# DAG surgery helpers
# ---------------------------------------------------------------------------


def _find_kind(node: ph.PhysicalOp, cls) -> Optional[ph.PhysicalOp]:
    if isinstance(node, cls):
        return node
    for c in node.children:
        hit = _find_kind(c, cls)
        if hit is not None:
            return hit
    return None


def _replace(node: ph.PhysicalOp, mapping: dict) -> ph.PhysicalOp:
    """Memoized rebuild substituting ``mapping[id(old)] -> new`` subtrees;
    shared nodes stay shared."""
    memo = dict(mapping)

    def walk(n: ph.PhysicalOp) -> ph.PhysicalOp:
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(walk(c) for c in n.children)
        out = n if all(a is b for a, b in zip(kids, n.children)) \
            else n.with_children(*kids)
        memo[id(n)] = out
        return out

    return walk(node)


def _cse(root: ph.PhysicalOp) -> tuple[ph.PhysicalOp, int]:
    """Collapse structurally identical subtrees (same signature) into one
    shared node instance, bottom-up. Already-shared nodes are walked once
    (per-object memo), so ``merged`` counts genuine duplicates only."""
    seen: dict = {}     # signature -> canonical node
    memo: dict = {}     # id(original) -> rewritten/canonical node
    merged = 0

    def walk(n: ph.PhysicalOp) -> ph.PhysicalOp:
        nonlocal merged
        if id(n) in memo:
            return memo[id(n)]
        kids = tuple(walk(c) for c in n.children)
        cand = n if all(a is b for a, b in zip(kids, n.children)) \
            else n.with_children(*kids)
        sig = cand.signature()
        if sig in seen:
            if seen[sig] is not cand:
                merged += 1
            cand = seen[sig]
        else:
            seen[sig] = cand
        memo[id(n)] = cand
        return cand

    return walk(root), merged


def _est_rows(node: ph.PhysicalOp, db: Database, cache: dict) -> float:
    return ph.estimate(node, db, _cache=cache)[id(node)][0]


def _est_cost(node: ph.PhysicalOp, db: Database, cache: dict) -> float:
    return ph.estimate(node, db, _cache=cache)[id(node)][1]


# ---------------------------------------------------------------------------
# The GCDI rewrite pipeline (runs on the Project subtree)
# ---------------------------------------------------------------------------


def _optimize_gcdi(proj: ph.PhysicalOp, db: Database, report: OptReport,
                   cache: dict, join_enum: str) -> ph.PhysicalOp:
    p = proj.logical
    q = p.query
    pattern = q.match

    node = proj.children[0]
    residual = []
    if isinstance(node, ph.Residual):
        residual = list(node.preds)
        node = node.children[0]

    # -- extract the join tree: cluster leaves + the full join predicate set
    leaves: list[ph.PhysicalOp] = []

    def collect(n: ph.PhysicalOp) -> None:
        if isinstance(n, (ph.EquiJoin, ph.IntraFilter)):
            for c in n.children:
                collect(c)
        else:
            leaves.append(n)

    collect(node)

    # -- pass 1: selection sink-down --------------------------------------
    leaves, residual = _sink_selections(leaves, residual, report)

    # -- pass 2: column pruning (projection sink-down into the scans) ------
    leaves = _prune_columns(leaves, db, q, residual, report)

    # -- pass 2b: cost-based access-path selection per table scan ----------
    leaves = _select_access_paths(leaves, db, report)

    # -- pass 3+4: join enumeration with semi-join siding inside ----------
    cands = []
    if pattern is not None and p.semi_join_idx:
        cands = _siding_candidates(leaves, db, p)
    if len(cands) > MAX_SIDING_ENUM:
        # too many candidates for the joint 3^k sweep: decide each siding
        # greedily against the all-post plan, then enumerate the join order
        leaves = _side_semi_joins(leaves, db, p, report, cache)
        cands = []

    best = None     # (cost, config, root, notes)
    costs: dict[tuple, float] = {}
    for config in itertools.product(SIDINGS, repeat=len(cands)):
        leaves_v = _apply_siding(leaves, cands, config, db, p)
        current, order, bushy = _enumerate_joins(
            leaves_v, db, q, pattern, residual, cache, join_enum)
        cost = _est_cost(current, db, cache)
        costs[config] = cost
        if best is None or cost < best[0]:
            best = (cost, config, current, order, bushy)

    cost, config, current, order, bushy = best
    for cand, choice in zip(cands, config):
        alt = costs.get(config[:cand["pos"]] + ("post",)
                        + config[cand["pos"] + 1:], cost)
        jp = cand["jp"]
        if choice == "mask":
            report.add("semi-join", f"join#{cand['i']} ({jp}): graph-side "
                       f"mask on {cand['vvar']} — plan cost {cost:.3g} < "
                       f"post-match {alt:.3g}")
        elif choice == "reduce":
            report.add("semi-join", f"join#{cand['i']} ({jp}): table-side "
                       f"reduce of {cand['tcoll']} — plan cost {cost:.3g} < "
                       f"post-match {alt:.3g}")
        else:
            others = [c for cfg, c in costs.items()
                      if cfg[cand["pos"]] != "post"]
            detail = f" (cost {cost:.3g} <= {min(others):.3g})" if others else ""
            report.add("semi-join",
                       f"join#{cand['i']} ({jp}): kept post-match{detail}")
    if order is not None and (bushy or list(order) != sorted(order)):
        shape = "bushy " if bushy else ""
        report.add("join-order", f"{join_enum} {shape}{list(order)} "
                                 f"(query order {sorted(order)})")

    _annotate_match_access(current, db)
    current = _select_match_path(current, db, report, cache)
    if residual:
        current = ph.Residual(residual, current)
    return proj.with_children(current)


def _leaf_cols(leaf: ph.PhysicalOp) -> frozenset:
    return getattr(leaf, "out_cols", frozenset())


def _table_leaf(leaf: ph.PhysicalOp) -> Optional[ph.Alias]:
    return leaf if isinstance(leaf, ph.Alias) else None


def _sink_selections(leaves: list, residual: list, report: OptReport
                     ) -> tuple[list, list]:
    """Move residual σ predicates below the joins: into the Select above the
    owning table scan, or as a filter on the owning cluster."""
    leaves = list(leaves)
    kept: list = []
    for pred in residual:
        target = None
        for li, leaf in enumerate(leaves):
            if ph._static_has_col(_leaf_cols(leaf), pred.attr):
                target = li
                break
        if target is None:
            kept.append(pred)
            continue
        leaf = leaves[target]
        alias = _table_leaf(leaf)
        if alias is not None and pred.collection == alias.name:
            inner = alias.children[0]
            if isinstance(inner, ph.Select):
                inner = ph.Select(inner.children[0], list(inner.preds) + [pred])
            else:
                inner = ph.Select(inner, [pred])
            new_leaf = alias.with_children(inner)
            report.add("sink-down", f"{pred!r} -> Select[{alias.name}]")
        else:
            new_leaf = ph.Residual([pred], leaf)
            new_leaf.out_cols = _leaf_cols(leaf)
            report.add("sink-down", f"{pred!r} -> {leaf.kind} cluster")
        leaves[target] = new_leaf
    return leaves, kept


# ---------------------------------------------------------------------------
# Access-path selection (index / zone skip-scan / full scan), per table leaf
# ---------------------------------------------------------------------------


def _select_access_paths(leaves: list, db: Database,
                         report: OptReport) -> list:
    """Cost-compare the three access paths of every ``Select``-over-
    ``ScanTable`` leaf — posting-list :class:`~repro.core.physical.IndexScan`,
    zone-map :class:`~repro.core.physical.IndexSelect` skip-scan, and the
    full scan — using the existing ``ColumnStats`` selectivities and the
    live zone-map candidate fractions. The cheapest replaces the pair; the
    decision is recorded as ``access=`` provenance either way (rendered by
    ``explain``/``explain_last``)."""
    im = getattr(db, "_index_manager", None)
    leaves = list(leaves)
    for li, leaf in enumerate(leaves):
        alias = _table_leaf(leaf)
        if alias is None or alias.name not in db.tables:
            continue
        top = alias.children[0]
        prune = top if isinstance(top, ph.PruneCols) else None
        node = prune.children[0] if prune is not None else top
        if isinstance(node, ph.ScanTable):
            node.access = "full-scan"
            continue
        if not (isinstance(node, ph.Select)
                and isinstance(node.children[0], ph.ScanTable)):
            continue
        sel_node, scan = node, node.children[0]
        tbl = db.tables[alias.name]
        n = float(tbl.nrows)
        preds = sel_node.preds
        sels = [tbl.stats(p.column).selectivity(p) for p in preds]
        cost_full = cost_mod.cost_scan(n) + cost_mod.cost_filter(n, len(preds))
        best = ("full-scan", cost_full, None)
        for i, p in enumerate(preds):
            if im is None:
                break
            idx = im.get(alias.name, p.column)
            if idx is None:
                continue
            hits = n * sels[i]
            # residual predicates point-evaluate on the picked pred's hits
            rest = (cost_mod.cost_filter(hits, len(preds) - 1)
                    if len(preds) > 1 else 0.0)
            if idx.serves(p.op):
                c = cost_mod.cost_index_lookup(n, hits) + rest
                if c < best[1]:
                    best = (idx.kind, c, i)
            frac = idx.zone_fraction(p)
            if frac is not None:
                c = cost_mod.cost_zone_scan(
                    n, frac, idx.zones.n_chunks if idx.zones else 0.0) + rest
                if c < best[1]:
                    best = ("zone", c, i)
        access, c, i = best
        if i is None:
            sel_node.access = "full-scan"
            scan.access = "full-scan"
            continue
        if access == "zone":
            new_node = ph.IndexSelect(alias.name, scan.epoch, preds, i)
        else:
            new_node = ph.IndexScan(alias.name, scan.epoch, preds, i, access)
        rebuilt = (prune.with_children(new_node) if prune is not None
                   else new_node)
        leaves[li] = alias.with_children(rebuilt)
        report.add("access-path",
                   f"{alias.name}: {access} on {preds[i]!r} "
                   f"(cost {c:.3g} < full scan {cost_full:.3g})")
    return leaves


def _annotate_match_access(root: ph.PhysicalOp, db: Database) -> None:
    """Record (as ``access=`` provenance) whether the pattern's pushed
    predicates will seed candidate sets from the graph's composite
    (label, attr) indexes at match time — mirroring the runtime check in
    ``pattern._candidate_set`` (including its MIN_INDEX_ROWS floor)."""
    mp = _find_kind(root, ph.MatchPattern)
    if mp is None or mp.pplan is None:
        return
    from . import pattern as pattern_mod
    im = getattr(db, "_index_manager", None)
    served = []
    if im is not None:
        g = db.graphs[mp.graph]
        pat = mp.pplan.pattern
        edge_vars = {e.var for e in pat.edges}
        for var, ps in sorted(mp.pplan.pushed.items()):
            label = None if var in edge_vars else pat.vertex(var).label
            tbl = g.edges if label is None else g.vertex_tables[label]
            if tbl.nrows < pattern_mod.MIN_INDEX_ROWS:
                continue    # runtime falls back to the vectorized scan
            if any((idx := im.get(mp.graph, pr.column, label=label)) is not None
                   and idx.serves(pr.op) for pr in ps):
                served.append(var)
    mp.access = f"index-seed[{','.join(served)}]" if served else "mask-scan"


def _select_match_path(root: ph.PhysicalOp, db: Database, report: OptReport,
                       cache: dict) -> ph.PhysicalOp:
    """Third access path for pattern matching: cost-compare the host matcher
    (``pattern.match``) against the device flavors — the fused Pallas chain
    (zone-filtered predicate tables, one end-of-chain sync) and the per-hop
    jit matcher — and lower the MatchPattern to a ``DeviceMatchPattern``
    when a device plan wins. Only mask-free chain patterns on settled
    (no-pending-delta) graphs qualify; the frontier-size estimate gates out
    patterns whose padded capacity would not fit the static-shape budget."""
    if not DEVICE_MATCH:
        return root
    mp = _find_kind(root, ph.MatchPattern)
    if (mp is None or mp.pplan is None or mp.children
            or not mp.pplan.pattern.edges or not mp.pplan.pattern.is_chain):
        return root
    g = db.graphs.get(mp.graph)
    if g is None or g.delta.has_pending():
        return root
    p = mp.pplan
    # peak padded-frontier estimate across hops (pre-predicate expansion —
    # the kernel's capacity must hold every candidate before compaction);
    # shared with the static plan verifier, which re-derives the same bound
    peak = cost_mod.device_frontier_peak(g, p)
    if peak > DEVICE_MAX_FRONTIER:
        report.add("access-path", f"{mp.graph}: pattern stays on host "
                   f"matcher (est peak frontier {peak:.3g} exceeds device "
                   f"budget {DEVICE_MAX_FRONTIER:.3g})")
        return root
    cap = cost_mod.padded_capacity(peak)
    cost_host = _est_cost(mp, db, cache)
    best = None
    for access in ("device-pallas", "device-jit"):
        # the node embeds the graph's *catalog* write epoch (base + lineage
        # carry), matching MatchPattern — g.epoch alone diverges after a
        # graph is replaced via db.add_graph and would collide signatures
        # across the replacement
        dm = ph.DeviceMatchPattern(mp.graph, db.epoch_of(mp.graph), p,
                                   access=access, capacity=cap)
        c = _est_cost(dm, db, cache)
        if best is None or c < best[0]:
            best = (c, dm)
    c, dm = best
    if c < cost_host:
        report.add("access-path",
                   f"{mp.graph}: {dm.access} pattern match, capacity={cap} "
                   f"(cost {c:.3g} < host {cost_host:.3g})")
        return _replace(root, {id(mp): dm})
    report.add("access-path", f"{mp.graph}: pattern stays on host matcher "
               f"(cost {cost_host:.3g} <= device {c:.3g})")
    return root


def _needed_columns(q, coll: str, residual: list) -> set:
    """Bare column names of ``coll`` referenced above its scan."""
    need: set = set()
    for a in q.select:
        c, _, col = a.partition(".")
        if c == coll and col:
            need.add(col)
    for jp in q.joins:
        for side in (jp.left, jp.right):
            c, _, col = side.partition(".")
            if c == coll and col:
                need.add(col)
    for pred in residual:
        if pred.collection == coll:
            need.add(pred.column)
    return need


def _prune_columns(leaves: list, db: Database, q, residual: list,
                   report: OptReport) -> list:
    leaves = list(leaves)
    for li, leaf in enumerate(leaves):
        alias = _table_leaf(leaf)
        if alias is None or alias.name not in db.tables:
            continue
        have = set(db.tables[alias.name].columns)
        need = _needed_columns(q, alias.name, residual) & have
        if not need or need >= have:
            continue
        pruned = ph.PruneCols(alias.children[0], tuple(sorted(need)))
        leaves[li] = alias.with_children(pruned)
        # with_children carried the full-table out_cols over — narrow the
        # annotation to the surviving columns or downstream passes (and the
        # verifier's V-ANN check) see a stale schema
        leaves[li].out_cols = frozenset(f"{alias.name}.{c}" for c in need)
        report.add("prune", f"{alias.name}: keep {sorted(need)} "
                            f"of {len(have)} column(s)")
    return leaves


# ---------------------------------------------------------------------------
# Semi-join siding (Eq. 8 -> 9/10), enumerated jointly with the join order
# ---------------------------------------------------------------------------

SIDINGS = ("post", "mask", "reduce")


def _siding_candidates(leaves: list, db: Database, p) -> list[dict]:
    """Resolve each Eq. 9/10 candidate graph↔table join to its leaves: the
    table leaf to reduce / feed the mask from, and the pattern var to mask."""
    q = p.query
    pattern = q.match
    vset = {v.var for v in pattern.vertices}
    graph_i = next((i for i, l in enumerate(leaves)
                    if _find_kind(l, ph.MatchPattern) is not None), None)
    if graph_i is None:
        return []
    out: list[dict] = []
    for i in sorted(p.semi_join_idx):
        jp = q.joins[i]
        side = _graph_join_side(q, vset, jp)
        if side is None:
            continue
        tbl_attr, var_attr = side
        tcoll, tcol = tbl_attr.split(".", 1)
        vvar, vcol = var_attr.split(".", 1)
        tbl_i = next((ti for ti, l in enumerate(leaves)
                      if _table_leaf(l) is not None
                      and _table_leaf(l).name == tcoll), None)
        if tbl_i is None:
            continue
        out.append({"pos": len(out), "i": i, "jp": jp, "vvar": vvar,
                    "vcol": vcol, "tcoll": tcoll, "tcol": tcol,
                    "label": pattern.vertex(vvar).label,
                    "graph_i": graph_i, "tbl_i": tbl_i})
    return out


def _apply_siding(leaves: list, cands: list, config: tuple, db: Database,
                  p) -> list:
    """Build the leaf set for one siding configuration. Mask children are
    the *same* table subtree objects that feed the final equi-joins, so the
    dedup-aware cumulative cost (and later CSE) charges them once."""
    if not cands:
        return leaves
    leaves_v = list(leaves)
    pattern = p.query.match
    gname = pattern.graph
    gep = db.epoch_of(gname)
    orig_subtrees = {c["tbl_i"]: leaves[c["tbl_i"]].children[0]
                     for c in cands}
    masks: list[tuple[str, ph.PhysicalOp]] = []
    for cand, choice in zip(cands, config):
        if choice == "mask":
            mask = ph.SemiJoinMask(gname, gep, cand["label"], cand["vcol"],
                                   cand["tcol"], orig_subtrees[cand["tbl_i"]])
            mask.ocol_src = ("table", cand["tcoll"], cand["tcol"])
            masks.append((cand["vvar"], mask))
        elif choice == "reduce":
            alias = leaves_v[cand["tbl_i"]]
            reduce_node = ph.SemiJoinReduce(gname, gep, cand["label"],
                                            cand["vcol"], cand["tcol"],
                                            alias.children[0])
            reduce_node.ocol_src = ("table", cand["tcoll"], cand["tcol"])
            leaves_v[cand["tbl_i"]] = alias.with_children(reduce_node)
    if masks:
        gi = cands[0]["graph_i"]
        mp = _find_kind(leaves_v[gi], ph.MatchPattern)
        mp_new = mp.with_children(*mp.children, *(m for _, m in masks))
        mp_new.mask_vars = tuple(mp.mask_vars) + tuple(v for v, _ in masks)
        leaves_v[gi] = _replace(leaves_v[gi], {id(mp): mp_new})
    return leaves_v


# ---------------------------------------------------------------------------
# Join enumeration: Selinger DP over connected subsets (bushy), greedy
# fallback for large join graphs
# ---------------------------------------------------------------------------


def _enumerate_joins(leaves: list, db: Database, q, pattern, residual: list,
                     cache: dict, join_enum: str
                     ) -> tuple[ph.PhysicalOp, Optional[list], bool]:
    """Re-merge the join clusters. Returns ``(root, order, bushy)`` where
    ``order`` is the applied join-predicate sequence (None when nothing was
    enumerated) and ``bushy`` flags a tree with composite inputs on both
    sides of some join."""
    clusters = [{"node": leaf, "cols": set(_leaf_cols(leaf)),
                 "rows": _est_rows(leaf, db, cache)} for leaf in leaves]
    pending = [(i, jp, (ph._key_source(q, pattern, jp.left),
                        ph._key_source(q, pattern, jp.right)))
               for i, jp in enumerate(q.joins)]
    order: list[int] = []

    def find(attr: str) -> Optional[int]:
        for ci, c in enumerate(clusters):
            if ph._static_has_col(c["cols"], attr):
                return ci
        return None

    def apply_intra(ci: int) -> None:
        """Fold every pending predicate now internal to cluster ``ci``."""
        for item in list(pending):
            i, jp, ks = item
            li, ri = find(jp.left), find(jp.right)
            if li == ri == ci:
                node = ph.IntraFilter(jp, clusters[ci]["node"])
                node.key_src = ks
                ls, rs = (ph.resolve_key_stats(db, src) for src in ks)
                clusters[ci]["node"] = node
                clusters[ci]["rows"] = ph.est_intra_filter_rows(
                    clusters[ci]["rows"], ls, rs)
                pending.remove(item)
                order.append(i)

    for ci in range(len(clusters)):
        apply_intra(ci)

    if pending and join_enum != "greedy" and len(clusters) <= MAX_DP_RELATIONS:
        return _dp_joins(clusters, pending, db, q, residual, cache, order,
                         leftdeep=(join_enum == "dp-leftdeep"))
    return _greedy_joins(clusters, pending, db, q, residual, cache, order,
                         find, apply_intra)


def _greedy_joins(clusters, pending, db, q, residual, cache, order,
                  find, apply_intra) -> tuple[ph.PhysicalOp, list, bool]:
    """Greedy smallest-intermediate-first re-merge of the join clusters —
    the fallback above :data:`MAX_DP_RELATIONS` (and ``join_enum="greedy"``)."""
    while pending:
        best = None
        for item in pending:
            i, jp, ks = item
            li, ri = find(jp.left), find(jp.right)
            if li is None or ri is None or li == ri:
                continue
            ls, rs = (ph.resolve_key_stats(db, s) for s in ks)
            est = ph.est_join_rows(clusters[li]["rows"], clusters[ri]["rows"],
                                   ls, rs)
            if best is None or (est, i) < (best[0], best[1]):
                best = (est, i, item, li, ri)
        if best is None:
            break   # remaining predicates span unreachable clusters
        est, i, item, li, ri = best
        _, jp, ks = item
        pending.remove(item)
        lc, rc = clusters[li], clusters[ri]
        # build-side selection: the smaller estimated input becomes the
        # right (sorted/build) side of the sort-merge equi-join
        if lc["rows"] < rc["rows"]:
            jp = type(jp)(jp.right, jp.left)
            ks = (ks[1], ks[0])
            lc, rc = rc, lc
        join = ph.EquiJoin(jp, lc["node"], rc["node"])
        join.key_src = ks
        keep, drop = min(li, ri), max(li, ri)
        clusters[keep] = {"node": join, "cols": lc["cols"] | rc["cols"],
                          "rows": est}
        del clusters[drop]
        order.append(i)
        apply_intra(keep)

    if len(clusters) > 1:
        # same covering rule as the builder, including its loud failure on a
        # genuinely disconnected query — clusters are never dropped silently
        current = ph.pick_connected_cluster(
            [(c["node"], c["cols"]) for c in clusters],
            list(q.select) + [pr.attr for pr in residual])
    else:
        current = clusters[0]["node"]
    return current, order, False


def _dp_joins(clusters, pending, db, q, residual, cache, order,
              leftdeep: bool) -> tuple[ph.PhysicalOp, list, bool]:
    """Selinger-style DP over connected subsets of the join graph. Each
    subset keeps its cheapest plan; splits without a connecting predicate
    are skipped (no cross products), so only *connected* subsets fill in —
    a genuinely disconnected query falls back to the builder's covering
    rule per component. With ``leftdeep`` the splits are restricted to
    (composite, single-leaf), which yields the best left-deep plan — the
    baseline the bushy enumerator is measured against."""
    n = len(clusters)

    def leaf_of(attr: str) -> Optional[int]:
        for ci, c in enumerate(clusters):
            if ph._static_has_col(c["cols"], attr):
                return ci
        return None

    edges = []          # (pred idx, jp, key_src, left leaf, right leaf)
    for (i, jp, ks) in pending:
        li, ri = leaf_of(jp.left), leaf_of(jp.right)
        if li is None or ri is None or li == ri:
            continue    # unresolvable predicate: same outcome as greedy
        edges.append((i, jp, ks, li, ri))

    best: dict[int, dict] = {}
    for ci, c in enumerate(clusters):
        best[1 << ci] = {"node": c["node"], "rows": c["rows"],
                         "cost": _est_cost(c["node"], db, cache),
                         "cols": c["cols"], "joins": (), "bushy": False}

    full = (1 << n) - 1
    for mask in range(3, full + 1):
        if mask & (mask - 1) == 0:
            continue                        # singleton
        low = mask & -mask
        # canonical split walk: s1 always contains the lowest bit of mask,
        # so each unordered (s1, s2) pair is visited exactly once
        s1 = (mask - 1) & mask
        while s1:
            s2 = mask ^ s1
            if (s1 & low) and (not leftdeep
                               or bin(s1).count("1") == 1
                               or bin(s2).count("1") == 1):
                e1, e2 = best.get(s1), best.get(s2)
                if e1 is not None and e2 is not None:
                    conn = [(i, jp, ks, li, ri) for (i, jp, ks, li, ri)
                            in edges
                            if ((1 << li) & s1 and (1 << ri) & s2)
                            or ((1 << ri) & s1 and (1 << li) & s2)]
                    if conn:
                        cand = _join_entry(e1, e2, conn, s1, s2, db, cache)
                        if mask not in best \
                                or cand["cost"] < best[mask]["cost"]:
                            best[mask] = cand
            s1 = (s1 - 1) & mask

    if full in best:
        entry = best[full]
        return entry["node"], order + list(entry["joins"]), entry["bushy"]

    # disconnected join graph: resolve each connected component, then keep
    # the component covering the projection (builder's loud covering rule)
    comps = _components(n, edges)
    parts = []
    for comp in comps:
        entry = best.get(comp)
        if entry is not None:
            parts.append((entry["node"], entry["cols"]))
    current = ph.pick_connected_cluster(
        parts, list(q.select) + [pr.attr for pr in residual])
    for comp in comps:
        entry = best.get(comp)
        if entry is not None and entry["node"] is current:
            order = order + list(entry["joins"])
    return current, order, any(best[c]["bushy"] for c in comps if c in best)


def _join_entry(e1: dict, e2: dict, conn: list, s1: int, s2: int,
                db: Database, cache: dict) -> dict:
    """Combine two DP entries across their connecting predicates: the most
    selective predicate becomes the EquiJoin, the rest fold in as
    IntraFilters on top (exactly what the executor runs)."""
    cands = []
    for (i, jp, ks, li, ri) in conn:
        if not ((1 << li) & s1):            # orient: left attr lives in e1
            jp = type(jp)(jp.right, jp.left)
            ks = (ks[1], ks[0])
        ls, rs = (ph.resolve_key_stats(db, s) for s in ks)
        est = ph.est_join_rows(e1["rows"], e2["rows"], ls, rs)
        cands.append((est, i, jp, ks))
    cands.sort(key=lambda t: (t[0], t[1]))
    est, i0, jp0, ks0 = cands[0]
    l, r = e1, e2
    if l["rows"] < r["rows"]:               # build side = smaller input
        jp0 = type(jp0)(jp0.right, jp0.left)
        ks0 = (ks0[1], ks0[0])
        l, r = r, l
    node = ph.EquiJoin(jp0, l["node"], r["node"])
    node.key_src = ks0
    rows = est
    cost = e1["cost"] + e2["cost"] + cost_mod.cost_join(l["rows"], r["rows"])
    applied = [i0]
    for (_, i, jp, ks) in sorted(cands[1:], key=lambda t: t[1]):
        node = ph.IntraFilter(jp, node)
        node.key_src = ks
        ls2, rs2 = (ph.resolve_key_stats(db, src) for src in ks)
        cost += cost_mod.cost_filter(rows)
        rows = ph.est_intra_filter_rows(rows, ls2, rs2)
        applied.append(i)
    return {"node": node, "rows": rows, "cost": cost,
            "cols": e1["cols"] | e2["cols"],
            "joins": e1["joins"] + e2["joins"] + tuple(applied),
            "bushy": (e1["bushy"] or e2["bushy"]
                      or (bin(s1).count("1") > 1 and bin(s2).count("1") > 1))}


def _components(n: int, edges: list) -> list[int]:
    """Connected components of the leaf join graph, as bitmasks."""
    parent = list(range(n))

    def root(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for (_, _, _, li, ri) in edges:
        parent[root(li)] = root(ri)
    comps: dict[int, int] = {}
    for i in range(n):
        comps[root(i)] = comps.get(root(i), 0) | (1 << i)
    return list(comps.values())


# ---------------------------------------------------------------------------
# Greedy per-candidate siding (fallback above MAX_SIDING_ENUM candidates)
# ---------------------------------------------------------------------------


def _side_semi_joins(leaves: list, db: Database, p, report: OptReport,
                     cache: dict) -> list:
    """Eq. 8 -> 9/10 with cost-based *siding*, one candidate at a time: per
    candidate graph↔table join, compare (A) post-match join only, (B)
    graph-side candidate mask, (C) table-side reduction by vertex keys —
    apply the cheapest. (The joint enumeration in ``_optimize_gcdi`` covers
    the common small-candidate case; this pass is its scalable fallback.)"""
    pattern = p.query.match
    gep = db.epoch_of(pattern.graph)
    leaves = list(leaves)

    for cand in _siding_candidates(leaves, db, p):
        i, jp = cand["i"], cand["jp"]
        vvar, vcol = cand["vvar"], cand["vcol"]
        tcoll, tcol = cand["tcoll"], cand["tcol"]
        label = cand["label"]
        graph_i, tbl_i = cand["graph_i"], cand["tbl_i"]
        alias = leaves[tbl_i]
        tbl_subtree = alias.children[0]
        mp = _find_kind(leaves[graph_i], ph.MatchPattern)

        def mp_cost_excl_tables(node: ph.MatchPattern) -> float:
            """Match cost with every mask's *table* subtree excluded — the
            table scans execute once under any siding (they feed the final
            equi-joins regardless), so no option gets charged for them."""
            c = _est_cost(node, db, cache)
            seen: set = set()
            for m in node.children:
                t = m.children[0]
                if id(t) not in seen:   # shared subtrees are counted once
                    seen.add(id(t))
                    c -= _est_cost(t, db, cache)
            return c

        n_t = _est_rows(tbl_subtree, db, cache)
        est_match = _est_rows(mp, db, cache)

        # (A) keep the post-match equi-join
        cost_a = mp_cost_excl_tables(mp) + cost_mod.cost_join(est_match, n_t)

        # (B) graph-side mask shrinking the candidate vertex set
        mask = ph.SemiJoinMask(pattern.graph, gep, label, vcol, tcol,
                               tbl_subtree)
        mask.ocol_src = ("table", tcoll, tcol)
        mp_b = mp.with_children(*mp.children, mask)
        mp_b.mask_vars = tuple(mp.mask_vars) + (vvar,)
        est_match_b = _est_rows(mp_b, db, cache)
        cost_b = (mp_cost_excl_tables(mp_b)
                  + cost_mod.cost_join(est_match_b, n_t))

        # (C) table-side reduction by the vertex keys
        reduce_node = ph.SemiJoinReduce(pattern.graph, gep, label, vcol,
                                        tcol, tbl_subtree)
        reduce_node.ocol_src = ("table", tcoll, tcol)
        n_t_c = _est_rows(reduce_node, db, cache)
        cost_c = (mp_cost_excl_tables(mp)
                  + _est_cost(reduce_node, db, cache)
                  - _est_cost(tbl_subtree, db, cache)
                  + cost_mod.cost_join(est_match, n_t_c))

        best = min(cost_a, cost_b, cost_c)
        if best == cost_b:
            leaves[graph_i] = _replace(leaves[graph_i], {id(mp): mp_b})
            report.add("semi-join", f"join#{i} ({jp}): graph-side mask on "
                       f"{vvar} — cost {cost_b:.3g} < post-match {cost_a:.3g}")
        elif best == cost_c:
            leaves[tbl_i] = alias.with_children(reduce_node)
            report.add("semi-join", f"join#{i} ({jp}): table-side reduce of "
                       f"{tcoll} — cost {cost_c:.3g} < post-match {cost_a:.3g}")
        else:
            report.add("semi-join", f"join#{i} ({jp}): kept post-match "
                       f"(cost {cost_a:.3g} <= {min(cost_b, cost_c):.3g})")
    return leaves
