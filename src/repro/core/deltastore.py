"""LSM-style delta store for graph mutations (write-path subsystem).

The paper's staged insertion protocol (§4.4) keeps record and topology
storage consistent, but a naive implementation pays a full O(V+E) topology
rebuild per write batch. This module absorbs mutations in side structures so
that every write is O(batch):

* pending vertex rows are buffered per label (columnar run lists);
* pending edges become immutable :class:`EdgeSegment` sorted runs — small
  delta-CSR segments, one per insert batch, queried by binary search
  (forward and reverse);
* deleted edges are tombstoned in a bitmap over the edge-tid space.

Reads are *base ⊕ delta*: the owning :class:`~repro.core.storage.Graph`
consults its base CSRs plus every delta segment, minus tombstones
(``Graph.expand``), and merges pending record runs into its tables lazily
(cached until the next write). A size/cost-triggered :meth:`Graph.compact`
folds the delta into a fresh base — the only place a full rebuild remains,
now amortized over many batches (the memtable/sorted-run design of LSM
engines, adapted to CSR topology storage).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from .storage import Graph


# ---------------------------------------------------------------------------
# Write-path cost accounting (consumed by benchmarks/update_bench.py)
# ---------------------------------------------------------------------------


WRITE_COUNTER_FIELDS = ("write_batches", "write_rows", "write_ops",
                        "compactions", "compact_ops")


class WriteCounters:
    """Elementary-op counters separating the O(batch) write path from the
    amortized O(V+E) compaction work, so benchmarks/tests can assert that the
    hot path never performs rebuild-scale work. Each :class:`~repro.core.
    storage.Graph` owns one (``graph.write_counters``); the engine registers
    them into its telemetry registry as pull sources via :meth:`metrics`."""

    def __init__(self):
        self.write_batches = 0
        self.write_rows = 0
        self.write_ops = 0      # ops charged on insert/delete (O(batch log batch))
        self.compactions = 0
        self.compact_ops = 0    # ops charged by compaction (O(V+E))

    def bump(self, **ops) -> None:
        for k, v in ops.items():
            setattr(self, k, getattr(self, k) + v)

    def metrics(self) -> dict:
        return {f: getattr(self, f) for f in WRITE_COUNTER_FIELDS}

    def reset(self):
        self.__init__()


# Write counters live per graph (``Graph.write_counters``); engines expose
# them through the registry as ``deltastore.<graph>.<field>``. The former
# process-global ``WRITE_COUNTERS`` alias is gone — it leaked state across
# Database instances and tests.


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeltaConfig:
    """Compaction policy knobs. A compaction triggers when any bound is
    exceeded after a write (checked in O(1))."""

    min_delta_edges: int = 4096       # floor before the ratio trigger applies
    max_delta_ratio: float = 0.25     # delta edges vs base edges
    max_segments: int = 64            # sorted runs before forced merge
    max_tombstone_frac: float = 0.25  # dead fraction of the edge-tid space
    max_delta_vertices: int = 8192
    auto_compact: bool = True


# ---------------------------------------------------------------------------
# Growable arrays (amortized O(1) append; views are O(1))
# ---------------------------------------------------------------------------


class Growable:
    """Capacity-doubling 1-D array. ``view()`` returns the live prefix; views
    are invalidated by the next reallocating ``append`` (callers re-fetch)."""

    __slots__ = ("_arr", "n")

    def __init__(self, arr: np.ndarray):
        self._arr = np.asarray(arr)
        self.n = len(self._arr)

    def append(self, vals) -> None:
        vals = np.asarray(vals, dtype=self._arr.dtype)
        need = self.n + len(vals)
        if need > len(self._arr):
            cap = max(need, 2 * len(self._arr), 16)
            grown = np.empty(cap, dtype=self._arr.dtype)
            grown[:self.n] = self._arr[:self.n]
            self._arr = grown
        self._arr[self.n:need] = vals
        self.n = need

    def view(self) -> np.ndarray:
        return self._arr[:self.n]

    def __len__(self):
        return self.n


def expand_runs(starts, counts) -> tuple[np.ndarray, np.ndarray]:
    """Expand per-row runs ``[starts[i], starts[i]+counts[i])`` into flat
    slot indices. Returns ``(pos, slots)``: ``pos[j]`` is the row the j-th
    output belongs to, ``slots[j]`` its global slot. The shared core of CSR
    frontier expansion, segment probes, and sort-merge join run expansion."""
    counts = np.asarray(counts)
    total = int(counts.sum())
    pos = np.repeat(np.arange(len(counts)), counts)
    out_off = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=out_off[1:])
    slots = np.repeat(np.asarray(starts), counts) + (
        np.arange(total) - np.repeat(out_off[:-1], counts))
    return pos, slots


# ---------------------------------------------------------------------------
# Edge segments: immutable sorted runs (the delta-CSR building block)
# ---------------------------------------------------------------------------


class EdgeSegment:
    """One insert batch as an immutable run, sorted twice: by source nid
    (forward adjacency) and by target nid (reverse). ``neighbors`` answers a
    whole-frontier expansion with two binary searches + a run expansion —
    O(|frontier| log |segment| + output)."""

    __slots__ = ("src_key", "src_dst", "src_eid", "dst_key", "dst_src", "dst_eid")

    def __init__(self, src_nid: np.ndarray, dst_nid: np.ndarray, eid: np.ndarray):
        src_nid = np.asarray(src_nid, dtype=np.int64)
        dst_nid = np.asarray(dst_nid, dtype=np.int64)
        eid = np.asarray(eid, dtype=np.int64)
        order = np.argsort(src_nid, kind="stable")
        self.src_key = src_nid[order]
        self.src_dst = dst_nid[order]
        self.src_eid = eid[order]
        rorder = np.argsort(dst_nid, kind="stable")
        self.dst_key = dst_nid[rorder]
        self.dst_src = src_nid[rorder]
        self.dst_eid = eid[rorder]

    def __len__(self):
        return len(self.src_key)

    def neighbors(self, frontier: np.ndarray, reverse: bool = False
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (pos, dst, eid) where ``pos`` indexes into ``frontier``."""
        if reverse:
            key, val, eid = self.dst_key, self.dst_src, self.dst_eid
        else:
            key, val, eid = self.src_key, self.src_dst, self.src_eid
        lo = np.searchsorted(key, frontier, side="left")
        hi = np.searchsorted(key, frontier, side="right")
        pos, slots = expand_runs(lo, hi - lo)
        return pos, val[slots], eid[slots]

    def range_view(self, lo: int, hi: int, reverse: bool = False
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Zero-copy sub-run of edges whose (source, or target when
        ``reverse``) nid lies in ``[lo, hi)`` — the per-partition view of
        this delta segment. Both sort orders are precomputed, so a
        partition's slice is two binary searches; returns
        ``(key, other_endpoint, eid)`` views into the sorted run."""
        if reverse:
            key, val, eid = self.dst_key, self.dst_src, self.dst_eid
        else:
            key, val, eid = self.src_key, self.src_dst, self.src_eid
        a = int(np.searchsorted(key, lo, side="left"))
        b = int(np.searchsorted(key, hi, side="left"))
        return key[a:b], val[a:b], eid[a:b]


# ---------------------------------------------------------------------------
# The per-graph delta store
# ---------------------------------------------------------------------------


class GraphDelta:
    """Pending mutations of one :class:`Graph` since its last compaction.

    Record side: per-label vertex runs + edge-row runs (merged lazily into
    the graph's table views). Topology side: :class:`EdgeSegment` runs plus a
    tombstone bitmap over the edge-tid space. New vertices receive nids
    appended after the base nid space (the base label-block layout is only
    restored by compaction, which re-sorts labels into contiguous blocks).
    """

    def __init__(self, n_base_edges: int):
        self.vertex_rows: dict[str, dict[str, list]] = {}  # label -> col -> [runs]
        self.n_new_vertices: dict[str, int] = {}
        self.new_nids: dict[str, Growable] = {}            # label -> nids of new vertices
        self.segments: list[EdgeSegment] = []
        self.edge_rows: dict[str, list] = {}               # col -> [runs]
        self.n_new_edges = 0
        self.tombstone = Growable(np.zeros(n_base_edges, dtype=bool))
        self.n_tombstones = 0

    # ---- vertex side ----
    def buffer_vertices(self, label: str, columns: dict[str, np.ndarray],
                        nids: np.ndarray) -> None:
        runs = self.vertex_rows.setdefault(label, {})
        for k, v in columns.items():
            runs.setdefault(k, []).append(v)
        self.n_new_vertices[label] = self.n_new_vertices.get(label, 0) + len(nids)
        if label not in self.new_nids:
            self.new_nids[label] = Growable(np.zeros(0, dtype=np.int64))
        self.new_nids[label].append(nids)

    def label_new_nids(self, label: str) -> Optional[np.ndarray]:
        g = self.new_nids.get(label)
        return g.view() if g is not None and g.n else None

    @property
    def n_new_vertices_total(self) -> int:
        return sum(self.n_new_vertices.values())

    # ---- edge side ----
    def buffer_edges(self, columns: dict[str, np.ndarray],
                     segment: EdgeSegment) -> None:
        for k, v in columns.items():
            self.edge_rows.setdefault(k, []).append(v)
        self.segments.append(segment)
        self.n_new_edges += len(segment)
        self.tombstone.append(np.zeros(len(segment), dtype=bool))

    def tombstone_edges(self, edge_tids: np.ndarray) -> int:
        tids = np.unique(np.asarray(edge_tids))  # dedupe: count each tid once
        t = self.tombstone.view()
        fresh = int((~t[tids]).sum())
        t[tids] = True
        self.n_tombstones += fresh
        return fresh

    def live_mask_for(self, eids: np.ndarray) -> np.ndarray:
        return ~self.tombstone.view()[eids]

    def live_edge_mask(self) -> np.ndarray:
        return ~self.tombstone.view()

    # ---- bookkeeping ----
    def has_pending(self) -> bool:
        return bool(self.segments or self.n_tombstones
                    or any(self.n_new_vertices.values()))

    def stats(self) -> dict:
        return {
            "segments": len(self.segments),
            "delta_edges": self.n_new_edges,
            "delta_vertices": self.n_new_vertices_total,
            "tombstones": self.n_tombstones,
        }


def should_compact(cfg: DeltaConfig, delta: GraphDelta, n_base_edges: int) -> bool:
    if not cfg.auto_compact:
        return False
    if len(delta.segments) > cfg.max_segments:
        return True
    if delta.n_new_edges > max(cfg.min_delta_edges,
                               cfg.max_delta_ratio * max(n_base_edges, 1)):
        return True
    total_e = n_base_edges + delta.n_new_edges
    if total_e and delta.n_tombstones > cfg.max_tombstone_frac * total_e:
        return True
    if delta.n_new_vertices_total > cfg.max_delta_vertices:
        return True
    return False


# ---------------------------------------------------------------------------
# Incremental merged record views: capacity-doubling column buffers
# ---------------------------------------------------------------------------


def _promote(buf: Growable, run_dtype) -> Growable:
    """Dtype promotion (e.g. a float batch into an int column): one O(n)
    re-seed, matching np.concatenate semantics — Growable.append alone would
    silently cast/truncate to the buffer dtype."""
    promoted = np.result_type(buf.view().dtype, run_dtype)
    if promoted != buf.view().dtype:
        return Growable(buf.view().astype(promoted))
    return buf


class ColumnMerger:
    """Incremental base ⊕ runs merge for one column. The base is wrapped
    (O(1)); the first append pays one O(base) copy into a capacity-doubling
    buffer; every later write/read cycle appends only the unseen run tail —
    the merge is O(delta), not O(base), per cycle."""

    def __init__(self, base):
        from .storage import DictColumn, RaggedColumn, compute_stats
        self.n_runs = 0
        if isinstance(base, DictColumn):
            self.kind = "dict"
            self.codes = Growable(base.codes)
            self.vocab = Growable(np.asarray(list(base.vocab), dtype=object))
            self.index = {v: i for i, v in enumerate(base.vocab)}
            self.counts = Growable(np.bincount(
                base.codes, minlength=len(base.vocab)).astype(np.int64))
        elif isinstance(base, RaggedColumn):
            self.kind = "ragged"
            self.values = Growable(np.asarray(base.values))
            self.offsets = Growable(np.asarray(base.offsets, dtype=np.int64))
        else:
            self.kind = "array"
            self.buf = Growable(np.asarray(base))
            arr = np.asarray(base)
            # incremental §6.3 stats ride along for numeric columns: each
            # absorbed run extends min/max/histogram/MCV counts/NDV in
            # O(batch), so the optimizer sees fresh statistics — including
            # the histogram-overlap join model (ColumnStats.join_overlap),
            # whose bucket/MCV inputs these are — without an O(base)
            # recompute
            self.stats = compute_stats(arr) if arr.dtype.kind in "ifu" else None

    def absorb(self, runs: list) -> None:
        """Fold runs[n_absorbed:] into the buffers (the delta tail only)."""
        from .storage import encode_batch
        for r in runs[self.n_runs:]:
            if self.kind == "dict":
                vals = np.asarray(r, dtype=object).tolist()
                new_codes, fresh = encode_batch(vals, self.index, self.vocab.n)
                if fresh:
                    self.vocab.append(np.asarray(fresh, dtype=object))
                    self.counts.append(np.zeros(len(fresh), dtype=np.int64))
                np.add.at(self.counts.view(), new_codes, 1)
                self.codes.append(new_codes)
            elif self.kind == "ragged":
                rows = [np.asarray(row) for row in r]
                last = int(self.offsets.view()[-1])
                lens = np.asarray([len(row) for row in rows], dtype=np.int64)
                self.offsets.append(last + np.cumsum(lens))
                if len(rows):
                    tail = np.concatenate(rows) if len(rows) > 1 else rows[0]
                    self.values = _promote(self.values, tail.dtype)
                    self.values.append(tail)
            else:
                run = np.asarray(r)
                self.buf = _promote(self.buf, run.dtype)
                self.buf.append(run)
                if self.stats is not None and run.dtype.kind in "ifu":
                    self.stats.extend_numeric(run)
                else:
                    self.stats = None   # non-numeric append: fall back to lazy
        self.n_runs = len(runs)

    def stats_view(self):
        """Current ColumnStats maintained across absorbs (dict columns
        rebuild exact MCV counts from the incrementally-kept per-code
        totals; numeric columns carry the extended histogram/MCV object),
        or None when the column kind falls back to lazy recomputation
        (ragged columns). These are the distributions the optimizer's
        ``join_overlap`` estimates read, so merged base ⊕ delta views keep
        distribution-aware join cardinalities current per append."""
        from .storage import dict_stats
        if self.kind == "dict":
            return dict_stats(self.codes.n, self.vocab.view(),
                              self.counts.view())
        if self.kind == "array":
            return self.stats
        return None

    def view(self):
        from .storage import DictColumn, RaggedColumn
        if self.kind == "dict":
            return DictColumn(codes=self.codes.view(), vocab=self.vocab.view())
        if self.kind == "ragged":
            return RaggedColumn(values=self.values.view(),
                                offsets=self.offsets.view())
        return self.buf.view()


class TableMerger:
    """Incremental base ⊕ delta view of one record table. ``table(runs)``
    absorbs only runs appended since the last call and returns a (cached)
    merged Table — alternating single-batch writes with record reads no
    longer re-pay an O(base) concat per cycle."""

    def __init__(self, base_table):
        self.name = base_table.name
        self.mergers = {k: ColumnMerger(c) for k, c in base_table.columns.items()}
        self._cached = None
        self._cached_runs = -1

    def table(self, runs: dict[str, list]):
        from .storage import Table
        n_runs = max((len(r) for r in runs.values()), default=0)
        if self._cached is not None and n_runs == self._cached_runs:
            return self._cached
        for k, m in self.mergers.items():
            m.absorb(runs.get(k, []))
        self._cached = Table(self.name,
                             {k: m.view() for k, m in self.mergers.items()})
        # hand the incrementally-maintained stats to the merged view, so
        # Table.stats() on a base ⊕ delta table is O(1) instead of O(rows)
        for k, m in self.mergers.items():
            s = m.stats_view()
            if s is not None:
                self._cached._stats[k] = s
        self._cached_runs = n_runs
        return self._cached


# ---------------------------------------------------------------------------
# Column-run merging (shared by the lazy table views and compaction)
# ---------------------------------------------------------------------------


def concat_column(base, runs: list):
    """Merge a base column with pending runs of the same column. Dictionary
    columns extend their vocabulary incrementally (no decode + re-unique of
    existing rows); ragged runs are lists-of-lists; plain arrays concatenate."""
    from .storage import DictColumn, RaggedColumn  # local import (cycle)

    if isinstance(base, DictColumn):
        new_vals: list = []
        for r in runs:
            new_vals.extend(np.asarray(r, dtype=object).tolist())
        return base.append(new_vals)
    if isinstance(base, RaggedColumn):
        tail = RaggedColumn(lists=[np.asarray(row) for r in runs for row in r])
        values = (np.concatenate([base.values, tail.values])
                  if len(tail.values) else base.values)
        offsets = np.concatenate([base.offsets, base.offsets[-1] + tail.offsets[1:]])
        return RaggedColumn(values=values, offsets=offsets)
    # plain arrays: let numpy promote dtypes (int64 base + float run ->
    # float64), matching what the pre-delta insert path did — casting runs
    # to the base dtype would silently truncate inserted values
    return np.concatenate([np.asarray(base)] + [np.asarray(r) for r in runs])
