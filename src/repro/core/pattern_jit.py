"""Device-resident (jit-able) pattern matching: fixed-capacity padded
frontiers with overflow-detect-and-retry (the DESIGN §2 static-shape
adaptation — the TPU analogue of buffer-pool spill).

The host engine (core.pattern) is the system of record; this module is the
accelerator path: a one-hop-at-a-time frontier expansion where every array
has a static capacity, compiled once per (capacity, graph-shape) and reused
across queries. The planner's cardinality estimates choose the initial
capacity; on overflow the wrapper doubles and re-runs (amortized O(1)
recompiles thanks to power-of-two capacities).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .storage import Graph


@functools.partial(jax.jit, static_argnames=("capacity",))
def expand_frontier(row_ptr: jax.Array, col_idx: jax.Array,
                    edge_id: jax.Array, frontier: jax.Array,
                    frontier_mask: jax.Array, member: jax.Array,
                    edge_mask: jax.Array, *, capacity: int):
    """One hybrid-traversal hop on device.

    frontier: (C,) padded nids; member/edge_mask: boolean tables over nids /
    edge tids (the pushed predicates). Returns (src_slot, dst, eid, mask,
    overflowed): all (capacity,) padded outputs where ``src_slot`` indexes
    into the INPUT frontier (so callers can join path prefixes).
    """
    C = frontier.shape[0]
    deg = jnp.where(frontier_mask,
                    row_ptr[frontier + 1] - row_ptr[frontier], 0)
    out_off = jnp.cumsum(deg) - deg                     # exclusive prefix sum
    total = jnp.sum(deg)
    overflowed = total > capacity

    # slot i of the output belongs to the frontier entry whose out_off range
    # covers i: searchsorted over the (sorted) offsets
    slots = jnp.arange(capacity)
    src_slot = jnp.clip(
        jnp.searchsorted(out_off, slots, side="right") - 1, 0, C - 1)
    within = slots - out_off[src_slot]
    valid = slots < jnp.minimum(total, capacity)
    src_nid = frontier[src_slot]
    pos = jnp.clip(row_ptr[src_nid] + within, 0, col_idx.shape[0] - 1)
    dst = col_idx[pos].astype(jnp.int32)
    eid = edge_id[pos].astype(jnp.int32)
    valid &= member[jnp.clip(dst, 0, member.shape[0] - 1)]
    valid &= edge_mask[jnp.clip(eid, 0, edge_mask.shape[0] - 1)]
    return src_slot, dst, eid, valid, overflowed


class DevicePatternMatcher:
    """Chain-pattern matching fully on device with capacity retry."""

    def __init__(self, g: Graph, initial_capacity: int = 1 << 12,
                 max_capacity: int = 1 << 26):
        if g.delta.has_pending():
            # the device snapshot reads base CSRs only; compacting here
            # would silently renumber edge tids under the caller's feet
            raise ValueError(
                f"graph {g.name!r} has pending delta writes; call "
                "g.compact() before building a DevicePatternMatcher")
        self.g = g
        self.row_ptr = jnp.asarray(g.fwd.row_ptr)
        self.col_idx = jnp.asarray(g.fwd.col_idx)
        self.edge_id = jnp.asarray(g.fwd.edge_id)
        self.initial_capacity = initial_capacity
        self.max_capacity = max_capacity
        self.recompiles = 0

    def match_chain(self, start_nids: np.ndarray,
                    vertex_members: list[Optional[np.ndarray]],
                    edge_masks: list[Optional[np.ndarray]]):
        """vertex_members[h]: bool table over nids for hop-h target (None =
        label-unconstrained); edge_masks[h] likewise over edge tids.
        Returns (columns, masks): per-hop nid columns of the matched paths.
        """
        n, m = self.g.n_vertices, self.g.edges.nrows
        hops = len(edge_masks)
        cap = max(self.initial_capacity, 1 << int(np.ceil(np.log2(
            max(len(start_nids), 1)))))

        while True:
            cols, ok = self._run(start_nids, vertex_members, edge_masks, cap)
            if ok:
                return cols
            if cap >= self.max_capacity:
                raise RuntimeError(f"pattern frontier exceeded max capacity "
                                   f"{self.max_capacity}")
            cap *= 2
            self.recompiles += 1

    def _run(self, start_nids, vertex_members, edge_masks, cap):
        n, m = self.g.n_vertices, self.g.edges.nrows
        ones_v = jnp.ones((n,), bool)
        ones_e = jnp.ones((max(m, 1),), bool)

        C0 = len(start_nids)
        frontier = jnp.zeros((cap,), jnp.int32).at[:C0].set(
            jnp.asarray(start_nids, jnp.int32))
        fmask = jnp.zeros((cap,), bool).at[:C0].set(True)
        path_cols = [frontier]
        path_mask = fmask

        for h, (vm, em) in enumerate(zip(vertex_members, edge_masks)):
            member = ones_v if vm is None else jnp.asarray(vm)
            emask = ones_e if em is None else jnp.asarray(em)
            src_slot, dst, eid, valid, overflow = expand_frontier(
                self.row_ptr, self.col_idx, self.edge_id,
                path_cols[-1], path_mask, member, emask, capacity=cap)
            if bool(overflow):
                return None, False
            # re-join path prefixes through src_slot
            path_cols = [c[src_slot] for c in path_cols]
            path_cols.append(dst)
            path_mask = valid & path_mask[src_slot]

        # compact on host (final materialization = the graph-relation)
        keep = np.asarray(path_mask)
        return [np.asarray(c)[keep] for c in path_cols], True
