"""Device-resident (jit-able) pattern matching: fixed-capacity padded
frontiers with overflow-detect-and-retry (the DESIGN §2 static-shape
adaptation — the TPU analogue of buffer-pool spill).

The host engine (core.pattern) is the system of record; this module is the
accelerator glue. Two device flavors share the predicate-lowering code:

  * ``DevicePatternMatcher`` — the per-hop jit path: one ``expand_frontier``
    dispatch per hop with a host overflow sync between hops, dense
    predicate tables built by full column scans. Compiled once per
    (capacity, graph-shape) and reused across queries.
  * ``device_match(flavor="pallas")`` — the fused path
    (:mod:`repro.kernels.traversal`): the whole chain is one jit'd program
    (the Pallas kernel per hop on TPU, its jnp oracle on CPU), predicate
    tables are built through zone-map skip-scans (predicate-dead chunks
    are never read) and the chunk-survivor bitmap rides into the kernel as
    a prefetch filter; the host syncs once at the end of the chain.

Both flavors are epoch-stamped against the graph: a snapshot taken before a
write burst refuses to serve (pending deltas) or re-syncs (compacted) before
the next match — mirroring the ``IndexManager`` refresh discipline.

The planner's cardinality estimates choose the initial capacity; on overflow
the wrapper doubles and re-runs (amortized O(1) recompiles thanks to
power-of-two capacities). ``COUNTERS``/``metrics()`` surface recompiles,
per-capacity retries and kernel launch counts to the telemetry registry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.traversal import ops as kernel_ops

from . import pattern as pattern_mod
from .storage import Graph, Table


class StaleSnapshotError(ValueError):
    """The device CSR snapshot no longer matches the graph and cannot be
    refreshed (pending deltas — compact first)."""


@dataclasses.dataclass
class _Counters:
    matches: int = 0            # device_match invocations
    recompiles: int = 0         # jit-path capacity doublings
    retries: int = 0            # fused-path capacity doublings
    refreshes: int = 0          # snapshot re-syncs after epoch bumps
    stale_rejects: int = 0      # refused matches on pending deltas
    retry_caps: dict = dataclasses.field(default_factory=dict)

    def bump_retry(self, cap: int) -> None:
        self.retry_caps[cap] = self.retry_caps.get(cap, 0) + 1

    def metrics(self) -> dict:
        out = {"matches": self.matches, "recompiles": self.recompiles,
               "retries": self.retries, "refreshes": self.refreshes,
               "stale_rejects": self.stale_rejects}
        for cap, k in sorted(self.retry_caps.items()):
            out[f"retries.cap_{cap}"] = k
        return out


COUNTERS = _Counters()


def metrics() -> dict:
    """Telemetry registry source: matcher counters + fused-kernel launch
    counters, one flat namespace (cumulative; the engine's per-query view
    comes from registry snapshot deltas)."""
    out = COUNTERS.metrics()
    for k, v in kernel_ops.COUNTERS.metrics().items():
        out[f"kernel.{k}"] = v
    return out


@functools.partial(jax.jit, static_argnames=("capacity",))
def expand_frontier(row_ptr: jax.Array, col_idx: jax.Array,
                    edge_id: jax.Array, frontier: jax.Array,
                    frontier_mask: jax.Array, member: jax.Array,
                    edge_mask: jax.Array, *, capacity: int):
    """One hybrid-traversal hop on device.

    frontier: (C,) padded nids; member/edge_mask: boolean tables over nids /
    edge tids (the pushed predicates). Returns (src_slot, dst, eid, mask,
    overflowed): all (capacity,) padded outputs where ``src_slot`` indexes
    into the INPUT frontier (so callers can join path prefixes).
    """
    C = frontier.shape[0]
    deg = jnp.where(frontier_mask,
                    row_ptr[frontier + 1] - row_ptr[frontier], 0)
    out_off = jnp.cumsum(deg) - deg                     # exclusive prefix sum
    total = jnp.sum(deg)
    overflowed = total > capacity

    # slot i of the output belongs to the frontier entry whose out_off range
    # covers i: searchsorted over the (sorted) offsets
    slots = jnp.arange(capacity)
    src_slot = jnp.clip(
        jnp.searchsorted(out_off, slots, side="right") - 1, 0, C - 1)
    within = slots - out_off[src_slot]
    valid = slots < jnp.minimum(total, capacity)
    src_nid = frontier[src_slot]
    pos = jnp.clip(row_ptr[src_nid] + within, 0, col_idx.shape[0] - 1)
    dst = col_idx[pos].astype(jnp.int32)
    eid = edge_id[pos].astype(jnp.int32)
    valid &= member[jnp.clip(dst, 0, member.shape[0] - 1)]
    valid &= edge_mask[jnp.clip(eid, 0, edge_mask.shape[0] - 1)]
    return src_slot, dst, eid, valid, overflowed


class DevicePatternMatcher:
    """Chain-pattern matching fully on device with capacity retry. The CSR
    snapshot is epoch-stamped: ``refresh()`` re-syncs after a compaction
    and refuses (``StaleSnapshotError``) while deltas are pending, so the
    matcher can be cached on the graph and reused across write bursts."""

    def __init__(self, g: Graph, initial_capacity: int = 1 << 12,
                 max_capacity: int = 1 << 26):
        self.g = g
        self.initial_capacity = initial_capacity
        self.max_capacity = max_capacity
        self.recompiles = 0
        self.refreshes = 0
        self.last_capacity = 0
        self._snapshot()

    def _snapshot(self) -> None:
        g = self.g
        if g.delta.has_pending():
            # the device snapshot reads base CSRs only; compacting here
            # would silently renumber edge tids under the caller's feet
            COUNTERS.stale_rejects += 1
            raise StaleSnapshotError(
                f"graph {g.name!r} has pending delta writes; call "
                "g.compact() before building a DevicePatternMatcher")
        self.row_ptr = jnp.asarray(g.fwd.row_ptr)
        self.col_idx = jnp.asarray(g.fwd.col_idx)
        self.edge_id = jnp.asarray(g.fwd.edge_id)
        self.row_ptr_r = jnp.asarray(g.rev.row_ptr)
        self.col_idx_r = jnp.asarray(g.rev.col_idx)
        self.edge_id_r = jnp.asarray(g.rev.edge_id)
        self.epoch = g.epoch

    def refresh(self) -> None:
        """Refuse-or-refresh before serving: no-op while the graph epoch is
        unchanged; re-snapshot after a compaction settled the writes; raise
        while deltas are pending (mirrors ``ColumnIndex.refresh``)."""
        if self.g.epoch == self.epoch:
            return
        self._snapshot()
        self.refreshes += 1
        COUNTERS.refreshes += 1

    def csr(self, reverse: bool = False):
        if reverse:
            return self.row_ptr_r, self.col_idx_r, self.edge_id_r
        return self.row_ptr, self.col_idx, self.edge_id

    def match_chain(self, start_nids: np.ndarray,
                    vertex_members: list,
                    edge_masks: list, reverse: bool = False,
                    initial_capacity: Optional[int] = None):
        """vertex_members[h]: bool table over nids for hop-h target (None =
        label-unconstrained); edge_masks[h] likewise over edge tids.
        Returns (vcols, ecols): per-hop nid columns and per-hop edge-tid
        columns of the matched paths (compacted, host arrays).
        """
        self.refresh()
        cap = max(initial_capacity or self.initial_capacity,
                  1 << int(np.ceil(np.log2(max(len(start_nids), 1)))))

        while True:
            self.last_capacity = cap
            cols, ecols, ok = self._run(start_nids, vertex_members,
                                        edge_masks, cap, reverse)
            if ok:
                return cols, ecols
            if cap >= self.max_capacity:
                raise RuntimeError(f"pattern frontier exceeded max capacity "
                                   f"{self.max_capacity}")
            cap *= 2
            self.recompiles += 1
            COUNTERS.recompiles += 1
            COUNTERS.bump_retry(cap)

    def _run(self, start_nids, vertex_members, edge_masks, cap, reverse):
        n, m = self.g.n_vertices, self.g.edges.nrows
        ones_v = jnp.ones((n,), bool)
        ones_e = jnp.ones((max(m, 1),), bool)
        row_ptr, col_idx, edge_id = self.csr(reverse)

        C0 = len(start_nids)
        frontier = jnp.zeros((cap,), jnp.int32).at[:C0].set(
            jnp.asarray(start_nids, jnp.int32))
        fmask = jnp.zeros((cap,), bool).at[:C0].set(True)
        path_cols = [frontier]
        path_ecols: list = []
        path_mask = fmask

        for vm, em in zip(vertex_members, edge_masks):
            member = ones_v if vm is None else jnp.asarray(vm)
            emask = ones_e if em is None else jnp.asarray(em)
            src_slot, dst, eid, valid, overflow = expand_frontier(
                row_ptr, col_idx, edge_id,
                path_cols[-1], path_mask, member, emask, capacity=cap)
            if bool(overflow):          # per-hop host sync
                return None, None, False
            # re-join path prefixes through src_slot
            path_cols = [c[src_slot] for c in path_cols]
            path_ecols = [c[src_slot] for c in path_ecols]
            path_cols.append(dst)
            path_ecols.append(eid)
            path_mask = valid & path_mask[src_slot]

        # compact on host (final materialization = the graph-relation)
        keep = np.asarray(path_mask)
        return ([np.asarray(c)[keep] for c in path_cols],
                [np.asarray(c)[keep] for c in path_ecols], True)


def get_matcher(g: Graph, initial_capacity: int = 1 << 12
                ) -> DevicePatternMatcher:
    """The graph's cached matcher (holds the device CSR snapshot across
    queries); built lazily, kept fresh via ``refresh()``."""
    m = getattr(g, "_device_matcher", None)
    if m is None or m.g is not g:
        m = DevicePatternMatcher(g, initial_capacity)
        g._device_matcher = m
    return m


# ---------------------------------------------------------------------------
# Plan lowering: PatternPlan -> device tables (shared by both flavors)
# ---------------------------------------------------------------------------


def prepare_chain(g: Graph, pplan, zone: bool = True) -> Optional[dict]:
    """Lower a chain PatternPlan to the device-table form: start nids, a
    per-hop member table over the nid space (pushed vertex predicates and
    the multi-label constraint folded in), per-hop edge-predicate tables
    over the tid space, and — with ``zone=True`` — the zone-map chunk
    survivor bitmap per hop (built via ``masked_eval`` skip-scans, so
    predicate-dead chunks are never read even while building the table).
    Uses the same ``pattern._candidate_set`` logic as the host matcher, so
    index-seeded start frontiers carry over. Returns None for non-chain
    patterns (the host matcher keeps those)."""
    pattern = pplan.pattern
    if not pattern.is_chain or not pattern.edges:
        return None
    chain_vars = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
    edge_vars = [e.var for e in pattern.edges]
    hop_vars = chain_vars[::-1] if pplan.reverse else chain_vars
    hop_edges = edge_vars[::-1] if pplan.reverse else edge_vars

    cand = {v: pattern_mod._candidate_set(g, pattern, v,
                                          pplan.pushed.get(v, []))
            for v in chain_vars}

    def member_of(v: str) -> Optional[np.ndarray]:
        c = cand[v]
        if c is None:
            if len(g.labels) > 1:
                # label constraint (host matcher's implicit hop filter)
                return np.asarray(
                    g.vertex_label_code
                    == g.label_code_of(pattern.vertex(v).label))
            return None
        full = np.zeros(g.n_vertices, dtype=bool)
        if c[0] == "mask":
            full[g.label_nids(pattern.vertex(v).label)] = c[1]
        else:       # vid rows -> nids
            full[g.nid_of(pattern.vertex(v).label, c[1])] = True
        return full

    members = [member_of(v) for v in hop_vars[1:]]

    im = getattr(g, "_index_manager", None)
    chunk = 0
    edge_preds: list = []
    chunk_alives: list = []
    for evar in hop_edges:
        preds = pplan.pushed.get(evar, [])
        if not preds:
            edge_preds.append(None)
            chunk_alives.append(None)
            continue
        mask: Optional[np.ndarray] = None
        alive: Optional[np.ndarray] = None
        for p in preds:
            pm = None
            ch = None
            idx = im.get(g.name, p.column) if (zone and im is not None) \
                else None
            if idx is not None:
                pm = idx.zone_mask(p)       # skip-scan: dead chunks unread
                if pm is not None and idx.zones is not None:
                    ch = idx.zones.candidate_chunks(p)
                    chunk = idx.zones.chunk
                    kernel_ops.COUNTERS.chunks_alive += int(ch.sum())
                    kernel_ops.COUNTERS.chunks_total += len(ch)
            if pm is None:
                pm = np.asarray(g.edges.eval_predicate(p))
            mask = pm if mask is None else mask & pm
            if ch is not None:
                alive = ch if alive is None else alive & ch
        edge_preds.append(mask)
        chunk_alives.append(alive)

    v0 = hop_vars[0]
    c0 = cand[v0]
    if c0 is None:
        start_nids = g.label_nids(pattern.vertex(v0).label)
    elif c0[0] == "rows":
        start_nids = np.atleast_1d(g.nid_of(pattern.vertex(v0).label, c0[1]))
    else:
        v0_nids = g.label_nids(pattern.vertex(v0).label)
        start_nids = v0_nids[c0[1]]

    from .cost import ZONE_CHUNK
    return {"start_nids": start_nids, "members": members,
            "edge_preds": edge_preds, "chunk_alives": chunk_alives,
            "reverse": bool(pplan.reverse),
            "chunk": chunk or ZONE_CHUNK,
            "chain_vars": chain_vars, "edge_vars": edge_vars}


def _round_capacity(n: int) -> int:
    return 1 << max(7, int(np.ceil(np.log2(max(n, 1)))))


def _estimate_capacity(g: Graph, prep: dict) -> int:
    """Pick the launch capacity from the lowered plan itself: walk the hops
    with the label-aware fan-out and the *actual* predicate-table survivor
    fractions, and size for the peak pre-predicate candidate count (the
    kernel must hold every candidate before compaction). Headroom 2x; the
    overflow-retry loop still backstops underestimates, this just keeps the
    steady state at one launch."""
    fan = g.hop_expansion(reverse=prep["reverse"])
    fr = float(len(prep["start_nids"]))
    peak = max(fr, 64.0)
    for mem, ep in zip(prep["members"], prep["edge_preds"]):
        cand = fr * fan
        peak = max(peak, cand)
        s_e = float(np.mean(ep)) if ep is not None else 1.0
        s_m = float(np.mean(mem)) if mem is not None else 1.0
        fr = cand * s_e * s_m
    return _round_capacity(int(2.0 * peak))


def _kernel_span_args(hops: int, capacity: int, n_vertices: int,
                      n_edges: int, prep: dict, launches: int) -> dict:
    """Analytic flops/bytes of the device traversal — the span payload
    ``roofline.from_trace`` reads (the operator is a DAG leaf, so the
    generic shape-derived model in ``telemetry.kernel_args`` has nothing to
    work from). Memory model: per hop, three int32 outputs plus per-slot
    gather traffic over the padded capacity (the device moves padded
    arrays regardless of validity), plus the predicate tables actually
    read — edge tables scaled by the zone-survivor fraction."""
    per_slot = 3 * 4 + (4 + 4 + 8 + 2 + 1)    # outputs + gathers
    tbl_bytes = 0.0
    for mem in prep["members"]:
        if mem is not None:
            tbl_bytes += n_vertices
    for ep, ca in zip(prep["edge_preds"], prep["chunk_alives"]):
        if ep is None:
            continue
        frac = (float(ca.sum()) / max(len(ca), 1)) if ca is not None else 1.0
        tbl_bytes += frac * n_edges + (0 if ca is None else len(ca))
    flops = float(hops) * capacity * 12.0 * launches
    nbytes = (float(hops) * capacity * per_slot * launches + tbl_bytes)
    return {"flops": flops, "bytes": int(nbytes), "hops": hops,
            "capacity": capacity,
            "zone_chunks_alive": kernel_ops.COUNTERS.chunks_alive,
            "zone_chunks_total": kernel_ops.COUNTERS.chunks_total}


def device_match(g: Graph, pplan, *, flavor: str = "pallas",
                 initial_capacity: Optional[int] = None,
                 max_capacity: int = 1 << 24,
                 use_kernel: Optional[bool] = None):
    """Execute a chain PatternPlan on the device path and build the same
    graph-relation Table as ``pattern.match`` (vertex columns hold vids,
    edge columns hold tids; deferred predicates applied). Returns
    (rel, kernel_args) — the second element is the telemetry span payload.
    ``flavor``: "pallas" (fused chain, zone-filtered tables) or "jit"
    (per-hop ``DevicePatternMatcher``). Raises ``StaleSnapshotError`` on
    pending deltas; callers degrade to the host matcher."""
    COUNTERS.matches += 1
    matcher = get_matcher(g)
    matcher.refresh()
    prep = prepare_chain(g, pplan, zone=(flavor == "pallas"))
    if prep is None:
        raise ValueError(f"pattern {pplan.pattern.canonical()!r} is not a "
                         "chain; device path unavailable")
    pattern = pplan.pattern
    start = prep["start_nids"]
    hops = len(prep["edge_vars"])
    launches = 1

    if flavor == "jit":
        vcols, ecols = matcher.match_chain(
            start, prep["members"], prep["edge_preds"],
            reverse=prep["reverse"],
            initial_capacity=initial_capacity or _estimate_capacity(g, prep))
        cap = matcher.last_capacity
    else:
        row_ptr, col_idx, edge_id = matcher.csr(prep["reverse"])
        cap = initial_capacity or _estimate_capacity(g, prep)
        cap = max(cap, _round_capacity(len(start)))
        while True:
            vcols, ecols, ok = kernel_ops.traverse_chain(
                row_ptr, col_idx, edge_id, g.n_vertices, g.edges.nrows,
                start, prep["members"], prep["edge_preds"],
                prep["chunk_alives"], capacity=cap, chunk=prep["chunk"],
                use_kernel=use_kernel)
            if ok:
                break
            if cap >= max_capacity:
                raise RuntimeError(f"pattern frontier exceeded max capacity "
                                   f"{max_capacity}")
            cap *= 2
            launches += 1
            COUNTERS.retries += 1
            COUNTERS.bump_retry(cap)

    if prep["reverse"]:
        vcols = vcols[::-1]
        ecols = ecols[::-1]
    cols: dict[str, np.ndarray] = {}
    for var, col in zip(prep["chain_vars"], vcols):
        cols[var] = g.vids_of(col)
    for evar, col in zip(prep["edge_vars"], ecols):
        cols[evar] = col
    rel = Table(f"match:{pattern.graph}", cols)
    rel = pattern_mod.apply_deferred(g, pattern, rel, pplan.deferred)
    kargs = _kernel_span_args(hops, cap, g.n_vertices, g.edges.nrows, prep,
                              launches)
    kargs["flavor"] = flavor
    return rel, kargs
