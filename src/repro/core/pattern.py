"""Topology- and attribute-aware pattern matching (paper §5.2, Algorithm 2).

Vectorized re-derivation: Algorithm 2's DFS stack over partial paths becomes
whole-frontier expansion — at hop i the set of valid partial paths is a
(n_paths, i+1) binding matrix; one CSR gather advances every path at once.
Semantics (the multiset of matched bindings) are identical; property tests
check against a literal transcription of the pseudocode.

Attribute-awareness (Fig. 6):
  * rule-based: single-sided predicates are pushed and traversal starts from
    the predicate side (forward/reverse);
  * cost-based: with predicates on both ends, effective cardinalities
    |M(v)| * S_phi(v) decide the start side; end-vertex equality predicates are
    always pushed, inequality deferred, range predicates cost-compared.
Query-aware traversal pruning (§6.2): hops whose target carries no predicate
and is not projected skip the record fetch entirely (topology-only gather).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import cost as cost_mod
from . import traversal
from .schema import Pattern, Predicate
from .storage import Graph, Table


@dataclasses.dataclass
class PatternPlan:
    pattern: Pattern
    reverse: bool                       # traversal direction (Fig. 6)
    pushed: dict                        # var -> [Predicate] evaluated before traversal
    deferred: dict                      # var -> [Predicate] evaluated on the graph-relation
    fetch_vars: set                     # vars whose records must be fetched (projection/deferred)
    est_cost: float = 0.0

    def describe(self) -> str:
        d = "reverse" if self.reverse else "forward"
        return (f"PatternPlan(dir={d}, pushed={{{', '.join(f'{k}:{v}' for k, v in self.pushed.items())}}}, "
                f"deferred={{{', '.join(f'{k}:{v}' for k, v in self.deferred.items())}}}, "
                f"fetch={sorted(self.fetch_vars)})")


def _predicate_selectivity(tbl: Table, preds: list[Predicate]) -> float:
    s = 1.0
    for p in preds:
        s *= tbl.stats(p.column).selectivity(p)
    return s


def plan_pattern(g: Graph, pattern: Pattern, phi: dict[str, list[Predicate]],
                 projected: set[str], force_reverse: Optional[bool] = None,
                 enable_pushdown: bool = True) -> PatternPlan:
    """Choose direction + pushdown set per Fig. 6. ``phi`` maps pattern var ->
    predicates (the predicate assignment function), ``projected`` lists vars
    referenced by the enclosing projection."""
    chain_vars = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
    src_var, dst_var = chain_vars[0], chain_vars[-1]
    pushed: dict[str, list[Predicate]] = {}
    deferred: dict[str, list[Predicate]] = {v: list(ps) for v, ps in phi.items() if ps}

    if not enable_pushdown:
        reverse = bool(force_reverse)
        fetch = set(projected) | set(deferred)
        return PatternPlan(pattern, reverse, {}, deferred, fetch)

    def tbl_of(var: str) -> Table:
        return g.vertex_tables[pattern.vertex(var).label]

    s_preds = deferred.get(src_var, [])
    t_preds = deferred.get(dst_var, [])

    if s_preds and not t_preds:
        reverse = False                      # Fig. 6(a): start from predicate side
    elif t_preds and not s_preds:
        reverse = True                       # Fig. 6(b)
    elif s_preds and t_preds:                # Fig. 6(c): cost-based
        cs = tbl_of(src_var).nrows * _predicate_selectivity(tbl_of(src_var), s_preds)
        ct = tbl_of(dst_var).nrows * _predicate_selectivity(tbl_of(dst_var), t_preds)
        reverse = ct < cs
    else:
        # no end predicates: start from the smaller candidate set
        reverse = tbl_of(dst_var).nrows < tbl_of(src_var).nrows
    if force_reverse is not None:
        reverse = force_reverse

    start_var = dst_var if reverse else src_var
    end_var = src_var if reverse else dst_var

    # start-side predicates always pushed (they define the initial frontier)
    if deferred.get(start_var):
        pushed[start_var] = deferred.pop(start_var)

    # end-vertex rules: equality -> push; inequality -> defer; range -> cost
    if deferred.get(end_var):
        push_list, defer_list = [], []
        tbl = tbl_of(end_var)
        for p in deferred[end_var]:
            if p.is_equality or p.op == "in":
                push_list.append(p)
            elif p.is_inequality:
                defer_list.append(p)
            else:  # range: compare push vs defer costs (§6.3)
                if cost_mod.should_push_range(g, tbl, p):
                    push_list.append(p)
                else:
                    defer_list.append(p)
        if push_list:
            pushed[end_var] = push_list
        if defer_list:
            deferred[end_var] = defer_list
        else:
            deferred.pop(end_var)

    # interior vertices / edges: equality+in pushed (columnar mask is cheap),
    # everything else deferred
    for var in list(deferred):
        if var in (start_var, end_var):
            continue
        push_list = [p for p in deferred[var] if p.is_equality or p.op == "in" or p.is_range]
        defer_list = [p for p in deferred[var] if not (p.is_equality or p.op == "in" or p.is_range)]
        if push_list:
            pushed.setdefault(var, []).extend(push_list)
        if defer_list:
            deferred[var] = defer_list
        else:
            deferred.pop(var)

    fetch = set(projected) | set(deferred)
    return PatternPlan(pattern, reverse, pushed, deferred, fetch)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

# Below this row count a vectorized column scan beats the posting-list
# machinery (binary probes + gathers carry fixed per-call overhead), so
# candidate sets fall back to the scan path on tiny labels.
MIN_INDEX_ROWS = 1024


def _candidate_set(g: Graph, pattern: Pattern, var: str,
                   preds: list[Predicate],
                   extra: Optional[np.ndarray] = None
                   ) -> Optional[tuple[str, np.ndarray]]:
    """M(v_p) after pushdown, as a tagged candidate set over the label's
    vid (or edge-tid) space: ``("rows", row_ids)`` when an index served the
    predicates (postings, no O(n) scan), ``("mask", bool_mask)`` from the
    scan path, or the ``None`` sentinel when the var carries no constraint
    at all — callers skip the all-true mask and its downstream
    intersections entirely (Lines 3-7 of Algorithm 2 with the §5.2
    pushdown modification). ``extra`` is a pre-computed candidate mask over
    the same space — the semi-join output of join pushdown (Eq. 9/10),
    intersected in.

    When the graph carries a composite (label, attr) index serving a
    pushed predicate (:mod:`repro.core.index`), the candidate set is
    seeded from the index postings and the remaining predicates are
    point-evaluated on those rows only — no O(n) column scan."""
    if not preds and extra is None:
        return None
    is_edge = any(e.var == var for e in pattern.edges)
    tbl = g.edges if is_edge else g.vertex_tables[pattern.vertex(var).label]
    if preds:
        im = getattr(g, "_index_manager", None)
        rows = None
        rest = list(preds)
        if im is not None and tbl.nrows >= MIN_INDEX_ROWS:
            rest = []
            label = None if is_edge else pattern.vertex(var).label
            for p in preds:
                hit = im.lookup(g.name, p, label=label)
                if hit is None:
                    rest.append(p)
                    continue
                rows = hit if rows is None \
                    else np.intersect1d(rows, hit, assume_unique=True)
                traversal.COUNTERS.cpu_ops += len(hit)
        if rows is not None:
            # index-seeded: residual predicates touch the candidates only
            for p in rest:
                if len(rows):
                    rows = rows[tbl.eval_predicate(p, rows=rows)]
                traversal.COUNTERS.record_fetches += len(rows)
                traversal.COUNTERS.cpu_ops += len(rows)
            if extra is not None:
                rows = rows[extra[rows]]
            return ("rows", rows)
        mask: Optional[np.ndarray] = None
        for p in preds:
            m = tbl.eval_predicate(p)
            mask = m if mask is None else (mask & m)
            traversal.COUNTERS.record_fetches += tbl.nrows  # column scan
            traversal.COUNTERS.cpu_ops += tbl.nrows
        if extra is not None:
            mask = mask & extra
        return ("mask", mask)
    return ("mask", extra)


def _as_mask(cand: Optional[tuple[str, np.ndarray]],
             n: int) -> Optional[np.ndarray]:
    """Materialize a tagged candidate set as a boolean mask of length n."""
    if cand is None:
        return None
    kind, data = cand
    if kind == "mask":
        return data
    mask = np.zeros(n, dtype=bool)
    mask[data] = True
    return mask


@dataclasses.dataclass
class MatchState:
    """Prepared (pre-traversal) state of one pattern match: candidate/member
    masks, per-edge masks, and the start frontier. Splitting preparation from
    the hop loop lets the sharded executor run :func:`expand_chain` on
    contiguous blocks of ``start_nids`` — every path is seeded by exactly one
    start vertex and the hop loop preserves row order, so the block outputs
    concatenate to the serial result bit-for-bit."""

    plan: PatternPlan
    chain_vars: list
    edge_vars: list
    hop_vars: list
    hop_edges: list
    member_of: "callable"
    edge_mask: dict
    start_nids: np.ndarray

    def materialize_members(self) -> None:
        """Force every lazily-built member mask (call before fanning the hop
        loop out to worker threads — the memo is not thread-safe)."""
        for v in self.hop_vars[1:]:
            self.member_of(v)


def prepare_match(g: Graph, plan: PatternPlan,
                  extra_masks: Optional[dict] = None) -> MatchState:
    """Candidate-set construction + start-frontier seeding of Algorithm 2
    (everything before the hop loop)."""
    extra_masks = extra_masks or {}
    pattern = plan.pattern
    chain_vars = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
    edge_vars = [e.var for e in pattern.edges]

    hop_vars = list(chain_vars)
    hop_edges = list(edge_vars)
    if plan.reverse:
        hop_vars = hop_vars[::-1]
        hop_edges = hop_edges[::-1]

    # vertex candidate sets over the vid space; nid-space member masks are
    # materialized lazily (scatter through label_nids: with pending delta
    # vertices a label's nid set is its base block plus appended delta
    # nids, in merged-table row order) — and only for vars that actually
    # filter a hop. Index-seeded ("rows") start vars never pay the scatter.
    cand = {v: _candidate_set(g, pattern, v, plan.pushed.get(v, []),
                              extra_masks.get(v)) for v in chain_vars}
    member: dict[str, Optional[np.ndarray]] = {}

    def member_of(v: str) -> Optional[np.ndarray]:
        if v not in member:
            c = cand[v]
            if c is None:
                member[v] = None
            else:
                full = np.zeros(g.n_vertices, dtype=bool)
                if c[0] == "mask":
                    full[g.label_nids(pattern.vertex(v).label)] = c[1]
                else:   # vid rows -> nids (delta rows included)
                    full[g.nid_of(pattern.vertex(v).label, c[1])] = True
                member[v] = full
        return member[v]

    edge_mask: dict[str, Optional[np.ndarray]] = {
        e: _as_mask(_candidate_set(g, pattern, e, plan.pushed.get(e, [])),
                    g.edges.nrows) for e in edge_vars}

    # initial frontier (Line 9): candidates of the first hop var
    v0 = hop_vars[0]
    c0 = cand[v0]
    if c0 is None:
        start_nids = g.label_nids(pattern.vertex(v0).label)
    elif c0[0] == "rows":
        # frontier seeded straight from index postings — no full-label mask
        start_nids = np.atleast_1d(g.nid_of(pattern.vertex(v0).label, c0[1]))
    else:
        v0_nids = g.label_nids(pattern.vertex(v0).label)
        start_nids = v0_nids[c0[1]]

    return MatchState(plan, chain_vars, edge_vars, hop_vars, hop_edges,
                      member_of, edge_mask, start_nids)


def expand_chain(g: Graph, st: MatchState,
                 start_nids: np.ndarray) -> dict[str, np.ndarray]:
    """The hop loop of Algorithm 2 over a given start frontier. Returns the
    graph-relation columns (vertex vars -> vids, edge vars -> edge tids),
    rows in start-major order. Deferred predicates are NOT applied here."""
    plan, pattern = st.plan, st.plan.pattern
    paths_v = [np.asarray(start_nids)]  # per-var nid columns, in hop order
    paths_e: list[np.ndarray] = []      # per-edge tid columns

    for evar, nvar in zip(st.hop_edges, st.hop_vars[1:]):
        frontier = paths_v[-1]
        # base ⊕ delta expansion (tombstoned edges already filtered)
        row_rep, dst, eid = g.expand(frontier, reverse=plan.reverse)
        total = len(dst)
        traversal.COUNTERS.cpu_ops += total + len(frontier)

        # build the hop filter lazily: unconstrained hops never allocate
        # (or intersect) an all-true mask
        keep = None
        nmask = st.member_of(nvar)
        if nmask is not None:
            keep = nmask[dst]
            traversal.COUNTERS.cpu_ops += total
        elif len(g.labels) > 1:
            # label constraint: dst must carry nvar's label
            keep = (g.vertex_label_code[dst]
                    == g.label_code_of(pattern.vertex(nvar).label))
        if st.edge_mask[evar] is not None:
            em = st.edge_mask[evar][eid]
            keep = em if keep is None else (keep & em)
            traversal.COUNTERS.cpu_ops += total

        if keep is not None:
            row_rep, dst, eid = row_rep[keep], dst[keep], eid[keep]
        paths_v = [c[row_rep] for c in paths_v]
        paths_e = [c[row_rep] for c in paths_e]
        paths_v.append(dst)
        paths_e.append(eid)

    if plan.reverse:
        paths_v = paths_v[::-1]
        paths_e = paths_e[::-1]

    cols: dict[str, np.ndarray] = {}
    for var, col in zip(st.chain_vars, paths_v):
        cols[var] = g.vids_of(col)  # store vids (label-local) in the graph-relation
    for evar, col in zip(st.edge_vars, paths_e):
        cols[evar] = col
    return cols


def match(g: Graph, plan: PatternPlan,
          extra_masks: Optional[dict] = None) -> Table:
    """Execute P(G, P): returns the graph-relation as a Table with one column
    per pattern var — vertex columns hold vids, edge columns hold edge tids.
    ``extra_masks`` maps vertex vars to semi-join candidate masks (join
    pushdown inputs, supplied as explicit plan edges by the physical DAG)."""
    st = prepare_match(g, plan, extra_masks)
    cols = expand_chain(g, st, st.start_nids)
    rel = Table(f"match:{plan.pattern.graph}", cols)

    # deferred predicate evaluation on the graph-relation (Cost_prop, Eq. 13)
    return apply_deferred(g, plan.pattern, rel, plan.deferred)


def apply_deferred(g: Graph, pattern: Pattern, rel: Table, deferred: dict) -> Table:
    """Evaluate deferred predicates on a materialized graph-relation."""
    edge_vars = [e.var for e in pattern.edges]
    if not deferred or not rel.nrows:
        return rel
    mask = np.ones(rel.nrows, dtype=bool)
    for var, preds in deferred.items():
        is_edge = var in edge_vars
        tbl = g.edges if is_edge else g.vertex_tables[pattern.vertex(var).label]
        ids = np.asarray(rel.col(var))
        traversal.COUNTERS.record_fetches += len(ids) * len(preds)
        for p in preds:
            if len(ids) < tbl.nrows:
                # fewer bindings than records: point-evaluate on the
                # referenced rows instead of scanning the whole column
                mask &= tbl.eval_predicate(p, rows=ids)
            else:
                mask &= tbl.eval_predicate(p)[ids]
            traversal.COUNTERS.cpu_ops += len(ids)
    return rel.take(np.nonzero(mask)[0])


# ---------------------------------------------------------------------------
# Shortest-path search (topology-only GraphAM; powers M2Bench G6-G8)
# ---------------------------------------------------------------------------


def shortest_path_lengths(g: Graph, src_nids: np.ndarray, dst_nids: np.ndarray,
                          max_hops: int = 64) -> np.ndarray:
    """Multi-source BFS over the CSR topology (no record access — the pure
    topology-driven mode the hybrid operator also supports). Returns hop
    distance per (src, dst) pair, -1 if unreachable."""
    src_nids = np.asarray(src_nids)
    dst_nids = np.asarray(dst_nids)
    out = np.full(len(src_nids), -1, dtype=np.int32)
    # group by src to share BFS frontiers
    uniq, inv = np.unique(src_nids, return_inverse=True)
    for i, s in enumerate(uniq):
        dist = np.full(g.n_vertices, -1, dtype=np.int32)
        dist[s] = 0
        frontier = np.array([s])
        for h in range(1, max_hops + 1):
            _, nxt, _ = g.expand(frontier)
            nxt = np.unique(nxt)
            nxt = nxt[dist[nxt] < 0]
            if len(nxt) == 0:
                break
            dist[nxt] = h
            frontier = nxt
        sel = inv == i
        out[sel] = dist[dst_nids[sel]]
    return out
