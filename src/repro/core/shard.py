"""Morsel-parallel sharded execution of the physical operator DAG.

The engine keeps ONE executor (``physical.execute``); sharding enters
through ``ExecContext.shard``. When a :class:`ShardRuntime` is attached, the
executor offers every node to :meth:`ShardRuntime.run`, which either executes
it morsel-parallel over hash/row shards or returns the ``NOT_SHARDED``
sentinel, at which point the serial ``node.run`` fires unchanged. All span /
inter-buffer / memo machinery therefore applies identically to both paths.

Every sharded operator is **bit-for-bit identical** to its serial twin:

* ``Select`` / ``Residual`` / ``IntraFilter`` — per-shard predicate masks are
  ANDed and the table gathered ONCE with the globally-ordered surviving row
  set (contiguous row blocks, so concatenated survivors are in serial order;
  a conjunction of masks selects the same rows as sequential takes).
* ``EquiJoin`` — the build (right) side is hash-partitioned on the join key
  by a stable counting sort, then each shard is stably key-sorted: all rows
  of one key land in one shard with their original relative order, so each
  per-key run is byte-identical to the serially sorted run. Probe morsels
  are contiguous probe-position blocks; the run-expansion formula is the
  serial one, so the (li, ri) pair stream is exactly the serial stream.
  Bounded-range integer keys additionally get a dense direct-address index
  over the partition (O(1) vectorized probes instead of per-shard binary
  search) — same runs, same stream.
* ``MatchPattern`` — ``pattern.prepare_match`` runs once; the hop loop
  (``pattern.expand_chain``) runs per contiguous block of start vertices.
  The serial output is start-major with order preserved across hops, so
  block outputs concatenate to the serial relation.
* ``TableJoinMatch`` — hop-0 edge-row blocks; the k-way join expansion is
  left-major, so blocks concatenate exactly.
* ``Rel2Matrix`` — born-sharded matrix generation: each row block is cast
  and staged to the device independently and the blocks are concatenated
  device-side (``analytics.rel2matrix_sharded``) — the GCDA kernels consume
  the result without a host gather. The sharding spec lands in the
  operator's trace span via ``last_kernel_args``.

The :class:`Exchange` operator (``physical.Exchange``) marks where the build
side of a join is repartitioned. The runtime caches built partitions keyed by
``(child signature fingerprint, key column, k)`` — signatures embed source
write epochs, so a cached partition is valid exactly until the source
mutates, and a repeated join over the same build side skips the shuffle
entirely (the co-partitioned fast path, counted in ``exchanges_reused``).

Worker-pool note: morsels run on a small thread pool (bounded by the host
core count). Correctness never depends on the worker count — results are
reassembled in morsel order — and the speedup on few-core hosts comes from
the *algorithmic* effects above (fused masks with one gather, per-shard sort
runs with shorter binary searches, block-wise device staging), not from
thread concurrency.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Callable, Optional

import numpy as np

from . import analytics
from . import cost as cost_mod
from . import join as join_mod
from . import pattern as pattern_mod
from . import physical as physical_mod
from . import traversal
from .deltastore import expand_runs
from .interbuffer import fingerprint
from .storage import Database, Table, _col_slice, shard_bounds

NOT_SHARDED = object()   # sentinel: "runtime declined, run the serial path"

# node kinds the runtime can execute morsel-parallel (everything else —
# scans, index paths, projections, device kernels — stays serial)
SHARDABLE_KINDS = frozenset({
    "Select", "Residual", "IntraFilter", "EquiJoin", "Exchange",
    "MatchPattern", "TableJoinMatch", "Rel2Matrix",
})


# ---------------------------------------------------------------------------
# Hash partitioning of join build sides
# ---------------------------------------------------------------------------


def hash_shard_ids(keys: np.ndarray, k: int) -> np.ndarray:
    """Shard id per key. Equal keys always map to the same shard — the only
    property the join relies on. Numeric keys hash by value (mod k); string /
    object keys via the process-stable ``hash``."""
    keys = np.asarray(keys)
    if keys.dtype.kind in "iufb":
        return (keys.astype(np.int64) % k).astype(np.int64)
    return np.fromiter((hash(x) % k for x in keys),
                       dtype=np.int64, count=len(keys))


@dataclasses.dataclass
class BuildPartition:
    """Hash-partitioned, per-shard key-sorted build side of an equi-join.

    For bounded-range integer keys (the FK-join common case) the partition
    also carries a dense direct-address index over the key span:
    ``dense_lo[v - kmin]`` / ``dense_cnt[v - kmin]`` locate key ``v``'s run
    inside ``rows_cat`` in O(1) — the probe becomes two vectorized gathers
    instead of a per-shard binary search (a key lives in exactly one shard,
    so each key has exactly one contiguous run). Probing either access path
    yields the identical (li, ri) stream."""

    keys: list            # per-shard key runs, each stably key-sorted
    rows_cat: np.ndarray  # per-shard sorted row ids, concatenated
    base: np.ndarray      # shard s occupies rows_cat[base[s]:base[s+1]]
    k: int
    kmin: int = 0
    dense_lo: Optional[np.ndarray] = None   # global run start per key
    dense_cnt: Optional[np.ndarray] = None  # run length per key

    def rows_per_shard(self) -> np.ndarray:
        return np.diff(self.base)


# dense index budget: key span may exceed the build row count by at most
# this factor (beyond it the direct-address table stops paying for itself)
DENSE_SPAN_FACTOR = 8


def build_partition(tbl: Table, col: str, k: int) -> BuildPartition:
    """Partition ``tbl``'s join-key column into k hash shards. Stable
    counting sort into shard runs, then a stable key sort per shard: every
    key's rows keep their original relative order, so per-key runs match the
    global stable sort byte-for-byte."""
    rk, rrows = join_mod._key_arrays(tbl, col)
    traversal.COUNTERS.cpu_ops += len(rk)
    sh = hash_shard_ids(rk, k)
    perm = np.argsort(sh, kind="stable")        # stable counting sort
    counts = np.bincount(sh, minlength=k)
    base = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(counts, out=base[1:])
    keys: list = []
    rows_cat = np.empty(len(rk), dtype=np.int64)
    for s in range(k):
        idx = perm[base[s]:base[s + 1]]
        rk_p, rr_p = rk[idx], rrows[idx]
        order = np.argsort(rk_p, kind="stable")
        keys.append(rk_p[order])
        rows_cat[base[s]:base[s + 1]] = rr_p[order]
    part = BuildPartition(keys=keys, rows_cat=rows_cat, base=base, k=k)
    if len(rk) and rk.dtype.kind in "iu":
        kmin, kmax = int(rk.min()), int(rk.max())
        span = kmax - kmin + 1
        if span <= max(DENSE_SPAN_FACTOR * len(rk), 65536):
            keys_cat = np.concatenate(keys)
            starts = np.flatnonzero(
                np.r_[True, keys_cat[1:] != keys_cat[:-1]])
            dense_lo = np.zeros(span, dtype=np.int64)
            dense_lo[(keys_cat[starts] - kmin).astype(np.int64)] = starts
            dense_cnt = np.bincount((rk - kmin).astype(np.int64),
                                    minlength=span)
            part.kmin, part.dense_lo, part.dense_cnt = \
                kmin, dense_lo, dense_cnt
    return part


# ---------------------------------------------------------------------------
# Plan preparation: shard-count choice, annotation, exchange insertion
# ---------------------------------------------------------------------------


def dominant_rows(root: "physical_mod.PhysicalOp", db: Database) -> float:
    """Largest base collection the DAG reads — the input the §6.3 sharded
    cost model weighs against per-shard setup overhead."""
    best, seen, stack = 0.0, set(), [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        name = getattr(n, "name", None)
        if n.kind in ("ScanTable", "IndexScan", "IndexSelect") \
                and name in db.tables:
            best = max(best, float(db.tables[name].nrows))
        gname = getattr(n, "graph", None)
        if gname is not None and gname in db.graphs:
            best = max(best, float(db.graphs[gname].edges.nrows))
        stack.extend(n.children)
    return best


def prepare_plan(root: "physical_mod.PhysicalOp", db: Database, k: int
                 ) -> tuple["physical_mod.PhysicalOp", int]:
    """Cost-gate the shard count, then rewrite the DAG for sharded
    execution: clone every node (the input plan stays untouched), stamp
    ``shards=k`` on shardable kinds, and insert an :class:`Exchange` under
    the build (right) side of every EquiJoin. Returns ``(new_root, k)``;
    ``k == 1`` means serial execution was chosen and ``root`` is returned
    unchanged."""
    k_eff = cost_mod.choose_shard_count(dominant_rows(root, db), k)
    if k_eff <= 1:
        return root, 1
    memo: dict[int, physical_mod.PhysicalOp] = {}

    def rewrite(n: "physical_mod.PhysicalOp") -> "physical_mod.PhysicalOp":
        if id(n) in memo:
            return memo[id(n)]
        m = n.with_children(*[rewrite(c) for c in n.children])
        if m.kind == "EquiJoin":
            ex = physical_mod.Exchange(m.children[1], key=m.jp.right, k=k_eff)
            ex.shards = k_eff
            m = m.with_children(m.children[0], ex)
        if m.kind in SHARDABLE_KINDS:
            m.shards = k_eff
        memo[id(n)] = m
        return m

    new_root = rewrite(root)
    # re-stamp schema annotations on the rewritten DAG (the inserted
    # Exchange nodes carry none; with_children clones keep stale refs)
    from . import verify as verify_mod
    verify_mod.annotate_out_cols(new_root, db)
    return new_root, k_eff


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


class ShardRuntime:
    """Morsel-parallel execution backend attached to ``ExecContext.shard``.

    One instance per engine: the worker pool and the exchange-partition
    cache persist across queries, which is what makes repeated joins over an
    unchanged build side co-partitioned (shuffle-skip)."""

    NOT_SHARDED = NOT_SHARDED
    CACHE_SLOTS = 8     # cached build partitions (LRU)

    def __init__(self, k: int, max_workers: Optional[int] = None):
        self.k = max(int(k), 1)
        self._pool = None
        self._pool_lock = threading.Lock()
        self._max_workers = max_workers or min(self.k, os.cpu_count() or 1)
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.Lock()
        self._m = {"morsels": 0.0, "queue_wait_s": 0.0,
                   "exchanges_built": 0.0, "exchanges_reused": 0.0,
                   "sharded_ops": 0.0, "serial_fallbacks": 0.0,
                   "rows_shard_max": 0.0, "rows_shard_sum": 0.0,
                   "shard_partitions": 0.0}

    # ------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        """Registry-source snapshot (namespace ``shard.``): morsel counts,
        queue wait, exchange build/reuse, and rows-per-shard skew."""
        with self._lock:
            out = dict(self._m)
        parts = out["shard_partitions"]
        out["rows_shard_mean"] = out["rows_shard_sum"] / parts if parts else 0.0
        return out

    def _bump(self, **kw) -> None:
        with self._lock:
            for name, v in kw.items():
                self._m[name] += v

    def _note_skew(self, rows_per_shard) -> None:
        rows = np.asarray(rows_per_shard, dtype=np.float64)
        if not len(rows):
            return
        with self._lock:
            self._m["rows_shard_max"] = max(self._m["rows_shard_max"],
                                            float(rows.max()))
            self._m["rows_shard_sum"] += float(rows.sum())
            self._m["shard_partitions"] += len(rows)

    # ---------------------------------------------------------- worker pool
    def _ensure_pool(self):
        with self._pool_lock:
            if self._pool is None:
                from concurrent.futures import ThreadPoolExecutor
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="shard-morsel")
            return self._pool

    def _map(self, fn: Callable, items: list) -> list:
        """Run ``fn(*item)`` per item on the pool; results in item order.
        Queue wait (submit -> task start) feeds the ``shard.queue_wait_s``
        metric."""
        if len(items) <= 1:
            self._bump(morsels=float(len(items)))
            return [fn(*it) for it in items]
        pool = self._ensure_pool()

        def timed(item, t_submit):
            self._bump(morsels=1.0,
                       queue_wait_s=time.perf_counter() - t_submit)
            return fn(*item)

        t0 = time.perf_counter()
        futs = [pool.submit(timed, it, t0) for it in items]
        return [f.result() for f in futs]

    def shutdown(self) -> None:
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    # ------------------------------------------------------------- dispatch
    def run(self, node, ctx, inputs: list):
        """Executor hook: run ``node`` sharded or return NOT_SHARDED."""
        k = getattr(node, "shards", None)
        if not k or k <= 1 or node.kind not in SHARDABLE_KINDS:
            return NOT_SHARDED
        fn = getattr(self, _DISPATCH[node.kind])
        out = fn(node, ctx, *inputs)
        if out is NOT_SHARDED:
            self._bump(serial_fallbacks=1.0)
        else:
            self._bump(sharded_ops=1.0)
        return out

    # --------------------------------------------------- fused row filters
    def _filter_rows(self, t: Table, k: int, mask_of: Callable) -> Table:
        """Shared core of Select/Residual/IntraFilter: per contiguous row
        block, AND all predicate masks (``mask_of(sub, lo, hi)``) and record
        local survivors; gather the full table ONCE with the concatenated
        (globally ordered) row set."""
        bounds = [b for b in shard_bounds(t.nrows, k) if b[0] < b[1]]

        def task(lo, hi):
            sub = Table(t.name, {c: _col_slice(v, lo, hi)
                                 for c, v in t.columns.items()})
            mask = mask_of(sub, lo, hi)
            return np.nonzero(mask)[0].astype(np.int64) + lo

        parts = self._map(task, bounds)
        self._note_skew([len(p) for p in parts])
        rows = (np.concatenate(parts) if parts
                else np.empty(0, dtype=np.int64))
        return t.take(rows)

    def _run_select(self, node, ctx, t: Table):
        if not node.preds or t.nrows == 0:
            return NOT_SHARDED

        def mask_of(sub: Table, lo, hi):
            mask = sub.eval_predicate(node.preds[0])
            for pred in node.preds[1:]:
                mask = mask & sub.eval_predicate(pred)
            return mask

        return self._filter_rows(t, node.shards, mask_of)

    def _run_residual(self, node, ctx, t: Table):
        if not node.preds or t.nrows == 0:
            return NOT_SHARDED
        # resolve prefixed attrs against the joined relation once
        preds = [dataclasses.replace(
            p, attr=f"x.{physical_mod._col_in(t, p.attr)}")
            for p in node.preds]

        def mask_of(sub: Table, lo, hi):
            mask = sub.eval_predicate(preds[0])
            for pred in preds[1:]:
                mask = mask & sub.eval_predicate(pred)
            return mask

        return self._filter_rows(t, node.shards, mask_of)

    def _run_intrafilter(self, node, ctx, t: Table):
        if t.nrows == 0:
            return NOT_SHARDED
        lv = np.asarray(t.col(physical_mod._col_in(t, node.jp.left)))
        rv = np.asarray(t.col(physical_mod._col_in(t, node.jp.right)))

        def mask_of(sub: Table, lo, hi):
            return lv[lo:hi] == rv[lo:hi]

        return self._filter_rows(t, node.shards, mask_of)

    # ------------------------------------------------------------ exchange
    def _partition_key(self, child_node, col: str, k: int) -> tuple:
        return (fingerprint(child_node.signature()), col, k)

    def _partition_for(self, key: tuple, tbl: Table, col: str, k: int,
                       count_reuse: bool = True) -> BuildPartition:
        with self._lock:
            part = self._cache.get(key)
            if part is not None:
                self._cache.move_to_end(key)
        if part is not None:
            if count_reuse:
                self._bump(exchanges_reused=1.0)
            return part
        part = build_partition(tbl, col, k)
        self._bump(exchanges_built=1.0)
        self._note_skew(part.rows_per_shard())
        with self._lock:
            self._cache[key] = part
            while len(self._cache) > self.CACHE_SLOTS:
                self._cache.popitem(last=False)
        return part

    def _run_exchange(self, node, ctx, t: Table):
        """Materialize (or reuse) the build partition; the operator's output
        is the unchanged child table — the partition is a side structure the
        parent EquiJoin binds to through the cache."""
        col = physical_mod._col_in(t, node.key)
        self._partition_for(self._partition_key(node.children[0], col, node.k),
                            t, col, node.k)
        return t

    # ------------------------------------------------------------ equi-join
    def _run_equijoin(self, node, ctx, lc: Table, rc: Table):
        k = node.shards
        lcol = physical_mod._col_in(lc, node.jp.left)
        rcol = physical_mod._col_in(rc, node.jp.right)
        rchild = node.children[1]
        if rchild.kind == "Exchange":
            key = self._partition_key(rchild.children[0], rcol, rchild.k)
        else:
            key = self._partition_key(rchild, rcol, k)
        # binding to the partition the Exchange child just built is not a
        # co-partition skip — only Exchange-level cache hits count as reuse
        part = self._partition_for(key, rc, rcol, k, count_reuse=False)

        lk, lrows = join_mod._key_arrays(lc, lcol)
        traversal.COUNTERS.cpu_ops += len(lk)
        morsel = max(int(cost_mod.MORSEL_ROWS), 1)
        bounds = [(m0, min(m0 + morsel, len(lk)))
                  for m0 in range(0, len(lk), morsel)]

        use_dense = part.dense_lo is not None and lk.dtype.kind in "iu"

        def probe(m0, m1):
            lk_m = lk[m0:m1]
            if use_dense:
                # direct-address fast path: two gathers locate each probe
                # key's (global) run in rows_cat — no hashing, no search
                idx = lk_m.astype(np.int64) - part.kmin
                valid = (idx >= 0) & (idx < len(part.dense_lo))
                idx = np.where(valid, idx, 0)
                lo_m = part.dense_lo[idx]
                cnt_m = np.where(valid, part.dense_cnt[idx], 0)
                l_rep, slots = expand_runs(lo_m, cnt_m)
                return lrows[m0 + l_rep], part.rows_cat[slots]
            sh_m = hash_shard_ids(lk_m, k)
            lo_m = np.zeros(len(lk_m), dtype=np.int64)
            cnt_m = np.zeros(len(lk_m), dtype=np.int64)
            for s in range(k):
                sel = sh_m == s
                if not sel.any():
                    continue
                ks = part.keys[s]
                lo = np.searchsorted(ks, lk_m[sel], side="left")
                hi = np.searchsorted(ks, lk_m[sel], side="right")
                lo_m[sel] = lo
                cnt_m[sel] = hi - lo
            # serial run-expansion formula: probe-position-major, run order
            l_rep, pos = expand_runs(lo_m, cnt_m)
            li = lrows[m0 + l_rep]
            ri = part.rows_cat[part.base[sh_m[l_rep]] + pos]
            return li, ri

        parts = self._map(probe, bounds) if bounds else []
        if parts:
            li = np.concatenate([p[0] for p in parts])
            ri = np.concatenate([p[1] for p in parts])
        else:
            li = ri = np.empty(0, dtype=np.int64)
        traversal.COUNTERS.cpu_ops += len(li)
        lt, rt = lc.take(li), rc.take(ri)
        cols = dict(lt.columns)
        cols.update(rt.columns)
        return Table(f"{lc.name}⋈{rc.name}", cols)

    # ------------------------------------------------------- pattern match
    def _run_match(self, node, ctx, *masks):
        g = ctx.db.graphs[node.graph]
        extra: dict = {}
        for var, m in zip(node.mask_vars, masks):
            extra[var] = m if var not in extra else (extra[var] & m)
        st = pattern_mod.prepare_match(g, node.pplan,
                                       extra_masks=extra or None)
        starts = np.asarray(st.start_nids)
        if len(starts) == 0:
            return NOT_SHARDED
        st.materialize_members()    # force lazy masks before worker fan-out
        bounds = [b for b in shard_bounds(len(starts), node.shards)
                  if b[0] < b[1]]

        def task(lo, hi):
            return pattern_mod.expand_chain(g, st, starts[lo:hi])

        parts = self._map(task, bounds)
        self._note_skew([len(next(iter(p.values()))) if p else 0
                         for p in parts])
        cols = {var: np.concatenate([p[var] for p in parts])
                for var in parts[0]}
        rel = Table(f"match:{node.pplan.pattern.graph}", cols)
        return pattern_mod.apply_deferred(g, node.pplan.pattern, rel,
                                          node.pplan.deferred)

    # ------------------------------------------------- table-join ablation
    def _run_tablejoinmatch(self, node, ctx):
        g = ctx.db.graphs[node.graph]
        pat = node.pattern
        chain = [pat.vertices[0].var] + [e.dst for e in pat.edges]
        evars = [e.var for e in pat.edges]
        if not evars:
            return NOT_SHARDED
        live = g.live_edge_ids()
        svid = np.asarray(g.edges.col("svid"))
        tvid = np.asarray(g.edges.col("tvid"))
        if g.delta.n_tombstones:
            svid, tvid = svid[live], tvid[live]
        if len(svid) == 0:
            return NOT_SHARDED
        traversal.COUNTERS.record_fetches += 2 * len(svid) * len(evars)
        order = np.argsort(svid, kind="stable")
        svid_s = svid[order]
        bounds = [b for b in shard_bounds(len(svid), node.shards)
                  if b[0] < b[1]]

        def task(lo, hi):
            cols = {chain[0]: svid[lo:hi], evars[0]: live[lo:hi],
                    chain[1]: tvid[lo:hi]}
            cur = Table("join0", cols)
            work = 0
            for h in range(1, len(evars)):
                tail = np.asarray(cur.col(chain[h]))
                lo_ = np.searchsorted(svid_s, tail, side="left")
                hi_ = np.searchsorted(svid_s, tail, side="right")
                l_rep, pos = expand_runs(lo_, hi_ - lo_)
                work += len(pos)
                rows = order[pos]
                ncols = {c: np.asarray(v)[l_rep]
                         for c, v in cur.columns.items()}
                ncols[evars[h]] = live[rows]
                ncols[chain[h + 1]] = tvid[rows]
                cur = Table(f"join{h}", ncols)
            return cur, work

        parts = self._map(task, bounds)
        work = sum(w for _, w in parts)
        traversal.COUNTERS.cpu_ops += work
        traversal.COUNTERS.record_fetches += work
        self._note_skew([p.nrows for p, _ in parts])
        cols = {c: np.concatenate([np.asarray(p.columns[c])
                                   for p, _ in parts])
                for c in parts[0][0].columns}
        rel = Table(f"join{len(evars) - 1}", cols)
        return pattern_mod.apply_deferred(g, pat, rel, node.deferred)

    # -------------------------------------------------- born-sharded GCDA
    def _run_rel2matrix(self, node, ctx, rel: Table):
        if rel.nrows == 0:
            return NOT_SHARDED
        mat, spec = analytics.rel2matrix_sharded(rel, node.columns,
                                                 node.shards)
        self._note_skew(spec.pop("rows_per_block", []))
        node.last_kernel_args = spec    # -> merged into the GCDA trace span
        return mat


_DISPATCH = {
    "Select": "_run_select",
    "Residual": "_run_residual",
    "IntraFilter": "_run_intrafilter",
    "EquiJoin": "_run_equijoin",
    "Exchange": "_run_exchange",
    "MatchPattern": "_run_match",
    "TableJoinMatch": "_run_tablejoinmatch",
    "Rel2Matrix": "_run_rel2matrix",
}
