"""Multi-model schema, predicates, and the SFMW query AST (paper §3.2).

A GCDI task is a Select-From-Match-Where (SFMW) expression, Eq. (1):

    T = pi_A( sigma_Psi( H1 join_F1 ... join_Fk-1 ( gpi_A' P(Hk, Pk) ) ) )

The AST here mirrors that algebra: ``Query`` holds projections (``select``),
collections (``froms``), an optional graph ``match`` (pattern), cross-model
join predicates (``joins``), and residual predicates (``where``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

# ---------------------------------------------------------------------------
# Predicates (paper Definition 5)
# ---------------------------------------------------------------------------

OPS = ("==", "!=", "<", "<=", ">", ">=", "range", "in")


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Single-collection predicate  F: record -> bool  over one attribute.

    ``attr`` is ``"collection.column"`` (document path expressions use dots
    too — the storage layer shreds paths into columns, so ``orders.item.id``
    is just a column name).
    """

    attr: str
    op: str
    value: Any
    value2: Any = None  # upper bound for "range"

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"bad predicate op {self.op!r}")

    @property
    def collection(self) -> str:
        return self.attr.split(".", 1)[0]

    @property
    def column(self) -> str:
        return self.attr.split(".", 1)[1]

    @property
    def is_equality(self) -> bool:
        return self.op == "=="

    @property
    def is_inequality(self) -> bool:
        return self.op == "!="

    @property
    def is_range(self) -> bool:
        return self.op in ("<", "<=", ">", ">=", "range")

    def __repr__(self):  # compact for plan printouts
        if self.op == "range":
            return f"{self.attr} in [{self.value},{self.value2}]"
        return f"{self.attr} {self.op} {self.value!r}"


@dataclasses.dataclass(frozen=True)
class JoinPred:
    """Cross-model equi-join predicate  F(h1, h2) := h1.left == h2.right."""

    left: str   # "collection.column"
    right: str  # "collection.column"

    @property
    def left_collection(self) -> str:
        return self.left.split(".", 1)[0]

    @property
    def right_collection(self) -> str:
        return self.right.split(".", 1)[0]

    def __repr__(self):
        return f"{self.left}={self.right}"


# ---------------------------------------------------------------------------
# Graph patterns (paper §5.2):  P = (G_p, U, Phi)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PatternVertex:
    var: str            # variable name, e.g. "p"
    label: str          # vertex label, e.g. "Persons"


@dataclasses.dataclass(frozen=True)
class PatternEdge:
    var: str
    label: str
    src: str            # source vertex var
    dst: str            # target vertex var


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A chain/star pattern graph. ``vertices``/``edges`` define G_p; the
    ordered hybrid-traversal sequence U is derived by the planner (forward or
    reverse, per the cost model); ``Phi`` (predicate assignment) lives in the
    enclosing Query.where and is *assigned* to pattern elements by the
    planner's graph-predicate-pushdown pass.
    """

    graph: str                       # graph collection name
    vertices: tuple[PatternVertex, ...]
    edges: tuple[PatternEdge, ...]

    def vertex(self, var: str) -> PatternVertex:
        for v in self.vertices:
            if v.var == var:
                return v
        raise KeyError(var)

    def canonical(self) -> tuple:
        """Structural identity of the pattern graph — the stable tuple the
        physical plan's node signatures embed (§6.4 structural matching)."""
        return (self.graph,
                tuple((v.var, v.label) for v in self.vertices),
                tuple((e.var, e.label, e.src, e.dst) for e in self.edges))

    @property
    def is_chain(self) -> bool:
        # v0 -e0-> v1 -e1-> v2 ... (each edge links consecutive vertices)
        if not self.edges:
            return True
        order = [v.var for v in self.vertices]
        for i, e in enumerate(self.edges):
            if e.src not in order or e.dst not in order:
                return False
        return True


def chain_pattern(graph: str, *hops: tuple[str, str, str, str, str]) -> Pattern:
    """Build a chain pattern from (src_var, src_label, edge_label, dst_var,
    dst_label) hops, e.g. ``chain_pattern("Interested_in",
    ("p","Persons","Interested_in","t","Tags"))``."""
    vertices: list[PatternVertex] = []
    edges: list[PatternEdge] = []
    seen = {}
    for i, (sv, sl, el, dv, dl) in enumerate(hops):
        if sv not in seen:
            seen[sv] = PatternVertex(sv, sl)
            vertices.append(seen[sv])
        if dv not in seen:
            seen[dv] = PatternVertex(dv, dl)
            vertices.append(seen[dv])
        edges.append(PatternEdge(f"e{i}", el, sv, dv))
    return Pattern(graph, tuple(vertices), tuple(edges))


# ---------------------------------------------------------------------------
# SFMW query
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Query:
    """Select-From-Match-Where GCDI task (Eq. 1)."""

    select: tuple[str, ...]                 # projection attributes "coll.col" or "var.prop"
    froms: tuple[str, ...]                  # relational/document collection names
    match: Optional[Pattern] = None         # at most one graph pattern (paper Eq. 8)
    joins: tuple[JoinPred, ...] = ()        # cross-model join predicates, in join order
    where: tuple[Predicate, ...] = ()       # selection predicate set Psi

    def predicates_on(self, collection: str) -> list[Predicate]:
        return [p for p in self.where if p.collection == collection]

    def source_names(self) -> tuple[str, ...]:
        """Every collection this task reads (tables/documents + the matched
        graph) — the set whose write epochs gate inter-buffer reuse."""
        names = list(self.froms)
        if self.match is not None:
            names.append(self.match.graph)
        return tuple(names)


# ---------------------------------------------------------------------------
# GCDA task spec (Eq. 5/6):  T = A(G(T_GCDI))
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalyticsTask:
    """``op`` in {"MULTIPLY", "SIMILARITY", "REGRESSION"} applied to matrices
    generated from GCDI results (paper Table 3). ``inputs`` name matrix
    sources: either ("rel2matrix", query, columns) local access, or
    ("random", query, group_col, value_col) random access aggregation.
    """

    op: str
    inputs: Sequence[Any]
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class GCDIATask:
    integration: Query
    analytics: AnalyticsTask
