"""GCDI plan generation + optimization framework (paper §6.1-6.3).

Four mechanisms, as in the paper:
  1. *Graph predicate pushdown* — predicates on pattern vars are assigned to
     the pattern (Phi) and pushed per the Fig. 6 rule/cost strategies; and
     predicates on a rel/doc collection joined with a pattern vertex on the
     same attribute are *replicated* onto the graph side (transitivity).
  2. *Join pushdown* — Eq. (8) -> Eq. (9)/(10): a join between a table and the
     graph-relation is rewritten (cost-based) into a semi-join that shrinks
     the graph's candidate vertex sets *before* matching.
  3. *GCDI rewriting* — match trimming (patterns with no topology constraint
     -> record scan; v-e-v patterns touching only edges -> edge scan) and
     projection trimming (drop graph-projection columns never referenced).
  4. *Query-aware traversal pruning* — carried by PatternPlan.fetch_vars:
     unreferenced, predicate-free pattern vars never fetch records.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import pattern as pattern_mod
from .schema import JoinPred, Predicate, Query
from .storage import Database, Table


@dataclasses.dataclass
class GCDIPlan:
    query: Query
    pattern_plan: Optional[pattern_mod.PatternPlan]
    table_pushdown: dict                  # collection -> [Predicate]
    residual: list                        # predicates evaluated post-join
    semi_join_idx: set                    # candidate graph↔table join indices (Eq. 9/10
                                          # siding is decided by repro.core.optimizer)
    graph_projection: set                 # pattern vars kept after projection trimming
    match_trim: Optional[str]             # None | "vertex_scan" | "edge_scan"
    notes: list = dataclasses.field(default_factory=list)

    def explain(self) -> str:
        lines = ["GCDI plan:"]
        for c, ps in self.table_pushdown.items():
            lines.append(f"  σ-pushdown[{c}]: {ps}")
        if self.pattern_plan:
            lines.append("  " + self.pattern_plan.describe())
        if self.match_trim:
            lines.append(f"  match-trimming: {self.match_trim}")
        if self.semi_join_idx:
            lines.append(f"  join-pushdown candidates (Eq.9/10): joins "
                         f"{sorted(self.semi_join_idx)} (siding: optimizer)")
        lines.append(f"  graph-projection A' = {sorted(self.graph_projection)}")
        if self.residual:
            lines.append(f"  residual σ: {self.residual}")
        lines.extend("  note: " + n for n in self.notes)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------


def plan(db: Database, q: Query, enable_opt: bool = True,
         enable_pattern_pushdown: Optional[bool] = None) -> GCDIPlan:
    if enable_pattern_pushdown is None:
        enable_pattern_pushdown = enable_opt
    notes: list[str] = []
    pattern = q.match
    pattern_vars: set[str] = set()
    if pattern:
        pattern_vars = {v.var for v in pattern.vertices} | {e.var for e in pattern.edges}

    # --- split predicates: table-pushable / pattern (Phi) / residual ---
    table_pushdown: dict[str, list[Predicate]] = {}
    phi: dict[str, list[Predicate]] = {}
    residual: list[Predicate] = []
    for p in q.where:
        if p.collection in pattern_vars:
            phi.setdefault(p.collection, []).append(p)   # mechanism 1 (into match)
        elif p.collection in q.froms:
            if enable_opt:
                table_pushdown.setdefault(p.collection, []).append(p)
            else:
                residual.append(p)
        else:
            residual.append(p)

    # --- mechanism 1b: replicate predicates across models via join equality ---
    if enable_opt and pattern:
        for jp in q.joins:
            lc, rc = jp.left_collection, jp.right_collection
            tbl_side, var_side = None, None
            if lc in q.froms and rc in pattern_vars:
                tbl_side, var_side = jp.left, jp.right
            elif rc in q.froms and lc in pattern_vars:
                tbl_side, var_side = jp.right, jp.left
            if tbl_side is None:
                continue
            tcoll, tcol = tbl_side.split(".", 1)
            vvar, vcol = var_side.split(".", 1)
            for p in table_pushdown.get(tcoll, []):
                if p.column == tcol and p.is_equality:
                    rep = Predicate(f"{vvar}.{vcol}", p.op, p.value, p.value2)
                    if rep in phi.get(vvar, []):
                        continue    # the query already states it directly
                    phi.setdefault(vvar, []).append(rep)
                    notes.append(f"replicated {p} across join {jp} -> {rep}")

    # --- mechanism 3a: match trimming ---
    match_trim = None
    if enable_opt and pattern:
        referenced = _referenced_vars(q, pattern_vars)
        if not pattern.edges:
            match_trim = "vertex_scan"
            notes.append("match-trimming: pattern has no topology constraint")
        elif (len(pattern.edges) == 1 and len(pattern.vertices) == 2
              and all(v not in phi for v in (pattern.vertices[0].var, pattern.vertices[1].var))
              and referenced <= {pattern.edges[0].var}):
            match_trim = "edge_scan"
            notes.append("match-trimming: v-e-v with edge-only predicates/projection")

    # --- mechanism 3b: projection trimming ---
    graph_projection: set[str] = set()
    if pattern:
        graph_projection = _referenced_vars(q, pattern_vars)
        if enable_opt:
            notes.append(f"projection-trimming keeps {sorted(graph_projection)} of "
                         f"{sorted(pattern_vars)}")
        else:
            graph_projection = set(pattern_vars)

    # --- mechanism 2: join pushdown candidates (Eq. 8 -> 9/10) ---
    # The *logical* decision stops at eligibility: which joins connect a
    # table/document collection to a pattern vertex. The cost-based siding
    # (graph-side mask vs. table-side reduce vs. post-match join) is a
    # physical rewrite, made by repro.core.optimizer against live statistics.
    semi_join_idx: set[int] = set()
    if enable_opt and pattern and not match_trim:
        for i, jp in enumerate(q.joins):
            if _graph_join_side(q, pattern_vars, jp) is not None:
                semi_join_idx.add(i)
                notes.append(f"join-pushdown candidate join#{i} ({jp}): "
                             "siding decided by the optimizer")

    # --- pattern plan (mechanism 1 + 4 inside) ---
    pattern_plan = None
    if pattern and not match_trim:
        pattern_plan = pattern_mod.plan_pattern(
            db.graphs[pattern.graph], pattern, phi, graph_projection,
            enable_pushdown=enable_pattern_pushdown)
    elif pattern and match_trim:
        pattern_plan = pattern_mod.PatternPlan(pattern, False, {}, phi, graph_projection)

    return GCDIPlan(q, pattern_plan, table_pushdown, residual, semi_join_idx,
                    graph_projection, match_trim, notes)


def _referenced_vars(q: Query, pattern_vars: set[str]) -> set[str]:
    """Vars referenced by projection, joins, or residual predicates."""
    ref = set()
    for a in q.select:
        c = a.split(".", 1)[0]
        if c in pattern_vars:
            ref.add(c)
    for jp in q.joins:
        for side in (jp.left, jp.right):
            c = side.split(".", 1)[0]
            if c in pattern_vars:
                ref.add(c)
    for p in q.where:
        if p.collection in pattern_vars:
            ref.add(p.collection)
    return ref


def _graph_join_side(q: Query, pattern_vars: set[str], jp: JoinPred):
    if jp.left_collection in q.froms and jp.right_collection in pattern_vars:
        return jp.left, jp.right
    if jp.right_collection in q.froms and jp.left_collection in pattern_vars:
        return jp.right, jp.left
    return None


# ---------------------------------------------------------------------------
# Execution — the logical plan is lowered to the physical operator DAG
# (repro.core.physical) and walked bottom-up; steps 1-5 of the old monolithic
# executor are now node constructors in ``physical.build_gcdi``.
# ---------------------------------------------------------------------------


def execute(db: Database, p: GCDIPlan, mode: str = "gredo") -> Table:
    from . import physical
    dag = physical.build_gcdi(db, p, mode=mode)
    return physical.execute(dag, physical.ExecContext(db))
