"""GCDI plan generation + optimization framework (paper §6.1-6.3).

Four mechanisms, as in the paper:
  1. *Graph predicate pushdown* — predicates on pattern vars are assigned to
     the pattern (Phi) and pushed per the Fig. 6 rule/cost strategies; and
     predicates on a rel/doc collection joined with a pattern vertex on the
     same attribute are *replicated* onto the graph side (transitivity).
  2. *Join pushdown* — Eq. (8) -> Eq. (9)/(10): a join between a table and the
     graph-relation is rewritten (cost-based) into a semi-join that shrinks
     the graph's candidate vertex sets *before* matching.
  3. *GCDI rewriting* — match trimming (patterns with no topology constraint
     -> record scan; v-e-v patterns touching only edges -> edge scan) and
     projection trimming (drop graph-projection columns never referenced).
  4. *Query-aware traversal pruning* — carried by PatternPlan.fetch_vars:
     unreferenced, predicate-free pattern vars never fetch records.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import cost as cost_mod
from . import pattern as pattern_mod
from .schema import JoinPred, Pattern, Predicate, Query
from .storage import Database, Graph, Table


@dataclasses.dataclass
class GCDIPlan:
    query: Query
    pattern_plan: Optional[pattern_mod.PatternPlan]
    table_pushdown: dict                  # collection -> [Predicate]
    residual: list                        # predicates evaluated post-join
    semi_join_idx: set                    # indices into query.joins executed as graph semi-joins
    graph_projection: set                 # pattern vars kept after projection trimming
    match_trim: Optional[str]             # None | "vertex_scan" | "edge_scan"
    notes: list = dataclasses.field(default_factory=list)

    def explain(self) -> str:
        lines = ["GCDI plan:"]
        for c, ps in self.table_pushdown.items():
            lines.append(f"  σ-pushdown[{c}]: {ps}")
        if self.pattern_plan:
            lines.append("  " + self.pattern_plan.describe())
        if self.match_trim:
            lines.append(f"  match-trimming: {self.match_trim}")
        if self.semi_join_idx:
            lines.append(f"  join-pushdown (Eq.9/10) on joins {sorted(self.semi_join_idx)}")
        lines.append(f"  graph-projection A' = {sorted(self.graph_projection)}")
        if self.residual:
            lines.append(f"  residual σ: {self.residual}")
        lines.extend("  note: " + n for n in self.notes)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------


def plan(db: Database, q: Query, enable_opt: bool = True,
         enable_pattern_pushdown: Optional[bool] = None) -> GCDIPlan:
    if enable_pattern_pushdown is None:
        enable_pattern_pushdown = enable_opt
    notes: list[str] = []
    pattern = q.match
    pattern_vars: set[str] = set()
    if pattern:
        pattern_vars = {v.var for v in pattern.vertices} | {e.var for e in pattern.edges}

    # --- split predicates: table-pushable / pattern (Phi) / residual ---
    table_pushdown: dict[str, list[Predicate]] = {}
    phi: dict[str, list[Predicate]] = {}
    residual: list[Predicate] = []
    for p in q.where:
        if p.collection in pattern_vars:
            phi.setdefault(p.collection, []).append(p)   # mechanism 1 (into match)
        elif p.collection in q.froms:
            if enable_opt:
                table_pushdown.setdefault(p.collection, []).append(p)
            else:
                residual.append(p)
        else:
            residual.append(p)

    # --- mechanism 1b: replicate predicates across models via join equality ---
    if enable_opt and pattern:
        for jp in q.joins:
            lc, rc = jp.left_collection, jp.right_collection
            tbl_side, var_side = None, None
            if lc in q.froms and rc in pattern_vars:
                tbl_side, var_side = jp.left, jp.right
            elif rc in q.froms and lc in pattern_vars:
                tbl_side, var_side = jp.right, jp.left
            if tbl_side is None:
                continue
            tcoll, tcol = tbl_side.split(".", 1)
            vvar, vcol = var_side.split(".", 1)
            for p in table_pushdown.get(tcoll, []):
                if p.column == tcol and p.is_equality:
                    rep = Predicate(f"{vvar}.{vcol}", p.op, p.value, p.value2)
                    phi.setdefault(vvar, []).append(rep)
                    notes.append(f"replicated {p} across join {jp} -> {rep}")

    # --- mechanism 3a: match trimming ---
    match_trim = None
    if enable_opt and pattern:
        referenced = _referenced_vars(q, pattern_vars)
        if not pattern.edges:
            match_trim = "vertex_scan"
            notes.append("match-trimming: pattern has no topology constraint")
        elif (len(pattern.edges) == 1 and len(pattern.vertices) == 2
              and all(v not in phi for v in (pattern.vertices[0].var, pattern.vertices[1].var))
              and referenced <= {pattern.edges[0].var}):
            match_trim = "edge_scan"
            notes.append("match-trimming: v-e-v with edge-only predicates/projection")

    # --- mechanism 3b: projection trimming ---
    graph_projection: set[str] = set()
    if pattern:
        graph_projection = _referenced_vars(q, pattern_vars)
        if enable_opt:
            notes.append(f"projection-trimming keeps {sorted(graph_projection)} of "
                         f"{sorted(pattern_vars)}")
        else:
            graph_projection = set(pattern_vars)

    # --- mechanism 2: cost-based join pushdown (Eq. 8 -> 9/10) ---
    semi_join_idx: set[int] = set()
    if enable_opt and pattern and not match_trim:
        g: Graph = db.graphs[pattern.graph]
        for i, jp in enumerate(q.joins):
            side = _graph_join_side(q, pattern_vars, jp)
            if side is None:
                continue
            tbl_attr, var_attr = side
            tcoll = tbl_attr.split(".", 1)[0]
            tbl = db.tables[tcoll]
            n_t = tbl.nrows
            for p in table_pushdown.get(tcoll, []):
                n_t = int(n_t * tbl.stats(p.column).selectivity(p))
            vvar = var_attr.split(".", 1)[0]
            vlabel = pattern.vertex(vvar).label
            n_v = g.vertex_tables[vlabel].nrows
            hops = len(pattern.edges)
            est_match = n_v * (g.avg_out_degree ** hops)
            # Plan A (Eq. 8): match on full candidates, then join
            # (n_live_edges: base edges may drift from reality between
            # delta-store compactions)
            cost_a = cost_mod.cost_pattern(0, 0, n_v, g.n_live_edges, n_v, hops,
                                           g.avg_out_degree, est_match, 0)
            cost_a += cost_mod.cost_join(est_match, n_t)
            # Plan B (Eq. 9/10): semi-join shrinks candidates, then match
            shrink = min(1.0, n_t / max(n_v, 1))
            est_match_b = n_v * shrink * (g.avg_out_degree ** hops)
            cost_b = cost_mod.cost_join(n_v, n_t)
            cost_b += cost_mod.cost_pattern(0, 0, int(n_v * shrink), g.n_live_edges,
                                            n_v * shrink, hops, g.avg_out_degree,
                                            est_match_b, 0)
            if cost_b < cost_a:
                semi_join_idx.add(i)
                notes.append(f"join-pushdown join#{i} ({jp}): cost {cost_b:.3g} < {cost_a:.3g}")
            else:
                notes.append(f"join kept post-match join#{i} ({jp}): {cost_a:.3g} <= {cost_b:.3g}")

    # --- pattern plan (mechanism 1 + 4 inside) ---
    pattern_plan = None
    if pattern and not match_trim:
        pattern_plan = pattern_mod.plan_pattern(
            db.graphs[pattern.graph], pattern, phi, graph_projection,
            enable_pushdown=enable_pattern_pushdown)
    elif pattern and match_trim:
        pattern_plan = pattern_mod.PatternPlan(pattern, False, {}, phi, graph_projection)

    return GCDIPlan(q, pattern_plan, table_pushdown, residual, semi_join_idx,
                    graph_projection, match_trim, notes)


def _referenced_vars(q: Query, pattern_vars: set[str]) -> set[str]:
    """Vars referenced by projection, joins, or residual predicates."""
    ref = set()
    for a in q.select:
        c = a.split(".", 1)[0]
        if c in pattern_vars:
            ref.add(c)
    for jp in q.joins:
        for side in (jp.left, jp.right):
            c = side.split(".", 1)[0]
            if c in pattern_vars:
                ref.add(c)
    for p in q.where:
        if p.collection in pattern_vars:
            ref.add(p.collection)
    return ref


def _graph_join_side(q: Query, pattern_vars: set[str], jp: JoinPred):
    if jp.left_collection in q.froms and jp.right_collection in pattern_vars:
        return jp.left, jp.right
    if jp.right_collection in q.froms and jp.left_collection in pattern_vars:
        return jp.right, jp.left
    return None


# ---------------------------------------------------------------------------
# Execution — the logical plan is lowered to the physical operator DAG
# (repro.core.physical) and walked bottom-up; steps 1-5 of the old monolithic
# executor are now node constructors in ``physical.build_gcdi``.
# ---------------------------------------------------------------------------


def execute(db: Database, p: GCDIPlan, mode: str = "gredo") -> Table:
    from . import physical
    dag = physical.build_gcdi(db, p, mode=mode)
    return physical.execute(dag, physical.ExecContext(db))
