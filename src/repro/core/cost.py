"""Cost model (paper §6.3), retargeted from disk I/O to a memory-hierarchy
model suitable for the TPU/vectorized engine.

The paper charges ``Cost_IO`` per record fetch and ``Cost_cpu`` per function
call / predicate evaluation. We keep the exact formula structure (Eqs. 11-16)
and re-interpret the constants: one "I/O" = moving a record across the
HBM->VMEM boundary (bytes / bandwidth), one "cpu" = one vector-lane op. The
*ratio* is what drives planning; calibrated so record fetches dominate
identifier-space ops, as on the paper's disk engine.
"""
from __future__ import annotations

import numpy as np

# Relative unit costs. On TPU v5e: HBM 819 GB/s, VPU ~ 4 ops/cycle/lane;
# a 64B record fetch ~ 78ns/1KB-row amortized vs ~0.5ns per lane op -> ~40x.
COST_IO = 40.0
COST_CPU = 1.0


# ---- hybrid traversal costs (4 cases, §6.3) --------------------------------

def cost_v_to_nid(n: int) -> float:
    return n * COST_CPU


def cost_nid_to_v(n: int) -> float:
    return n * (COST_CPU + COST_IO)


def cost_nid_to_nid(n: int, avg_deg: float) -> float:
    return n * avg_deg * COST_CPU


def cost_nid_to_e(n: int, avg_deg: float) -> float:
    return n * avg_deg * (2 * COST_CPU + COST_IO)


# ---- pattern matching cost (Eq. 11-13) --------------------------------------

def cost_pattern(n_push_v: int, n_push_e: int, n_vertices: int, n_edges: int,
                 est_frontier: float, hops: int, avg_deg: float,
                 est_result: float, n_deferred: int) -> float:
    cost_algo2 = (n_push_v * n_vertices + n_push_e * n_edges) * (COST_IO + COST_CPU)
    lam = sum(avg_deg ** (h + 1) for h in range(hops))  # traversals per start record
    cost_algo2 += est_frontier * lam * COST_CPU
    cost_prop = est_result * n_deferred * COST_CPU
    return cost_algo2 + cost_prop


COST_LAUNCH = 5000.0   # fixed dispatch + host<->device sync per launch window
DEVICE_LANES = 8.0     # vector-lane speedup of device frontier expansion


def cost_device_match(n_push_v: int, n_push_e: int, n_vertices: int,
                      n_edges: int, est_frontier: float, hops: int,
                      avg_deg: float, est_result: float, n_deferred: int, *,
                      zone_frac: float = 1.0,
                      per_hop_sync: bool = False) -> float:
    """Device-resident pattern match (DeviceMatchPattern). Differs from
    ``cost_pattern`` in three ways: vertex predicate tables are pure columnar
    scans (no per-record fetch), edge predicate tables read only the
    zone-candidate fraction of the edge column (the kernel's prefetch filter
    skips dead chunks), and the per-record traversal work runs at vector
    width. In exchange every launch window pays a fixed dispatch+sync
    charge — per hop for the jit matcher (it syncs on the overflow flag each
    hop), once for the fused chain (one end-of-chain sync)."""
    tables = (n_push_v * n_vertices * COST_CPU
              + n_push_e * max(zone_frac, 0.0) * n_edges * (COST_IO + COST_CPU))
    lam = sum(avg_deg ** (h + 1) for h in range(hops))
    traverse = est_frontier * lam * COST_CPU / DEVICE_LANES
    launches = (2.0 * hops) if per_hop_sync else 2.0
    return tables + traverse + launches * COST_LAUNCH + est_result * n_deferred * COST_CPU


def should_push_range(g, tbl, pred) -> bool:
    """Cost-compare pushing a range predicate at the end vertex vs deferring
    it to the graph-relation (Fig. 6 end-vertex rule)."""
    sel = tbl.stats(pred.column).selectivity(pred)
    n = tbl.nrows
    avg_deg = g.avg_out_degree
    # push: full column scan now, but frontier shrinks by sel
    est_matches = n * avg_deg  # rough |P(G,P)| upper bound for one hop
    push_cost = n * (COST_IO + COST_CPU) + sel * est_matches * COST_CPU
    # defer: full expansion, then evaluate on result rows (record fetch each)
    defer_cost = est_matches * (COST_CPU + COST_IO)
    return push_cost <= defer_cost


# ---- physical-operator costs (consumed by physical.estimate) ---------------

def cost_scan(n: int) -> float:
    """Sequential RecordAM scan of n records."""
    return n * (COST_IO + COST_CPU)


def cost_project(n: int, n_attrs: int) -> float:
    """Graph projection π̂_A': one tid-based record fetch per (row, attr)."""
    return n * max(n_attrs, 1) * (COST_IO + COST_CPU)


def cost_filter(n: float, n_preds: int = 1) -> float:
    """Post-scan/post-join predicate application (Select residue,
    IntraFilter, Residual): one vector-lane compare per (row, predicate).
    Shared by ``physical.estimate`` and the optimizer's join enumerator so
    both charge identical prices for folding a predicate into a plan."""
    return float(n) * max(n_preds, 1) * COST_CPU


def cost_index_lookup(n: float, hits: float) -> float:
    """Posting-list access path: binary probes into the sorted postings
    (log n) plus one tid-based record fetch per matching row — the price
    that undercuts ``cost_scan`` exactly when the predicate is selective."""
    return (np.log2(max(n, 2.0)) * COST_CPU
            + max(hits, 0.0) * (COST_IO + COST_CPU))


ZONE_CHUNK = 2048   # rows per zone-map chunk (repro.core.index imports this)


def cost_zone_scan(n: float, frac: float, n_chunks: float = 0.0) -> float:
    """Zone-map skip-scan: one min/max probe per chunk, then a sequential
    scan of the candidate fraction only. Callers holding the live ZoneMap
    pass its actual ``n_chunks``; the default derives from ZONE_CHUNK."""
    nch = n_chunks if n_chunks else max(float(n) / ZONE_CHUNK, 1.0)
    return nch * COST_CPU + max(frac, 0.0) * float(n) * (COST_IO + COST_CPU)


def cost_semijoin(n_left: int, n_right: int) -> float:
    """Semi-join reduction (Eq. 9/10 mask build): sort the smaller key set,
    binary-probe the larger — no output expansion."""
    nl, nr = max(n_left, 1), max(n_right, 1)
    small = min(nl, nr)
    return (small * np.log2(max(small, 2)) + nl + nr) * COST_CPU


# ---- matrix generation + analytical operator costs (GCDA, Eq. 5/6) ---------

def cost_matrix_gen(n: int, k: int) -> float:
    """REL2MATRIX / random access: one gather+scatter per (row, feature)."""
    return n * max(k, 1) * (COST_IO + COST_CPU)


def cost_matmul(n: int, k: int, m: int) -> float:
    return float(n) * max(k, 1) * max(m, 1) * COST_CPU


def cost_similarity(n: int, k: int, m: int) -> float:
    # normalize both sides + one (n, m) score matmul
    return (n + m) * max(k, 1) * COST_CPU + cost_matmul(n, k, m)


def cost_regression(n: int, k: int, iters: int) -> float:
    return 2.0 * float(iters) * cost_matmul(n, k, 1)


# ---- cross-model join cost (Eq. 14-16) ---------------------------------------

BLOCK_RECORDS = 1024  # b: records per block (vector register tile analogue)


def cost_join(n_left: int, n_right: int, in_memory: bool = True) -> float:
    if in_memory:  # Eq. 14 — but our engine sorts: O((N+M) log) cpu
        nl, nr = max(n_left, 1), max(n_right, 1)
        return (nl * np.log2(nl) + nr * np.log2(nr) + nl + nr) * COST_CPU
    # Eq. 15 (both fit in buffer) — kept for fidelity with the paper
    return ((n_left + n_right) / BLOCK_RECORDS) * COST_IO + n_left * n_right * COST_CPU


def cost_join_nested(n_left: int, n_right: int) -> float:
    """Eq. 14 literal (nested loop) — used by the volcano baseline."""
    return n_left * n_right * COST_CPU


# ---- sharded execution costs (morsel-parallel operator DAG) -----------------

MORSEL_ROWS = 262144        # probe-side rows per morsel (large: amortizes
                            # per-morsel dispatch; fits L2-ish working sets)
SHARD_MIN_ROWS = 100000     # below this dominant input, serial execution wins
SHARD_OVERHEAD = 2000.0     # fixed per-shard setup (task dispatch, slicing)


def cost_exchange(n: float, k: int) -> float:
    """Partition-exchange: hash every key (one lane op), one stable counting
    sort into k runs (two passes over the rows), then a per-shard key sort.
    Co-partitioned inputs (cached partitions at the same epoch) skip this
    entirely — the cost the executor's exchange cache saves."""
    n = max(float(n), 1.0)
    per_shard = n / max(k, 1)
    return (3.0 * n + k * per_shard * np.log2(max(per_shard, 2.0))) * COST_CPU


def cost_sharded_scan(n: float, n_preds: int, k: int) -> float:
    """Fused per-shard filter: predicate masks are ANDed per shard and rows
    are gathered once, instead of one full ``take`` per predicate — the
    row-movement term drops from ``n_preds`` gathers to one."""
    n = max(float(n), 0.0)
    return (n * max(n_preds, 1) * COST_CPU     # mask evaluation
            + n * COST_IO                       # single gather
            + k * SHARD_OVERHEAD)


def cost_sharded_join(n_left: float, n_right: float, k: int) -> float:
    """Hash-sharded sort-merge join: the build side pays the exchange + one
    per-shard key sort; each probe morsel binary-searches its shard only
    (log of the per-shard run, not of the whole build side)."""
    nl, nr = max(float(n_left), 1.0), max(float(n_right), 1.0)
    per_shard = nr / max(k, 1)
    probe = nl * (1.0 + np.log2(max(per_shard, 2.0))) * COST_CPU
    return cost_exchange(nr, k) + probe + k * SHARD_OVERHEAD


def choose_shard_count(dominant_rows: float, k_requested: int) -> int:
    """Cost-based shard-count choice: serial (k=1) when the dominant input
    is too small for the per-shard setup + exchange to pay off. The
    crossover is where the sharded join/scan costs (above) undercut the
    serial ``cost_join``/``cost_scan`` — in practice a fixed floor, since
    both models scale linearly past it."""
    k = max(int(k_requested), 1)
    if k == 1 or dominant_rows < SHARD_MIN_ROWS:
        return 1
    return k


# ---------------------------------------------------------------------------
# Device traversal capacity (§ device lowering): shared by the optimizer's
# access-path selection and the static plan verifier — the two must derive
# the identical bound or verification would reject the optimizer's own plans.
# ---------------------------------------------------------------------------


def padded_capacity(peak: float) -> int:
    """Static-shape frontier capacity for an estimated peak candidate count:
    2x headroom (estimates err low on skewed fan-out), rounded up to a
    power of two with a 128-slot floor (one compaction block)."""
    need = max(int(peak * 2.0), 1)
    return 1 << max(7, (need - 1).bit_length())


def device_frontier_peak(g, pplan) -> float:
    """Statically derivable peak frontier of a mask-free chain pattern:
    start-label cardinality scaled by pushed-predicate selectivity, then
    per-hop label-aware expansion — *pre*-predicate, since the kernel's
    capacity must hold every candidate before in-kernel compaction."""
    pat = pplan.pattern
    chain = [pat.vertices[0].var] + [e.dst for e in pat.edges]
    hop_order = chain[::-1] if pplan.reverse else chain
    start = hop_order[0]
    stbl = g.vertex_tables[pat.vertex(start).label]
    n_start = float(stbl.nrows)
    for pr in pplan.pushed.get(start, []):
        n_start *= stbl.stats(pr.column).selectivity(pr)
    peak = front = max(n_start, 1.0)
    for v in hop_order[:-1]:
        front *= g.hop_expansion(reverse=pplan.reverse,
                                 label=pat.vertex(v).label)
        peak = max(peak, front)
    return peak
