"""In-memory inter-buffer for matrix storage (paper §4.2, §6.4).

Materializes GCDI results as device-resident matrices that analytical
operators consume directly (no tuple-at-a-time production). Entries are
keyed by a *structural fingerprint* of the producing GCDI plan + matrix
generation spec, so semantically-equivalent GCDIA tasks reuse materialized
outputs without re-execution (paper: "intermediate results in the
inter-buffer are reused across analytical tasks via structural matching of
GCDI plans").
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def fingerprint(*parts: Any) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
    return h.hexdigest()[:16]


def _column_nbytes(col) -> int:
    if hasattr(col, "codes"):       # DictColumn: codes + a vocab estimate
        return int(col.codes.nbytes) + 16 * len(col.vocab)
    if hasattr(col, "offsets"):     # RaggedColumn
        return int(np.asarray(col.values).nbytes) + int(col.offsets.nbytes)
    return int(np.asarray(col).nbytes)


def value_nbytes(val) -> int:
    """Resident size of an inter-buffer entry: a device matrix or a
    materialized GCDI relation (columnar Table)."""
    if hasattr(val, "columns"):     # Table duck type
        return sum(_column_nbytes(c) for c in val.columns.values())
    if hasattr(val, "size") and hasattr(val, "dtype"):
        return int(val.size) * val.dtype.itemsize
    return int(np.asarray(val).nbytes)


class InterBuffer:
    """LRU over an :class:`OrderedDict` (MRU at the end). Re-putting an
    existing key replaces it in place (no duplicate order entries), and
    eviction may drop every entry — a single matrix larger than the capacity
    is not retained.

    Admission is cost-aware: a put carrying an ``est_cost`` (the §6.3
    estimated recompute cost of the producing sub-plan) is only admitted
    when that cost exceeds a footprint-scaled threshold
    (``admit_cost_per_byte`` cost units per resident byte) — cheap-to-
    recompute bulky intermediates bypass the cache instead of evicting
    expensive ones. Puts without an estimate are always admitted.

    Thread-safe: morsel workers of the sharded executor hit ``get``/``put``
    concurrently, so the store, byte accounting, and hit/miss counters are
    guarded by one lock (LRU reordering under concurrency must not corrupt
    the OrderedDict)."""

    def __init__(self, capacity_bytes: int = 2 << 30,
                 admit_cost_per_byte: float = 0.0):
        self.capacity_bytes = capacity_bytes
        self.admit_cost_per_byte = admit_cost_per_byte
        self._store: OrderedDict[str, jax.Array] = OrderedDict()
        self._lock = threading.Lock()
        self._nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bypasses = 0

    def get(self, key: str):
        with self._lock:
            mat = self._store.get(key)
            if mat is not None:
                self.hits += 1
                self._store.move_to_end(key)
                return mat
            self.misses += 1
            return None

    def admits(self, nbytes: int, est_cost: Optional[float]) -> bool:
        if est_cost is None or self.admit_cost_per_byte <= 0:
            return True
        return est_cost >= self.admit_cost_per_byte * max(nbytes, 1)

    def put(self, key: str, mat, est_cost: Optional[float] = None):
        if not hasattr(mat, "columns"):   # matrices live on device; Tables as-is
            mat = jnp.asarray(mat)
        with self._lock:
            if not self.admits(value_nbytes(mat), est_cost):
                self.bypasses += 1
                return mat
            old = self._store.pop(key, None)
            if old is not None:
                self._nbytes -= value_nbytes(old)
            self._store[key] = mat
            self._nbytes += value_nbytes(mat)
            self._evict()
            return mat

    def counters(self) -> str:
        """One-line hit/bypass accounting for explain output."""
        return (f"hits={self.hits} misses={self.misses} "
                f"bypasses={self.bypasses} evictions={self.evictions} "
                f"entries={len(self)} bytes={self._nbytes}")

    def metrics(self) -> dict:
        """Numeric counter snapshot — the telemetry registry source. hits/
        misses/bypasses/evictions are cumulative (delta-able); entries/bytes
        are point-in-time gauges."""
        return {"hits": self.hits, "misses": self.misses,
                "bypasses": self.bypasses, "evictions": self.evictions,
                "entries": len(self), "bytes": self._nbytes}

    def nbytes(self) -> int:
        return self._nbytes

    def __len__(self):
        return len(self._store)

    def _evict(self):
        # caller holds self._lock
        while self._nbytes > self.capacity_bytes and self._store:
            _, victim = self._store.popitem(last=False)
            self._nbytes -= value_nbytes(victim)
            self.evictions += 1

    def clear(self):
        with self._lock:
            self._store.clear()
            self._nbytes = 0
