"""In-memory inter-buffer for matrix storage (paper §4.2, §6.4).

Materializes GCDI results as device-resident matrices that analytical
operators consume directly (no tuple-at-a-time production). Entries are
keyed by a *structural fingerprint* of the producing GCDI plan + matrix
generation spec, so semantically-equivalent GCDIA tasks reuse materialized
outputs without re-execution (paper: "intermediate results in the
inter-buffer are reused across analytical tasks via structural matching of
GCDI plans").
"""
from __future__ import annotations

import hashlib
from typing import Any, Optional

import jax
import jax.numpy as jnp


def fingerprint(*parts: Any) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
    return h.hexdigest()[:16]


class InterBuffer:
    def __init__(self, capacity_bytes: int = 2 << 30):
        self.capacity_bytes = capacity_bytes
        self._store: dict[str, jax.Array] = {}
        self._order: list[str] = []
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[jax.Array]:
        if key in self._store:
            self.hits += 1
            self._order.remove(key)
            self._order.append(key)
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: str, mat: jax.Array) -> jax.Array:
        mat = jnp.asarray(mat)
        self._store[key] = mat
        self._order.append(key)
        self._evict()
        return mat

    def nbytes(self) -> int:
        return sum(int(v.size) * v.dtype.itemsize for v in self._store.values())

    def _evict(self):
        while self.nbytes() > self.capacity_bytes and len(self._order) > 1:
            victim = self._order.pop(0)
            del self._store[victim]

    def clear(self):
        self._store.clear()
        self._order.clear()
