"""Unified physical operator DAG (paper §5/§6.4, operator-level execution).

One plan IR from GCDI scans to GCDA kernels. ``planner.plan`` still makes
the *logical* decisions (pushdown sets, semi-join choices, match trimming);
:func:`build_gcdi` / :func:`build_gcdia` turn a :class:`~.planner.GCDIPlan`
(plus an optional analytics spec) into a typed DAG of :class:`PhysicalOp`
nodes, and :func:`execute` walks it bottom-up.

Every node carries
  * ``children`` — input operators,
  * ``run(ctx, *inputs)`` — the vectorized implementation,
  * ``stats`` — per-operator rows / bytes / seconds / cache flags,
  * ``signature()`` — a canonical structural fingerprint that embeds the
    write epochs of every source collection the subtree reads.

The inter-buffer is keyed on node signatures (structural plan matching,
§6.4): a repeated GCDIA task with a *different* analytics operator reuses
the materialized GCDI relation and generated matrices mid-plan, because the
shared sub-DAG has the same signature; any write to a source collection
bumps its epoch and changes every dependent signature, so stale reuse is
impossible. This replaces both monkey-patch execution paths: semi-join
candidate masks are ordinary :class:`SemiJoinMask` input edges into
:class:`MatchPattern`, and the GredoDB-S ablation is a
:class:`TableJoinMatch` node over the relational join engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from . import analytics
from . import join as join_mod
from . import pattern as pattern_mod
from . import telemetry
from . import traversal
from .interbuffer import InterBuffer, fingerprint, value_nbytes
from .schema import JoinPred, Pattern, Query
from .storage import Database, Table


# ---------------------------------------------------------------------------
# Node infrastructure
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class NodeStats:
    rows: Optional[int] = None
    nbytes: int = 0
    seconds: float = 0.0
    executed: bool = False
    cached: bool = False        # satisfied from the inter-buffer
    memoized: bool = False      # satisfied from this execution's memo


class ExecContext:
    """One bottom-up DAG execution: per-run memo keyed by node signature
    (shared sub-plans run once) plus an optional persistent inter-buffer
    consulted at cacheable nodes (cross-task structural reuse)."""

    def __init__(self, db: Database, interbuffer: Optional[InterBuffer] = None,
                 ests: Optional[dict] = None,
                 trace: Optional["telemetry.QueryTrace"] = None,
                 fence_device: bool = False, shard=None):
        self.db = db
        self.interbuffer = interbuffer
        self.ests = ests          # id(node) -> (est_rows, est_cost): feeds
                                  # the cost-aware inter-buffer admission
        self.trace = trace        # telemetry span sink; None = tracing off
        self.fence_device = fence_device  # block_until_ready GCDA outputs
                                          # inside their span (tracing only)
        self.shard = shard        # shard.ShardRuntime; None = serial execution
        self.memo: dict = {}
        self.nodes_run = 0
        self.nodes_reused = 0     # inter-buffer hits during this execution


class PhysicalOp:
    kind = "op"
    cacheable = False   # eligible for inter-buffer persistence

    def __init__(self, *children: "PhysicalOp"):
        self.children = tuple(children)
        self.stats = NodeStats()
        self._sig = None

    # -- structural fingerprint (embeds source epochs via params) --
    def params(self) -> tuple:
        return ()

    def signature(self) -> tuple:
        if self._sig is None:
            self._sig = (self.kind, self.params(),
                         tuple(c.signature() for c in self.children))
        return self._sig

    def run(self, ctx: ExecContext, *inputs):
        raise NotImplementedError

    def describe(self) -> str:
        return self.kind

    def with_children(self, *children: "PhysicalOp") -> "PhysicalOp":
        """Shallow clone with replaced inputs — the rewrite primitive of the
        optimizer. Annotations (out_cols, key_src, logical) carry over; the
        signature cache and stats are reset."""
        import copy
        clone = copy.copy(self)
        clone.children = tuple(children)
        clone.stats = NodeStats()
        clone._sig = None
        return clone


def _preds_sig(preds) -> tuple:
    return tuple(repr(p) for p in preds)


def _pred_map_sig(m: dict) -> tuple:
    return tuple((v, _preds_sig(ps)) for v, ps in sorted(m.items()))


def _pattern_sig(pattern: Pattern) -> tuple:
    return pattern.canonical()


def _pplan_sig(pplan) -> tuple:
    if pplan is None:
        return ()
    return (bool(pplan.reverse), _pred_map_sig(pplan.pushed),
            _pred_map_sig(pplan.deferred), tuple(sorted(pplan.fetch_vars)))


def _result_rows(out) -> Optional[int]:
    if isinstance(out, Table):
        return out.nrows
    if hasattr(out, "shape"):
        return int(out.shape[0]) if getattr(out, "ndim", 0) else 1
    return None


# ---------------------------------------------------------------------------
# GCDI operators (plan steps 1-5 as node constructors)
# ---------------------------------------------------------------------------


class ScanTable(PhysicalOp):
    """Base relational/document collection scan (RecordAM full scan)."""
    kind = "ScanTable"

    def __init__(self, name: str, epoch: int):
        super().__init__()
        self.name = name
        self.epoch = epoch

    def params(self):
        return (self.name, self.epoch)

    def run(self, ctx, *inputs):
        return ctx.db.tables[self.name]

    def describe(self):
        return f"ScanTable[{self.name}]"


class Select(PhysicalOp):
    """σ with pushed-down predicates (mechanism 1: table-side pushdown)."""
    kind = "Select"

    def __init__(self, child: PhysicalOp, preds: list):
        super().__init__(child)
        self.preds = list(preds)

    def params(self):
        return _preds_sig(self.preds)

    def run(self, ctx, t: Table):
        for pred in self.preds:
            t = t.take(np.nonzero(t.eval_predicate(pred))[0])
        return t

    def describe(self):
        return f"Select[{', '.join(repr(p) for p in self.preds)}]"


class IndexScan(PhysicalOp):
    """Index-backed access path replacing a Select-over-ScanTable pair:
    postings of the most selective servable predicate seed the row set, the
    remaining predicates are point-evaluated on those rows only, and the
    table is gathered once via the tid-based RecordAM. Chosen by the
    optimizer's cost-based access-path selection; falls back to the full
    scan at runtime if the index was dropped since planning."""
    kind = "IndexScan"

    def __init__(self, name: str, epoch: int, preds: list, pick: int,
                 access: str):
        super().__init__()
        self.name = name
        self.epoch = epoch
        self.preds = list(preds)
        self.pick = int(pick)
        self.access = access        # "hash" | "sorted" (explain provenance)

    def params(self):
        return (self.name, self.epoch, _preds_sig(self.preds), self.pick,
                self.access)

    def run(self, ctx, *inputs):
        t = ctx.db.tables[self.name]
        im = getattr(ctx.db, "_index_manager", None)
        rows = im.lookup(self.name, self.preds[self.pick]) if im else None
        if rows is None:            # index gone: degrade, don't fail
            for pred in self.preds:
                t = t.take(np.nonzero(t.eval_predicate(pred))[0])
            return t
        rows = np.sort(rows)        # scan row order, deterministically
        for i, pred in enumerate(self.preds):
            if i != self.pick and len(rows):
                rows = rows[t.eval_predicate(pred, rows=rows)]
        traversal.COUNTERS.record_fetches += len(rows) * max(len(self.preds), 1)
        return t.take(rows)

    def describe(self):
        return (f"IndexScan[{self.name}: {self.preds[self.pick]!r} "
                f"via {self.access}]")


class IndexSelect(PhysicalOp):
    """Zone-map skip-scan access path: the picked predicate is evaluated
    chunk-wise through the column's zone maps (non-candidate chunks are
    never read), remaining predicates point-evaluate on the survivors.
    Effective when the column is clustered (e.g. monotone keys), where
    min/max pruning touches O(hits) chunks."""
    kind = "IndexSelect"

    def __init__(self, name: str, epoch: int, preds: list, pick: int):
        super().__init__()
        self.name = name
        self.epoch = epoch
        self.preds = list(preds)
        self.pick = int(pick)
        self.access = "zone"

    def params(self):
        return (self.name, self.epoch, _preds_sig(self.preds), self.pick)

    def run(self, ctx, *inputs):
        t = ctx.db.tables[self.name]
        im = getattr(ctx.db, "_index_manager", None)
        rows = im.zone_rows(self.name, self.preds[self.pick]) if im else None
        if rows is None:            # zones gone: degrade, don't fail
            for pred in self.preds:
                t = t.take(np.nonzero(t.eval_predicate(pred))[0])
            return t
        for i, pred in enumerate(self.preds):
            if i != self.pick and len(rows):
                rows = rows[t.eval_predicate(pred, rows=rows)]
        traversal.COUNTERS.record_fetches += len(rows) * max(len(self.preds), 1)
        return t.take(rows)

    def describe(self):
        return (f"IndexSelect[{self.name}: {self.preds[self.pick]!r} "
                f"via zone-skip]")


class Alias(PhysicalOp):
    """Qualify column names with the collection name before cluster joins."""
    kind = "Alias"

    def __init__(self, child: PhysicalOp, name: str):
        super().__init__(child)
        self.name = name

    def params(self):
        return (self.name,)

    def run(self, ctx, t: Table):
        return Table(t.name, {f"{self.name}.{k}": v for k, v in t.columns.items()})

    def describe(self):
        return f"Alias[{self.name}]"


class SemiJoinMask(PhysicalOp):
    """Join pushdown (Eq. 9/10): graph ⋈̂ table as a candidate vertex mask
    consumed by MatchPattern — an explicit plan edge, not a monkey-patch."""
    kind = "SemiJoinMask"

    def __init__(self, graph: str, epoch: int, label: str, vcol: str,
                 ocol: str, table_child: PhysicalOp):
        super().__init__(table_child)
        self.graph = graph
        self.epoch = epoch
        self.label = label
        self.vcol = vcol
        self.ocol = ocol

    def params(self):
        return (self.graph, self.epoch, self.label, self.vcol, self.ocol)

    def run(self, ctx, other: Table):
        g = ctx.db.graphs[self.graph]
        return join_mod.semi_join_graph(g, self.label, self.vcol, other, self.ocol)

    def describe(self):
        return f"SemiJoinMask[{self.label}.{self.vcol} ∈ {self.ocol}]"


class SemiJoinReduce(PhysicalOp):
    """The opposite siding of the Eq. 9/10 semi-join: keep the rows of a
    relational/document child whose join column appears among the graph's
    vertex keys. Chosen by the optimizer when the vertex key set is the
    smaller build input (the table side is what shrinks)."""
    kind = "SemiJoinReduce"

    def __init__(self, graph: str, epoch: int, label: str, vcol: str,
                 ocol: str, table_child: PhysicalOp):
        super().__init__(table_child)
        self.graph = graph
        self.epoch = epoch
        self.label = label
        self.vcol = vcol
        self.ocol = ocol

    def params(self):
        return (self.graph, self.epoch, self.label, self.vcol, self.ocol)

    def run(self, ctx, t: Table):
        g = ctx.db.graphs[self.graph]
        mask = join_mod.semi_join_table(t, self.ocol, g, self.label, self.vcol)
        return t.take(np.nonzero(mask)[0])

    def describe(self):
        return f"SemiJoinReduce[{self.ocol} ∈ {self.label}.{self.vcol}]"


class PruneCols(PhysicalOp):
    """Projection sink-down into the scan: drop base-table columns never
    referenced above (join keys, projection, residual predicates), so joins
    and record gathers move fewer bytes."""
    kind = "PruneCols"

    def __init__(self, child: PhysicalOp, cols: tuple):
        super().__init__(child)
        self.cols = tuple(cols)

    def params(self):
        return (self.cols,)

    def run(self, ctx, t: Table):
        return Table(t.name, {c: t.columns[c] for c in self.cols
                              if c in t.columns})

    def describe(self):
        return f"PruneCols[{', '.join(self.cols)}]"


class MatchPattern(PhysicalOp):
    """Hybrid topology+attribute pattern matching (Algorithm 2). Children
    are SemiJoinMask nodes whose masks shrink candidate sets before the
    traversal; ``mask_vars[i]`` names the pattern var mask ``i`` applies to."""
    kind = "MatchPattern"

    def __init__(self, graph: str, epoch: int, pplan, mask_vars: tuple,
                 *mask_children: PhysicalOp):
        super().__init__(*mask_children)
        self.graph = graph
        self.epoch = epoch
        self.pplan = pplan
        self.mask_vars = tuple(mask_vars)

    def params(self):
        return (self.graph, self.epoch, _pattern_sig(self.pplan.pattern),
                _pplan_sig(self.pplan), self.mask_vars)

    def run(self, ctx, *masks):
        g = ctx.db.graphs[self.graph]
        extra: dict = {}
        for var, m in zip(self.mask_vars, masks):
            extra[var] = m if var not in extra else (extra[var] & m)
        return pattern_mod.match(g, self.pplan, extra_masks=extra or None)

    def describe(self):
        p = self.pplan
        d = "rev" if p.reverse else "fwd"
        pushed = ",".join(f"{v}:{len(ps)}" for v, ps in sorted(p.pushed.items())) or "-"
        deferred = ",".join(f"{v}:{len(ps)}" for v, ps in sorted(p.deferred.items())) or "-"
        hops = len(p.pattern.edges)
        return (f"MatchPattern[{self.graph} dir={d} hops={hops} "
                f"pushed={pushed} deferred={deferred}]")


class DeviceMatchPattern(PhysicalOp):
    """Mask-free chain match executed on the accelerator — the third access
    path of the pattern operator, chosen by the optimizer off frontier-size
    and selectivity estimates. ``access`` selects the flavor:
    ``device-pallas`` runs the fused traversal kernel family (zone-filtered
    predicate tables, in-kernel compaction, one launch window per chain);
    ``device-jit`` runs the per-hop ``DevicePatternMatcher``. Falls back to
    the host matcher at runtime if the graph has grown pending deltas since
    planning (the device snapshot reads base CSRs only)."""
    kind = "DeviceMatchPattern"

    def __init__(self, graph: str, epoch: int, pplan,
                 access: str = "device-pallas",
                 capacity: Optional[int] = None):
        super().__init__()
        self.graph = graph
        self.epoch = epoch
        self.pplan = pplan
        self.access = access
        self.capacity = capacity
        # per-execution analytic flops/bytes; merged into the telemetry span
        # (this is a DAG leaf — the generic shape-derived kernel_args model
        # has no inputs to derive from)
        self.last_kernel_args: Optional[dict] = None

    def params(self):
        return (self.graph, self.epoch, _pattern_sig(self.pplan.pattern),
                _pplan_sig(self.pplan), self.access, self.capacity)

    def run(self, ctx, *inputs):
        from . import pattern_jit
        g = ctx.db.graphs[self.graph]
        if g.delta.has_pending():
            # planned against a compacted snapshot that has since grown
            # deltas: degrade to the host matcher, don't fail
            self.access = "host-fallback"
            return pattern_mod.match(g, self.pplan)
        flavor = "jit" if self.access == "device-jit" else "pallas"
        rel, kargs = pattern_jit.device_match(
            g, self.pplan, flavor=flavor, initial_capacity=self.capacity)
        self.last_kernel_args = kargs
        return rel

    def describe(self):
        p = self.pplan
        d = "rev" if p.reverse else "fwd"
        pushed = ",".join(f"{v}:{len(ps)}"
                          for v, ps in sorted(p.pushed.items())) or "-"
        cap = f" cap={self.capacity}" if self.capacity else ""
        return (f"DeviceMatchPattern[{self.graph} dir={d} "
                f"hops={len(p.pattern.edges)} pushed={pushed} "
                f"via {self.access}{cap}]")


class TableJoinMatch(PhysicalOp):
    """GredoDB-S ablation: the pattern as k-way edge-table equi-joins (the
    TBS strategy §2.2) with deferred predicates evaluated post-hoc."""
    kind = "TableJoinMatch"

    def __init__(self, graph: str, epoch: int, pattern: Pattern, deferred: dict):
        super().__init__()
        self.graph = graph
        self.epoch = epoch
        self.pattern = pattern
        self.deferred = dict(deferred)

    def params(self):
        return (self.graph, self.epoch, _pattern_sig(self.pattern),
                _pred_map_sig(self.deferred))

    def run(self, ctx, *inputs):
        g = ctx.db.graphs[self.graph]
        rel = join_mod.match_by_joins(g, self.pattern)
        return pattern_mod.apply_deferred(g, self.pattern, rel, self.deferred)

    def describe(self):
        return f"TableJoinMatch[{self.graph} hops={len(self.pattern.edges)}]"


class VertexScan(PhysicalOp):
    """Match trimming case 1 (§6.2): no topology constraint -> record scan."""
    kind = "VertexScan"

    def __init__(self, graph: str, epoch: int, pattern: Pattern, pplan):
        super().__init__()
        self.graph = graph
        self.epoch = epoch
        self.pattern = pattern
        self.pplan = pplan

    def params(self):
        return (self.graph, self.epoch, _pattern_sig(self.pattern),
                _pplan_sig(self.pplan))

    def run(self, ctx, *inputs):
        g = ctx.db.graphs[self.graph]
        var = self.pattern.vertices[0].var
        tbl = g.vertex_tables[self.pattern.vertex(var).label]
        mask = np.ones(tbl.nrows, dtype=bool)
        preds = self.pplan.deferred.get(var, []) if self.pplan else []
        for pred in preds:
            mask &= tbl.eval_predicate(pred)
        return Table(f"match:{self.pattern.graph}", {var: np.nonzero(mask)[0]})

    def describe(self):
        return f"VertexScan[{self.graph}.{self.pattern.vertices[0].var}]"


class EdgeScan(PhysicalOp):
    """Match trimming case 2 (§6.2): v-e-v, edge-only predicates -> edge scan."""
    kind = "EdgeScan"

    def __init__(self, graph: str, epoch: int, pattern: Pattern, pplan):
        super().__init__()
        self.graph = graph
        self.epoch = epoch
        self.pattern = pattern
        self.pplan = pplan

    def params(self):
        return (self.graph, self.epoch, _pattern_sig(self.pattern),
                _pplan_sig(self.pplan))

    def run(self, ctx, *inputs):
        g = ctx.db.graphs[self.graph]
        evar = self.pattern.edges[0].var
        mask = g.live_edge_mask()
        preds = self.pplan.deferred.get(evar, []) if self.pplan else []
        for pred in preds:
            mask &= g.edges.eval_predicate(pred)
        return Table(f"match:{self.pattern.graph}", {evar: np.nonzero(mask)[0]})

    def describe(self):
        return f"EdgeScan[{self.graph}.{self.pattern.edges[0].var}]"


class GraphProject(PhysicalOp):
    """Graph projection π̂_A' (projection trimming): fetch referenced record
    attributes for matched bindings via the tid-based RecordAM."""
    kind = "GraphProject"

    def __init__(self, graph: str, epoch: int, pattern: Pattern, keep: tuple,
                 wanted: dict, child: PhysicalOp):
        super().__init__(child)
        self.graph = graph
        self.epoch = epoch
        self.pattern = pattern
        self.keep = tuple(sorted(keep))
        self.wanted = {v: list(dict.fromkeys(attrs)) for v, attrs in wanted.items()}

    def params(self):
        return (self.graph, self.epoch, self.keep,
                tuple((v, tuple(a)) for v, a in sorted(self.wanted.items())))

    def run(self, ctx, rel: Table):
        g = ctx.db.graphs[self.graph]
        edge_vars = {e.var for e in self.pattern.edges}
        cols: dict[str, np.ndarray] = {}
        for var in self.keep:
            if var not in rel.columns:
                continue
            ids = np.asarray(rel.col(var))
            cols[f"{var}.__id"] = ids
            tbl = (g.edges if var in edge_vars
                   else g.vertex_tables[self.pattern.vertex(var).label])
            for attr in self.wanted.get(var, []):
                col = tbl.col(attr)
                cols[f"{var}.{attr}"] = (col.take(ids) if hasattr(col, "take")
                                         else np.asarray(col)[ids])
                traversal.COUNTERS.record_fetches += len(ids)
        return Table(rel.name, cols if cols else dict(rel.columns))

    def describe(self):
        return f"GraphProject[{self.graph} keep={','.join(self.keep) or '-'}]"


class EquiJoin(PhysicalOp):
    """Cross-model sort-merge equi-join ⋈̂ merging two plan clusters."""
    kind = "EquiJoin"

    def __init__(self, jp: JoinPred, left: PhysicalOp, right: PhysicalOp):
        super().__init__(left, right)
        self.jp = jp

    def params(self):
        return (self.jp.left, self.jp.right)

    def run(self, ctx, lc: Table, rc: Table):
        li, ri = join_mod.equi_join_indices(
            lc, _col_in(lc, self.jp.left), rc, _col_in(rc, self.jp.right))
        lt, rt = lc.take(li), rc.take(ri)
        cols = dict(lt.columns)
        cols.update(rt.columns)
        return Table(f"{lc.name}⋈{rc.name}", cols)

    def describe(self):
        return f"EquiJoin[{self.jp.left}={self.jp.right}]"


class Exchange(PhysicalOp):
    """Partition-exchange: hash-partitions the child's rows on a join key
    into k shards. Inserted under the build side of an EquiJoin by the shard
    planner; the serial executor runs it as the identity (the partition is a
    *view*, not a row shuffle), while the sharded executor materializes —
    or reuses, when a co-partitioned build from an earlier query at the same
    epoch is cached — the per-shard sorted key runs the join probes bind to."""
    kind = "Exchange"

    def __init__(self, child: PhysicalOp, key: str, k: int):
        super().__init__(child)
        self.key = key
        self.k = int(k)
        self.shards = int(k)

    def params(self):
        return (self.key, self.k)

    def run(self, ctx, t: Table):
        return t

    def describe(self):
        return f"Exchange[{self.key} -> {self.k}p]"


class IntraFilter(PhysicalOp):
    """Join predicate whose sides already live in one cluster: a row filter."""
    kind = "IntraFilter"

    def __init__(self, jp: JoinPred, child: PhysicalOp):
        super().__init__(child)
        self.jp = jp

    def params(self):
        return (self.jp.left, self.jp.right)

    def run(self, ctx, t: Table):
        lv = np.asarray(t.col(_col_in(t, self.jp.left)))
        rv = np.asarray(t.col(_col_in(t, self.jp.right)))
        return t.take(np.nonzero(lv == rv)[0])

    def describe(self):
        return f"IntraFilter[{self.jp.left}={self.jp.right}]"


class Residual(PhysicalOp):
    """σ_Ψ residue: predicates evaluated on the joined relation."""
    kind = "Residual"

    def __init__(self, preds: list, child: PhysicalOp):
        super().__init__(child)
        self.preds = list(preds)

    def params(self):
        return _preds_sig(self.preds)

    def run(self, ctx, t: Table):
        for pred in self.preds:
            col = _col_in(t, pred.attr)
            mask = t.eval_predicate(dataclasses.replace(pred, attr=f"x.{col}"))
            t = t.take(np.nonzero(mask)[0])
        return t

    def describe(self):
        return f"Residual[{', '.join(repr(p) for p in self.preds)}]"


class Project(PhysicalOp):
    """π_A final projection — the GCDI root. Its signature embeds the write
    epoch of *every* collection the task reads, so it is the structural-match
    reuse point for the materialized GCDI relation. Cacheable."""
    kind = "Project"
    cacheable = True

    def __init__(self, select: tuple, epochs: tuple, child: PhysicalOp):
        super().__init__(child)
        self.select = tuple(select)
        self.epochs = tuple(epochs)

    def params(self):
        return (self.select, self.epochs)

    def run(self, ctx, t: Table):
        cols = {}
        for a in self.select:
            cols[a] = t.col(_col_in(t, a))
        return Table("result", cols)

    def describe(self):
        return f"Project[{', '.join(self.select)}]"


# ---------------------------------------------------------------------------
# GCDA operators (matrix generation G + analytical operators A, Eq. 5)
# ---------------------------------------------------------------------------


class Rel2Matrix(PhysicalOp):
    """REL2MATRIX local access: columnar GCDI columns -> (n, k) device matrix."""
    kind = "Rel2Matrix"
    cacheable = True

    def __init__(self, columns, child: PhysicalOp):
        super().__init__(child)
        self.columns = tuple(columns)

    def params(self):
        return (self.columns,)

    def run(self, ctx, rel: Table):
        return analytics.rel2matrix(rel, self.columns)

    def describe(self):
        return f"Rel2Matrix[{', '.join(self.columns)}]"


class RandomAccessMatrix(PhysicalOp):
    """Random access: aggregate multi-valued attributes of qualifying records
    into per-group multi-hot / count feature rows."""
    kind = "RandomAccessMatrix"
    cacheable = True

    def __init__(self, group_col: str, value_col: str, n_features: int,
                 child: PhysicalOp):
        super().__init__(child)
        self.group_col = group_col
        self.value_col = value_col
        self.n_features = int(n_features)

    def params(self):
        return (self.group_col, self.value_col, self.n_features)

    def run(self, ctx, rel: Table):
        m, _ = analytics.random_access_matrix(
            rel, self.group_col, self.value_col, self.n_features)
        return m

    def describe(self):
        return (f"RandomAccessMatrix[{self.group_col} x {self.value_col} "
                f"-> {self.n_features}f]")


class Const(PhysicalOp):
    """Literal matrix input."""
    kind = "Const"

    def __init__(self, value):
        super().__init__()
        self.value = value
        arr = np.asarray(value)
        # content digest computed once — signatures stay O(1) per build
        self._digest = (str(arr.dtype), arr.shape, fingerprint(arr.tobytes()))

    def params(self):
        return self._digest

    def run(self, ctx, *inputs):
        import jax.numpy as jnp
        return jnp.asarray(self.value)

    def describe(self):
        return f"Const[{np.asarray(self.value).shape}]"


class MatMul(PhysicalOp):
    """MULTIPLY via the tiled MXU kernel; one child means the Gram product."""
    kind = "MatMul"
    cacheable = True

    def __init__(self, use_kernel, lhs: PhysicalOp, rhs: Optional[PhysicalOp] = None):
        super().__init__(*([lhs] if rhs is None else [lhs, rhs]))
        self.use_kernel = use_kernel
        self.gram = rhs is None

    def params(self):
        return (self.gram, self.use_kernel)

    def run(self, ctx, x, y=None):
        rhs = x.T if y is None else y
        return analytics.multiply(x, rhs, use_kernel=self.use_kernel)

    def describe(self):
        return "MatMul[gram]" if self.gram else "MatMul"


class Similarity(PhysicalOp):
    """SIMILARITY: pairwise cosine scores via the fused kernel."""
    kind = "Similarity"
    cacheable = True

    def __init__(self, use_kernel, lhs: PhysicalOp, rhs: Optional[PhysicalOp] = None):
        super().__init__(*([lhs] if rhs is None else [lhs, rhs]))
        self.use_kernel = use_kernel
        self.self_sim = rhs is None

    def params(self):
        return (self.self_sim, self.use_kernel)

    def run(self, ctx, x, y=None):
        return analytics.similarity(x, x if y is None else y,
                                    use_kernel=self.use_kernel)

    def describe(self):
        return "Similarity[self]" if self.self_sim else "Similarity"


class Regression(PhysicalOp):
    """REGRESSION: logistic regression with the fused gradient kernel."""
    kind = "Regression"
    cacheable = True

    def __init__(self, iters: int, use_kernel, x: PhysicalOp, y: PhysicalOp):
        super().__init__(x, y)
        self.iters = int(iters)
        self.use_kernel = use_kernel

    def params(self):
        return (self.iters, self.use_kernel)

    def run(self, ctx, x, y):
        return analytics.regression(x, y.reshape(-1), iters=self.iters,
                                    use_kernel=self.use_kernel)[0]

    def describe(self):
        return f"Regression[iters={self.iters}]"


# ---------------------------------------------------------------------------
# DAG construction: GCDIPlan -> operator DAG (planner steps 1-5)
# ---------------------------------------------------------------------------


def _col_in(t: Table, attr: str) -> str:
    if attr in t.columns:
        return attr
    if "." in attr:
        bare = attr.split(".", 1)[1]
        if bare in t.columns:
            return bare
    raise KeyError(f"{attr} not in {list(t.columns)[:12]}...")


def _static_has_col(cols: set, attr: str) -> bool:
    """Static mirror of ``_col_in`` over a predicted column-name set."""
    return attr in cols or ("." in attr and attr.split(".", 1)[1] in cols)


def _key_source(q: Query, pattern: Optional[Pattern], attr: str):
    """Resolve a join attribute to its backing base collection, for NDV
    lookup: ("table", name, col) | ("vertex", graph, label, col) |
    ("edge", graph, col) | None."""
    coll, _, col = attr.partition(".")
    if not col:
        return None
    if coll in q.froms:
        return ("table", coll, col)
    if pattern is not None:
        for v in pattern.vertices:
            if v.var == coll:
                return ("vertex", pattern.graph, v.label, col)
        for e in pattern.edges:
            if e.var == coll:
                return ("edge", pattern.graph, col)
    return None


def resolve_key_stats(db: Database, src):
    """ColumnStats of a ``_key_source`` result against the live catalog
    (merged base ⊕ delta views), or None."""
    try:
        if src is None:
            return None
        if src[0] == "table":
            return db.tables[src[1]].stats(src[2])
        if src[0] == "vertex":
            return db.graphs[src[1]].vertex_tables[src[2]].stats(src[3])
        if src[0] == "edge":
            return db.graphs[src[1]].edges.stats(src[2])
    except KeyError:
        return None
    return None


def catalog_epochs(db: Database) -> tuple:
    """Write-epoch snapshot of every collection in the catalog — the key
    that gates reuse of cached §6.3 estimates across planner invocations
    (a delta-store append bumps its source epoch and invalidates them)."""
    names = sorted(set(db.tables) | set(db.graphs))
    return tuple((n, db.epoch_of(n)) for n in names)


def pick_connected_cluster(clusters: list, needed: list):
    """Select the cluster (node, column-set pairs) covering every needed
    attribute when joins left more than one behind. Raises on a genuinely
    disconnected query — never silently drops result columns."""
    scored = sorted(
        ((sum(1 for a in needed if _static_has_col(cols, a)), i)
         for i, (_, cols) in enumerate(clusters)),
        key=lambda t: (-t[0], t[1]))
    if scored[0][0] < len(needed):
        raise ValueError("query is disconnected: projection attributes "
                         "span un-joined collections")
    return clusters[scored[0][1]][0]


# Distribution-aware join estimation toggle. True (default): per-key /
# per-bucket overlap of the two key distributions (ColumnStats.join_overlap)
# with NDV containment only as fallback. False: the pre-histogram NDV-only
# model — kept as the measurable baseline for q-error regressions
# (benchmarks/run.py --suite optimizer toggles it to report both).
HIST_JOIN_EST = True


def est_join_rows(nl: float, nr: float, ls, rs) -> float:
    return est_join_rows_detail(nl, nr, ls, rs)[0]


def est_join_rows_detail(nl: float, nr: float, ls, rs) -> tuple[float, str]:
    """|L ⋈ R| with estimate provenance, as ``(rows, how)``.

    Distribution-aware path: ``ls.join_overlap(rs)`` gives the expected
    matches between the two *base* key columns (exact per-value products for
    MCV/dict columns, per-equi-width-bucket-pair overlap otherwise); the
    filtered-input selectivities are threaded into those bucket counts by
    scaling with ``(nl / |L_base|) · (nr / |R_base|)`` — the fraction of
    each base side actually flowing into the join (uniform-filter
    assumption; fan-out of earlier joins scales the same way, > 1).

    Fallback (``how == "ndv"``): uniform-key containment nl·nr / max(ndv)
    with NDVs capped by the (possibly filtered) input cardinalities; when
    neither key resolves to base statistics, max(nl, nr)."""
    if (HIST_JOIN_EST and ls is not None and rs is not None
            and ls.n and rs.n):
        ov = ls.join_overlap(rs)
        if ov is not None:
            matches, how = ov
            return matches * (nl / ls.n) * (nr / rs.n), how
    ndvs = []
    if ls is not None and ls.ndv:
        ndvs.append(min(float(ls.ndv), max(nl, 1.0)))
    if rs is not None and rs.ndv:
        ndvs.append(min(float(rs.ndv), max(nr, 1.0)))
    if not ndvs:
        return float(max(nl, nr)), "no-stats"
    return nl * nr / max(max(ndvs), 1.0), "ndv"


def est_intra_filter_rows(rows: float, ls, rs) -> float:
    """Rows surviving an IntraFilter (a join predicate whose sides already
    live in one cluster): divide by the larger key NDV, clamped to the
    input cardinality; 3.0 default when neither key resolves. The single
    formula shared by :func:`estimate` and the optimizer's join enumerator
    — their costs must agree or the DP picks orders the final cost model
    contradicts."""
    ndv = max((float(s.ndv) for s in (ls, rs) if s is not None), default=3.0)
    return rows / max(min(ndv, max(rows, 1.0)), 1.0)


def build_gcdi(db: Database, p, mode: str = "gredo") -> PhysicalOp:
    """Emit the *naive* physical DAG for a logical GCDIPlan: clusters join
    in query order and graph↔table joins stay post-match equi-joins. The
    dynamic cluster merging of the old executor is simulated statically
    (each collection's output column set is known at plan time); cluster
    roots carry ``out_cols`` and joins carry resolved key sources, which is
    what :func:`repro.core.optimizer.optimize` rewrites against."""
    q: Query = p.query
    pattern = q.match

    # step 1: base tables with pushed selections
    table_nodes: dict[str, PhysicalOp] = {}
    for name in q.froms:
        node: PhysicalOp = ScanTable(name, db.epoch_of(name))
        preds = p.table_pushdown.get(name, [])
        if preds:
            node = Select(node, preds)
        table_nodes[name] = node

    # step 2: graph side
    graph_node: Optional[PhysicalOp] = None
    vars_in_rel: set[str] = set()
    if pattern:
        gname = pattern.graph
        gep = db.epoch_of(gname)
        all_vars = ({v.var for v in pattern.vertices}
                    | {e.var for e in pattern.edges})
        if mode == "single":
            deferred = p.pattern_plan.deferred if p.pattern_plan else {}
            graph_node = TableJoinMatch(gname, gep, pattern, deferred)
            vars_in_rel = all_vars
        elif p.match_trim == "vertex_scan":
            graph_node = VertexScan(gname, gep, pattern, p.pattern_plan)
            vars_in_rel = {pattern.vertices[0].var}
        elif p.match_trim == "edge_scan":
            graph_node = EdgeScan(gname, gep, pattern, p.pattern_plan)
            vars_in_rel = {pattern.edges[0].var}
        else:
            # naive: no semi-join pushdown — Eq. 8 shape. The optimizer
            # makes the cost-based Eq. 9/10 siding decision per candidate.
            graph_node = MatchPattern(gname, gep, p.pattern_plan, ())
            vars_in_rel = all_vars

        # graph projection π̂_A' — static column prediction mirrors run()
        keep = set(p.graph_projection) & vars_in_rel
        wanted: dict[str, list[str]] = {}
        for a in (list(q.select) + [jp.left for jp in q.joins]
                  + [jp.right for jp in q.joins]):
            c = a.split(".", 1)[0]
            if c in keep and "." in a:
                wanted.setdefault(c, []).append(a.split(".", 1)[1])
        graph_node = GraphProject(gname, gep, pattern, tuple(sorted(keep)),
                                  wanted, graph_node)
        graph_cols: set[str] = set()
        for var in sorted(keep):
            graph_cols.add(f"{var}.__id")
            for attr in dict.fromkeys(wanted.get(var, [])):
                graph_cols.add(f"{var}.{attr}")
        if not graph_cols:
            graph_cols = set(vars_in_rel)
        graph_node.out_cols = frozenset(graph_cols)

    # step 3: multi-way joins — static cluster merging in query order
    clusters: list[tuple[PhysicalOp, set[str]]] = []
    if graph_node is not None:
        clusters.append((graph_node, graph_cols))
    for name in q.froms:
        t = db.tables[name]
        alias = Alias(table_nodes[name], name)
        alias.out_cols = frozenset(f"{name}.{k}" for k in t.columns)
        clusters.append((alias, set(alias.out_cols)))

    def _find(attr: str) -> int:
        for ci, (_, cols) in enumerate(clusters):
            if _static_has_col(cols, attr):
                return ci
        raise KeyError(f"join attr {attr} not found in any cluster")

    for jp in q.joins:
        li_c, ri_c = _find(jp.left), _find(jp.right)
        if li_c == ri_c:
            node, cols = clusters[li_c]
            intra = IntraFilter(jp, node)
            intra.key_src = (_key_source(q, pattern, jp.left),
                             _key_source(q, pattern, jp.right))
            clusters[li_c] = (intra, cols)
            continue
        ln, lc = clusters[li_c]
        rn, rc = clusters[ri_c]
        join = EquiJoin(jp, ln, rn)
        join.key_src = (_key_source(q, pattern, jp.left),
                        _key_source(q, pattern, jp.right))
        clusters[min(li_c, ri_c)] = (join, lc | rc)
        del clusters[max(li_c, ri_c)]

    if len(clusters) > 1:
        # disconnected query: keep the cluster holding the projection attrs
        current = pick_connected_cluster(
            clusters, list(q.select) + [pr.attr for pr in p.residual])
    else:
        current = clusters[0][0]

    # step 4: residual predicates
    if p.residual:
        current = Residual(p.residual, current)

    # step 5: final projection — root signature carries every source epoch
    epochs = tuple((n, db.epoch_of(n)) for n in q.source_names())
    root = Project(q.select, epochs, current)
    root.logical = p    # the optimizer rewrites against the logical plan

    # full-coverage schema annotations: every relational node carries the
    # statically inferred out_cols (not just cluster roots and aliases) —
    # what the optimizer's pruning and the plan verifier read
    from . import verify as verify_mod
    verify_mod.annotate_out_cols(root, db)
    return root


def build_gcdia(db: Database, p, task, mode: str = "gredo", *,
                use_kernel=None, iters: int = 100) -> PhysicalOp:
    """Full GCDIA DAG: GCDI root -> matrix generation -> analytical op."""
    gcdi_root = build_gcdi(db, p, mode=mode)
    mats: list[PhysicalOp] = []
    for spec in task.analytics.inputs:
        kind = spec[0]
        if kind == "rel2matrix":
            mats.append(Rel2Matrix(tuple(spec[1]), gcdi_root))
        elif kind == "random":
            mats.append(RandomAccessMatrix(spec[1], spec[2], spec[3], gcdi_root))
        elif kind == "const":
            mats.append(Const(spec[1]))
        else:
            raise ValueError(kind)
    op = task.analytics.op
    if op == "MULTIPLY":
        return MatMul(use_kernel, mats[0], mats[1] if len(mats) > 1 else None)
    if op == "SIMILARITY":
        return Similarity(use_kernel, mats[0], mats[1] if len(mats) > 1 else None)
    if op == "REGRESSION":
        if len(mats) < 2:
            raise ValueError("REGRESSION needs (features, labels)")
        return Regression(iters, use_kernel, mats[0], mats[1])
    raise ValueError(op)


# ---------------------------------------------------------------------------
# Execution: bottom-up walk with signature memoization + inter-buffer reuse
# ---------------------------------------------------------------------------

# Per-operator result-footprint tracking (stats.nbytes / the bytes= explain
# bits). Kept on by default; benchmarks timing bare operator latency may
# disable it.
TRACK_NBYTES = True


def execute(node: PhysicalOp, ctx: ExecContext):
    # The disabled-telemetry path must stay within ~2% of the pre-telemetry
    # executor: every tracing addition below is gated on one local None check.
    trace = ctx.trace
    sig = node.signature()
    if sig in ctx.memo:
        node.stats.memoized = True
        if trace is not None:
            trace.instant(node.kind, detail=node.describe(), cache="memo",
                          rows=node.stats.rows)
        return ctx.memo[sig]
    if ctx.interbuffer is not None and node.cacheable:
        hit = ctx.interbuffer.get(fingerprint(sig))
        if hit is not None:
            node.stats.cached = True
            node.stats.rows = _result_rows(hit)
            node.stats.nbytes = value_nbytes(hit)
            ctx.nodes_reused += 1
            ctx.memo[sig] = hit
            if trace is not None:
                trace.instant(node.kind, detail=node.describe(),
                              cache="interbuffer-hit", rows=node.stats.rows,
                              nbytes=node.stats.nbytes)
            return hit
    if trace is not None:
        # spans open before the child recursion so the parent covers its
        # inputs and the trace nests exactly like the DAG
        gcda = node.kind in telemetry.GCDA_KINDS
        sid = trace.begin(node.kind, cat="gcda" if gcda else "gcdi",
                          detail=node.describe())
    inputs = [execute(c, ctx) for c in node.children]
    t0 = time.perf_counter()
    sh = ctx.shard
    if sh is not None:
        # morsel-parallel path: the runtime handles the kinds it shards and
        # returns its NOT_SHARDED sentinel for everything else (serial run)
        out = sh.run(node, ctx, inputs)
        if out is sh.NOT_SHARDED:
            out = node.run(ctx, *inputs)
    else:
        out = node.run(ctx, *inputs)
    node.stats.seconds += time.perf_counter() - t0
    node.stats.executed = True
    node.stats.rows = _result_rows(out)
    if ctx.interbuffer is not None or TRACK_NBYTES:
        # the footprint walk costs ~10µs/node: always on for the admission
        # policy and (by default) for explain diagnostics; latency
        # microbenchmarks flip TRACK_NBYTES off to time the bare operators
        node.stats.nbytes = value_nbytes(out)
    ctx.nodes_run += 1
    if trace is not None:
        args: dict = {"sig": fingerprint(sig)}
        if gcda:
            args["dispatch_s"] = node.stats.seconds
            if ctx.fence_device:
                sync = telemetry.fence(out)
                args["sync_s"] = sync
                node.stats.seconds += sync  # device wait belongs to the op
            args.update(telemetry.kernel_args(node.kind, tuple(inputs), out,
                                              iters=getattr(node, "iters", 1)))
            extra = getattr(node, "last_kernel_args", None)
            if extra:
                # leaf kernels (DeviceMatchPattern) report their own
                # flops/bytes — the shape-derived model above sees no inputs
                args.update(extra)
        if node.stats.rows is not None:
            args["rows"] = node.stats.rows
        if node.stats.nbytes:
            args["nbytes"] = node.stats.nbytes
        est = ctx.ests.get(id(node)) if ctx.ests is not None else None
        if est is not None:
            args["est_rows"] = est[0]
            if node.stats.rows is not None:
                args["q_error"] = telemetry.q_error(est[0], node.stats.rows)
        acc = getattr(node, "access", None)
        if acc is not None:
            args["access"] = acc
        trace.end(sid, **args)
    if ctx.interbuffer is not None and node.cacheable:
        est = ctx.ests.get(id(node)) if ctx.ests is not None else None
        out = ctx.interbuffer.put(fingerprint(sig), out,
                                  est_cost=None if est is None else est[1])
    ctx.memo[sig] = out
    return out


def estimate(root: PhysicalOp, db: Database,
             _cache: Optional[dict] = None) -> dict:
    """Static (est_rows, est_cost) per node, bottom-up, using the §6.3 cost
    model over the live column statistics (NDV, histograms, MCV counts) —
    the numbers the optimizer's DAG rewrites and the cost-aware inter-buffer
    admission key off. ``est_cost`` is *cumulative*: the operator's own cost
    plus that of every *distinct* node in its subtree (shared sub-plans are
    counted once, matching the executor's signature memoization) — i.e. the
    estimated price of recomputing the node from base collections.
    Returns ``{id(node): (est_rows, est_cost)}``.

    ``_cache`` (optional) memoizes per-node results across repeated calls,
    keyed by the node's *signature* — the canonical structural fingerprint
    that embeds every source collection's write epoch. A cached estimate is
    therefore valid for any structurally identical node (across the
    optimizer's candidate plans *and* across queries), and a delta-store
    append changes the source epoch, the signature, and hence the cache
    key — stale cardinalities can never be replayed. The optimizer
    additionally clears its shared cache on any catalog-epoch change
    (``optimizer.optimize``), which garbage-collects entries the new
    signatures would never hit."""
    from . import cost as cost_mod
    rows_of: dict[int, float] = {}     # est rows per node
    own: dict[int, float] = {}         # the operator's own (non-subtree) cost
    cum: dict[int, float] = {}         # dedup-summed subtree cost per node
    nodes: dict[int, PhysicalOp] = {}
    width: dict[int, float] = {}       # est columns of matrix-valued nodes

    def sel(tbl: Table, preds) -> float:
        s = 1.0
        for p in preds:
            s *= tbl.stats(p.column).selectivity(p)
        return s

    def pred_sel(pred) -> float:
        if pred.collection in db.tables:
            return db.tables[pred.collection].stats(pred.column).selectivity(pred)
        return 1.0 / 3.0

    def mask_rows(n: SemiJoinMask, child_rows: float) -> float:
        """Expected candidate vertices a semi-join mask keeps."""
        n_label = float(db.graphs[n.graph].vertex_tables[n.label].nrows)
        os = resolve_key_stats(db, getattr(n, "ocol_src", None))
        keys = min(float(os.ndv), child_rows) if os is not None else child_rows
        return min(n_label, max(keys, 0.0))

    def walk(n: PhysicalOp) -> float:
        if id(n) in rows_of:
            return rows_of[id(n)]
        nodes[id(n)] = n
        if _cache is not None:
            ent = _cache.get(n.signature())
            if ent is not None:
                rows_of[id(n)], own[id(n)], width[id(n)] = ent[0]
                if ent[1] is not None:
                    cum[id(n)] = ent[1]
                if ent[2] is not None:
                    n.est_src = ent[2]
                for c in n.children:    # register descendants for dedup sums
                    walk(c)
                return rows_of[id(n)]
        child_rows = [walk(c) for c in n.children]
        first = child_rows[0] if child_rows else 0.0
        if isinstance(n, ScanTable):
            rows = float(db.tables[n.name].nrows)
            cost = cost_mod.cost_scan(rows)
        elif isinstance(n, Select):
            s = sel(db.tables[n.preds[0].collection], n.preds) if n.preds else 1.0
            rows = first * s
            cost = cost_mod.cost_filter(first, len(n.preds))
        elif isinstance(n, IndexScan):
            tbl = db.tables[n.name]
            nt = float(tbl.nrows)
            sels = [tbl.stats(p.column).selectivity(p) for p in n.preds]
            hits = nt * sels[n.pick]
            rows = nt * float(np.prod(sels)) if sels else nt
            cost = cost_mod.cost_index_lookup(nt, hits)
            if len(n.preds) > 1:    # residual point-evaluation on the hits
                cost += cost_mod.cost_filter(hits, len(n.preds) - 1)
        elif isinstance(n, IndexSelect):
            tbl = db.tables[n.name]
            nt = float(tbl.nrows)
            sels = [tbl.stats(p.column).selectivity(p) for p in n.preds]
            rows = nt * float(np.prod(sels)) if sels else nt
            im = getattr(db, "_index_manager", None)
            idx = (im.get(n.name, n.preds[n.pick].column)
                   if im is not None else None)
            frac = idx.zone_fraction(n.preds[n.pick]) if idx is not None else None
            chunks = (idx.zones.n_chunks
                      if idx is not None and idx.zones is not None else 0.0)
            cost = cost_mod.cost_zone_scan(nt, 1.0 if frac is None else frac,
                                           chunks)
            if len(n.preds) > 1:    # residuals run on every picked-pred hit
                cost += cost_mod.cost_filter(nt * sels[n.pick],
                                             len(n.preds) - 1)
        elif isinstance(n, PruneCols):
            rows = first
            cost = len(n.cols) * cost_mod.COST_CPU
        elif isinstance(n, SemiJoinMask):
            n_label = float(db.graphs[n.graph].vertex_tables[n.label].nrows)
            rows = mask_rows(n, first)
            cost = cost_mod.cost_semijoin(first, n_label)
        elif isinstance(n, SemiJoinReduce):
            g = db.graphs[n.graph]
            n_label = float(g.vertex_tables[n.label].nrows)
            vs = g.vertex_tables[n.label].stats(n.vcol) \
                if n.vcol in g.vertex_tables[n.label].columns else None
            os = resolve_key_stats(db, getattr(n, "ocol_src", None))
            keys = min(float(vs.ndv), n_label) if vs is not None else n_label
            dom = float(os.ndv) if os is not None else max(first, 1.0)
            rows = first * min(1.0, keys / max(dom, 1.0))
            cost = cost_mod.cost_semijoin(first, n_label)
        elif isinstance(n, MatchPattern):
            g = db.graphs[n.graph]
            p = n.pplan
            chain = [p.pattern.vertices[0].var] + [e.dst for e in p.pattern.edges]
            start = chain[-1] if p.reverse else chain[0]
            stbl = g.vertex_tables[p.pattern.vertex(start).label]
            n_start = stbl.nrows * sel(stbl, p.pushed.get(start, []))
            # semi-join candidate masks shrink the start frontier (or filter
            # the result, when the masked var is not the traversal start)
            filter_frac = 1.0
            for var, mchild, crows in zip(n.mask_vars, n.children, child_rows):
                mnode = mchild if isinstance(mchild, SemiJoinMask) else None
                label = p.pattern.vertex(var).label
                n_label = float(g.vertex_tables[label].nrows)
                kept = crows if mnode is not None else n_label
                frac = min(1.0, kept / max(n_label, 1.0))
                if var == start:
                    n_start *= frac
                else:
                    filter_frac *= frac
            hops = len(p.pattern.edges)
            # per-hop, label-aware expansion: each hop's fan-out is the
            # live-edge count over *that hop's* source-label population (the
            # traversal-order chain, so reverse directions and mixed-label
            # paths stop compounding one global average)
            hop_order = chain[::-1] if p.reverse else chain
            fanouts = [g.hop_expansion(reverse=p.reverse,
                                       label=p.pattern.vertex(v).label)
                       for v in hop_order[:-1]]
            expansion = float(np.prod(fanouts)) if fanouts else 1.0
            # end/interior pushed predicates filter the expansion too
            end_sel = 1.0
            for var, ps in p.pushed.items():
                if var == start:
                    continue
                vtbl = (g.edges if any(e.var == var for e in p.pattern.edges)
                        else g.vertex_tables[p.pattern.vertex(var).label])
                end_sel *= sel(vtbl, ps)
            rows = n_start * expansion * filter_frac * end_sel
            # Eq. 11-13 charge per-hop traversal work off one fan-out
            # scalar; feed it the geometric mean of the per-hop values
            gm_fanout = expansion ** (1.0 / hops) if hops else 0.0
            cost = cost_mod.cost_pattern(
                sum(len(ps) for v, ps in p.pushed.items()
                    if not any(e.var == v for e in p.pattern.edges)),
                sum(len(ps) for v, ps in p.pushed.items()
                    if any(e.var == v for e in p.pattern.edges)),
                g.n_vertices, g.n_live_edges, n_start, hops,
                gm_fanout, rows,
                sum(len(ps) for ps in p.deferred.values()))
        elif isinstance(n, DeviceMatchPattern):
            # same cardinality math as MatchPattern (no mask children),
            # priced with the device cost model: vertex predicate tables are
            # columnar scans, edge tables read the zone-candidate fraction
            # only, frontier work runs at vector width, and each launch
            # window pays a fixed dispatch+sync charge (per hop on the jit
            # flavor, once on the fused flavor)
            g = db.graphs[n.graph]
            p = n.pplan
            chain = [p.pattern.vertices[0].var] + [e.dst for e in p.pattern.edges]
            start = chain[-1] if p.reverse else chain[0]
            stbl = g.vertex_tables[p.pattern.vertex(start).label]
            n_start = stbl.nrows * sel(stbl, p.pushed.get(start, []))
            hops = len(p.pattern.edges)
            hop_order = chain[::-1] if p.reverse else chain
            fanouts = [g.hop_expansion(reverse=p.reverse,
                                       label=p.pattern.vertex(v).label)
                       for v in hop_order[:-1]]
            expansion = float(np.prod(fanouts)) if fanouts else 1.0
            end_sel = 1.0
            edge_vset = {e.var for e in p.pattern.edges}
            for var, ps in p.pushed.items():
                if var == start:
                    continue
                vtbl = (g.edges if var in edge_vset
                        else g.vertex_tables[p.pattern.vertex(var).label])
                end_sel *= sel(vtbl, ps)
            rows = n_start * expansion * end_sel
            gm_fanout = expansion ** (1.0 / hops) if hops else 0.0
            zf = 1.0
            im = getattr(db, "_index_manager", None)
            if im is not None and n.access != "device-jit":
                for var, ps in p.pushed.items():
                    if var not in edge_vset:
                        continue
                    for pr in ps:
                        f = im.zone_fraction(n.graph, pr)
                        if f is not None:
                            zf = min(zf, f)
            cost = cost_mod.cost_device_match(
                sum(len(ps) for v, ps in p.pushed.items()
                    if v not in edge_vset),
                sum(len(ps) for v, ps in p.pushed.items()
                    if v in edge_vset),
                g.n_vertices, g.n_live_edges, n_start, hops,
                gm_fanout, rows,
                sum(len(ps) for ps in p.deferred.values()),
                zone_frac=zf, per_hop_sync=(n.access == "device-jit"))
        elif isinstance(n, TableJoinMatch):
            g = db.graphs[n.graph]
            hops = len(n.pattern.edges)
            e = g.n_live_edges
            if hops:
                # k-way edge-table joins: the first edge table contributes
                # |E| rows; every later hop multiplies by the fan-out of its
                # shared chain vertex, label-aware per hop (the pattern's
                # own direction — not the graph-global forward average,
                # which is wrong on reverse traversals of bipartite graphs)
                tchain = ([n.pattern.vertices[0].var]
                          + [ed.dst for ed in n.pattern.edges])
                rows = float(e)
                for v in tchain[1:-1]:
                    rows *= g.hop_expansion(label=n.pattern.vertex(v).label)
            else:
                rows = float(g.vertex_tables[n.pattern.vertices[0].label].nrows)
            cost = sum(cost_mod.cost_join(rows, e) for _ in range(max(hops, 1)))
        elif isinstance(n, VertexScan):
            g = db.graphs[n.graph]
            tbl = g.vertex_tables[n.pattern.vertex(n.pattern.vertices[0].var).label]
            preds = n.pplan.deferred.get(n.pattern.vertices[0].var, []) if n.pplan else []
            rows = tbl.nrows * sel(tbl, preds)
            cost = cost_mod.cost_scan(tbl.nrows)
        elif isinstance(n, EdgeScan):
            g = db.graphs[n.graph]
            preds = n.pplan.deferred.get(n.pattern.edges[0].var, []) if n.pplan else []
            rows = g.edges.nrows * sel(g.edges, preds)
            cost = cost_mod.cost_scan(g.edges.nrows)
        elif isinstance(n, GraphProject):
            rows = first
            cost = cost_mod.cost_project(first, sum(map(len, n.wanted.values())))
        elif isinstance(n, EquiJoin):
            ls, rs = (resolve_key_stats(db, s)
                      for s in getattr(n, "key_src", (None, None)))
            rows, n.est_src = est_join_rows_detail(
                child_rows[0], child_rows[1], ls, rs)
            cost = cost_mod.cost_join(child_rows[0], child_rows[1])
        elif isinstance(n, IntraFilter):
            ls, rs = (resolve_key_stats(db, s)
                      for s in getattr(n, "key_src", (None, None)))
            rows = est_intra_filter_rows(first, ls, rs)
            cost = cost_mod.cost_filter(first)
        elif isinstance(n, Residual):
            s = 1.0
            for pred in n.preds:
                s *= pred_sel(pred)
            rows = first * s
            cost = cost_mod.cost_filter(first, len(n.preds))
        elif isinstance(n, Exchange):
            rows = first
            cost = cost_mod.cost_exchange(first, n.k)
        elif isinstance(n, Rel2Matrix):
            rows = first
            width[id(n)] = float(len(n.columns))
            cost = cost_mod.cost_matrix_gen(first, len(n.columns))
        elif isinstance(n, RandomAccessMatrix):
            rows = first
            width[id(n)] = float(n.n_features)
            cost = cost_mod.cost_matrix_gen(first, n.n_features)
        elif isinstance(n, Const):
            shape = n._digest[1]
            rows = float(shape[0]) if shape else 1.0
            width[id(n)] = float(shape[1]) if len(shape) > 1 else 1.0
            cost = 0.0
        elif isinstance(n, MatMul):
            k = width.get(id(n.children[0]), 1.0)
            m = first if n.gram else width.get(id(n.children[1]), 1.0)
            rows = first
            width[id(n)] = m
            cost = cost_mod.cost_matmul(first, k, m)
        elif isinstance(n, Similarity):
            k = width.get(id(n.children[0]), 1.0)
            m = first if n.self_sim else child_rows[1]
            rows = first
            width[id(n)] = m
            cost = cost_mod.cost_similarity(first, k, m)
        elif isinstance(n, Regression):
            k = width.get(id(n.children[0]), 1.0)
            rows = k
            width[id(n)] = 1.0
            cost = cost_mod.cost_regression(first, k, n.iters)
        else:   # Alias / Project / remaining pass-throughs
            rows = first
            width[id(n)] = width.get(id(n.children[0]), 1.0) if n.children else 1.0
            cost = first * cost_mod.COST_CPU
        rows_of[id(n)] = rows
        own[id(n)] = cost
        if _cache is not None:
            _cache[n.signature()] = [(rows, cost, width.get(id(n), 1.0)),
                                     None, getattr(n, "est_src", None)]
        return rows

    walk(root)

    def cumulative(n: PhysicalOp) -> float:
        """Sum of own costs over the *distinct* nodes of n's subtree —
        shared sub-plans count once, like the executor runs them. Memoized
        per node (and persisted in ``_cache``: a node's subtree cost is
        context-independent)."""
        if id(n) in cum:
            return cum[id(n)]
        seen: set[int] = set()
        total = 0.0
        stack = [n]
        while stack:
            m = stack.pop()
            if id(m) in seen:
                continue
            seen.add(id(m))
            total += own[id(m)]
            stack.extend(m.children)
        cum[id(n)] = total
        if _cache is not None:
            ent = _cache.get(n.signature())
            if ent is not None:
                ent[1] = total
        return total

    return {nid: (rows_of[nid], cumulative(m)) for nid, m in nodes.items()}


def plan_fingerprint(root: PhysicalOp) -> str:
    """Stable 16-hex identity of a plan, derived from the root signature.
    Signatures embed source write-epochs, so the same template re-planned
    after a mutation fingerprints differently — exactly the identity the
    flight recorder wants (a record names *this* plan against *this* data
    version, not the query template)."""
    return fingerprint(root.signature())


def collect_stats(root: PhysicalOp) -> list[dict]:
    """Flatten per-operator stats (pre-order, shared nodes once)."""
    out: list[dict] = []
    seen: set[int] = set()

    def walk(n: PhysicalOp, depth: int):
        if id(n) in seen:
            return
        seen.add(id(n))
        s = n.stats
        out.append({"op": n.kind, "describe": n.describe(), "depth": depth,
                    "rows": s.rows, "nbytes": s.nbytes, "seconds": s.seconds,
                    "executed": s.executed, "cached": s.cached})
        for c in n.children:
            walk(c, depth + 1)

    walk(root, 0)
    return out


def total_seconds(root: PhysicalOp) -> float:
    """Summed per-operator wall seconds over distinct executed nodes —
    ``stats.seconds`` wraps only ``node.run``, so this is self-time and the
    denominator of the ``pct=`` explain bits."""
    return sum(r["seconds"] for r in collect_stats(root) if r["executed"])


def explain(root: PhysicalOp, stats: bool = False,
            db: Optional[Database] = None,
            ests: Optional[dict] = None, top: int = 0) -> str:
    """GCDIPlan.explain()-style rendering of the operator DAG. With
    ``stats=True`` (after execution) each row shows rows/bytes/seconds and
    the operator's share of total plan time, plus whether it was satisfied
    from the inter-buffer; with ``db`` (or a precomputed ``ests`` map) each
    row also shows the §6.3 cost-model estimates — so a post-execution
    rendering puts est_rows next to the actual rows per operator.
    ``top > 0`` appends the k hottest operators sorted by wall seconds."""
    lines: list[str] = []
    seen: dict[int, int] = {}
    if ests is None:
        ests = estimate(root, db) if db is not None else {}
    total = max(total_seconds(root), 1e-12) if stats else 1.0
    hot: list[PhysicalOp] = []

    def walk(n: PhysicalOp, depth: int):
        pad = "  " * depth
        if id(n) in seen:
            lines.append(f"{pad}^shared:{n.describe()}")
            return
        seen[id(n)] = len(lines)
        bits = []
        if stats:
            s = n.stats
            if s.cached:
                bits.append("interbuffer-hit")
            elif s.memoized and not s.executed:
                bits.append("memo")
            if s.rows is not None:
                bits.append(f"rows={s.rows}")
            if s.nbytes:
                bits.append(f"bytes={s.nbytes}")
            if s.executed:
                bits.append(f"ms={s.seconds * 1e3:.2f}")
                bits.append(f"pct={s.seconds / total * 100:.1f}%")
                hot.append(n)
        if id(n) in ests:
            er, ec = ests[id(n)]
            bits.append(f"est_rows={er:.3g}")
            bits.append(f"est_cost={ec:.3g}")
            src = getattr(n, "est_src", None)
            if src is not None:     # join-estimate provenance (per-bucket
                bits.append(f"est_via={src}")   # overlap vs NDV fallback)
        if stats or ests:           # access-path provenance (optimizer's
            acc = getattr(n, "access", None)    # index/zone/full decision)
            if acc is not None:
                bits.append(f"access={acc}")
            shards = getattr(n, "shards", None)  # shard-planner provenance
            if shards is not None:
                bits.append(f"shards={shards}")
        suffix = "  (" + ", ".join(bits) + ")" if bits else ""
        lines.append(f"{pad}{n.describe()}{suffix}")
        for c in n.children:
            walk(c, depth + 1)

    walk(root, 0)
    if stats and top > 0 and hot:
        hot.sort(key=lambda n: n.stats.seconds, reverse=True)
        lines.append(f"== top {min(top, len(hot))} operators by time ==")
        for n in hot[:top]:
            lines.append(f"  {n.describe()}: ms={n.stats.seconds * 1e3:.2f} "
                         f"({n.stats.seconds / total * 100:.1f}%)")
    return "\n".join(lines)
