"""Cross-model join operator ``⋈̂`` (paper §5.3, Algorithm 3), vectorized.

Two strategies, as in the paper:
  1. rel/doc x rel/doc — record-level equi-join. The paper uses nested-loop /
     PK-index joins; the TPU-idiomatic equivalent is a sort+searchsorted
     equi-join (one gather per probe, no hash tables, fully vectorizable).
  2. graph x rel/doc — entity linking: the join filters the graph's vertex or
     edge record set in place and returns the (still-graph) collection, so a
     subsequent match runs on the reduced candidate sets (join pushdown,
     Eq. 9/10).
"""
from __future__ import annotations

import numpy as np

from . import traversal
from .schema import JoinPred
from .storage import DictColumn, Graph, RaggedColumn, Table


def _key_arrays(tbl: Table, column: str):
    """Return (keys, row_ids). Ragged (multi-valued NF²) columns unnest:
    each element becomes a probe key with its parent row id."""
    col = tbl.col(column)
    if isinstance(col, DictColumn):
        return col.vocab[col.codes], np.arange(tbl.nrows)
    if isinstance(col, RaggedColumn):
        rows = np.repeat(np.arange(len(col)), col.lengths())
        return col.values, rows
    return np.asarray(col), np.arange(tbl.nrows)


def equi_join_indices(left: Table, lcol: str, right: Table, rcol: str
                      ) -> tuple[np.ndarray, np.ndarray]:
    """All (left_row, right_row) pairs with left.lcol == right.rcol.
    Sort-based: sort right keys, binary-search each left key, expand runs."""
    lk, lrows = _key_arrays(left, lcol)
    rk, rrows = _key_arrays(right, rcol)
    traversal.COUNTERS.cpu_ops += len(lk) + len(rk)

    order = np.argsort(rk, kind="stable")
    rk_s, rrows_s = rk[order], rrows[order]
    lo = np.searchsorted(rk_s, lk, side="left")
    hi = np.searchsorted(rk_s, lk, side="right")
    counts = hi - lo
    total = int(counts.sum())
    l_rep = np.repeat(np.arange(len(lk)), counts)
    out_off = np.zeros(len(lk) + 1, dtype=np.int64)
    np.cumsum(counts, out=out_off[1:])
    pos = np.repeat(lo, counts) + (np.arange(total) - np.repeat(out_off[:-1], counts))
    traversal.COUNTERS.cpu_ops += total
    return lrows[l_rep], rrows_s[pos]


def join_tables(left: Table, right: Table, pred: JoinPred,
                lprefix: str = "", rprefix: str = "") -> Table:
    """Strategy 1: rel/doc ⋈̂ rel/doc producing a linked NF² collection."""
    lcol = pred.left.split(".", 1)[1]
    rcol = pred.right.split(".", 1)[1]
    li, ri = equi_join_indices(left, lcol, right, rcol)
    lt, rt = left.take(li), right.take(ri)
    cols = {}
    for k, v in lt.columns.items():
        cols[f"{lprefix or left.name}.{k}"] = v
    for k, v in rt.columns.items():
        cols[f"{rprefix or right.name}.{k}"] = v
    traversal.COUNTERS.record_fetches += len(li) + len(ri)
    return Table(f"{left.name}⋈{right.name}", cols)


def member_mask(tbl: Table, col: str, keys: np.ndarray) -> np.ndarray:
    """Boolean mask over ``tbl`` rows whose ``col`` value appears in ``keys``
    (ANY semantics for ragged columns). The shared probe of both semi-join
    sidings: graph-side candidate masks and table-side reductions."""
    tk, trows = _key_arrays(tbl, col)
    traversal.COUNTERS.cpu_ops += len(tk) + len(keys)
    keys_u = np.unique(np.asarray(keys))
    hit = np.zeros(tbl.nrows, dtype=bool)
    if len(keys_u):
        pos = np.clip(np.searchsorted(keys_u, tk), 0, len(keys_u) - 1)
        np.logical_or.at(hit, trows, keys_u[pos] == tk)
    return hit


def semi_join_graph(g: Graph, label: str, vcol: str, other: Table, ocol: str
                    ) -> np.ndarray:
    """Strategy 2 (Lines 4-12): graph ⋈̂ rel/doc. Returns the boolean mask of
    vertices of ``label`` whose ``vcol`` appears in ``other.ocol`` — i.e. the
    updated vertex record set V of the output graph. The topology is shared
    (candidate-set semantics), which is what enables join pushdown into the
    match (Eq. 9/10)."""
    ok, _ = _key_arrays(other, ocol)
    return member_mask(g.vertex_tables[label], vcol, ok)


def semi_join_table(tbl: Table, col: str, g: Graph, label: str, vcol: str
                    ) -> np.ndarray:
    """The reverse siding of the Eq. 9/10 semi-join: boolean mask of *table*
    rows whose ``col`` appears among the graph's ``label.vcol`` vertex keys.
    Reduces the relational/document side before the final equi-join when the
    vertex key set is the smaller build input."""
    vk, _ = _key_arrays(g.vertex_tables[label], vcol)
    return member_mask(tbl, col, vk)


def match_by_joins(g: Graph, pat) -> Table:
    """TBS-style pattern matching (GredoDB-S): k-hop pattern == k-way
    self-join of the edge table on svid/tvid (index-accelerated in
    AgensGraph; sort-merge here). No topology store, no pushdown —
    intermediate results grow multiplicatively, which is exactly the §2.2
    critique. Executed by the physical plan's TableJoinMatch operator."""
    from .deltastore import expand_runs
    chain_vars = [pat.vertices[0].var] + [e.dst for e in pat.edges]
    edge_vars = [e.var for e in pat.edges]
    if not edge_vars:  # vertex-only pattern: full vertex scan
        var = pat.vertices[0].var
        n = g.vertex_tables[pat.vertex(var).label].nrows
        traversal.COUNTERS.record_fetches += n
        return Table("join0", {var: np.arange(n)})
    live = g.live_edge_ids()  # tombstoned edges never join
    svid = np.asarray(g.edges.col("svid"))
    tvid = np.asarray(g.edges.col("tvid"))
    if g.delta.n_tombstones:  # only copy-filter when something is dead
        svid, tvid = svid[live], tvid[live]
    traversal.COUNTERS.record_fetches += 2 * len(svid) * max(len(edge_vars), 1)

    cols = {chain_vars[0]: svid, edge_vars[0]: live, chain_vars[1]: tvid}
    cur = Table("join0", cols)
    # the edge table is static across hops: sort once, probe per hop
    order = np.argsort(svid, kind="stable")
    svid_s = svid[order]
    for h in range(1, len(edge_vars)):
        # join cur.tail == edges.svid
        tail = np.asarray(cur.col(chain_vars[h]))
        lo = np.searchsorted(svid_s, tail, "left")
        hi = np.searchsorted(svid_s, tail, "right")
        l_rep, pos = expand_runs(lo, hi - lo)
        total = len(pos)
        traversal.COUNTERS.cpu_ops += total
        traversal.COUNTERS.record_fetches += total
        rows = order[pos]
        ncols = {k: np.asarray(v)[l_rep] for k, v in cur.columns.items()}
        ncols[edge_vars[h]] = live[rows]
        ncols[chain_vars[h + 1]] = tvid[rows]
        cur = Table(f"join{h}", ncols)
    return cur


def semi_join_graph_edges(g: Graph, ecol: str, other: Table, ocol: str) -> np.ndarray:
    """graph ⋈̂ rel/doc over edge records: boolean mask of edges."""
    ok, _ = _key_arrays(other, ocol)
    return member_mask(g.edges, ecol, ok)
