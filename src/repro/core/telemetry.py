"""Unified observability: span tracing, metrics registry, q-error monitor.

The engine's instrumentation was a handful of disconnected counters
(module-global write counters, cumulative inter-buffer tallies, per-index
staleness counts) plus a text-only ``explain_last``. This module unifies
them behind three primitives, all off-by-default and designed so the
*disabled* path costs a few pointer checks per operator:

* **Metrics** — :class:`Counter` / :class:`Gauge` / :class:`Histogram`
  (fixed log-spaced latency buckets with p50/p95/p99 readout) under one
  namespaced :class:`Registry`. Existing subsystem counters plug in as
  *sources* (pull-based collectors), so ``Registry.snapshot()`` is one flat
  ``name -> value`` dict and :func:`Registry.delta` turns the
  cumulative-forever tallies into correct per-query numbers.
* **Spans** — every physical-operator execution emits a :class:`Span`
  (op kind, wall seconds, rows/bytes, est vs. actual rows, access-path and
  cache provenance) into a bounded per-engine :class:`TraceCollector`.
  Traces export as Chrome trace-event JSON (:meth:`TraceCollector.to_chrome`,
  loadable in Perfetto / ``chrome://tracing``) and as an ``EXPLAIN
  ANALYZE``-style annotated tree (:meth:`QueryTrace.render`).
* **Q-error monitor** — per-operator ``max(est/actual, actual/est)`` row
  ratios land in a bounded misestimate log; operators above a configurable
  threshold are flagged per plan (:class:`QErrorMonitor`) — the feedback
  hook the optimizer's stats revalidation will consume.

GCDA kernel spans carry ``dispatch_s`` (host time until the call returns)
and ``sync_s`` (``block_until_ready`` wait), so jit/device time is
attributed separately from host time; ``benchmarks/roofline.py`` consumes
these via its ``from_trace`` helper.

Everything here is dependency-free within the engine (numpy + stdlib; jax
only through duck-typed ``block_until_ready``), so every core module may
import it without cycles.
"""
from __future__ import annotations

import dataclasses
import json
import re
import threading
import time
from typing import Any, Callable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Metrics: counters, gauges, fixed-bucket histograms
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter. ``snapshot()`` values subtract cleanly.
    ``inc`` is lock-guarded — ``+=`` is not atomic under threads and morsel
    workers increment shared counters concurrently."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self.value += n

    def reset(self) -> None:
        with self._lock:
            self.value = 0


class Gauge:
    """Point-in-time value (resident bytes, entry counts, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


# Per-decade 1/2.5/5 steps from 1µs to 10s — fixed bucket bounds so two
# histograms (or two snapshots of one) are always mergeable/comparable.
DEFAULT_LATENCY_BUCKETS: tuple = tuple(
    m * 10.0 ** e for e in range(-6, 2) for m in (1.0, 2.5, 5.0))


class Histogram:
    """Fixed-bucket histogram with percentile readout. Buckets are upper
    bounds; an observation lands in the first bucket whose bound is >= the
    value (the last bucket is open-ended). Percentiles interpolate linearly
    inside the winning bucket and clamp to the observed min/max."""

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str, bounds: tuple = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = np.zeros(len(self.bounds) + 1, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[int(np.searchsorted(self.bounds, v, side="left"))] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """q in [0, 100]. 0 observations -> nan."""
        if self.count == 0:
            return float("nan")
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = 1.0 - (cum - rank) / c
                est = lo + frac * (hi - lo)
                return float(min(max(est, self.min), self.max))
        return self.max

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def summary(self) -> dict:
        if self.count == 0:
            # finite zeros, never NaN: empty histograms flow through
            # snapshots into JSON dumps / OpenMetrics text, where NaN is
            # invalid. `percentile()` itself keeps returning NaN — "no
            # observations" and "p99 == 0.0" are different claims.
            return {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "p50": self.p50, "p95": self.p95, "p99": self.p99}

    def reset(self) -> None:
        with self._lock:
            self.counts[:] = 0
            self.count = 0
            self.sum = 0.0
            self.min = float("inf")
            self.max = float("-inf")


class Registry:
    """Namespaced metric registry. Besides push-style metrics (``counter`` /
    ``gauge`` / ``histogram``), subsystems with their own counters register
    as *sources*: a callable returning a flat ``{name: number}`` dict,
    evaluated at :meth:`snapshot` time. That absorbs the pre-existing
    scattered tallies (delta-store write counters, inter-buffer admission,
    index staleness/rebuild counts) without rewriting their hot paths.

    ``snapshot()`` -> flat dict; :func:`Registry.delta` subtracts two
    snapshots — cumulative counters become per-interval numbers (for gauges
    the delta is the net change). Histograms contribute
    ``name.count/.sum/.p50/.p95/.p99``; the percentile keys are absolute
    (session-cumulative) and excluded from deltas."""

    _ABSOLUTE_SUFFIXES = (".p50", ".p95", ".p99")

    def __init__(self):
        self._metrics: dict[str, Any] = {}
        self._sources: dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: tuple = DEFAULT_LATENCY_BUCKETS) -> Histogram:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Histogram(name, bounds)
            elif not isinstance(m, Histogram):
                raise TypeError(f"{name} is a {type(m).__name__}, not Histogram")
            return m

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"{name} is a {type(m).__name__}, not {cls.__name__}")
            return m

    def register_source(self, namespace: str, fn: Callable[[], dict]) -> None:
        """``fn()`` contributes ``{f"{namespace}.{k}": v}`` per snapshot."""
        with self._lock:
            self._sources[namespace] = fn

    def snapshot(self) -> dict:
        out: dict[str, float] = {}
        with self._lock:
            metrics = list(self._metrics.items())
            sources = list(self._sources.items())
        for name, m in metrics:
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        for ns, fn in sources:
            try:
                vals = fn()
            except Exception:       # a dead source never breaks a snapshot
                continue
            for k, v in vals.items():
                out[f"{ns}.{k}"] = v
        return out

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """after - before per key (new keys pass through); percentile keys
        are reported as-is from ``after`` (quantiles don't subtract)."""
        out = {}
        for k, v in after.items():
            if k.endswith(Registry._ABSOLUTE_SUFFIXES):
                out[k] = v
                continue
            try:
                out[k] = v - before.get(k, 0)
            except TypeError:
                out[k] = v
        return out

    def reset(self) -> None:
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    @staticmethod
    def _om_name(name: str) -> str:
        """Metric-name sanitizer for the OpenMetrics grammar:
        ``[a-zA-Z_:][a-zA-Z0-9_:]*`` — dots and slashes become
        underscores."""
        n = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
        return n if re.match(r"[a-zA-Z_:]", n) else "_" + n

    @staticmethod
    def _om_value(v) -> str:
        v = float(v)
        return repr(int(v)) if v == int(v) else repr(v)

    def to_openmetrics(self) -> str:
        """Render the registry as Prometheus/OpenMetrics exposition text:
        counters as ``<name>_total``, gauges bare, histograms as cumulative
        ``_bucket{le=...}`` series plus ``_sum``/``_count``. Source metrics
        (pull-style subsystem tallies) export as gauges under their
        namespace. This is the scrape endpoint payload for serving-layer
        deployments — pair with ``engine.health()``, whose verdicts land
        here as ``health_*`` gauges."""
        with self._lock:
            metrics = sorted(self._metrics.items())
            sources = sorted(self._sources.items())
        lines: list[str] = []
        for name, m in metrics:
            n = self._om_name(name)
            if isinstance(m, Counter):
                lines += [f"# TYPE {n} counter",
                          f"{n}_total {self._om_value(m.value)}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {n} gauge",
                          f"{n} {self._om_value(m.value)}"]
            else:                                   # Histogram
                lines.append(f"# TYPE {n} histogram")
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += int(c)
                    lines.append(f'{n}_bucket{{le="{bound:g}"}} {cum}')
                lines.append(f'{n}_bucket{{le="+Inf"}} {m.count}')
                lines += [f"{n}_sum {self._om_value(m.sum)}",
                          f"{n}_count {m.count}"]
        for ns, fn in sources:
            try:
                vals = fn()
            except Exception:       # a dead source never breaks a scrape
                continue
            for k, v in sorted(vals.items()):
                n = self._om_name(f"{ns}.{k}")
                lines += [f"# TYPE {n} gauge", f"{n} {self._om_value(v)}"]
        lines.append("# EOF")
        return "\n".join(lines) + "\n"

    def __len__(self):
        return len(self._metrics)


_DEFAULT_REGISTRY = Registry()


def default_registry() -> Registry:
    """Process-global registry for callers that want one shared sink.
    New code should prefer a per-engine / per-test Registry (write-path
    counters live per graph in ``Graph.write_counters``)."""
    return _DEFAULT_REGISTRY


# ---------------------------------------------------------------------------
# Spans: per-operator tracing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Span:
    """One operator execution (or cache pseudo-event) in a query trace.
    ``ts``/``dur`` are seconds relative to the owning trace's origin; spans
    of a query nest strictly (a parent opens before and closes after all of
    its children)."""

    id: int
    parent: int             # -1 for the query root
    name: str               # operator kind ("MatchPattern", "EquiJoin", ...)
    cat: str                # "gcdi" | "gcda" | "cache" | "query"
    ts: float
    dur: float = 0.0
    detail: str = ""        # PhysicalOp.describe()
    args: dict = dataclasses.field(default_factory=dict)


class QueryTrace:
    """The span tree of one query/analyze execution. ``begin``/``end`` keep
    an explicit open-span stack, matching the executor's recursion; an
    ``instant`` span records cache hits (inter-buffer / memo) as zero-ish
    duration pseudo-spans so the trace covers every DAG node touched."""

    def __init__(self, label: str, origin: Optional[float] = None):
        self.label = label
        self.t0 = time.perf_counter() if origin is None else origin
        self.spans: list[Span] = []
        self._stack: list[int] = []
        self._lock = threading.Lock()
        root = Span(id=0, parent=-1, name="query", cat="query",
                    ts=0.0, detail=label)
        self.spans.append(root)
        self._stack.append(0)

    # -- recording --
    def begin(self, name: str, cat: str = "gcdi", detail: str = "") -> int:
        with self._lock:
            sid = len(self.spans)
            self.spans.append(Span(id=sid, parent=self._stack[-1], name=name,
                                   cat=cat, ts=time.perf_counter() - self.t0,
                                   detail=detail))
            self._stack.append(sid)
            return sid

    def end(self, sid: int, **args) -> None:
        with self._lock:
            s = self.spans[sid]
            s.dur = (time.perf_counter() - self.t0) - s.ts
            if args:
                s.args.update(args)
            while self._stack and self._stack[-1] != sid:
                self._stack.pop()       # tolerate unbalanced ends
            if self._stack:
                self._stack.pop()

    def instant(self, name: str, detail: str = "", **args) -> int:
        sid = self.begin(name, cat="cache", detail=detail)
        self.end(sid, **args)
        return sid

    def close(self, **args) -> None:
        """Close the query root (and anything left open)."""
        for sid in reversed(self._stack[1:]):
            self.end(sid)
        self.end(0, **args)

    # -- views --
    def children_of(self, sid: int) -> list[Span]:
        return [s for s in self.spans if s.parent == sid]

    def shape(self) -> list:
        """Nested ``(name, [children...])`` of the operator spans — directly
        comparable to the physical DAG's structure in tests."""
        def rec(sid: int):
            return [(s.name, rec(s.id)) for s in self.children_of(sid)]
        return rec(0)

    def total_seconds(self) -> float:
        return self.spans[0].dur

    def render(self, top: int = 0) -> str:
        """EXPLAIN ANALYZE-style annotated tree: per-operator wall seconds,
        % of the query total, rows, est vs. actual, cache/access provenance.
        ``top > 0`` appends the k hottest operators by self-time."""
        total = max(self.total_seconds(), 1e-12)
        lines: list[str] = []

        def self_seconds(s: Span) -> float:
            return s.dur - sum(c.dur for c in self.children_of(s.id))

        def rec(sid: int, depth: int):
            for s in self.children_of(sid):
                bits = [f"ms={s.dur * 1e3:.3f}", f"pct={s.dur / total * 100:.1f}%"]
                for k in ("rows", "est_rows", "q_error", "nbytes", "access",
                          "cache", "dispatch_s", "sync_s"):
                    if k in s.args:
                        v = s.args[k]
                        bits.append(f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}")
                lines.append("  " * depth + (s.detail or s.name)
                             + "  (" + ", ".join(bits) + ")")
                rec(s.id, depth + 1)

        lines.append(f"{self.label}  (total_ms={total * 1e3:.3f})")
        rec(0, 1)
        if top > 0:
            ops = [s for s in self.spans if s.cat in ("gcdi", "gcda")]
            ops.sort(key=self_seconds, reverse=True)
            lines.append(f"== top {top} operators by self time ==")
            for s in ops[:top]:
                lines.append(f"  {s.detail or s.name}: "
                             f"self_ms={self_seconds(s) * 1e3:.3f} "
                             f"({self_seconds(s) / total * 100:.1f}%)")
        return "\n".join(lines)


class TraceCollector:
    """Bounded per-engine store of recent :class:`QueryTrace` objects. The
    bound is on total retained spans — when a new query would exceed it, the
    oldest whole traces are dropped (``dropped_spans`` counts them)."""

    def __init__(self, max_spans: int = 65536):
        self.max_spans = int(max_spans)
        self.traces: list[QueryTrace] = []
        self.dropped_spans = 0
        self._lock = threading.Lock()

    def start_query(self, label: str) -> QueryTrace:
        qt = QueryTrace(label)
        with self._lock:
            self.traces.append(qt)
            self._trim_locked()
        return qt

    def trim(self) -> None:
        with self._lock:
            self._trim_locked()

    def _trim_locked(self) -> None:
        total = sum(len(t.spans) for t in self.traces)
        while len(self.traces) > 1 and total > self.max_spans:
            victim = self.traces.pop(0)
            total -= len(victim.spans)
            self.dropped_spans += len(victim.spans)

    def last(self) -> Optional[QueryTrace]:
        return self.traces[-1] if self.traces else None

    def clear(self) -> None:
        self.traces.clear()

    # -- export --
    def to_chrome(self, pid: int = 1) -> dict:
        """Chrome trace-event JSON (the "Trace Event Format"), loadable in
        Perfetto / chrome://tracing: one complete ("ph": "X") event per
        span, ts/dur in microseconds, one tid per query trace."""
        events = []
        for tid, qt in enumerate(self.traces):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": qt.label}})
            for s in qt.spans:
                events.append({
                    "name": s.name, "cat": s.cat, "ph": "X", "pid": pid,
                    "tid": tid, "ts": s.ts * 1e6, "dur": s.dur * 1e6,
                    "args": {**s.args,
                             **({"detail": s.detail} if s.detail else {})},
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_chrome_json(self, pid: int = 1) -> str:
        return json.dumps(self.to_chrome(pid=pid), default=_json_default)


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    return str(o)


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema check of an exported trace (used by the bench-trace smoke
    step and tests). Returns a list of problems — empty means valid."""
    problems: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents"]
    by_tid: dict[int, list[dict]] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key}")
        if ev.get("ph") == "X":
            if not (isinstance(ev.get("ts"), (int, float))
                    and isinstance(ev.get("dur"), (int, float))):
                problems.append(f"event {i}: X event without numeric ts/dur")
            elif ev["ts"] < 0 or ev["dur"] < 0:
                problems.append(f"event {i}: negative ts/dur")
            else:
                by_tid.setdefault(ev["tid"], []).append(ev)
    # spans of one query must nest: each event lies inside its enclosing
    # predecessor (stack discipline over [ts, ts+dur), small float slack)
    eps = 0.5   # µs
    for tid, evs in by_tid.items():
        stack: list[dict] = []
        for ev in sorted(evs, key=lambda e: (e["ts"], -e["dur"])):
            while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack:
                parent = stack[-1]
                if ev["ts"] + ev["dur"] > parent["ts"] + parent["dur"] + eps:
                    problems.append(
                        f"tid {tid}: span {ev['name']} overlaps parent "
                        f"{parent['name']} without nesting")
            stack.append(ev)
    return problems


# ---------------------------------------------------------------------------
# Q-error monitor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MisEstimate:
    """One flagged operator: estimated vs. actual rows and the q-error
    ratio, with enough provenance to find the plan that produced it."""

    query: str
    op: str
    detail: str
    est_rows: float
    actual_rows: float
    q_error: float

    def __repr__(self):
        return (f"q_error={self.q_error:.1f} {self.op} "
                f"est={self.est_rows:.3g} actual={self.actual_rows:.3g} "
                f"[{self.query}] {self.detail}")


def q_error(est: float, actual: float) -> float:
    """max(est/actual, actual/est) with both sides clamped to >= 1 row —
    the standard cardinality-quality metric (1.0 = perfect)."""
    e = max(float(est), 1.0)
    a = max(float(actual), 1.0)
    return max(e / a, a / e)


class QErrorMonitor:
    """Per-operator est-vs-actual regression log. Every observation lands
    in the session histogram; observations at or above ``threshold`` are
    kept in a bounded misestimate log (worst-first eviction). The per-plan
    ``flagged`` list is the feedback the optimizer's stats-revalidation
    hook consumes: re-collect statistics for exactly the operators that
    misestimated."""

    def __init__(self, threshold: float = 4.0, max_log: int = 512):
        self.threshold = float(threshold)
        self.max_log = int(max_log)
        self.observations = 0
        self.flagged_total = 0
        self.log: list[MisEstimate] = []
        self.last_plan: list[MisEstimate] = []

    def start_plan(self) -> None:
        self.last_plan = []

    def record(self, query: str, op: str, detail: str,
               est_rows: float, actual_rows: float) -> float:
        qe = q_error(est_rows, actual_rows)
        self.observations += 1
        if qe >= self.threshold:
            self.flagged_total += 1
            m = MisEstimate(query, op, detail, float(est_rows),
                            float(actual_rows), qe)
            self.last_plan.append(m)
            self.log.append(m)
            if len(self.log) > self.max_log:
                self.log.sort(key=lambda x: x.q_error, reverse=True)
                del self.log[self.max_log:]
        return qe

    def worst(self, k: int = 5) -> list[MisEstimate]:
        return sorted(self.log, key=lambda m: m.q_error, reverse=True)[:k]

    def metrics(self) -> dict:
        return {"observations": self.observations,
                "flagged": self.flagged_total,
                "log_size": len(self.log)}


# ---------------------------------------------------------------------------
# GCDA kernel attribution helpers
# ---------------------------------------------------------------------------

GCDA_KINDS = ("Rel2Matrix", "RandomAccessMatrix", "MatMul", "Similarity",
              "Regression", "Const", "DeviceMatchPattern")


def fence(value) -> float:
    """``block_until_ready`` the (possibly nested) device value; returns the
    seconds spent waiting. Host values cost one attribute probe."""
    t0 = time.perf_counter()
    bur = getattr(value, "block_until_ready", None)
    if bur is not None:
        bur()
    return time.perf_counter() - t0


def kernel_args(kind: str, inputs: tuple, out, iters: int = 1) -> dict:
    """Analytic flops/bytes of one GCDA operator execution, derived from
    runtime shapes (the flop model lives with the kernels in
    ``analytics.flops_estimate``) — the span payload
    ``roofline.from_trace()`` reads."""
    from . import analytics

    def shape(v):
        return tuple(int(d) for d in getattr(v, "shape", ()) or ())

    def nbytes(v):
        n = getattr(v, "nbytes", None)
        return int(n) if n is not None else 0

    args: dict[str, Any] = {}
    shapes = [shape(v) for v in inputs]
    flops = analytics.flops_estimate(kind, shapes, iters=iters)
    if flops:
        args["flops"] = flops
    total_bytes = sum(nbytes(v) for v in inputs) + nbytes(out)
    if total_bytes:
        args["bytes"] = total_bytes
    if shapes:
        args["in_shapes"] = [list(s) for s in shapes]
    return args


# ---------------------------------------------------------------------------
# The per-engine telemetry session
# ---------------------------------------------------------------------------


class Telemetry:
    """One engine's observability session: a :class:`Registry`, a bounded
    :class:`TraceCollector`, and a :class:`QErrorMonitor`. Constructed via
    ``GredoEngine(telemetry=True)`` / ``GredoEngine(telemetry=Telemetry(...))``
    or transiently by ``engine.profile``. ``fence_device`` controls whether
    GCDA outputs are synchronized (``block_until_ready``) inside their span
    so device time is attributed to the producing operator — tracing-only
    behavior; the disabled path never fences."""

    def __init__(self, registry: Optional[Registry] = None,
                 max_spans: int = 65536, qerror_threshold: float = 4.0,
                 fence_device: bool = True):
        self.registry = registry if registry is not None else Registry()
        self.collector = TraceCollector(max_spans=max_spans)
        self.qerror = QErrorMonitor(threshold=qerror_threshold)
        self.fence_device = fence_device
        self.registry.register_source("qerror", self.qerror.metrics)

    def last_trace(self) -> Optional[QueryTrace]:
        return self.collector.last()
