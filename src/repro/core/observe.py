"""Production observability: flight recorder, health rules, workload replay.

This is the always-on layer *above* ``repro.core.telemetry``. Telemetry is a
scalpel — spans, registry, q-error monitor — that the user switches on for a
profiling session. This module is the seatbelt that is worn in production:

**Flight recorder** (`FlightRecorder`) — a bounded ring of recent query
records (label, plan fingerprint, operator stats, inter-buffer / registry
deltas, q-error flags, verify report). Capture is cheap enough to stay on
when tracing is off: everything in a record is data the engine already
computed for ``explain_last``. On a *trigger* — latency over the template's
SLO (or an EWMA-based anomaly), a q-error flag, a ``PlanVerificationError``,
a kernel overflow-retry storm, an inter-buffer hit-rate collapse — the ring
is dumped to ``experiments/flight_*.json`` so the incident is debuggable
after the fact.

**Health rules** (`evaluate_health`) — a rule table over registry snapshots
and the recorder's per-template latency EWMAs (latency vs SLO, q-error
drift, inter-buffer hit rate, shard skew, exchange reuse, index refresh
churn, kernel retry storms), folded into an ok/warn/critical
``HealthReport``. ``GredoEngine.health()`` renders it in ``explain_last``
and exports it as gauges; ``Registry.to_openmetrics()`` serves the whole
registry as Prometheus/OpenMetrics text.

**Workload capture & replay** (`WorkloadRecorder`, `replay`) — the
interleaved query/mutation stream is recorded to JSONL (queries with result
fingerprints and the source epochs they saw; graph mutations with full
payloads) and replayed deterministically against a fresh database, so any
flight-recorder dump or bench regression is reproducible offline.

Import discipline: this module must not import ``engine`` at module scope
(engine imports us); ``replay`` imports it lazily.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import collections
from typing import Any, Optional

import numpy as np

from .schema import (AnalyticsTask, GCDIATask, JoinPred, Pattern,
                     PatternEdge, PatternVertex, Predicate, Query)

__all__ = [
    "FlightRecorder", "QueryRecord", "HealthCheck", "HealthReport",
    "WorkloadRecorder", "ReplayMismatch", "ReplayReport", "replay",
    "evaluate_health", "query_to_dict", "query_from_dict", "task_to_dict",
    "task_from_dict", "result_fingerprint",
]


# =========================================================================
# serialization helpers (queries, tasks, arrays, results)
# =========================================================================

def _scalar(v):
    """numpy scalar -> python scalar (JSON-safe); passthrough otherwise."""
    return v.item() if isinstance(v, np.generic) else v


def _encode_value(v):
    """JSON-encode a mutation-payload value: ndarray -> tagged dict with
    dtype preserved; nested lists (ragged column data) recurse."""
    if isinstance(v, np.ndarray):
        return {"__ndarray__": v.tolist(), "dtype": str(v.dtype)}
    if isinstance(v, dict):
        return {k: _encode_value(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_encode_value(x) for x in v]
    return _scalar(v)


def _decode_value(v):
    if isinstance(v, dict) and "__ndarray__" in v:
        return np.asarray(v["__ndarray__"], dtype=np.dtype(v["dtype"]))
    if isinstance(v, dict):
        return {k: _decode_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_decode_value(x) for x in v]
    return v


def _detuple(v):
    """JSON round-trips tuples as lists; analytics task inputs are nested
    tuples of str/int — restore them so replayed plan signatures match."""
    return tuple(_detuple(x) for x in v) if isinstance(v, list) else v


def query_to_dict(q: Query) -> dict:
    d: dict[str, Any] = {"select": list(q.select), "froms": list(q.froms)}
    if q.match is not None:
        d["match"] = {
            "graph": q.match.graph,
            "vertices": [[v.var, v.label] for v in q.match.vertices],
            "edges": [[e.var, e.label, e.src, e.dst] for e in q.match.edges],
        }
    d["joins"] = [[j.left, j.right] for j in q.joins]
    d["where"] = [[p.attr, p.op, _scalar(p.value), _scalar(p.value2)]
                  for p in q.where]
    return d


def query_from_dict(d: dict) -> Query:
    match = None
    if d.get("match"):
        m = d["match"]
        match = Pattern(
            graph=m["graph"],
            vertices=tuple(PatternVertex(*v) for v in m["vertices"]),
            edges=tuple(PatternEdge(*e) for e in m["edges"]))
    return Query(
        select=tuple(d["select"]), froms=tuple(d["froms"]), match=match,
        joins=tuple(JoinPred(*j) for j in d.get("joins", ())),
        where=tuple(Predicate(*w) for w in d.get("where", ())))


def task_to_dict(t: GCDIATask) -> dict:
    a = t.analytics
    return {"integration": query_to_dict(t.integration),
            "analytics": {"op": a.op,
                          "inputs": [_encode_value(i) for i in a.inputs],
                          "params": dict(a.params)}}


def task_from_dict(d: dict) -> GCDIATask:
    a = d["analytics"]
    return GCDIATask(
        integration=query_from_dict(d["integration"]),
        analytics=AnalyticsTask(a["op"],
                                [_detuple(i) for i in a["inputs"]],
                                dict(a.get("params", {}))))


def result_fingerprint(out) -> str:
    """Stable 16-hex content hash of a query/task result. Tables hash every
    column (dictionary columns by *decoded* values, so vocab numbering can't
    alias; ragged columns by values+offsets); arrays hash dtype+bytes.
    Device arrays are pulled to host — call this off the hot path."""
    import hashlib
    h = hashlib.sha256()
    cols = getattr(out, "columns", None)
    if cols is not None:                              # Table
        for name in cols:
            col = cols[name]
            h.update(name.encode())
            if hasattr(col, "codes"):                 # DictColumn
                vals = col.decode(col.codes)
                h.update("|".join(str(v) for v in vals).encode())
            elif hasattr(col, "offsets"):             # RaggedColumn
                h.update(np.ascontiguousarray(
                    np.asarray(col.values)).tobytes())
                h.update(np.ascontiguousarray(
                    np.asarray(col.offsets)).tobytes())
            else:
                a = np.ascontiguousarray(np.asarray(col))
                h.update(str(a.dtype).encode())
                h.update(a.tobytes())
        return h.hexdigest()[:16]
    if isinstance(out, tuple):                        # e.g. (weights, loss)
        for part in out:
            h.update(result_fingerprint(part).encode())
        return h.hexdigest()[:16]
    a = np.ascontiguousarray(np.asarray(out))
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:16]


def _finite(d: dict) -> dict:
    """Drop NaN/inf values (empty-histogram percentiles etc.) and coerce
    numpy scalars so the dict is strict-JSON dumpable."""
    out = {}
    for k, v in d.items():
        v = _scalar(v)
        if isinstance(v, float) and not math.isfinite(v):
            continue
        out[k] = v
    return out


# =========================================================================
# flight recorder
# =========================================================================

@dataclasses.dataclass
class QueryRecord:
    """One entry of the flight-recorder ring — everything needed to explain
    a single execution after the fact, already JSON-shaped."""

    seq: int
    ts: float                     # wall-clock (time.time) at capture
    label: str                    # query/task template label
    kind: str                     # "query" | "analyze" | "verify"
    mode: str
    plan_fingerprint: str         # fingerprint(dag.signature()) — epoch-aware
    seconds: Optional[float]
    shard_count: int
    operators: list               # physical.collect_stats rows
    interbuffer: dict             # this query's inter-buffer counter delta
    registry_delta: dict          # per-query registry delta (telemetry on)
    qerrors: list                 # flagged MisEstimates (telemetry on)
    verify: list                  # verify-report lines (debug mode)
    spans: list                   # span tree (tracing on), bounded
    triggers: list                # trigger names that fired on this record

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


_MAX_RECORD_SPANS = 512


def _span_tree(trace) -> list:
    """Serialize a QueryTrace's spans (id/parent/name/dur/detail), bounded
    so one pathological plan can't bloat every dump."""
    if trace is None:
        return []
    spans = list(getattr(trace, "spans", ()))[:_MAX_RECORD_SPANS]
    return [{"id": s.id, "parent": s.parent, "name": s.name, "cat": s.cat,
             "dur": round(s.dur, 9), "detail": s.detail,
             "args": _finite({k: v for k, v in s.args.items()
                              if isinstance(v, (int, float, str, bool))})}
            for s in spans]


class FlightRecorder:
    """Bounded ring of recent :class:`QueryRecord` s with trigger-driven
    auto-dump. Default-on per engine (``GredoEngine(observe=False)`` opts
    out); capture reuses the engine's ``last_*`` state so the per-query cost
    is a handful of dict builds.

    Triggers (each dumps the ring to ``dump_dir/flight_*.json``):

    - ``slo-breach`` — latency over the template's explicit SLO
      (``slo={"template": seconds}`` or ``default_slo``).
    - ``latency-anomaly`` — latency over ``anomaly_factor`` x the template's
      latency EWMA after ``warmup`` samples (and over ``anomaly_floor_s``,
      so micro-query jitter never fires it).
    - ``qerror`` — the telemetry q-error monitor flagged this plan.
    - ``verify-error`` — the static plan verifier raised (record captured
      via :meth:`record_verify_error` before the exception propagates).
    - ``kernel-retry-storm`` — >= ``retry_storm`` traversal-kernel overflow
      retries/recompiles within one query.
    - ``interbuffer-collapse`` — the hit-rate EWMA fell below
      ``collapse_frac`` of its historical peak (after the peak cleared
      ``collapse_min_peak``).
    """

    def __init__(self, ring: int = 64,
                 slo: Optional[dict] = None,
                 default_slo: Optional[float] = None,
                 anomaly_factor: float = 8.0,
                 anomaly_floor_s: float = 0.25,
                 ewma_alpha: float = 0.2,
                 warmup: int = 8,
                 retry_storm: int = 2,
                 collapse_frac: float = 0.25,
                 collapse_min_peak: float = 0.5,
                 dump_dir: str = "experiments",
                 auto_dump: bool = True,
                 max_dumps: int = 8):
        self.ring: "collections.deque[QueryRecord]" = \
            collections.deque(maxlen=ring)
        self.slo = dict(slo) if slo else {}
        self.default_slo = default_slo
        self.anomaly_factor = anomaly_factor
        self.anomaly_floor_s = anomaly_floor_s
        self.ewma_alpha = ewma_alpha
        self.warmup = warmup
        self.retry_storm = retry_storm
        self.collapse_frac = collapse_frac
        self.collapse_min_peak = collapse_min_peak
        self.dump_dir = dump_dir
        self.auto_dump = auto_dump
        self.max_dumps = max_dumps
        self.seq = 0
        self.latency_ewma: dict[str, float] = {}     # per-template seconds
        self.latency_n: dict[str, int] = {}
        self.hit_ewma: Optional[float] = None        # inter-buffer hit rate
        self.hit_peak = 0.0
        self.trigger_counts: dict[str, int] = {}
        self.dump_paths: list[str] = []
        self.dumps_suppressed = 0
        self._retries0 = 0

    # ---------------------------------------------------------- capture
    def begin(self, label: str) -> None:
        """Pre-query hook: snapshot the traversal-kernel retry counters so
        ``observe`` can attribute a retry storm to this query alone."""
        from . import pattern_jit
        c = pattern_jit.COUNTERS
        self._retries0 = c.retries + c.recompiles

    def observe(self, engine, kind: str = "query") -> Optional[QueryRecord]:
        """Post-query hook (engine._finish_query): build a record from the
        engine's ``last_*`` state, evaluate triggers, append to the ring,
        dump if anything fired."""
        stats = engine.last_stats
        if stats is None or engine.last_dag is None:
            return None
        from . import pattern_jit, physical
        tel = engine.telemetry
        trace = tel.collector.last() if tel is not None else None
        qerrors = (list(tel.qerror.last_plan) if tel is not None else [])
        label = getattr(engine, "_last_label", "") or kind
        seconds = stats.seconds
        rec = QueryRecord(
            seq=self.seq, ts=time.time(), label=label, kind=kind,
            mode=engine.mode,
            plan_fingerprint=physical.plan_fingerprint(engine.last_dag),
            seconds=seconds, shard_count=engine.last_shard_count,
            operators=list(stats.operators or ()),
            interbuffer=_finite(engine.last_interbuffer_delta),
            registry_delta=(_finite({k: v for k, v
                                     in engine.last_registry_delta.items()
                                     if v})
                            if tel is not None else {}),
            qerrors=[dataclasses.asdict(m) for m in qerrors],
            verify=(engine.last_verify.render()
                    if engine.debug and engine.last_verify is not None
                    else []),
            spans=_span_tree(trace),
            triggers=[])
        self.seq += 1
        rec.triggers = self._evaluate(rec, engine)
        self.ring.append(rec)
        for t in rec.triggers:
            self._dump(t, rec)
        return rec

    def record_verify_error(self, engine, label: str, dag,
                            report) -> Optional[str]:
        """Called by the engine just before ``PlanVerificationError``
        propagates: capture the failing plan + report and dump."""
        from . import physical
        rec = QueryRecord(
            seq=self.seq, ts=time.time(), label=label, kind="verify",
            mode=engine.mode,
            plan_fingerprint=(physical.plan_fingerprint(dag)
                              if dag is not None else ""),
            seconds=None, shard_count=engine.last_shard_count,
            operators=[], interbuffer={}, registry_delta={}, qerrors=[],
            verify=report.render(), spans=[], triggers=["verify-error"])
        self.seq += 1
        self.ring.append(rec)
        return self._dump("verify-error", rec)

    # --------------------------------------------------------- triggers
    def _evaluate(self, rec: QueryRecord, engine) -> list[str]:
        fired: list[str] = []
        label, seconds = rec.label, rec.seconds or 0.0

        # 1. explicit SLO / EWMA latency anomaly
        slo = self.slo.get(label, self.default_slo)
        if slo is not None and seconds > slo:
            fired.append("slo-breach")
        ewma = self.latency_ewma.get(label)
        n = self.latency_n.get(label, 0)
        if (ewma is not None and n >= self.warmup
                and seconds > max(self.anomaly_factor * ewma,
                                  self.anomaly_floor_s)):
            fired.append("latency-anomaly")
        a = self.ewma_alpha
        self.latency_ewma[label] = (seconds if ewma is None
                                    else (1 - a) * ewma + a * seconds)
        self.latency_n[label] = n + 1

        # 2. q-error flag (telemetry on)
        if rec.qerrors:
            fired.append("qerror")

        # 3. traversal-kernel overflow-retry storm within this query
        from . import pattern_jit
        c = pattern_jit.COUNTERS
        if (c.retries + c.recompiles) - self._retries0 >= self.retry_storm:
            fired.append("kernel-retry-storm")

        # 4. inter-buffer hit-rate collapse (EWMA vs. historical peak)
        ib = rec.interbuffer
        lookups = ib.get("hits", 0) + ib.get("misses", 0)
        if lookups > 0:
            rate = ib.get("hits", 0) / lookups
            self.hit_ewma = (rate if self.hit_ewma is None
                             else (1 - a) * self.hit_ewma + a * rate)
            self.hit_peak = max(self.hit_peak, self.hit_ewma)
            if (self.hit_peak >= self.collapse_min_peak
                    and self.hit_ewma < self.collapse_frac * self.hit_peak):
                fired.append("interbuffer-collapse")
        return fired

    # ------------------------------------------------------------- dump
    def _dump(self, trigger: str, rec: QueryRecord) -> Optional[str]:
        self.trigger_counts[trigger] = self.trigger_counts.get(trigger, 0) + 1
        if not self.auto_dump:
            return None
        if len(self.dump_paths) >= self.max_dumps:
            self.dumps_suppressed += 1      # bound incident-storm disk cost
            return None
        os.makedirs(self.dump_dir, exist_ok=True)
        path = os.path.join(self.dump_dir,
                            f"flight_{rec.seq:05d}_{trigger}.json")
        doc = {"version": 1, "trigger": trigger, "captured_at": rec.ts,
               "record": rec.to_json(),
               "ring": [r.to_json() for r in self.ring],
               "latency_ewma": {k: round(v, 9)
                                for k, v in self.latency_ewma.items()},
               "trigger_counts": dict(self.trigger_counts)}
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1, default=str)
        self.dump_paths.append(path)
        return path

    def metrics(self) -> dict:
        """Registry-source snapshot (namespace ``flight.``)."""
        out = {"records": float(self.seq),
               "dumps": float(len(self.dump_paths)),
               "dumps_suppressed": float(self.dumps_suppressed)}
        for t, n in self.trigger_counts.items():
            out[f"triggers.{t}"] = float(n)
        return out


# =========================================================================
# health rules
# =========================================================================

OK, WARN, CRITICAL = "ok", "warn", "critical"
_LEVELS = (OK, WARN, CRITICAL)          # index == severity order


@dataclasses.dataclass
class HealthCheck:
    name: str
    level: str        # ok | warn | critical
    detail: str


@dataclasses.dataclass
class HealthReport:
    status: str
    checks: list

    def render(self) -> list[str]:
        lines = [f"status: {self.status}"]
        lines += [f"[{c.level:>8}] {c.name}: {c.detail}" for c in self.checks]
        return lines

    def as_metrics(self) -> dict:
        """Gauge view (0=ok 1=warn 2=critical) — exported by
        ``engine.health()`` so OpenMetrics scrapes carry the verdicts."""
        out = {"health.status": float(_LEVELS.index(self.status))}
        for c in self.checks:
            out[f"health.{c.name}"] = float(_LEVELS.index(c.level))
        return out


def _rule_latency_slo(snap, fr) -> HealthCheck:
    if fr is None or not (fr.slo or fr.default_slo):
        return HealthCheck("latency_slo", OK, "no SLO configured")
    worst, level = "all templates within SLO", OK
    for label, ewma in sorted(fr.latency_ewma.items()):
        slo = fr.slo.get(label, fr.default_slo)
        if slo is None:
            continue
        if ewma > slo and level != CRITICAL:
            worst, level = (f"{label}: ewma {ewma:.3f}s > slo {slo:.3f}s",
                            CRITICAL)
        elif ewma > 0.8 * slo and level == OK:
            worst, level = (f"{label}: ewma {ewma:.3f}s within 20% of "
                            f"slo {slo:.3f}s", WARN)
    return HealthCheck("latency_slo", level, worst)


def _rule_qerror_drift(snap, fr) -> HealthCheck:
    obs = snap.get("qerror.observations", 0)
    flagged = snap.get("qerror.flagged", 0)
    if obs < 20:
        return HealthCheck("qerror_drift", OK,
                           f"{int(obs)} observations (need 20)")
    frac = flagged / obs
    level = CRITICAL if frac > 0.5 else WARN if frac > 0.2 else OK
    return HealthCheck("qerror_drift", level,
                       f"{int(flagged)}/{int(obs)} estimates flagged "
                       f"({frac:.0%})")


def _rule_interbuffer(snap, fr) -> HealthCheck:
    hits = snap.get("interbuffer.hits", 0)
    misses = snap.get("interbuffer.misses", 0)
    lookups = hits + misses
    if fr is not None and fr.hit_peak >= fr.collapse_min_peak \
            and fr.hit_ewma is not None \
            and fr.hit_ewma < fr.collapse_frac * fr.hit_peak:
        return HealthCheck("interbuffer", CRITICAL,
                           f"hit-rate ewma {fr.hit_ewma:.2f} collapsed from "
                           f"peak {fr.hit_peak:.2f}")
    if lookups < 16:
        return HealthCheck("interbuffer", OK,
                           f"{int(lookups)} lookups (need 16)")
    rate = hits / lookups
    level = WARN if rate < 0.05 else OK
    return HealthCheck("interbuffer", level,
                       f"hit rate {rate:.2f} over {int(lookups)} lookups")


def _rule_shard_skew(snap, fr) -> HealthCheck:
    parts = snap.get("shard.shard_partitions", 0)
    if parts < 4:
        return HealthCheck("shard_skew", OK, "no sharded partitions yet")
    mean = snap.get("shard.rows_shard_mean", 0.0)
    peak = snap.get("shard.rows_shard_max", 0.0)
    if mean <= 0:
        return HealthCheck("shard_skew", OK, "no shard rows recorded")
    skew = peak / mean
    level = CRITICAL if skew > 8 else WARN if skew > 3 else OK
    return HealthCheck("shard_skew", level,
                       f"max/mean rows per shard = {skew:.1f}")


def _rule_exchange_reuse(snap, fr) -> HealthCheck:
    built = snap.get("shard.exchanges_built", 0)
    reused = snap.get("shard.exchanges_reused", 0)
    total = built + reused
    if total < 8:
        return HealthCheck("exchange_reuse", OK,
                           f"{int(total)} exchanges (need 8)")
    rate = reused / total
    level = WARN if rate < 0.1 else OK
    return HealthCheck("exchange_reuse", level,
                       f"reuse rate {rate:.2f} ({int(reused)}/{int(total)})")


def _rule_index_churn(snap, fr) -> HealthCheck:
    lookups = refreshes = 0.0
    for k, v in snap.items():
        if not k.startswith("index."):
            continue
        if k.endswith(".lookups"):
            lookups += v
        elif k.endswith(".refreshes") or k.endswith(".rebuilds"):
            refreshes += v
    if lookups < 16:
        return HealthCheck("index_churn", OK,
                           f"{int(lookups)} index lookups (need 16)")
    churn = refreshes / lookups
    level = CRITICAL if churn > 0.5 else WARN if churn > 0.2 else OK
    return HealthCheck("index_churn", level,
                       f"{int(refreshes)} refreshes / {int(lookups)} lookups "
                       f"({churn:.0%} staleness churn)")


def _rule_kernel_retries(snap, fr) -> HealthCheck:
    matches = snap.get("traversal_kernels.matches", 0)
    retries = (snap.get("traversal_kernels.retries", 0)
               + snap.get("traversal_kernels.recompiles", 0))
    if matches < 8:
        return HealthCheck("kernel_retries", OK,
                           f"{int(matches)} kernel matches (need 8)")
    rate = retries / matches
    level = CRITICAL if rate > 1.0 else WARN if rate > 0.25 else OK
    return HealthCheck("kernel_retries", level,
                       f"{int(retries)} overflow retries over "
                       f"{int(matches)} matches")


_HEALTH_RULES = (
    ("latency_slo", _rule_latency_slo),
    ("qerror_drift", _rule_qerror_drift),
    ("interbuffer", _rule_interbuffer),
    ("shard_skew", _rule_shard_skew),
    ("exchange_reuse", _rule_exchange_reuse),
    ("index_churn", _rule_index_churn),
    ("kernel_retries", _rule_kernel_retries),
)


def evaluate_health(snapshot: dict,
                    recorder: Optional[FlightRecorder] = None
                    ) -> HealthReport:
    """Fold the rule table over a registry snapshot (flat ``ns.key`` ->
    number dict, e.g. ``engine.metrics_snapshot()``) plus the flight
    recorder's EWMAs. Rules that lack enough evidence report ``ok`` with a
    "(need N)" note rather than guessing."""
    checks = [fn(snapshot, recorder) for _, fn in _HEALTH_RULES]
    status = max((c.level for c in checks), key=_LEVELS.index, default=OK)
    return HealthReport(status=status, checks=checks)


# =========================================================================
# workload capture & replay
# =========================================================================

class ReplayMismatch(AssertionError):
    """Replay produced a different result relation than was captured."""


@dataclasses.dataclass
class ReplayReport:
    queries: int = 0
    analytics: int = 0
    mutations: int = 0
    mismatches: list = dataclasses.field(default_factory=list)
    results: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.mismatches


class WorkloadRecorder:
    """Context manager that records the interleaved query/mutation stream
    of one engine to JSONL (``engine.record(path)``). Each query event
    carries the result fingerprint and the source write-epochs it observed;
    graph mutations are captured via ``Graph.listeners`` with their full
    payloads, so ``replay`` can reproduce the stream — including epoch
    bumps, delta-store growth, and compactions — on a fresh database."""

    def __init__(self, engine, path: str):
        self.engine = engine
        self.path = path
        self.events = 0
        self._fh = None
        self._graphs: list = []

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "WorkloadRecorder":
        eng, db = self.engine, self.engine.db
        self._fh = open(self.path, "w")
        self._write({"kind": "header", "version": 1, "mode": eng.mode,
                     "n_shards": eng.n_shards,
                     "epochs": self._epochs()})
        eng._recorder = self
        for g in db.graphs.values():
            g.listeners.append(self._on_graph)
            self._graphs.append(g)
        db.listeners.append(self._on_db)
        return self

    def __exit__(self, *exc) -> None:
        self.engine._recorder = None
        for g in self._graphs:
            if self._on_graph in g.listeners:
                g.listeners.remove(self._on_graph)
        db = self.engine.db
        if self._on_db in db.listeners:
            db.listeners.remove(self._on_db)
        self._fh.close()
        self._fh = None

    def _epochs(self) -> dict:
        db = self.engine.db
        out = {name: db.epoch_of(name) for name in db.tables}
        out.update({name: g.epoch for name, g in db.graphs.items()})
        return out

    def _write(self, ev: dict) -> None:
        self._fh.write(json.dumps(ev, default=str) + "\n")
        self.events += 1

    # --------------------------------------------------------------- events
    def log_query(self, q: Query, result, seconds: float) -> None:
        self._write({"kind": "query", "query": query_to_dict(q),
                     "rows": getattr(result, "nrows", None),
                     "fp": result_fingerprint(result),
                     "seconds": round(seconds, 9),
                     "epochs": self._epochs()})

    def log_analyze(self, task: GCDIATask, out, *, iters: int,
                    use_kernel, seconds: float) -> None:
        self._write({"kind": "analyze", "task": task_to_dict(task),
                     "iters": iters, "use_kernel": use_kernel,
                     "fp": result_fingerprint(out),
                     "seconds": round(seconds, 9),
                     "epochs": self._epochs()})

    def _on_graph(self, graph, op: str, payload: dict) -> None:
        self._write({"kind": op, "graph": graph.name,
                     "payload": {k: _encode_value(v)
                                 for k, v in payload.items()}})

    def _on_db(self, op: str, name: str) -> None:
        self._write({"kind": op, "name": name})


def _apply_mutation(db, ev: dict) -> None:
    g = db.graphs[ev["graph"]]
    p = {k: _decode_value(v) for k, v in ev["payload"].items()}
    if ev["kind"] == "insert_vertices":
        g.insert_vertices(p["label"], p["rows"])
    elif ev["kind"] == "insert_edges":
        g.insert_edges(p["rows"])
    elif ev["kind"] == "delete_edges":
        g.delete_edges(p["edge_tids"])
    else:
        raise ValueError(f"unknown mutation event {ev['kind']!r}")


def replay(db, path: str, *, mode: Optional[str] = None,
           n_shards: Optional[int] = None, strict: bool = True,
           engine=None, keep_results: bool = False,
           **engine_kw) -> ReplayReport:
    """Replay a captured workload against ``db`` (normally a fresh
    ``m2bench.generate`` twin of the recorded database). Queries re-execute
    through a ``GredoEngine`` (mode/shards default to the recorded header);
    mutations re-apply via the graph write path, reproducing epoch bumps
    and delta-store growth. Each query's result fingerprint is checked
    against the capture — ``strict=True`` raises :class:`ReplayMismatch`
    on the first divergence."""
    from .engine import GredoEngine     # lazy: engine imports this module
    with open(path) as fh:
        events = [json.loads(line) for line in fh if line.strip()]
    header = events[0] if events and events[0].get("kind") == "header" else {}
    body = events[1:] if header else events
    eng = engine
    if eng is None:
        eng = GredoEngine(db, mode=mode or header.get("mode", "gredo"),
                          n_shards=n_shards or header.get("n_shards", 1),
                          **engine_kw)
    report = ReplayReport()
    for i, ev in enumerate(body):
        kind = ev["kind"]
        if kind == "query":
            out = eng.query(query_from_dict(ev["query"]))
            report.queries += 1
        elif kind == "analyze":
            out = eng.analyze(task_from_dict(ev["task"]),
                              iters=ev.get("iters", 100),
                              use_kernel=ev.get("use_kernel"))
            report.analytics += 1
        elif kind == "touch_table":
            db.touch_table(ev["name"])
            report.mutations += 1
            continue
        else:
            _apply_mutation(db, ev)
            report.mutations += 1
            continue
        fp = result_fingerprint(out)
        if ev.get("fp") and fp != ev["fp"]:
            msg = (f"event {i}: replayed {kind} fingerprint {fp} != "
                   f"captured {ev['fp']} (label={ev.get('query') or ev.get('task')})")
            report.mismatches.append(msg)
            if strict:
                raise ReplayMismatch(msg)
        if keep_results:
            report.results.append(out)
    return report
