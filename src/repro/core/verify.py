"""Static plan verification: typed schema inference over the physical DAG.

Three plan-mutating layers — the cost-based optimizer (DP join enumeration,
semi-join siding, CSE, sink-down), the shard rewriter (``Exchange``
insertion), and device lowering (``DeviceMatchPattern``) — all emit operator
DAGs that until now were only checked by running them. This module checks
them *without executing*: a bottom-up schema-inference pass computes the
exact output columns/dtypes of every operator (``ScanTable`` → ``Regression``)
and validates the invariants each layer is supposed to preserve. Violations
carry rule IDs:

========  ==================================================================
rule      invariant
========  ==================================================================
V-COL     every column reference resolves (join keys, predicates,
          projections, prune lists, mask variables); silent drops are WARNs
V-TYPE    join-key dtype compatibility: dict (string) keys never meet
          numeric keys; numeric width promotions are flagged as WARNs
V-GCDA    relational→matrix boundary: feature columns exist, ragged columns
          never feed ``Rel2Matrix``, float32 narrowing promotions are WARNs,
          analytical operators consume matrices (labels are one column wide)
V-EPOCH   epoch soundness: every source-reading leaf embeds its source's
          *current* write epoch; the GCDI root's epoch vector covers every
          collection its subtree reads
V-SIG     signatures of distinct schemas never collide (two nodes with equal
          ``signature()`` must infer equal schemas — the inter-buffer and
          CSE both key on it)
V-SHARD   shard invariants: ``shards`` stamps only on shardable kinds and
          with one consistent k; every sharded EquiJoin's build side is an
          ``Exchange`` partitioned on the join key; ``Exchange`` appears
          only as an EquiJoin build side
V-DEV     device-lowering preconditions: ``DeviceMatchPattern`` only on
          mask-free chain patterns with edges, capacity ≥ the statically
          derivable padded frontier bound; pending deltas (host fallback at
          runtime) are WARNs
V-ANN     ``out_cols`` annotations agree with the inferred schema (stale
          annotations mislead column pruning and the optimizer)
V-EQ      rewrite equivalence: optimizer/shard output schemas ≡ naive-plan
          schemas (rewrites may reorder, never retype)
========  ==================================================================

A plan *passes* verification when it has no ERROR-severity violations; WARNs
(silent promotions, runtime fallbacks) are surfaced but non-fatal. Entry
points: :func:`verify_plan` (one DAG), :func:`verify_equivalence` (naive vs
rewritten), :func:`annotate_out_cols` (stamp the inferred column sets on
every relational node — the full-coverage ``out_cols`` propagation the
optimizer's column pruning reads). Engine wiring lives in
``GredoEngine.verify`` / ``GredoEngine(debug=True)``.

Dispatch is by ``node.kind`` string, so this module never imports
``physical`` (which imports it back for annotation at build time).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import cost
from .storage import Database, DictColumn, RaggedColumn, Table

ERROR = "ERROR"
WARN = "WARN"


@dataclasses.dataclass(frozen=True)
class MatrixType:
    """Inferred type of a GCDA node: a device matrix (None = statically
    unknown width, e.g. the n×n output of a self-similarity)."""
    dtype: str
    width: Optional[int]

    def __repr__(self):
        w = "?" if self.width is None else self.width
        return f"matrix[{self.dtype}, k={w}]"


@dataclasses.dataclass(frozen=True)
class MaskType:
    """Inferred type of a SemiJoinMask output: a boolean candidate-vertex
    mask over ``graph``'s ``label`` vertex table."""
    graph: str
    label: str

    def __repr__(self):
        return f"mask[{self.graph}.{self.label}]"


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str       # "V-COL" | "V-TYPE" | ...
    severity: str   # ERROR | WARN
    node: str       # node.describe() of the offending operator
    message: str

    def render(self) -> str:
        return f"verify:{self.rule} {self.severity} {self.node}: {self.message}"


class VerifyReport:
    """Outcome of one or more verification passes: the violation list plus
    the per-node inferred schemas (keyed by ``id(node)``)."""

    def __init__(self):
        self.violations: list[Violation] = []
        self.schemas: dict[int, object] = {}

    @property
    def ok(self) -> bool:
        return not any(v.severity == ERROR for v in self.violations)

    @property
    def errors(self) -> list[Violation]:
        return [v for v in self.violations if v.severity == ERROR]

    @property
    def warnings(self) -> list[Violation]:
        return [v for v in self.violations if v.severity == WARN]

    def by_rule(self, rule: str) -> list[Violation]:
        return [v for v in self.violations if v.rule == rule]

    def add(self, rule: str, severity: str, node, message: str):
        desc = node if isinstance(node, str) else node.describe()
        self.violations.append(Violation(rule, severity, desc, message))

    def render(self) -> list[str]:
        return [v.render() for v in self.violations]

    def __repr__(self):
        ne, nw = len(self.errors), len(self.warnings)
        return f"VerifyReport(ok={self.ok}, errors={ne}, warnings={nw})"


class PlanVerificationError(RuntimeError):
    """Raised by debug-mode engines when a plan fails verification."""

    def __init__(self, report: VerifyReport):
        self.report = report
        lines = [v.render() for v in report.errors]
        super().__init__("plan verification failed:\n" + "\n".join(lines))


# ---------------------------------------------------------------------------
# dtype model
# ---------------------------------------------------------------------------


def dtype_of(col) -> str:
    """Dtype string of a stored column: ``dict`` (dictionary-encoded
    strings), ``ragged[<values>]`` (multi-valued NF²), or the numpy name."""
    if isinstance(col, DictColumn):
        return "dict"
    if isinstance(col, RaggedColumn):
        dt = getattr(col.values, "dtype", None)
        return f"ragged[{dt if dt is not None else np.asarray(col.values).dtype}]"
    dt = getattr(col, "dtype", None)   # ndarray / merged view: no copy
    return str(dt if dt is not None else np.asarray(col).dtype)


def table_schema(t: Table) -> dict:
    """Schema of a stored table, cached on the table object (debug-mode
    verification re-reads every leaf per stage; dtype strings are stable
    while the column set is). Callers must not mutate the result — every
    deriving inference rule builds a fresh dict."""
    marker = (len(t.columns),) + tuple(map(id, t.columns.values()))
    cached = getattr(t, "_verify_schema", None)
    if cached is not None and cached[0] == marker:
        return cached[1]
    s = {name: dtype_of(col) for name, col in t.columns.items()}
    t._verify_schema = (marker, s)
    return s


def _key_kind(dtype: str) -> str:
    """Join-key comparison class of a dtype string. Dict columns decode to
    their string vocab for joins; ragged columns unnest to their values."""
    if dtype == "dict":
        return "str"
    if dtype.startswith("ragged["):
        dtype = dtype[len("ragged["):-1]
    try:
        kind = np.dtype(dtype).kind
    except TypeError:
        return "other"
    if kind in "iub":
        return "int"
    if kind == "f":
        return "float"
    if kind in "UOS":
        return "str"
    return "other"


def _vtable(g, label: str) -> Optional[Table]:
    """Vertex table of ``label``, or None (``g.vertex_tables`` is a mapping
    view without ``.get``)."""
    return g.vertex_tables[label] if label in g.vertex_tables else None


def _resolve(schema: dict, attr: str) -> Optional[str]:
    """Static mirror of ``physical._col_in``: exact name, else the bare
    suffix after the collection qualifier. None when unresolved."""
    if attr in schema:
        return attr
    if "." in attr:
        bare = attr.split(".", 1)[1]
        if bare in schema:
            return bare
    return None


# ---------------------------------------------------------------------------
# schema inference
# ---------------------------------------------------------------------------

_INT64 = str(np.dtype(np.int64))


class _Inference:
    """One bottom-up inference walk. Never raises: structural breakage is
    recorded as a violation and inference continues with the best available
    approximation (empty schema), so one bad node reports every downstream
    consequence instead of aborting the pass."""

    def __init__(self, db: Database, report: VerifyReport):
        self.db = db
        self.report = report
        self.memo: dict[int, object] = {}
        self.sources: dict[int, set] = {}   # id(node) -> collection names read

    # -- helpers --

    def err(self, rule, node, msg):
        self.report.add(rule, ERROR, node, msg)

    def warn(self, rule, node, msg):
        self.report.add(rule, WARN, node, msg)

    def _graph(self, node):
        g = self.db.graphs.get(node.graph)
        if g is None:
            self.err("V-COL", node, f"graph {node.graph!r} not in catalog")
        return g

    def _check_graph_epoch(self, node):
        if node.graph in self.db.graphs and node.epoch != self.db.epoch_of(node.graph):
            self.err("V-EPOCH", node,
                     f"embeds epoch {node.epoch} but {node.graph!r} is at "
                     f"write epoch {self.db.epoch_of(node.graph)}")

    def _check_preds(self, node, preds, schema, what="predicate"):
        """Pushed-selection predicates resolve by bare column name
        (``Table.eval_predicate`` uses ``pred.column``)."""
        for pred in preds:
            if "." not in pred.attr:
                self.err("V-COL", node,
                         f"{what} {pred!r} is unqualified (needs "
                         f"collection.column)")
                continue
            if pred.column not in schema:
                self.err("V-COL", node,
                         f"{what} column {pred.column!r} not in input "
                         f"schema {sorted(schema)[:8]}")

    def _check_join_key_types(self, node, rule, lname, ldt, rname, rdt):
        lk, rk = _key_kind(ldt), _key_kind(rdt)
        if lk == rk:
            return
        if "str" in (lk, rk) and {lk, rk} & {"int", "float"}:
            self.err(rule, node,
                     f"join key dtype mismatch: {lname}:{ldt} vs "
                     f"{rname}:{rdt} (string keys never match numeric keys)")
        elif {lk, rk} == {"int", "float"}:
            self.warn(rule, node,
                      f"silent promotion at join key: {lname}:{ldt} vs "
                      f"{rname}:{rdt} (int keys compare as floats)")
        else:
            self.err(rule, node,
                     f"incomparable join key dtypes: {lname}:{ldt} vs "
                     f"{rname}:{rdt}")

    def _source_names(self, *nodes) -> set:
        out: set = set()
        for n in nodes:
            out |= self.sources.get(id(n), set())
        return out

    # -- the walk --

    def schema(self, node):
        nid = id(node)
        if nid in self.memo:
            return self.memo[nid]
        child_schemas = [self.schema(c) for c in node.children]
        fn = getattr(self, f"_infer_{node.kind}", None)
        if fn is None:
            self.err("V-COL", node, f"unknown operator kind {node.kind!r}")
            out = {}
        else:
            out = fn(node, *child_schemas)
        self.memo[nid] = out
        srcs = self._source_names(*node.children)
        if hasattr(node, "name") and node.kind in ("ScanTable", "IndexScan",
                                                   "IndexSelect"):
            srcs = srcs | {node.name}
        elif hasattr(node, "graph"):
            srcs = srcs | {node.graph}
        self.sources[nid] = srcs
        self.report.schemas[nid] = out
        # V-ANN: a carried out_cols annotation must agree with inference
        ann = getattr(node, "out_cols", None)
        if ann is not None and isinstance(out, dict) and out:
            if frozenset(ann) != frozenset(out):
                stale = sorted(set(ann) - set(out))[:6]
                missing = sorted(set(out) - set(ann))[:6]
                self.warn("V-ANN", node,
                          f"out_cols annotation disagrees with inferred "
                          f"schema (stale={stale}, unannotated={missing})")
        return out

    # -- relational / document leaves --

    def _table(self, node, name):
        t = self.db.tables.get(name)
        if t is None:
            self.err("V-COL", node, f"table {name!r} not in catalog")
            return None
        return t

    def _infer_ScanTable(self, node):
        t = self._table(node, node.name)
        if t is None:
            return {}
        if node.epoch != self.db.epoch_of(node.name):
            self.err("V-EPOCH", node,
                     f"embeds epoch {node.epoch} but {node.name!r} is at "
                     f"write epoch {self.db.epoch_of(node.name)}")
        return table_schema(t)

    def _infer_IndexScan(self, node):
        t = self._table(node, node.name)
        if t is None:
            return {}
        if node.epoch != self.db.epoch_of(node.name):
            self.err("V-EPOCH", node,
                     f"embeds epoch {node.epoch} but {node.name!r} is at "
                     f"write epoch {self.db.epoch_of(node.name)}")
        schema = table_schema(t)
        if not (0 <= node.pick < len(node.preds)):
            self.err("V-COL", node,
                     f"pick={node.pick} out of range for "
                     f"{len(node.preds)} predicate(s)")
        self._check_preds(node, node.preds, schema)
        return schema

    _infer_IndexSelect = _infer_IndexScan

    def _infer_Select(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        self._check_preds(node, node.preds, child)
        return child

    def _infer_Alias(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        return {f"{node.name}.{k}": v for k, v in child.items()}

    def _infer_PruneCols(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        missing = [c for c in node.cols if c not in child]
        if missing:
            self.warn("V-COL", node,
                      f"prune list names absent column(s) {missing} "
                      f"(silently dropped at runtime)")
        return {c: child[c] for c in node.cols if c in child}

    # -- semi-joins --

    def _semi_join_common(self, node, child):
        """Shared checks of both sidings; returns (vertex dtype, other
        dtype) or None."""
        self._check_graph_epoch(node)
        g = self._graph(node)
        if g is None or not isinstance(child, dict):
            if not isinstance(child, dict):
                self.err("V-COL", node,
                         f"input is {child!r}, expected a relation")
            return None
        vt = _vtable(g, node.label)
        if vt is None:
            self.err("V-COL", node,
                     f"vertex label {node.label!r} not in graph "
                     f"{node.graph!r}")
            return None
        if node.vcol not in vt.columns:
            self.err("V-COL", node,
                     f"vertex key {node.label}.{node.vcol} not a column of "
                     f"the {node.label!r} vertex table")
            return None
        if node.ocol not in child:
            self.err("V-COL", node,
                     f"table key {node.ocol!r} not in input schema "
                     f"{sorted(child)[:8]}")
            return None
        vdt = dtype_of(vt.columns[node.vcol])
        odt = child[node.ocol]
        self._check_join_key_types(node, "V-TYPE",
                                   f"{node.label}.{node.vcol}", vdt,
                                   node.ocol, odt)
        return vdt, odt

    def _infer_SemiJoinMask(self, node, child):
        self._semi_join_common(node, child)
        return MaskType(node.graph, node.label)

    def _infer_SemiJoinReduce(self, node, child):
        self._semi_join_common(node, child)
        return child if isinstance(child, dict) else {}

    # -- pattern matching --

    def _pattern_vars(self, pattern) -> dict:
        """Schema of a materialized graph-relation: one int64 id column per
        bound pattern var (vids for vertices, edge tids for edges)."""
        if pattern.edges:
            chain = [pattern.vertices[0].var] + [e.dst for e in pattern.edges]
            cols = dict.fromkeys(chain + [e.var for e in pattern.edges])
        else:
            cols = dict.fromkeys([pattern.vertices[0].var])
        return {v: _INT64 for v in cols}

    def _check_pattern_preds(self, node, g, pattern, pred_map, what):
        edge_vars = {e.var for e in pattern.edges}
        pat_vars = edge_vars | {v.var for v in pattern.vertices}
        for var, preds in sorted(pred_map.items()):
            if var not in pat_vars:
                self.err("V-COL", node,
                         f"{what} predicates bound to unknown pattern "
                         f"var {var!r}")
                continue
            tbl = (g.edges if var in edge_vars
                   else _vtable(g, pattern.vertex(var).label))
            if tbl is None:
                self.err("V-COL", node,
                         f"vertex label {pattern.vertex(var).label!r} "
                         f"not in graph {node.graph!r}")
                continue
            self._check_preds(node, preds, table_schema(tbl),
                              what=f"{what}[{var}]")

    def _infer_MatchPattern(self, node, *mask_schemas):
        self._check_graph_epoch(node)
        g = self._graph(node)
        if g is None or node.pplan is None:
            if node.pplan is None:
                self.err("V-COL", node, "has no pattern plan")
            return {}
        pattern = node.pplan.pattern
        if len(node.mask_vars) != len(node.children):
            self.err("V-COL", node,
                     f"{len(node.children)} mask child(ren) but "
                     f"{len(node.mask_vars)} mask var(s)")
        vset = {v.var for v in pattern.vertices}
        for var, ms in zip(node.mask_vars, mask_schemas):
            if var not in vset:
                self.err("V-COL", node,
                         f"mask var {var!r} is not a pattern vertex")
            if not isinstance(ms, MaskType):
                self.err("V-COL", node,
                         f"mask child for {var!r} yields {ms!r}, expected a "
                         f"vertex mask")
            elif var in vset and ms.label != pattern.vertex(var).label:
                self.err("V-COL", node,
                         f"mask for {var!r} is over label {ms.label!r} but "
                         f"the pattern binds {pattern.vertex(var).label!r}")
        self._check_pattern_preds(node, g, pattern, node.pplan.pushed, "pushed")
        self._check_pattern_preds(node, g, pattern, node.pplan.deferred,
                                  "deferred")
        return self._pattern_vars(pattern)

    def _infer_DeviceMatchPattern(self, node, *mask_schemas):
        self._check_graph_epoch(node)
        g = self._graph(node)
        if node.pplan is None:
            self.err("V-DEV", node, "has no pattern plan")
            return {}
        pattern = node.pplan.pattern
        if node.children:
            self.err("V-DEV", node,
                     f"has {len(node.children)} mask child(ren) — device "
                     f"lowering requires a mask-free pattern")
        if not pattern.edges:
            self.err("V-DEV", node,
                     "pattern has no edges (vertex scans never lower)")
        elif not pattern.is_chain:
            self.err("V-DEV", node, "pattern is not a chain")
        if g is not None:
            self._check_pattern_preds(node, g, pattern, node.pplan.pushed,
                                      "pushed")
            self._check_pattern_preds(node, g, pattern, node.pplan.deferred,
                                      "deferred")
            if g.delta.has_pending():
                self.warn("V-DEV", node,
                          "graph has pending deltas — runtime will fall "
                          "back to the host matcher")
            elif pattern.edges and pattern.is_chain and node.capacity is not None:
                peak = cost.device_frontier_peak(g, node.pplan)
                need = cost.padded_capacity(peak)
                if node.capacity < need:
                    self.err("V-DEV", node,
                             f"capacity {node.capacity} below the static "
                             f"frontier bound {need} (peak≈{peak:.3g})")
        return self._pattern_vars(pattern)

    def _infer_TableJoinMatch(self, node):
        self._check_graph_epoch(node)
        g = self._graph(node)
        if g is not None:
            self._check_pattern_preds(node, g, node.pattern, node.deferred,
                                      "deferred")
        return self._pattern_vars(node.pattern)

    def _infer_VertexScan(self, node):
        self._check_graph_epoch(node)
        g = self._graph(node)
        var = node.pattern.vertices[0].var
        if g is not None and node.pplan is not None:
            self._check_pattern_preds(node, g, node.pattern,
                                      {var: node.pplan.deferred.get(var, [])},
                                      "deferred")
        return {var: _INT64}

    def _infer_EdgeScan(self, node):
        self._check_graph_epoch(node)
        g = self._graph(node)
        if not node.pattern.edges:
            self.err("V-COL", node, "edge scan over an edge-free pattern")
            return {}
        evar = node.pattern.edges[0].var
        if g is not None and node.pplan is not None:
            self._check_pattern_preds(node, g, node.pattern,
                                      {evar: node.pplan.deferred.get(evar, [])},
                                      "deferred")
        return {evar: _INT64}

    def _infer_GraphProject(self, node, child):
        self._check_graph_epoch(node)
        g = self._graph(node)
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        if g is None:
            return {}
        edge_vars = {e.var for e in node.pattern.edges}
        out: dict = {}
        for var in node.keep:
            if var not in child:
                self.warn("V-COL", node,
                          f"keep var {var!r} not bound by the child match "
                          f"(silently skipped at runtime)")
                continue
            out[f"{var}.__id"] = child[var]
            tbl = (g.edges if var in edge_vars
                   else _vtable(g, node.pattern.vertex(var).label))
            if tbl is None:
                self.err("V-COL", node,
                         f"vertex label {node.pattern.vertex(var).label!r} "
                         f"not in graph {node.graph!r}")
                continue
            for attr in node.wanted.get(var, []):
                if attr not in tbl.columns:
                    self.err("V-COL", node,
                             f"projected attribute {var}.{attr} not a "
                             f"column of its backing table")
                    continue
                out[f"{var}.{attr}"] = dtype_of(tbl.columns[attr])
        return out if out else dict(child)

    # -- joins --

    def _infer_EquiJoin(self, node, left, right):
        if not isinstance(left, dict) or not isinstance(right, dict):
            self.err("V-COL", node, "join inputs must both be relations")
            return left if isinstance(left, dict) else (
                right if isinstance(right, dict) else {})
        lc = _resolve(left, node.jp.left)
        rc = _resolve(right, node.jp.right)
        if lc is None:
            self.err("V-COL", node,
                     f"left key {node.jp.left!r} not in left schema "
                     f"{sorted(left)[:8]}")
        if rc is None:
            self.err("V-COL", node,
                     f"right key {node.jp.right!r} not in right schema "
                     f"{sorted(right)[:8]}")
        if lc is not None and rc is not None:
            self._check_join_key_types(node, "V-TYPE", node.jp.left, left[lc],
                                       node.jp.right, right[rc])
        out = dict(left)
        for k, v in right.items():
            if k in out and out[k] != v:
                self.warn("V-COL", node,
                          f"column {k!r} ({out[k]}) overwritten by right "
                          f"side ({v})")
            out[k] = v
        return out

    def _infer_IntraFilter(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        lc = _resolve(child, node.jp.left)
        rc = _resolve(child, node.jp.right)
        for attr, res in ((node.jp.left, lc), (node.jp.right, rc)):
            if res is None:
                self.err("V-COL", node,
                         f"filter key {attr!r} not in input schema "
                         f"{sorted(child)[:8]}")
        if lc is not None and rc is not None:
            self._check_join_key_types(node, "V-TYPE", node.jp.left,
                                       child[lc], node.jp.right, child[rc])
        return child

    def _infer_Exchange(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        if _resolve(child, node.key) is None:
            self.err("V-SHARD", node,
                     f"partition key {node.key!r} not in input schema "
                     f"{sorted(child)[:8]}")
        return child

    def _infer_Residual(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        for pred in node.preds:
            if _resolve(child, pred.attr) is None:
                self.err("V-COL", node,
                         f"residual predicate column {pred.attr!r} not in "
                         f"input schema {sorted(child)[:8]}")
        return child

    def _infer_Project(self, node, child):
        if not isinstance(child, dict):
            self.err("V-COL", node, f"input is {child!r}, expected a relation")
            return {}
        out: dict = {}
        for a in node.select:
            res = _resolve(child, a)
            if res is None:
                self.err("V-COL", node,
                         f"projected attribute {a!r} not in input schema "
                         f"{sorted(child)[:10]}")
                continue
            out[a] = child[res]
        # the root's epoch vector must be current AND cover every collection
        # the subtree reads — it is the inter-buffer reuse key
        declared = dict(node.epochs)
        for name, ep in node.epochs:
            if name in self.db.tables or name in self.db.graphs:
                if ep != self.db.epoch_of(name):
                    self.err("V-EPOCH", node,
                             f"epoch vector pins {name!r}@{ep} but the "
                             f"catalog is at {self.db.epoch_of(name)}")
            else:
                self.err("V-EPOCH", node,
                         f"epoch vector names unknown collection {name!r}")
        uncovered = self._source_names(*node.children) - set(declared)
        if uncovered:
            self.err("V-EPOCH", node,
                     f"epoch vector misses source(s) {sorted(uncovered)} "
                     f"read by the subtree — cached results would survive "
                     f"their writes")
        return out

    # -- GCDA: relational → matrix boundary and analytical operators --

    def _infer_Rel2Matrix(self, node, child):
        if not isinstance(child, dict):
            self.err("V-GCDA", node,
                     f"input is {child!r}, expected a relation")
            return MatrixType("float32", len(node.columns))
        for c in node.columns:
            dt = child.get(c)
            if dt is None:
                self.err("V-COL", node,
                         f"feature column {c!r} not in input schema "
                         f"{sorted(child)[:10]} (matrix columns resolve "
                         f"exactly)")
            elif dt.startswith("ragged["):
                self.err("V-GCDA", node,
                         f"feature column {c!r} is multi-valued ({dt}) — "
                         f"ragged columns cannot densify; aggregate via "
                         f"RandomAccessMatrix instead")
            elif dt == "dict":
                self.warn("V-GCDA", node,
                          f"feature column {c!r} is dictionary-encoded — "
                          f"its integer codes become the feature values")
            elif dt not in ("float32",) and _key_kind(dt) in ("int", "float"):
                self.warn("V-GCDA", node,
                          f"feature column {c!r}:{dt} silently promotes to "
                          f"float32 at the matrix boundary")
        return MatrixType("float32", len(node.columns))

    def _infer_RandomAccessMatrix(self, node, child):
        out = MatrixType("float32", node.n_features)
        if not isinstance(child, dict):
            self.err("V-GCDA", node,
                     f"input is {child!r}, expected a relation")
            return out
        for what, c in (("group", node.group_col), ("value", node.value_col)):
            if c not in child:
                self.err("V-COL", node,
                         f"{what} column {c!r} not in input schema "
                         f"{sorted(child)[:10]}")
        gdt = child.get(node.group_col)
        if gdt is not None and _key_kind(gdt) not in ("int",):
            self.warn("V-GCDA", node,
                      f"group column {node.group_col!r}:{gdt} is not an "
                      f"integer id column")
        return out

    def _infer_Const(self, node):
        arr = np.asarray(node.value)
        width = (int(arr.shape[1]) if arr.ndim == 2
                 else (1 if arr.ndim == 1 else None))
        return MatrixType(str(arr.dtype), width)

    def _check_matrix_input(self, node, side, s) -> Optional[MatrixType]:
        if not isinstance(s, MatrixType):
            self.err("V-GCDA", node,
                     f"{side} input is {s!r}, expected a matrix")
            return None
        return s

    def _binary_matrix(self, node, schemas, out_width):
        xs = [self._check_matrix_input(node, side, s)
              for side, s in zip(("lhs", "rhs"), schemas)]
        good = [x for x in xs if x is not None]
        if len(good) == 2 and good[0].dtype != good[1].dtype:
            self.warn("V-GCDA", node,
                      f"operand dtypes differ ({good[0].dtype} vs "
                      f"{good[1].dtype}) — the device promotes silently")
        dtype = good[0].dtype if good else "float32"
        return MatrixType(dtype, out_width)

    def _infer_MatMul(self, node, *schemas):
        # gram (x @ x.T): n×n, width statically unknown; otherwise the rhs
        # width carries through
        if node.gram:
            return self._binary_matrix(node, schemas, None)
        rhs = schemas[1] if len(schemas) > 1 else None
        width = rhs.width if isinstance(rhs, MatrixType) else None
        return self._binary_matrix(node, schemas, width)

    def _infer_Similarity(self, node, *schemas):
        if not node.self_sim and len(schemas) == 2:
            both = [s for s in schemas if isinstance(s, MatrixType)]
            if len(both) == 2 and None not in (both[0].width, both[1].width) \
                    and both[0].width != both[1].width:
                self.err("V-GCDA", node,
                         f"similarity operands have different feature "
                         f"widths ({both[0].width} vs {both[1].width})")
        return self._binary_matrix(node, schemas, None)

    def _infer_Regression(self, node, x, y):
        xm = self._check_matrix_input(node, "feature", x)
        ym = self._check_matrix_input(node, "label", y)
        if ym is not None and ym.width not in (None, 1):
            self.err("V-GCDA", node,
                     f"label input is {ym.width} columns wide — "
                     f"reshape(-1) would silently flatten {ym.width} labels "
                     f"per row")
        if xm is not None and ym is not None and xm.dtype != ym.dtype:
            self.warn("V-GCDA", node,
                      f"feature/label dtypes differ ({xm.dtype} vs "
                      f"{ym.dtype})")
        return MatrixType(xm.dtype if xm else "float32", None)


# ---------------------------------------------------------------------------
# whole-plan passes
# ---------------------------------------------------------------------------


def _walk(root):
    seen, order, stack = set(), [], [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        order.append(n)
        stack.extend(n.children)
    return order


def _check_shard_invariants(root, report: VerifyReport, inf: _Inference):
    from . import shard as shard_mod    # runtime: shard imports physical

    nodes = _walk(root)
    parents: dict[int, list] = {}
    for n in nodes:
        for c in n.children:
            parents.setdefault(id(c), []).append(n)

    ks = set()
    for n in nodes:
        k = getattr(n, "shards", None)
        if not isinstance(k, int):
            continue
        ks.add(k)
        if n.kind not in shard_mod.SHARDABLE_KINDS:
            report.add("V-SHARD", ERROR, n,
                       f"stamped shards={k} but {n.kind} is not a "
                       f"shardable kind (the runtime would treat it as "
                       f"NOT_SHARDED)")
    if len(ks) > 1:
        report.add("V-SHARD", ERROR, root.describe(),
                   f"inconsistent shard counts across the plan: "
                   f"{sorted(ks)} (one Exchange layout per plan)")

    for n in nodes:
        if n.kind == "EquiJoin" and isinstance(getattr(n, "shards", None), int):
            build = n.children[1]
            if build.kind != "Exchange":
                report.add("V-SHARD", ERROR, n,
                           f"sharded join's build side is {build.kind}, "
                           f"not an Exchange — probes have no partitioned "
                           f"runs to bind to")
                continue
            if build.key != n.jp.right:
                report.add("V-SHARD", ERROR, n,
                           f"build-side Exchange partitions on "
                           f"{build.key!r} but the join probes "
                           f"{n.jp.right!r} — misaligned partition keys "
                           f"drop matches")
            if build.k != n.shards:
                report.add("V-SHARD", ERROR, n,
                           f"build-side Exchange has k={build.k} but the "
                           f"join runs {n.shards} shard(s)")
        if n.kind == "Exchange":
            ps = parents.get(id(n), [])
            bad = [p for p in ps
                   if not (p.kind == "EquiJoin" and len(p.children) > 1
                           and p.children[1] is n)]
            if bad or not ps:
                where = bad[0].describe() if bad else "the plan root"
                report.add("V-SHARD", ERROR, n,
                           f"Exchange must feed an EquiJoin build side, "
                           f"found under {where}")


def _check_signature_coherence(root, report: VerifyReport, inf: _Inference,
                               seen_sigs: Optional[dict] = None):
    """V-SIG: equal signatures must mean equal inferred schemas (CSE and
    the inter-buffer both substitute results across equal signatures)."""
    sigs = seen_sigs if seen_sigs is not None else {}
    for n in _walk(root):
        s = inf.memo.get(id(n))
        if s is None:
            continue
        key = n.signature()
        norm = tuple(sorted(s.items())) if isinstance(s, dict) else s
        prev = sigs.get(key)
        if prev is None:
            sigs[key] = (norm, n.describe())
        elif prev[0] != norm:
            report.add("V-SIG", ERROR, n,
                       f"signature collides with {prev[1]} but the schemas "
                       f"differ — cached results would cross-contaminate")
    return sigs


def verify_plan(root, db: Database,
                report: Optional[VerifyReport] = None,
                seen_sigs: Optional[dict] = None) -> VerifyReport:
    """Statically verify one physical DAG against the live catalog. Appends
    to ``report`` when given (so one report can span naive + rewritten +
    sharded passes); never executes an operator."""
    if report is None:
        report = VerifyReport()
    inf = _Inference(db, report)
    inf.schema(root)
    _check_shard_invariants(root, report, inf)
    _check_signature_coherence(root, report, inf, seen_sigs)
    return report


def _schema_repr(s) -> str:
    if isinstance(s, dict):
        return "{" + ", ".join(f"{k}:{v}" for k, v in s.items()) + "}"
    return repr(s)


def verify_equivalence(naive, rewritten, db: Database,
                       label: str = "rewrite",
                       report: Optional[VerifyReport] = None) -> VerifyReport:
    """V-EQ: the rewritten root must infer the same schema as the naive
    root — rewrites may reorder and re-side, never retype. Column *order*
    must also survive for relational roots (the result table the user sees)."""
    if report is None:
        report = VerifyReport()

    def _root_schema(n):
        # reuse a schema already inferred into this report by verify_plan
        # (deterministic per node object) instead of re-walking the DAG
        if id(n) in report.schemas:
            return report.schemas[id(n)]
        return _Inference(db, VerifyReport()).schema(n)   # silent pass

    ns, rs = _root_schema(naive), _root_schema(rewritten)
    same = (ns == rs if not isinstance(ns, dict)
            else (isinstance(rs, dict) and list(ns.items()) == list(rs.items())))
    if not same:
        report.add("V-EQ", ERROR, rewritten,
                   f"{label} retyped the plan root: naive "
                   f"{_schema_repr(ns)} vs rewritten {_schema_repr(rs)}")
    return report


def annotate_out_cols(root, db: Database) -> None:
    """Stamp the inferred output column set on every relational node as
    ``out_cols`` (full-coverage schema annotations; previously only cluster
    roots and aliases carried them). Mask/matrix nodes are skipped — the
    annotation is a column-name concept. Best-effort: inference collects
    violations instead of raising, so annotation never blocks plan build."""
    report = VerifyReport()
    inf = _Inference(db, report)
    inf.schema(root)
    for n in _walk(root):
        s = inf.memo.get(id(n))
        if isinstance(s, dict) and s:
            n.out_cols = frozenset(s)
