"""Hybrid traversal operator ``|->`` (paper §5.1, Algorithm 1), vectorized.

The paper's operator is a binary volcano iterator emitting (r1, r2) pairs for
operand combinations V×I, I×V, I×I, I×E. On TPU we re-derive it with set
semantics: one call consumes a whole operand set and returns all pairs as
parallel arrays. ``tests/test_oracle_equivalence.py`` checks this against a
literal transcription of Algorithm 1.

Operand encodings:
  * vertex records  -> (label, vid array)  [record side]
  * nid sets        -> int array of nids   [topology side]
  * edge records    -> edge tid array
A "membership filter" operand (the paper's ``nid_t in O^2`` test, Line 17) is
passed as an optional boolean lookup table over nids — an O(1) symbolic
identifier test, exactly as the paper argues (no record I/O).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .storage import Graph


class TraversalCounters:
    """Execution counters consumed by the cost model's calibration and the
    benchmark harness (records touched == the paper's I/O proxy)."""

    def __init__(self):
        self.record_fetches = 0   # Cost_IO-weighted accesses
        self.cpu_ops = 0          # Cost_cpu-weighted ops

    def reset(self):
        self.record_fetches = 0
        self.cpu_ops = 0


COUNTERS = TraversalCounters()


# ---- Case 1: V x I  (vertex records -> nids) -------------------------------

def v_to_nid(g: Graph, label: str, vids: np.ndarray) -> np.ndarray:
    """nidMap: (oid, vid) -> nid; vectorized one-to-one mapper."""
    COUNTERS.cpu_ops += len(vids)
    return g.nid_of(label, vids)


# ---- Case 2: I x V  (nids -> vertex records) -------------------------------

def nid_to_v(g: Graph, nids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """vertexMap + tid-based RecordAM: nids -> (label_code, vid). The caller
    gathers property columns with ``Table.take(vid)``."""
    nids = np.asarray(nids)
    COUNTERS.cpu_ops += len(nids)
    COUNTERS.record_fetches += len(nids)
    return g.vertex_label_code[nids], g.vertex_vid_of[nids]


# ---- Case 3: I x I  (source nids -> target nids) ---------------------------

def nid_to_nid(g: Graph, nids: np.ndarray, member: Optional[np.ndarray] = None,
               reverse: bool = False) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Whole-frontier adjacency expansion. Returns (src_rep, dst_nid, edge_tid)
    filtered by the optional ``member`` boolean table over target nids.

    The membership test is the paper's Line 17 — here a single vectorized
    gather ``member[dst]`` instead of a per-pair set probe, which removes the
    O(|O1|·|O2|) blowup the paper warns about (§5.1) by construction.
    """
    nids = np.asarray(nids)
    pos, dst, eid = g.expand(nids, reverse=reverse)
    src_rep = nids[pos]
    COUNTERS.cpu_ops += len(dst) + len(nids)
    if member is not None:
        keep = member[dst]
        COUNTERS.cpu_ops += len(dst)
        return src_rep[keep], dst[keep], eid[keep]
    return src_rep, dst, eid


# ---- Case 4: I x E  (source nids -> edge records) --------------------------

def nid_to_e(g: Graph, nids: np.ndarray, edge_mask: Optional[np.ndarray] = None,
             reverse: bool = False) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Adjacency expansion emitting edge tids (edgeMap + tid-based RecordAM).
    ``edge_mask`` is a boolean table over edge tids (predicate already
    evaluated columnar-side)."""
    nids = np.asarray(nids)
    pos, dst, eid = g.expand(nids, reverse=reverse)
    src_rep = nids[pos]
    COUNTERS.cpu_ops += len(dst) + len(nids)
    COUNTERS.record_fetches += len(eid)
    if edge_mask is not None:
        keep = edge_mask[eid]
        COUNTERS.cpu_ops += len(eid)
        return src_rep[keep], dst[keep], eid[keep]
    return src_rep, dst, eid


def member_table(n: int, nids: np.ndarray) -> np.ndarray:
    """Build the boolean membership lookup used by Case 3/4 filters."""
    m = np.zeros(n, dtype=bool)
    m[np.asarray(nids)] = True
    return m
