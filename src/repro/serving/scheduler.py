"""Continuous-batching serving scheduler over the LM decode path.

Fixed-slot design (static shapes end to end, jit-stable):
  * B cache slots, each (L, Hk, M, dh); a slot holds one in-flight request;
  * new requests prefill on a batch=1 cache then scatter into their slot —
    active decodes are never recomputed;
  * one decode step advances ALL active slots (per-slot lengths drive the
    attention masks — the kernel path is the same serve_step the decode_32k
    dry-run cell lowers);
  * finished requests (EOS or max_new) free their slot immediately, so the
    batch refills mid-flight (continuous batching).
Greedy decoding is deterministic: the scheduler's outputs are bit-identical
to serving each request alone (property-tested).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import (TransformerConfig, forward, init_cache,
                                  serve_step)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 32
    eos_id: int = -1            # -1 = never


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list
    prefill_len: int
    steps: int


class ContinuousBatcher:
    def __init__(self, params, cfg: TransformerConfig, n_slots: int = 4,
                 max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.B = n_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, n_slots, max_len)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.active: list[Optional[dict]] = [None] * n_slots
        self.stats = {"prefills": 0, "decode_steps": 0, "slot_occupancy": []}

        self._prefill = jax.jit(
            lambda p, c, t: forward(p, t, cfg, cache=c,
                                    cache_lengths=jnp.zeros((1,), jnp.int32)))
        self._decode = jax.jit(lambda p, c, t, l: serve_step(p, c, t, l, cfg))

    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int):
        P = len(req.prompt)
        small = init_cache(self.cfg, 1, self.max_len)
        logits, small = self._prefill(self.params,
                                      small,
                                      jnp.asarray(req.prompt, jnp.int32)[None])
        first = int(jnp.argmax(logits[0, P - 1]))
        # scatter the prefill cache into the slot
        self.cache = jax.tree.map(
            lambda big, one: big.at[:, slot].set(one[:, 0]),
            self.cache, small)
        self.lengths = self.lengths.at[slot].set(P)
        self.active[slot] = {"req": req, "out": [first], "steps": 0}
        self.stats["prefills"] += 1

    def _finished(self, state: dict) -> bool:
        req = state["req"]
        return (len(state["out"]) >= req.max_new
                or (req.eos_id >= 0 and state["out"][-1] == req.eos_id))

    # ------------------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Completion]:
        queue = list(requests)
        done: list[Completion] = []
        next_tok = np.zeros((self.B, 1), np.int32)

        while queue or any(s is not None for s in self.active):
            # admit into free slots
            for b in range(self.B):
                if self.active[b] is None and queue:
                    req = queue.pop(0)
                    self._admit(req, b)
                    next_tok[b, 0] = self.active[b]["out"][-1]
            self.stats["slot_occupancy"].append(
                sum(s is not None for s in self.active))

            # one decode step for all active slots
            active_mask = [s is not None for s in self.active]
            if not any(active_mask):
                continue
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(next_tok), self.lengths)
            self.stats["decode_steps"] += 1
            self.lengths = self.lengths + jnp.asarray(
                [1 if a else 0 for a in active_mask], jnp.int32)
            nxt = np.asarray(jnp.argmax(logits, -1))

            for b in range(self.B):
                st = self.active[b]
                if st is None:
                    continue
                st["out"].append(int(nxt[b]))
                st["steps"] += 1
                next_tok[b, 0] = int(nxt[b])
                if self._finished(st):
                    done.append(Completion(
                        rid=st["req"].rid, tokens=st["out"][:st["req"].max_new],
                        prefill_len=len(st["req"].prompt), steps=st["steps"]))
                    self.active[b] = None
                    self.lengths = self.lengths.at[b].set(0)
        return sorted(done, key=lambda c: c.rid)
