"""repro: GredoJAX — graph-centric cross-model data integration & analytics
(GredoDB reproduction) plus the multi-arch JAX/TPU training framework."""
__version__ = "0.1.0"
