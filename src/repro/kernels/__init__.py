"""Pallas TPU kernels. Each subpackage ships <name>.py (pl.pallas_call +
BlockSpec), ops.py (jit'd wrapper; interpret=True off-TPU), ref.py (pure-jnp
oracle)."""
from .cosine_sim import cosine_sim, cosine_sim_ref
from .embedding_bag import embedding_bag, embedding_bag_ref
from .flash_attention import flash_attention, flash_attention_ref
from .logreg import logreg_grad, logreg_grad_ref
from .matmul import matmul, matmul_ref

__all__ = [
    "matmul", "matmul_ref", "cosine_sim", "cosine_sim_ref",
    "logreg_grad", "logreg_grad_ref", "flash_attention",
    "flash_attention_ref", "embedding_bag", "embedding_bag_ref",
]
