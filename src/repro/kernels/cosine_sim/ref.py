"""Pure-jnp oracle for cosine similarity."""
import jax.numpy as jnp


def cosine_sim_ref(x, y, eps: float = 1e-12):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = x / jnp.sqrt(jnp.sum(x * x, -1, keepdims=True) + eps)
    yn = y / jnp.sqrt(jnp.sum(y * y, -1, keepdims=True) + eps)
    return jnp.dot(xn, yn.T)
