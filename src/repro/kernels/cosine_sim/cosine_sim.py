"""Fused cosine-similarity Pallas kernel — the SIMILARITY GCDA operator.

S[i,j] = <x_i, y_j> / (|x_i| |y_j|). The row inverse-norms are computed once
(one streaming pass, O(md+nd)) and fused into the matmul epilogue, so the
(m,n) score matrix is produced in a single kernel with no extra HBM round
trip for normalization — this is the paper's "distributed inner products and
normalization across row vectors" re-expressed as an MXU epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cosine_kernel(x_ref, y_ref, ix_ref, iy_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * ix_ref[...] * iy_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def cosine_sim(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
               bk: int = 128, eps: float = 1e-12, interpret: bool = False
               ) -> jax.Array:
    """x: (m, d), y: (n, d) -> (m, n) cosine scores."""
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2
    inv_x = jax.lax.rsqrt(jnp.sum(x.astype(jnp.float32) ** 2, -1) + eps)
    inv_y = jax.lax.rsqrt(jnp.sum(y.astype(jnp.float32) ** 2, -1) + eps)

    mp, np_, kp = (-m) % bm, (-n) % bn, (-d) % bk
    xp = jnp.pad(x, ((0, mp), (0, kp)))
    ytp = jnp.pad(y.T, ((0, kp), (0, np_)))
    ixp = jnp.pad(inv_x, (0, mp)).reshape(-1, 1)
    iyp = jnp.pad(inv_y, (0, np_)).reshape(1, -1)
    M, K = xp.shape
    _, N = ytp.shape

    out = pl.pallas_call(
        _cosine_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((bm, 1), lambda i, j, l: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, ytp, ixp, iyp)
    return out[:m, :n]
