from __future__ import annotations

import jax

from .cosine_sim import cosine_sim as _kernel
from .ref import cosine_sim_ref

_ON_TPU = jax.default_backend() == "tpu"


def cosine_sim(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128,
               use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return cosine_sim_ref(x, y)
    return _kernel(x, y, bm=bm, bn=bn, bk=bk, interpret=not _ON_TPU)
