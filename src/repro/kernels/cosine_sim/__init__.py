from .ops import cosine_sim
from .ref import cosine_sim_ref

__all__ = ["cosine_sim", "cosine_sim_ref"]
