"""Fused traversal Pallas kernel — the device-resident GCDI hot path.

One launch advances a whole batch of padded frontiers one hop: CSR
row-gather + neighbor expansion + pushed-predicate evaluation + in-kernel
compaction, with zone-map chunk metadata gating the edge-predicate reads.
The batched layout is the native one (grid = (B queries, capacity/blk
slot blocks)); a single query is the B=1 special case.

Layout notes (vs the per-hop jit matcher in ``core.pattern_jit``):

  * the degree prefix-sum and the overflow flag are computed in the jnp
    prelude (they are O(C) scans XLA fuses well); the kernel does the
    O(capacity) candidate work;
  * each (q, b) grid step owns ``blk`` candidate slots of query q. The
    slot->frontier-entry mapping is a broadcast compare against the
    offsets (the in-kernel searchsorted); gathers pull dst/eid, the
    member / chunk-alive / edge-predicate tables filter, and survivors are
    scattered to the query's running compaction offset held in SMEM —
    TPU grid steps run sequentially, so the scalar offset carries across
    slot blocks and resets at each query's first block;
  * a candidate whose edge tid lands in a zone-dead chunk is masked before
    the predicate gather — on compiled TPU the predicate table is blocked
    per chunk and dead chunks are never DMA'd into VMEM; interpret mode
    (the CI path) preserves the semantics with a masked gather;
  * ``.at[].set(mode="drop")`` gives the compaction scatter: dead slots
    target index ``capacity`` (one past the block) and vanish.

On CPU this runs under ``interpret=True`` for validation; wall-clock
benchmarking of the fused layout uses the jnp oracle (see
``benchmarks/traversal_bench.py`` for the framing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hop_kernel(out_off_ref, frontier_ref, total_ref, row_ptr_ref,
                col_idx_ref, edge_id_ref, member_ref, edge_pred_ref,
                chunk_alive_ref, src_ref, dst_ref, eid_ref, cnt_ref,
                off_sm, *, blk: int, capacity: int, chunk: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        off_sm[0] = 0
        cnt_ref[0, 0] = 0
        src_ref[...] = jnp.zeros(src_ref.shape, jnp.int32)
        dst_ref[...] = jnp.full(dst_ref.shape, -1, jnp.int32)
        eid_ref[...] = jnp.full(eid_ref.shape, -1, jnp.int32)

    oo = out_off_ref[0, :]                                   # (C,)
    fr = frontier_ref[0, :]
    total = total_ref[0, 0]
    slots = b * blk + jax.lax.broadcasted_iota(jnp.int32, (blk,), 0)

    # in-kernel searchsorted: the frontier entry owning slot s is the last
    # offset <= s (broadcast compare; offsets are sorted)
    src_slot = jnp.sum((oo[None, :] <= slots[:, None]).astype(jnp.int32),
                       axis=1) - 1
    src_slot = jnp.clip(src_slot, 0, oo.shape[0] - 1)
    within = slots - oo[src_slot]

    rp = row_ptr_ref[...]
    ci = col_idx_ref[...]
    ei = edge_id_ref[...]
    pos = jnp.clip(rp[fr[src_slot]] + within, 0, ci.shape[0] - 1)
    dst = ci[pos].astype(jnp.int32)
    eid = ei[pos].astype(jnp.int32)

    ok = slots < jnp.minimum(total, capacity)
    mem = member_ref[...]
    ok &= mem[jnp.clip(dst, 0, mem.shape[0] - 1)]
    ca = chunk_alive_ref[...]
    ok &= ca[jnp.clip(eid // chunk, 0, ca.shape[0] - 1)]
    ep = edge_pred_ref[...]
    ok &= ep[jnp.clip(eid, 0, ep.shape[0] - 1)]

    # compact survivors to the query's running offset; dead slots scatter
    # out of range and drop
    off = off_sm[0]
    inc = jnp.cumsum(ok.astype(jnp.int32))
    posn = jnp.where(ok, off + inc - 1, capacity)
    src_ref[0, :] = src_ref[0, :].at[posn].set(src_slot.astype(jnp.int32),
                                               mode="drop")
    dst_ref[0, :] = dst_ref[0, :].at[posn].set(dst, mode="drop")
    eid_ref[0, :] = eid_ref[0, :].at[posn].set(eid, mode="drop")
    off_sm[0] = off + inc[-1]

    @pl.when(b == pl.num_programs(1) - 1)
    def _fin():
        cnt_ref[0, 0] = off + inc[-1]


@functools.partial(jax.jit,
                   static_argnames=("capacity", "chunk", "blk", "interpret"))
def batched_hop(row_ptr: jax.Array, col_idx: jax.Array, edge_id: jax.Array,
                frontiers: jax.Array, fmasks: jax.Array, member: jax.Array,
                edge_pred: jax.Array, chunk_alive: jax.Array, *,
                capacity: int, chunk: int, blk: int = 128,
                interpret: bool = False):
    """B queries, one launch. frontiers/fmasks: (B, C). Returns
    (src_slot, dst, eid) as (B, capacity), count (B,), overflowed (B,) —
    the same contract as ``ref.batched_hop_ref``."""
    B, C = frontiers.shape
    if capacity % blk:
        raise ValueError(f"capacity {capacity} not a multiple of blk {blk}")
    fr = jnp.asarray(frontiers, jnp.int32)
    deg = jnp.where(fmasks, (row_ptr[fr + 1] - row_ptr[fr]).astype(jnp.int32),
                    0)
    out_off = (jnp.cumsum(deg, axis=1) - deg).astype(jnp.int32)
    total = jnp.sum(deg, axis=1, dtype=jnp.int32)[:, None]
    overflowed = total[:, 0] > capacity

    n1, m = row_ptr.shape[0], col_idx.shape[0]
    nmem, nch = member.shape[0], chunk_alive.shape[0]
    kernel = functools.partial(_hop_kernel, blk=blk, capacity=capacity,
                               chunk=chunk)
    src, dst, eid, cnt = pl.pallas_call(
        kernel,
        grid=(B, capacity // blk),
        in_specs=[
            pl.BlockSpec((1, C), lambda q, b: (q, 0)),       # out_off
            pl.BlockSpec((1, C), lambda q, b: (q, 0)),       # frontier
            pl.BlockSpec((1, 1), lambda q, b: (q, 0)),       # total
            pl.BlockSpec((n1,), lambda q, b: (0,)),          # row_ptr
            pl.BlockSpec((m,), lambda q, b: (0,)),           # col_idx
            pl.BlockSpec((m,), lambda q, b: (0,)),           # edge_id
            pl.BlockSpec((nmem,), lambda q, b: (0,)),        # member
            pl.BlockSpec((m,), lambda q, b: (0,)),           # edge_pred
            pl.BlockSpec((nch,), lambda q, b: (0,)),         # chunk_alive
        ],
        out_specs=[
            pl.BlockSpec((1, capacity), lambda q, b: (q, 0)),
            pl.BlockSpec((1, capacity), lambda q, b: (q, 0)),
            pl.BlockSpec((1, capacity), lambda q, b: (q, 0)),
            pl.BlockSpec((1, 1), lambda q, b: (q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, capacity), jnp.int32),
            jax.ShapeDtypeStruct((B, capacity), jnp.int32),
            jax.ShapeDtypeStruct((B, capacity), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(out_off, fr, total, row_ptr, col_idx, edge_id, member, edge_pred,
      chunk_alive)
    return src, dst, eid, cnt[:, 0], overflowed


def fused_hop(row_ptr, col_idx, edge_id, frontier, fmask, member, edge_pred,
              chunk_alive, *, capacity: int, chunk: int, blk: int = 128,
              interpret: bool = False):
    """Single-query fused hop (B=1 slice of the batched kernel); same
    contract as ``ref.fused_hop_ref``."""
    src, dst, eid, cnt, ovf = batched_hop(
        row_ptr, col_idx, edge_id, frontier[None, :], fmask[None, :],
        member, edge_pred, chunk_alive, capacity=capacity, chunk=chunk,
        blk=blk, interpret=interpret)
    return src[0], dst[0], eid[0], cnt[0], ovf[0]
