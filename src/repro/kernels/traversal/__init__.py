"""Fused traversal kernel family (device-resident GCDI): CSR row-gather +
neighbor expansion + predicate evaluation + in-kernel compaction in one
launch, with a batched multi-query variant. Layout per the family
convention: traversal.py (pl.pallas_call + BlockSpec), ops.py (dispatch +
whole-chain drivers), ref.py (pure-jnp oracle)."""
from .ops import (COUNTERS, batched_hop, batched_traverse, fused_hop,
                  traverse_chain)
from .ref import batched_hop_ref, fused_hop_ref

__all__ = [
    "fused_hop", "batched_hop", "traverse_chain", "batched_traverse",
    "fused_hop_ref", "batched_hop_ref", "COUNTERS",
]
