"""Dispatch + whole-chain drivers for the fused traversal kernels.

``fused_hop``/``batched_hop`` follow the family convention: the Pallas
kernel on TPU, the jnp oracle on CPU (interpret-mode Pallas is for
validation, not speed), ``use_kernel`` to force either.

``traverse_chain``/``batched_traverse`` run a whole chain pattern as ONE
jit'd program — every hop's expansion, predicate evaluation, compaction and
path re-join stays on device, and the host synchronizes once at the end
(overflow flag + final count). That is the latency contrast with the
per-hop ``DevicePatternMatcher``, which dispatches and syncs every hop.

COUNTERS feed the telemetry registry through
``core.pattern_jit.metrics`` (cumulative — per-query deltas come from
registry snapshots).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import traversal as kern

_ON_TPU = jax.default_backend() == "tpu"


@dataclasses.dataclass
class _Counters:
    launches: int = 0           # chain launches (one per traverse_chain call)
    hops: int = 0               # fused hops executed
    batched_queries: int = 0    # queries carried by batched launches
    chunks_alive: int = 0       # zone-map chunks surviving the prefetch filter
    chunks_total: int = 0       # zone-map chunks examined

    def metrics(self) -> dict:
        return {"launches": self.launches, "hops": self.hops,
                "batched_queries": self.batched_queries,
                "chunks_alive": self.chunks_alive,
                "chunks_total": self.chunks_total}

    def reset(self) -> None:
        self.launches = self.hops = self.batched_queries = 0
        self.chunks_alive = self.chunks_total = 0


COUNTERS = _Counters()


def fused_hop(row_ptr, col_idx, edge_id, frontier, fmask, member, edge_pred,
              chunk_alive, *, capacity: int, chunk: int,
              use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return ref.fused_hop_ref(row_ptr, col_idx, edge_id, frontier, fmask,
                                 member, edge_pred, chunk_alive,
                                 capacity=capacity, chunk=chunk)
    return kern.fused_hop(row_ptr, col_idx, edge_id, frontier, fmask, member,
                          edge_pred, chunk_alive, capacity=capacity,
                          chunk=chunk, interpret=not _ON_TPU)


def batched_hop(row_ptr, col_idx, edge_id, frontiers, fmasks, member,
                edge_pred, chunk_alive, *, capacity: int, chunk: int,
                use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU
    if not use_kernel:
        return ref.batched_hop_ref(row_ptr, col_idx, edge_id, frontiers,
                                   fmasks, member, edge_pred, chunk_alive,
                                   capacity=capacity, chunk=chunk)
    return kern.batched_hop(row_ptr, col_idx, edge_id, frontiers, fmasks,
                            member, edge_pred, chunk_alive, capacity=capacity,
                            chunk=chunk, interpret=not _ON_TPU)


# ---------------------------------------------------------------------------
# Whole-chain drivers (single launch window, one end-of-chain host sync)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("capacity", "chunk", "use_kernel",
                                    "interpret"))
def _chain_device(row_ptr, col_idx, edge_id, frontier, fmask, members,
                  edge_preds, chunk_alives, *, capacity: int, chunk: int,
                  use_kernel: bool, interpret: bool):
    if use_kernel:
        hop = functools.partial(kern.fused_hop, interpret=interpret)
    else:
        hop = ref.fused_hop_ref
    vcols = [frontier.astype(jnp.int32)]
    ecols: list = []
    count = jnp.sum(fmask).astype(jnp.int32)
    ovf = jnp.zeros((), bool)
    for vm, ep, ca in zip(members, edge_preds, chunk_alives):
        src, dst, eid, count, o = hop(row_ptr, col_idx, edge_id, frontier,
                                      fmask, vm, ep, ca, capacity=capacity,
                                      chunk=chunk)
        # re-join path prefixes through the compacted src slots
        vcols = [c[src] for c in vcols]
        ecols = [c[src] for c in ecols]
        vcols.append(dst)
        ecols.append(eid)
        frontier = jnp.maximum(dst, 0)
        fmask = jnp.arange(capacity, dtype=jnp.int32) < count
        ovf |= o
    return vcols, ecols, count, ovf


@functools.partial(jax.jit,
                   static_argnames=("capacity", "chunk", "use_kernel",
                                    "interpret"))
def _batched_chain_device(row_ptr, col_idx, edge_id, frontiers, fmasks,
                          members, edge_preds, chunk_alives, *, capacity: int,
                          chunk: int, use_kernel: bool, interpret: bool):
    if use_kernel:
        hop = functools.partial(kern.batched_hop, interpret=interpret)
    else:
        hop = ref.batched_hop_ref
    B = frontiers.shape[0]
    vcols = [frontiers.astype(jnp.int32)]
    ecols: list = []
    counts = jnp.sum(fmasks, axis=1).astype(jnp.int32)
    ovf = jnp.zeros((B,), bool)
    for vm, ep, ca in zip(members, edge_preds, chunk_alives):
        src, dst, eid, counts, o = hop(row_ptr, col_idx, edge_id, frontiers,
                                       fmasks, vm, ep, ca, capacity=capacity,
                                       chunk=chunk)
        vcols = [jnp.take_along_axis(c, src, axis=1) for c in vcols]
        ecols = [jnp.take_along_axis(c, src, axis=1) for c in ecols]
        vcols.append(dst)
        ecols.append(eid)
        frontiers = jnp.maximum(dst, 0)
        fmasks = (jnp.arange(capacity, dtype=jnp.int32)[None, :]
                  < counts[:, None])
        ovf |= o
    return vcols, ecols, counts, ovf


def _device_tables(n_vertices, n_edges, chunk, members, edge_preds,
                   chunk_alives):
    """Normalize optional host tables to device arrays (None = all-true)."""
    m = max(int(n_edges), 1)
    nch = max(-(-m // chunk), 1)
    ones_v = jnp.ones((max(int(n_vertices), 1),), bool)
    ones_e = jnp.ones((m,), bool)
    ones_c = jnp.ones((nch,), bool)
    mem = tuple(ones_v if v is None else jnp.asarray(v) for v in members)
    epr = tuple(ones_e if e is None else jnp.asarray(e) for e in edge_preds)
    cal = tuple(ones_c if c is None else jnp.asarray(c) for c in chunk_alives)
    return mem, epr, cal


def _padded_csr(row_ptr, col_idx, edge_id, n_edges):
    rp = jnp.asarray(row_ptr)
    if n_edges:
        return rp, jnp.asarray(col_idx), jnp.asarray(edge_id)
    # degenerate graph: 1-entry dummies keep every gather in range (deg is
    # all zero, so no candidate is ever valid)
    return rp, jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32)


def traverse_chain(row_ptr, col_idx, edge_id, n_vertices: int, n_edges: int,
                   start_nids, members, edge_preds, chunk_alives, *,
                   capacity: int, chunk: int, use_kernel: bool | None = None):
    """Run a whole chain in one jit'd program. ``members[h]`` /
    ``edge_preds[h]`` / ``chunk_alives[h]`` are per-hop tables (None =
    unconstrained). Returns (vcols, ecols, ok): trimmed np arrays of the
    matched path columns (hop order), or ``ok=False`` on capacity overflow
    (caller doubles and retries)."""
    if use_kernel is None:
        use_kernel = _ON_TPU
    rp, ci, ei = _padded_csr(row_ptr, col_idx, edge_id, n_edges)
    mem, epr, cal = _device_tables(n_vertices, n_edges, chunk, members,
                                   edge_preds, chunk_alives)
    C0 = len(start_nids)
    if capacity < C0 or capacity % 128:
        raise ValueError(f"capacity {capacity} must be a multiple of 128 "
                         f">= the start frontier ({C0})")
    frontier = jnp.zeros((capacity,), jnp.int32).at[:C0].set(
        jnp.asarray(start_nids, jnp.int32))
    fmask = jnp.zeros((capacity,), bool).at[:C0].set(True)
    vcols, ecols, count, ovf = _chain_device(
        rp, ci, ei, frontier, fmask, mem, epr, cal, capacity=capacity,
        chunk=chunk, use_kernel=bool(use_kernel), interpret=not _ON_TPU)
    COUNTERS.launches += 1
    COUNTERS.hops += len(mem)
    if bool(ovf):               # the chain's one host sync
        return None, None, False
    k = int(count)
    return ([np.asarray(c)[:k] for c in vcols],
            [np.asarray(c)[:k] for c in ecols], True)


def batched_traverse(row_ptr, col_idx, edge_id, n_vertices: int,
                     n_edges: int, start_nids, members, edge_preds,
                     chunk_alives, *, capacity: int, chunk: int,
                     use_kernel: bool | None = None):
    """Point-lookup batching: ``start_nids`` is (B,) — one start vertex per
    query; all B queries advance through the chain in single launches.
    Returns (vcols, ecols, counts, ok): per-query path columns as
    (B, capacity) np arrays valid up to ``counts[q]``, or ``ok=False`` if
    any query overflowed."""
    if use_kernel is None:
        use_kernel = _ON_TPU
    rp, ci, ei = _padded_csr(row_ptr, col_idx, edge_id, n_edges)
    mem, epr, cal = _device_tables(n_vertices, n_edges, chunk, members,
                                   edge_preds, chunk_alives)
    start = jnp.asarray(start_nids, jnp.int32)
    B = start.shape[0]
    if capacity % 128:
        raise ValueError(f"capacity {capacity} must be a multiple of 128")
    frontiers = jnp.zeros((B, capacity), jnp.int32).at[:, 0].set(start)
    fmasks = jnp.zeros((B, capacity), bool).at[:, 0].set(True)
    vcols, ecols, counts, ovf = _batched_chain_device(
        rp, ci, ei, frontiers, fmasks, mem, epr, cal, capacity=capacity,
        chunk=chunk, use_kernel=bool(use_kernel), interpret=not _ON_TPU)
    COUNTERS.launches += 1
    COUNTERS.hops += len(mem)
    COUNTERS.batched_queries += int(B)
    if bool(jnp.any(ovf)):      # the batch's one host sync
        return None, None, None, False
    return ([np.asarray(c) for c in vcols], [np.asarray(c) for c in ecols],
            np.asarray(counts), True)
