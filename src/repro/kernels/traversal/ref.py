"""Pure-jnp oracle for the fused traversal hop (DeviceMatchPattern).

One "fused hop" is the unit the Pallas kernel implements: CSR row-gather +
neighbor expansion + pushed-predicate evaluation + compaction, over a padded
fixed-capacity frontier. The oracle keeps the exact output contract the
kernel must hit so the equivalence tests compare arrays, not row sets:

  * candidates are laid out in slot order — frontier-slot-major, CSR
    position within a row (the same order the host matcher produces);
  * survivors are compacted to the front, preserving slot order;
  * padding is ``src=0, dst=-1, eid=-1`` beyond ``count``;
  * ``overflowed`` is true when the *pre-predicate* candidate total exceeds
    the capacity (the caller doubles and retries — survivors of a truncated
    expansion are never silently returned as complete).

``chunk_alive`` is the zone-map chunk survivor table over the edge-tid
space: a candidate whose edge lands in a predicate-dead chunk is dropped
without consulting ``edge_pred`` (on TPU the dead chunk's slice of the
predicate table is never DMA'd; here the gather is simply masked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("capacity", "chunk"))
def fused_hop_ref(row_ptr: jax.Array, col_idx: jax.Array, edge_id: jax.Array,
                  frontier: jax.Array, fmask: jax.Array, member: jax.Array,
                  edge_pred: jax.Array, chunk_alive: jax.Array, *,
                  capacity: int, chunk: int):
    """One fused hop. frontier/fmask: (C,) padded nids + validity; member:
    (n,) bool over nids; edge_pred: (m,) bool over edge tids; chunk_alive:
    (ceil(m/chunk),) bool. Returns (src_slot, dst, eid, count, overflowed)
    with the first ``count`` slots holding the compacted survivors —
    ``src_slot`` indexes the INPUT frontier so callers re-join path
    prefixes."""
    C = frontier.shape[0]
    fr = frontier.astype(jnp.int32)
    deg = jnp.where(fmask, (row_ptr[fr + 1] - row_ptr[fr]).astype(jnp.int32), 0)
    out_off = jnp.cumsum(deg) - deg                     # exclusive prefix sum
    total = jnp.sum(deg)
    overflowed = total > capacity

    slots = jnp.arange(capacity, dtype=jnp.int32)
    src_slot = jnp.clip(
        jnp.searchsorted(out_off, slots, side="right") - 1, 0, C - 1
    ).astype(jnp.int32)
    within = slots - out_off[src_slot]
    pos = jnp.clip(row_ptr[fr[src_slot]] + within, 0, col_idx.shape[0] - 1)
    dst = col_idx[pos].astype(jnp.int32)
    eid = edge_id[pos].astype(jnp.int32)

    ok = slots < jnp.minimum(total, capacity)
    ok &= member[jnp.clip(dst, 0, member.shape[0] - 1)]
    ok &= chunk_alive[jnp.clip(eid // chunk, 0, chunk_alive.shape[0] - 1)]
    ok &= edge_pred[jnp.clip(eid, 0, edge_pred.shape[0] - 1)]

    # stable compaction in slot order: survivors sort before dead slots and
    # keep their relative order (keys are unique, so no stable-sort caveat)
    count = jnp.sum(ok).astype(jnp.int32)
    order = jnp.argsort(jnp.where(ok, slots, capacity + slots))
    live = slots < count
    src_c = jnp.where(live, src_slot[order], 0)
    dst_c = jnp.where(live, dst[order], -1)
    eid_c = jnp.where(live, eid[order], -1)
    return src_c, dst_c, eid_c, count, overflowed


@functools.partial(jax.jit, static_argnames=("capacity", "chunk"))
def batched_hop_ref(row_ptr: jax.Array, col_idx: jax.Array,
                    edge_id: jax.Array, frontiers: jax.Array,
                    fmasks: jax.Array, member: jax.Array,
                    edge_pred: jax.Array, chunk_alive: jax.Array, *,
                    capacity: int, chunk: int):
    """Batched variant: frontiers/fmasks are (B, C) — B independent queries
    share the CSR and predicate tables and advance in one call. Returns the
    per-query (src_slot, dst, eid) as (B, capacity), count as (B,), and a
    per-query overflow flag."""
    def one(fr, fm):
        return fused_hop_ref(row_ptr, col_idx, edge_id, fr, fm, member,
                             edge_pred, chunk_alive,
                             capacity=capacity, chunk=chunk)
    return jax.vmap(one)(frontiers, fmasks)
