from __future__ import annotations

import jax

from .logreg import logreg_grad as _kernel
from .ref import logreg_grad_ref

_ON_TPU = jax.default_backend() == "tpu"


def logreg_grad(x, y, w, *, bn: int = 512, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return logreg_grad_ref(x, y, w)
    return _kernel(x, y, w, bn=bn, interpret=not _ON_TPU)
