from .ops import logreg_grad
from .ref import logreg_grad_ref

__all__ = ["logreg_grad", "logreg_grad_ref"]
