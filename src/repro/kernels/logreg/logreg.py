"""Fused logistic-regression gradient Pallas kernel — the REGRESSION GCDA
operator's inner loop.

One kernel computes  grad = X^T (sigmoid(Xw) - y) / n  and the batch loss by
streaming row blocks of X through VMEM once: each grid step loads an
(bn, d) block, runs the forward dot, the sigmoid, and the backward outer
product, and accumulates the (d,) gradient and scalar loss in VMEM scratch —
this is the paper's "iterative gradient computation aggregating contributions
from each partition in parallel" with the partition = a VMEM-resident row
block instead of a worker thread's tuple batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _logreg_kernel(x_ref, y_ref, w_ref, g_ref, loss_ref, gacc_ref, lacc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)
        lacc_ref[...] = jnp.zeros_like(lacc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    y = y_ref[...].astype(jnp.float32)          # (bn, 1)
    w = w_ref[...].astype(jnp.float32)          # (d, 1)
    z = jnp.dot(x, w, preferred_element_type=jnp.float32)   # (bn, 1)
    p = jax.nn.sigmoid(z)
    err = p - y                                  # (bn, 1)
    gacc_ref[...] += jnp.dot(x.T, err, preferred_element_type=jnp.float32)
    # numerically-stable logistic loss: log(1+e^z) - y*z = softplus(z) - y z
    lacc_ref[...] += jnp.sum(jax.nn.softplus(z) - y * z)

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _done():
        g_ref[...] = gacc_ref[...].astype(g_ref.dtype)
        loss_ref[...] = lacc_ref[...].astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def logreg_grad(x: jax.Array, y: jax.Array, w: jax.Array, *, bn: int = 512,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (n, d), y: (n,) in {0,1}, w: (d,). Returns (grad (d,), mean loss).

    Rows are zero-padded to a block multiple; padded rows contribute
    sigmoid(0)-0 = 0.5 error against x=0 features -> zero gradient, and a
    constant softplus(0) loss which is subtracted exactly.
    """
    n, d = x.shape
    pad = (-n) % bn
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    yp = jnp.pad(y.astype(jnp.float32), (0, pad)).reshape(-1, 1)
    w2 = w.reshape(-1, 1)
    grad, loss = pl.pallas_call(
        _logreg_kernel,
        grid=((n + pad) // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xp, yp, w2)
    # remove padded rows' constant softplus(0) = log 2 loss contribution
    loss = (loss[0, 0] - pad * jnp.log(2.0)) / n
    return grad[:, 0] / n, loss
