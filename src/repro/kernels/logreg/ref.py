"""Pure-jnp oracle for the fused logistic-regression gradient."""
import jax
import jax.numpy as jnp


def logreg_grad_ref(x, y, w):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    z = x @ w
    p = jax.nn.sigmoid(z)
    grad = x.T @ (p - y) / x.shape[0]
    loss = jnp.mean(jax.nn.softplus(z) - y * z)
    return grad, loss
