"""Tiled MXU matmul Pallas kernel — the MULTIPLY GCDA operator's hot loop.

Block-tiled C[i,j] = sum_k A[i,k] @ B[k,j] with a float32 VMEM accumulator;
grid (M/bm, N/bn, K/bk); the K axis is the sequential (arbitrary) dimension
so the accumulator scratch persists across K steps. Block shapes default to
MXU-aligned 128x128x128, giving bm*bk + bk*bn + bm*bn fp32 VMEM footprint
(= 192 KiB at defaults, well inside the ~16 MiB v5e VMEM budget, leaving room
for double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """C = x @ y with explicit VMEM tiling. Inputs are zero-padded to block
    multiples (zeros are exact for the accumulation)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, mp), (0, kp))) if (mp or kp) else x
    yp = jnp.pad(y, ((0, kp), (0, np_))) if (kp or np_) else y
    M, K = xp.shape
    _, N = yp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]
