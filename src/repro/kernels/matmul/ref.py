"""Pure-jnp oracle for the matmul kernel."""
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32)).astype(x.dtype)
