"""Public jit'd entry point for MULTIPLY. On non-TPU backends the Pallas
kernel runs in interpret mode (CPU validation); on TPU it compiles to MXU
tiles. ``use_kernel=False`` falls back to the jnp oracle (used by the
benchmarks to isolate kernel effects)."""
from __future__ import annotations

import jax

from .matmul import matmul as _matmul_kernel_call
from .ref import matmul_ref

_ON_TPU = jax.default_backend() == "tpu"


def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128,
           use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return matmul_ref(x, y)
    return _matmul_kernel_call(x, y, bm=bm, bn=bn, bk=bk, interpret=not _ON_TPU)
