"""Pure-jnp oracle: masked multi-head attention with GQA."""
import jax.numpy as jnp


def flash_attention_ref(q, k, v, lengths=None, *, causal: bool = True):
    b, h, sq, dh = q.shape
    _, hk, skv, _ = k.shape
    group = h // hk
    if lengths is None:
        lengths = jnp.full((b,), skv, jnp.int32)
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * dh ** -0.5
    kpos = jnp.arange(skv)[None, None, None, :]
    mask = kpos < lengths[:, None, None, None]
    if causal:
        qpos = (lengths[:, None, None, None] - sq) + jnp.arange(sq)[None, None, :, None]
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.nan_to_num(jnp.exp(s - jnp.max(s, -1, keepdims=True)))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
