"""Flash attention Pallas kernel (GQA, causal, KV-length masked).

Online-softmax tiling: grid (batch, q_heads, sq/bq, skv/bk); the KV axis is
the sequential dimension, with running max / normalizer / output accumulator
held in VMEM scratch. GQA is expressed in the BlockSpec index map — the KV
block for query head ``h`` is head ``h // group`` — so grouped heads re-read
the same KV tile from HBM only once per (i, j) step instead of materializing
repeated KV.

Query positions are assumed to be the *last* ``sq`` positions of a context of
``length`` tokens (length passed per batch row), which covers training
(length == sq), prefill, and single-token decode (sq == 1) with one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, bq: int, bk: int, sq: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, dh)
    length = len_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)

    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < length
    if causal:
        qpos = (length - sq) + i * bq + \
            jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        mask &= qpos >= kpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, :1]                              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)                         # (bq, 1)
    l_new = alpha * l_ref[...][:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == pl.num_programs(3) - 1)
    def _done():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    lengths: jax.Array | None = None, *, causal: bool = True,
                    bq: int = 128, bk: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q: (b, h, sq, dh); k/v: (b, hk, skv, dh); lengths: (b,) valid KV
    prefix length (defaults to skv). Queries occupy positions
    [length - sq, length)."""
    b, h, sq, dh = q.shape
    _, hk, skv, _ = k.shape
    assert h % hk == 0
    group = h // hk
    scale = dh ** -0.5
    if lengths is None:
        lengths = jnp.full((b,), skv, jnp.int32)
    len2d = lengths.astype(jnp.int32).reshape(b, 1)

    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    qpad, kpad = (-sq) % bq_, (-skv) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    SQ, SK = sq + qpad, skv + kpad

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq_, bk=bk_, sq=sq)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, SQ // bq_, SK // bk_),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, dh), lambda bb, hh, i, j: (bb, hh, i, 0)),
            pl.BlockSpec((1, 1, bk_, dh), lambda bb, hh, i, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1, bk_, dh), lambda bb, hh, i, j: (bb, hh // group, j, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, i, j: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, dh), lambda bb, hh, i, j: (bb, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, SQ, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, dh), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, len2d)
    return out[:, :, :sq, :]
