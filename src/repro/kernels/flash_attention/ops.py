from __future__ import annotations

import jax

from .flash_attention import flash_attention as _kernel
from .ref import flash_attention_ref

_ON_TPU = jax.default_backend() == "tpu"


def flash_attention(q, k, v, lengths=None, *, causal: bool = True,
                    bq: int = 128, bk: int = 128, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return flash_attention_ref(q, k, v, lengths, causal=causal)
    return _kernel(q, k, v, lengths, causal=causal, bq=bq, bk=bk,
                   interpret=not _ON_TPU)
