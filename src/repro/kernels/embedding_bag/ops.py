from __future__ import annotations

import jax

from .embedding_bag import embedding_bag as _kernel
from .ref import embedding_bag_ref

_ON_TPU = jax.default_backend() == "tpu"


def embedding_bag(table, indices, weights=None, *, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = _ON_TPU  # interpret-mode Pallas is for validation, not speed
    if not use_kernel:
        return embedding_bag_ref(table, indices, weights)
    return _kernel(table, indices, weights, interpret=not _ON_TPU)
