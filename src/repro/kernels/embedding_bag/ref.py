"""Pure-jnp oracle: gather + masked weighted sum (the segment_sum form)."""
import jax.numpy as jnp


def embedding_bag_ref(table, indices, weights=None):
    valid = (indices >= 0)
    if weights is None:
        weights = valid.astype(jnp.float32)
    else:
        weights = weights * valid
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)  # (n_bags, bag, D)
    return jnp.sum(rows.astype(jnp.float32) * weights[..., None], axis=1)
