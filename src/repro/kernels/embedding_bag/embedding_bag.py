"""EmbeddingBag Pallas kernel — the recsys hot path (JAX has no native
EmbeddingBag; this IS part of the system).

out[i] = sum_j weights[i, j] * table[indices[i, j]]

TPU adaptation: the indices are *scalar-prefetched* (SMEM) so the BlockSpec
index map of the embedding table can select the (1, D) row block for grid
step (i, j) — the gather is expressed as data-dependent block indexing, which
the Pallas pipeline turns into an HBM->VMEM DMA per row. Padded slots use
index -1 -> clamped to row 0 with weight 0 (exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += w_ref[0, 0] * table_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, *,
                  interpret: bool = False) -> jax.Array:
    """table: (V, D); indices: (n_bags, bag) int32, -1 = padding;
    weights: (n_bags, bag) or None (=1.0 for valid slots)."""
    n_bags, bag = indices.shape
    V, D = table.shape
    valid = (indices >= 0)
    if weights is None:
        weights = valid.astype(jnp.float32)
    else:
        weights = weights * valid
    idx = jnp.maximum(indices, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bags, bag),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
            pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, D), jnp.float32),
        interpret=interpret,
    )(idx, table, weights)
    return out
