"""Synthetic data pipelines: the M2Bench-style multi-model scenario, LM token
streams, graph samplers, and recsys batch generators."""
