"""Synthetic M2Bench-style multi-model scenario (paper §7, after [30]).

E-commerce scenario with the paper's running example:
  * relational: Product(id, title, price), Customer(id, person_id, name)
  * document:   Orders  {order_id, customer_id, product_id, quantity,
                          shipping: {city, days}, items: [tag ids]}
  * graphs:     Interested_in  (Persons -> Tags,   weight property)
                Follows        (Persons -> Persons)

Scale factor SF multiplies entity counts (the paper uses SF 1/2/5/10 over
M2Bench's 17k-84M records; this container scales the same shape down).

Queries exported mirror the paper's workload aliases:
  G1-G5: pattern-matching GCDI (Fig. 10/11); G6-G8 shortest-path;
  A1-A3: GCDA (regression / similarity / multiply).
"""
from __future__ import annotations

import numpy as np

from ..core.schema import (AnalyticsTask, GCDIATask, JoinPred, Pattern,
                           PatternVertex, Predicate, Query, chain_pattern)
from ..core.storage import Database, DictColumn, Graph, Table

N_TAGS = 200
FOOD_TAGS = 40          # tag ids [0, 40) are food-related
PRODUCT_TITLES = ("Yogurt", "Milk", "Bread", "Coffee", "Tea", "Chocolate",
                  "Laptop", "Phone", "Book", "Desk")


def generate(sf: int = 1, seed: int = 0) -> Database:
    rng = np.random.default_rng(seed)
    n_products = 1_000 * sf
    n_customers = 2_000 * sf
    n_orders = 10_000 * sf
    n_persons = n_customers + 500 * sf         # some persons aren't customers
    db = Database()

    # --- relational -------------------------------------------------------
    titles = [PRODUCT_TITLES[i % len(PRODUCT_TITLES)] + (f" v{i // len(PRODUCT_TITLES)}"
              if i >= len(PRODUCT_TITLES) else "") for i in range(n_products)]
    db.add_table(Table("Product", {
        "id": np.arange(n_products, dtype=np.int64),
        "title": DictColumn(values=titles),
        "price": rng.uniform(1, 500, n_products).round(2),
    }))
    db.add_table(Table("Customer", {
        "id": np.arange(n_customers, dtype=np.int64),
        "person_id": rng.permutation(n_persons)[:n_customers].astype(np.int64),
        "name": DictColumn(values=[f"cust_{i}" for i in range(n_customers)]),
        "age": rng.integers(18, 80, n_customers).astype(np.int64),
    }))

    # --- documents ----------------------------------------------------------
    cust_ids = rng.integers(0, n_customers, n_orders)
    prod_ids = rng.integers(0, n_products, n_orders)
    docs = []
    cities = ["wuhan", "beijing", "shanghai", "shenzhen", "chengdu"]
    for i in range(n_orders):
        docs.append({
            "order_id": int(i),
            "customer_id": int(cust_ids[i]),
            "product_id": int(prod_ids[i]),
            "quantity": int(rng.integers(1, 5)),
            "shipping": {"city": cities[int(rng.integers(0, len(cities)))],
                         "days": int(rng.integers(1, 10))},
            "items": rng.integers(0, N_TAGS, rng.integers(1, 4)).tolist(),
        })
    db.add_documents("Orders", docs)

    # --- Interested_in graph (Persons -> Tags) -----------------------------
    persons = Table("Persons", {
        "pid": np.arange(n_persons, dtype=np.int64),
        "country": DictColumn(values=[("cn", "us", "au", "uk")[i % 4]
                                      for i in range(n_persons)]),
    })
    tag_contents = ["food"] * FOOD_TAGS + [f"topic_{i}" for i in range(N_TAGS - FOOD_TAGS)]
    tags = Table("Tags", {
        "tid": np.arange(N_TAGS, dtype=np.int64),
        "content": DictColumn(values=tag_contents),
        "popularity": rng.uniform(0, 1, N_TAGS),
    })
    deg = rng.poisson(8, n_persons).clip(1, 40)
    src = np.repeat(np.arange(n_persons), deg)
    dst = rng.integers(0, N_TAGS, len(src))
    interest_edges = Table("Interested_in_edges", {
        "svid": src.astype(np.int64),
        "tvid": dst.astype(np.int64),
        "weight": rng.uniform(0, 1, len(src)),
    })
    db.add_graph(Graph("Interested_in", {"Persons": persons, "Tags": tags},
                       interest_edges, "Persons", "Tags"))

    # --- Follows graph (Persons -> Persons) --------------------------------
    fdeg = rng.poisson(5, n_persons).clip(0, 30)
    fsrc = np.repeat(np.arange(n_persons), fdeg)
    fdst = rng.integers(0, n_persons, len(fsrc))
    keep = fsrc != fdst
    follows_edges = Table("Follows_edges", {
        "svid": fsrc[keep].astype(np.int64),
        "tvid": fdst[keep].astype(np.int64),
        "since": rng.integers(2000, 2026, int(keep.sum())).astype(np.int64),
    })
    persons2 = Table("Persons", {k: v for k, v in persons.columns.items()})
    db.add_graph(Graph("Follows", {"Persons": persons2}, follows_edges,
                       "Persons", "Persons"))
    return db


# ---------------------------------------------------------------------------
# Skewed-key fixture: Zipfian join keys + the 4-source bushy exemplar
# ---------------------------------------------------------------------------

ZIPF_ALPHA = 0.9        # rank exponent of the Zipfian key draws
N_HUBS = 16             # distinct values of the low-NDV "hub" join key


def _zipf_weights(n: int, alpha: float = ZIPF_ALPHA) -> np.ndarray:
    w = np.arange(1, n + 1, dtype=np.float64) ** -alpha
    return w / w.sum()


def generate_skew(sf: int = 1, seed: int = 7) -> Database:
    """Zipfian-key workload (the MICRO/QUEST skew regime): the ``user_id``
    join keys of Clicks and Purchases follow a Zipf law over the same head,
    so the heavy keys match each other and the true join size is dominated
    by Σ_k c_k·p_k — which uniform-key NDV containment collapses to
    |L|·|R|/ndv, an order-of-magnitude underestimate. Histogram/MCV overlap
    (``ColumnStats.join_overlap``) recovers it.

    Also carries the 4-source bushy exemplar: two large fact tables
    (``SrcA``, ``DstB``) connected by a low-NDV ``hub`` key, each reducible
    by a small key list (``FiltA``, ``FiltD``) — the only cheap shape is
    bushy ``(FiltA⋈SrcA) ⋈ (DstB⋈FiltD)``; every left-deep order pays a
    huge hub-join intermediate on one side."""
    rng = np.random.default_rng(seed)
    db = Database()

    # --- Zipfian 3-join tables ---------------------------------------------
    n_users = 2_000 * sf
    n_clicks = 12_000 * sf
    n_purchases = 9_000 * sf
    n_pages, n_products = 500, 400
    w = _zipf_weights(n_users)
    db.add_table(Table("Clicks", {
        "click_id": np.arange(n_clicks, dtype=np.int64),
        "user_id": rng.choice(n_users, size=n_clicks, p=w).astype(np.int64),
        "page_id": rng.integers(0, n_pages, n_clicks).astype(np.int64),
    }))
    db.add_table(Table("Purchases", {
        "purchase_id": np.arange(n_purchases, dtype=np.int64),
        "user_id": rng.choice(n_users, size=n_purchases, p=w).astype(np.int64),
        "product_id": rng.integers(0, n_products, n_purchases).astype(np.int64),
    }))
    db.add_table(Table("Pages", {
        "id": np.arange(n_pages, dtype=np.int64),
        "kind": DictColumn(values=[("ad", "organic", "search", "social",
                                    "mail")[i % 5] for i in range(n_pages)]),
    }))
    db.add_table(Table("Products", {
        "id": np.arange(n_products, dtype=np.int64),
        "cat": DictColumn(values=[("gear", "food", "media", "home")[i % 4]
                                  for i in range(n_products)]),
    }))

    # --- 4-source bushy exemplar -------------------------------------------
    n_fact = 12_000 * sf
    n_keys = 60
    hubs = [f"h{i}" for i in range(N_HUBS)]
    db.add_table(Table("SrcA", {
        "id": np.arange(n_fact, dtype=np.int64),
        "akey": rng.integers(0, n_fact, n_fact).astype(np.int64),
        "hub": DictColumn(values=[hubs[i] for i in
                                  rng.integers(0, N_HUBS, n_fact)]),
    }))
    db.add_table(Table("DstB", {
        "id": np.arange(n_fact, dtype=np.int64),
        "bkey": rng.integers(0, n_fact, n_fact).astype(np.int64),
        "hub": DictColumn(values=[hubs[i] for i in
                                  rng.integers(0, N_HUBS, n_fact)]),
    }))
    db.add_table(Table("FiltA", {
        "akey": np.sort(rng.choice(n_fact, n_keys, replace=False)).astype(np.int64),
    }))
    db.add_table(Table("FiltD", {
        "bkey": np.sort(rng.choice(n_fact, n_keys, replace=False)).astype(np.int64),
    }))
    return db


def q_skew_3join() -> Query:
    """Skewed 3-join exemplar: the Clicks⋈Purchases key join is Zipf × Zipf
    (aligned heads), flanked by two uniform FK→PK joins with selective
    filters — the root cardinality hinges on the key-distribution overlap,
    exactly where NDV containment collapses."""
    return Query(
        select=("Clicks.click_id", "Purchases.purchase_id"),
        froms=("Clicks", "Purchases", "Pages", "Products"),
        joins=(JoinPred("Clicks.user_id", "Purchases.user_id"),
               JoinPred("Clicks.page_id", "Pages.id"),
               JoinPred("Purchases.product_id", "Products.id")),
        where=(Predicate("Pages.kind", "==", "ad"),
               Predicate("Products.cat", "==", "gear")),
    )


def q_bushy_4src() -> Query:
    """4-source chain FiltA—SrcA—DstB—FiltD whose only cheap plan is bushy:
    both fact tables must be reduced by their key lists *before* the
    many-many hub join; any left-deep order crosses the hub edge with one
    side unreduced and pays a ~1000x larger intermediate."""
    return Query(
        select=("SrcA.id", "DstB.id"),
        froms=("FiltA", "SrcA", "DstB", "FiltD"),
        joins=(JoinPred("FiltA.akey", "SrcA.akey"),
               JoinPred("SrcA.hub", "DstB.hub"),
               JoinPred("DstB.bkey", "FiltD.bkey")),
    )


# ---------------------------------------------------------------------------
# Workload: GCDI queries G1-G8 and GCDA tasks A1-A3 (paper aliases)
# ---------------------------------------------------------------------------


def q_g1() -> Query:
    """G1: single-hop pattern, equality predicate on target vertex +
    cross-model join with Customer (the paper's Fig. 1(a)/Eq. 2 query)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Customer.id", "t.tid"),
        froms=("Customer",),
        match=pat,
        joins=(JoinPred("Customer.person_id", "p.pid"),),
        where=(Predicate("t.content", "==", "food"),),
    )


def q_g2() -> Query:
    """G2: predicate on source side + document join (Orders docs)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Orders.order_id", "t.tid"),
        froms=("Customer", "Orders"),
        match=pat,
        joins=(JoinPred("Customer.person_id", "p.pid"),
               JoinPred("Orders.customer_id", "Customer.id")),
        where=(Predicate("p.country", "==", "cn"),
               Predicate("Orders.shipping.days", "<=", 3)),
    )


def q_g3() -> Query:
    """G3: two-hop pattern on the homogeneous Follows graph."""
    pat = chain_pattern("Follows",
                        ("a", "Persons", "Follows", "b", "Persons"),
                        ("b", "Persons", "Follows", "c", "Persons"))
    return Query(
        select=("a.pid", "c.pid"),
        froms=(),
        match=pat,
        where=(Predicate("a.country", "==", "au"),
               Predicate("c.country", "==", "uk")),
    )


def q_g4() -> Query:
    """G4: join-pushdown shape (Eq. 8): Product -> Orders -> Customer ->
    pattern; selective predicate on Product.title (the yogurt query)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Customer.id", "t.tid"),
        froms=("Product", "Orders", "Customer"),
        match=pat,
        joins=(JoinPred("Product.id", "Orders.product_id"),
               JoinPred("Orders.customer_id", "Customer.id"),
               JoinPred("Customer.person_id", "p.pid")),
        where=(Predicate("Product.title", "==", "Yogurt"),),
    )


def q_opt_skew() -> Query:
    """Skewed 3-join optimizer exemplar: the query order merges the two
    largest collections (graph relation, Orders) first and leaves the
    selective Product.title filter for last — smallest-intermediate-first
    reordering must flip it (Product ⋈ Orders ⋈ Customer ⋈ pattern)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Customer.id", "t.tid"),
        froms=("Orders", "Customer", "Product"),
        match=pat,
        joins=(JoinPred("Customer.person_id", "p.pid"),
               JoinPred("Orders.customer_id", "Customer.id"),
               JoinPred("Product.id", "Orders.product_id")),
        where=(Predicate("Product.title", "==", "Yogurt"),
               Predicate("t.content", "==", "food")),
    )


def build_indexes(db: Database):
    """Secondary indexes for the selective-access workload (the index
    benchmark suite and tests): table-side sorted/zone indexes on the join
    and lookup keys, plus the graph-side composite (label, attr) vertex
    indexes that seed pattern candidates. Returns the IndexManager."""
    im = db.indexes
    im.create("Customer", "person_id")                      # sorted (int key)
    im.create("Orders", "order_id", kind="zone")            # clustered: zones prune exactly
    im.create("Product", "price")                           # sorted (random float)
    im.create("Interested_in", "pid", label="Persons")      # composite (label, attr)
    im.create("Interested_in", "popularity", label="Tags")
    im.create("Interested_in", "content", label="Tags")     # hash over dict codes
    return im


def point_lookup_keys(db: Database) -> tuple[int, int]:
    """A consistent (person_id, order_id) pair for ``q_point_lookup``:
    order 0's customer and that customer's person, so the point query is
    non-empty at every scale factor."""
    orders = db.tables["Orders"]
    c0 = int(np.asarray(orders.col("customer_id"))[0])
    pid = int(np.asarray(db.tables["Customer"].col("person_id"))[c0])
    oid = int(np.asarray(orders.col("order_id"))[0])
    return pid, oid


def q_point_lookup(pid: int = 777, oid: int = 4242) -> Query:
    """Index exemplar 1: single-key equalities at ~1e-4 selectivity — the
    graph-side composite (Persons, pid) index seeds the match frontier
    from one vertex, the Customer.person_id sorted index replaces the
    table scan, and the clustered Orders.order_id zone maps skip-scan the
    document collection. Without indexes every predicate pays O(n) column
    scans. (Use ``point_lookup_keys`` for a non-empty result.)"""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Customer.id", "t.tid"),
        froms=("Customer", "Orders"),
        match=pat,
        joins=(JoinPred("Orders.customer_id", "Customer.id"),
               JoinPred("Customer.person_id", "p.pid")),
        where=(Predicate("p.pid", "==", pid),
               Predicate("Customer.person_id", "==", pid),
               Predicate("Orders.order_id", "==", oid)),
    )


def q_range_narrow(lo: float = 100.0, hi: float = 100.5) -> Query:
    """Index exemplar 2: tight numeric ranges — Product.price in a 0.1%
    window (table-side sorted index) and t.popularity in a 2% window
    (graph-side composite (Tags, popularity) index), flowing through the
    q_g4-shaped Product -> Orders -> Customer -> pattern join chain."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("Customer.id", "t.tid"),
        froms=("Product", "Orders", "Customer"),
        match=pat,
        joins=(JoinPred("Product.id", "Orders.product_id"),
               JoinPred("Orders.customer_id", "Customer.id"),
               JoinPred("Customer.person_id", "p.pid")),
        where=(Predicate("Product.price", "range", lo, hi),
               Predicate("t.popularity", "range", 0.90, 0.92)),
    )


def q_shard_join() -> Query:
    """Scan/join-heavy sharded-execution exemplar: the full Orders document
    collection (the largest base table) filtered by two pushed predicates,
    then FK-joined to Customer and Product on the integer keys. No graph
    pattern and no expected indexes — execution is dominated by the scan
    and the two large equi-joins, which is exactly what hash-sharded
    morsel-parallel execution accelerates."""
    return Query(
        select=("Orders.order_id", "Orders.quantity", "Orders.shipping.days",
                "Customer.id", "Customer.age", "Product.price"),
        froms=("Orders", "Customer", "Product"),
        joins=(JoinPred("Orders.customer_id", "Customer.id"),
               JoinPred("Orders.product_id", "Product.id")),
        where=(Predicate("Orders.quantity", ">=", 2),
               Predicate("Orders.shipping.days", "<=", 7)),
    )


def a_shard_reg() -> GCDIATask:
    """GCDIA rider for the shard benchmark: Rel2Matrix feature/label
    matrices over the numeric GCDI columns feeding a logistic REGRESSION
    (output stays d-sized and device-resident), so the born-sharded
    GCDI -> GCDA matrix handoff sits on the critical path at any row
    count."""
    return GCDIATask(
        integration=q_shard_join(),
        analytics=AnalyticsTask("REGRESSION", [
            ("rel2matrix", ("Orders.quantity", "Orders.shipping.days",
                            "Customer.age", "Product.price")),
            ("rel2matrix", ("Orders.quantity",)),
        ]),
    )


def q_g5() -> Query:
    """G5: range predicate on edge property (match-trimming candidate:
    v-e-v with edge-only predicates, but projection references vertices)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(
        select=("p.pid", "t.tid"),
        froms=(),
        match=pat,
        where=(Predicate("e0.weight", ">", 0.9),),
    )


def q_edge_scan() -> Query:
    """Match-trimming case 2 exemplar (paper §6.2 example 2)."""
    pat = chain_pattern("Interested_in", ("p", "Persons", "Interested_in", "t", "Tags"))
    return Query(select=("e0.weight",), froms=(), match=pat,
                 where=(Predicate("e0.weight", ">", 0.5),))


def q_vertex_scan() -> Query:
    """Match-trimming case 1 exemplar (paper §6.2 example 1)."""
    pat = Pattern("Interested_in", (PatternVertex("t", "Tags"),), ())
    return Query(select=("t.tid",), froms=(), match=pat,
                 where=(Predicate("t.content", "==", "food"),))


def a1_regression() -> GCDIATask:
    """A1: logistic regression — predict yogurt purchase from interest tags
    (the paper's running example)."""
    return GCDIATask(
        integration=q_g1(),
        analytics=AnalyticsTask("REGRESSION", [
            ("random", "Customer.id", "t.tid", N_TAGS),
        ]),
    )


def a2_similarity() -> GCDIATask:
    """A2: cosine similarity between customers' tag-interest vectors."""
    return GCDIATask(
        integration=q_g1(),
        analytics=AnalyticsTask("SIMILARITY", [
            ("random", "Customer.id", "t.tid", N_TAGS),
        ]),
    )


def a3_multiply() -> GCDIATask:
    """A3: matrix multiply — customer-tag incidence x tag co-occurrence."""
    return GCDIATask(
        integration=q_g1(),
        analytics=AnalyticsTask("MULTIPLY", [
            ("random", "Customer.id", "t.tid", N_TAGS),
        ]),
    )


def purchase_labels(db: Database, product_title: str = "Yogurt") -> np.ndarray:
    """Ground-truth labels for A1: 1 if the customer ever bought the
    product (computed across Product ⋈ Orders)."""
    prod = db.tables["Product"]
    orders = db.tables["Orders"]
    title_col = prod.col("title")
    pid = np.nonzero(title_col.codes == title_col.encode(product_title))[0]
    bought = np.isin(np.asarray(orders.col("product_id")), pid)
    labels = np.zeros(db.tables["Customer"].nrows, dtype=np.float32)
    labels[np.asarray(orders.col("customer_id"))[bought]] = 1.0
    return labels
