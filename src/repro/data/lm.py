"""Synthetic LM data pipeline: a deterministic, seekable token stream.

Deterministic addressing (stream[step, row] is a pure function of the seed)
makes the pipeline *restart-transparent*: after a failure the Trainer
resumes at step N and the pipeline regenerates exactly the batches it would
have produced — no data-loader state in the checkpoint. Sharded hosts each
draw their own row range (host_id striding)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def batch_at(self, step: int) -> dict:
        """Markov-chain-ish synthetic tokens: structured enough that a real
        LM loss decreases, deterministic per (seed, step, row)."""
        rows = self.batch // self.n_hosts
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.host_id)
        base = rng.integers(0, self.vocab, (rows, 1))
        drift = rng.integers(-8, 9, (rows, self.seq)).cumsum(axis=1)
        toks = (base + np.abs(drift)) % self.vocab
        toks = toks.astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1  # no target for the last position
        return {"tokens": toks, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
