"""Graph data pipeline: synthetic generators for the assigned shapes and a
REAL fanout neighbor sampler (the minibatch_lg regime) producing padded
static-shape subgraphs suitable for jit.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core.storage import build_csr
from ..models.gnn.common import GraphBatch


def random_feature_graph(n_nodes: int, n_edges: int, d_feat: int,
                         n_classes: int, seed: int = 0
                         ) -> tuple[GraphBatch, jnp.ndarray]:
    """Citation-style graph: features + node labels (full-batch)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    x = rng.standard_normal((n_nodes, d_feat)).astype(np.float32) * 0.2
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    g = GraphBatch(src=jnp.asarray(src, jnp.int32), dst=jnp.asarray(dst, jnp.int32),
                   x=jnp.asarray(x))
    return g, jnp.asarray(labels)


def random_molecule_batch(batch: int, n_nodes: int, n_edges: int,
                          n_species: int = 16, seed: int = 0
                          ) -> tuple[GraphBatch, jnp.ndarray]:
    """Batched small 3D graphs (flattened with graph_id) + energy labels."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    pos = rng.standard_normal((N, 3)).astype(np.float32) * 1.5
    species = rng.integers(0, n_species, N).astype(np.int32)
    # intra-graph edges, no self loops
    s_loc = rng.integers(0, n_nodes, E)
    d_off = rng.integers(1, n_nodes, E)
    d_loc = (s_loc + d_off) % n_nodes
    gidx = np.repeat(np.arange(batch), n_edges)
    src = (gidx * n_nodes + s_loc).astype(np.int32)
    dst = (gidx * n_nodes + d_loc).astype(np.int32)
    graph_id = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    energies = rng.standard_normal(batch).astype(np.float32)
    g = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                   pos=jnp.asarray(pos), species=jnp.asarray(species),
                   graph_id=jnp.asarray(graph_id), n_graphs=batch)
    return g, jnp.asarray(energies)


def random_geometric_graph(n_nodes: int, n_edges: int, n_species: int = 16,
                           seed: int = 0) -> tuple[GraphBatch, jnp.ndarray]:
    """Single large 3D point cloud (equivariant archs on graph shapes)."""
    rng = np.random.default_rng(seed)
    pos = rng.standard_normal((n_nodes, 3)).astype(np.float32) * 3
    species = rng.integers(0, n_species, n_nodes).astype(np.int32)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = ((src + rng.integers(1, n_nodes, n_edges)) % n_nodes).astype(np.int32)
    g = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                   pos=jnp.asarray(pos), species=jnp.asarray(species),
                   n_graphs=1)
    return g, jnp.zeros((1,), jnp.float32)


# ---------------------------------------------------------------------------
# Neighbor sampler (minibatch_lg): real fanout sampling over CSR
# ---------------------------------------------------------------------------


class NeighborSampler:
    """GraphSAGE-style fanout sampler. Produces padded, static-shape
    subgraphs: at fanouts (f1, f2) and S seeds the outputs are always
    (S*(1+f1+f1*f2)) nodes and (S*f1 + S*f1*f2) edges with validity masks —
    jit-stable across batches."""

    def __init__(self, n_nodes: int, src: np.ndarray, dst: np.ndarray,
                 x: np.ndarray, labels: np.ndarray, fanouts=(15, 10),
                 seed: int = 0):
        self.csr = build_csr(n_nodes, dst, src)  # sample in-neighbors
        self.n_nodes = n_nodes
        self.x = x
        self.labels = labels
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def _sample_layer(self, frontier: np.ndarray, fanout: int):
        """For each frontier node sample <= fanout in-neighbors (without
        replacement), padded to exactly fanout with -1."""
        deg = (self.csr.row_ptr[frontier + 1]
               - self.csr.row_ptr[frontier]).astype(np.int64)
        out = np.full((len(frontier), fanout), -1, dtype=np.int64)
        # vectorized sampling: random offsets modulo degree (with replacement
        # when deg > fanout is false this matches uniform; dedup not needed
        # for SAGE-style estimators)
        r = self.rng.integers(0, 1 << 62, size=(len(frontier), fanout))
        has = deg > 0
        offs = r[has] % deg[has, None]
        out[has] = self.csr.col_idx[self.csr.row_ptr[frontier[has], None]
                                    + offs]
        return out

    def sample(self, seeds: np.ndarray) -> tuple[GraphBatch, jnp.ndarray]:
        S = len(seeds)
        f1, f2 = self.fanouts
        l1 = self._sample_layer(seeds, f1)                 # (S, f1)
        l1_flat = l1.reshape(-1)
        l1_safe = np.maximum(l1_flat, 0)
        l2 = self._sample_layer(l1_safe, f2)               # (S*f1, f2)
        l2[l1_flat < 0] = -1
        l2_flat = l2.reshape(-1)

        # node table: [seeds | l1 | l2] with padding
        all_nodes = np.concatenate([seeds, l1_flat, l2_flat])
        node_mask = (all_nodes >= 0).astype(np.float32)
        safe_nodes = np.maximum(all_nodes, 0)

        n_sub = len(all_nodes)
        # edges: l1[i,j] -> seed i ; l2[e,j] -> l1-node e
        src1 = S + np.arange(S * f1)
        dst1 = np.repeat(np.arange(S), f1)
        m1 = l1_flat >= 0
        src2 = S + S * f1 + np.arange(S * f1 * f2)
        dst2 = S + np.repeat(np.arange(S * f1), f2)
        m2 = l2_flat >= 0
        src = np.concatenate([src1, src2]).astype(np.int32)
        dst = np.concatenate([dst1, dst2]).astype(np.int32)
        edge_mask = np.concatenate([m1, m2]).astype(np.float32)

        x = self.x[safe_nodes].astype(np.float32) * node_mask[:, None]
        labels = np.where(all_nodes >= 0, self.labels[safe_nodes], -1)
        # only seeds carry supervised labels
        labels[S:] = -1
        g = GraphBatch(src=jnp.asarray(src), dst=jnp.asarray(dst),
                       x=jnp.asarray(x),
                       node_mask=jnp.asarray(node_mask),
                       edge_mask=jnp.asarray(edge_mask))
        return g, jnp.asarray(labels.astype(np.int32))
