"""Production mesh construction. A FUNCTION (not a module constant) so that
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis rides
    DCN and composes with 'data' for hierarchical gradient reduction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices exist — used by tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
