"""Serving launcher: batched autoregressive decoding with a KV cache.

``python -m repro.launch.serve --arch qwen2-1.5b --batch 4 --prompt-len 32
--gen 32`` runs prefill + decode on the smoke config (CPU) or the published
config (--preset full, TPU-scale)."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    from repro import configs
    from repro.models import transformer as tfm

    mod = configs.get(args.arch)
    cfg = mod.config() if args.preset == "full" else mod.smoke_config()
    if args.preset == "smoke":
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)

    B, P, G = args.batch, args.prompt_len, args.gen
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    cache = tfm.init_cache(cfg, B, P + G)

    prefill = jax.jit(lambda p, c, t: tfm.forward(
        p, t, cfg, cache=c, cache_lengths=jnp.zeros((B,), jnp.int32)))
    decode = jax.jit(lambda p, c, t, l: tfm.serve_step(p, c, t, l, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, prompts)
    next_tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t1 = time.perf_counter()

    lengths = jnp.full((B,), P, jnp.int32)
    out = [next_tok]
    for i in range(G - 1):
        logits, cache = decode(params, cache, next_tok, lengths)
        next_tok = jnp.argmax(logits, -1)[:, None]
        lengths = lengths + 1
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    t2 = time.perf_counter()
    toks = jnp.concatenate(out, axis=1)
    print(f"[serve] {cfg.name}: prefill {B}x{P} in {t1-t0:.2f}s; "
          f"decoded {G} tokens in {t2-t1:.2f}s "
          f"({B*(G-1)/max(t2-t1,1e-9):.1f} tok/s)")
    print("[serve] sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
