import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (dry-run only: 512 placeholder host devices so jax.make_mesh can build the
#  production mesh; smoke tests and benches must NOT import this module.)
if os.environ.get("DRYRUN_DEVICE_COUNT"):  # local-test override, pre-jax-init
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["DRYRUN_DEVICE_COUNT"])

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., donate...).lower(*specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes
and record the result as JSON under experiments/dryrun/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             mesh_override=None, perf_variant: str = "") -> dict:
    import jax
    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.launch.hlo_analysis import collective_bytes, hlo_cost

    t0 = time.time()
    mesh = mesh_override if mesh_override is not None else \
        make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "multi_pod": multi_pod, "perf_variant": perf_variant}
    try:
        with mesh:
            cell = build_cell(arch, shape_name, mesh)
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            corrected = hlo_cost(hlo)  # trip-count-aware (XLA counts while
            #                            bodies once — verified empirically)

            rec.update({
                "ok": True,
                "kind": cell.kind,
                "meta": cell.meta,
                "lower_s": round(t_lower - t0, 2),
                "compile_s": round(t_compile - t_lower, 2),
                "flops_per_device": float(cost.get("flops", 0.0)),
                "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
                "dot_flops_per_device": corrected["flops"],
                "hbm_bytes_per_device": corrected["bytes"],
                "collectives": coll,
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                    "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                    "generated_code_bytes": getattr(
                        mem, "generated_code_size_in_bytes", 0),
                },
                "n_devices": mesh.devices.size,
            })
            print(f"[dryrun] {arch}/{shape_name}/{mesh_name}"
                  f"{'/' + perf_variant if perf_variant else ''}: OK "
                  f"compile={rec['compile_s']}s "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"coll={coll['total_bytes']:.3e}B")
            print(f"  memory: args={rec['memory']['argument_bytes']/1e9:.2f}GB "
                  f"out={rec['memory']['output_bytes']/1e9:.2f}GB "
                  f"temp={rec['memory']['temp_bytes']/1e9:.2f}GB")
    except Exception as e:  # noqa: BLE001 — record failures, they are bugs
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] {arch}/{shape_name}/{mesh_name}: FAIL {e}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{perf_variant}" if perf_variant else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        slim = {k: v for k, v in rec.items() if k != "traceback"}
        with open(path, "w") as f:
            json.dump(slim, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--perf-variant", default="",
                    help="tag an optimized variant (env flags set by caller)")
    args = ap.parse_args()

    from repro import configs

    cells = []
    if args.all:
        cells = [(a, s) for a, s, _ in configs.all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch.replace("-", "_").replace(".", "_"), args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out,
                           perf_variant=args.perf_variant)
            failures += 0 if rec.get("ok") else 1
    print(f"[dryrun] done: {len(cells) * len(meshes) - failures} ok, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
