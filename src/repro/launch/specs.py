"""Per-cell step builders + ShapeDtypeStruct input specs for the dry-run.

``build_cell(arch, shape_name, mesh)`` returns a ``Cell`` with:
  * ``fn``            — the step function to lower (train_step / prefill_step
                         / serve_step / gnn_train_step / recsys steps)
  * ``in_shardings``  — pytree of NamedSharding matching ``args``
  * ``args``          — pytree of jax.ShapeDtypeStruct (weak-type-correct,
                         shardable, never allocated)
  * ``meta``          — flops/bytes accounting inputs for §Roofline
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import configs as configs_pkg
from ..distributed import sharding as shr
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update

Pytree = Any


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def _sds(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh) -> Cell:
    from ..models import transformer as tfm

    mod = configs_pkg.get(arch)
    cfg = mod.config()
    B, S = spec["batch"], spec["seq"]
    dp = shr.dp_axes(mesh)
    import os
    dp_total = int(np.prod([shr.axis_size(mesh, a) for a in dp]))
    if cfg.is_moe:
        # dispatch groups == DP shards: top-k sort + capacity are shard-local
        cfg = dataclasses.replace(cfg, moe_groups=min(dp_total, B))
        if os.environ.get("REPRO_MOE_EP") == "1":  # §Perf M1 variant
            cfg = dataclasses.replace(cfg, mesh=mesh, mesh_dp=tuple(dp),
                                      moe_ep_axis="model")
        if os.environ.get("REPRO_MOE_SHARDMAP") == "1":  # §Perf M2 variant
            cfg = dataclasses.replace(cfg, mesh=mesh, mesh_dp=tuple(dp),
                                      moe_ep_axis="model",
                                      moe_impl="shard_map")

    params_shape = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = shr.lm_param_specs(cfg, mesh)
    pshard = shr.tree_shardings(pspecs, mesh)
    batch_sh = NamedSharding(mesh, P(dp, None))

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if spec["kind"] == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = shr.opt_state_specs(pspecs, params_shape, mesh)
        oshard = shr.tree_shardings(ospecs, mesh)
        opt_cfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            (loss, nll), grads = jax.value_and_grad(
                tfm.loss_fn, has_aux=True)(params, batch, cfg)
            params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, {"loss": loss, "nll": nll}

        args = (params_shape, opt_shape,
                {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)})
        in_sh = (pshard, oshard, {"tokens": batch_sh, "labels": batch_sh})
        return Cell(arch, shape_name, "train", train_step, args, in_sh,
                    donate_argnums=(0, 1),
                    meta={"tokens": B * S, "n_params": n_params,
                          "n_active": n_active, "fwd_bwd": True})

    import os
    kv_seq_shard = (os.environ.get("REPRO_KV_SEQ_SHARD") == "1"
                    and spec["kind"] == "decode")
    if kv_seq_shard:
        cfg = dataclasses.replace(cfg, mesh=mesh, mesh_dp=tuple(dp),
                                  kv_seq_shard="model")
    cache_shape = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S))
    cspecs = shr.lm_cache_specs(cfg, mesh, seq_shard=kv_seq_shard)
    cshard = shr.tree_shardings(cspecs, mesh)
    len_sh = NamedSharding(mesh, P(dp))

    if spec["kind"] == "prefill":
        def prefill_step(params, cache, tokens):
            logits, new_cache = tfm.forward(
                params, tokens, cfg, cache=cache,
                cache_lengths=jnp.zeros((tokens.shape[0],), jnp.int32))
            return logits[:, -1], new_cache

        args = (params_shape, cache_shape,
                jax.ShapeDtypeStruct((B, S), jnp.int32))
        in_sh = (pshard, cshard, batch_sh)
        return Cell(arch, shape_name, "prefill", prefill_step, args, in_sh,
                    donate_argnums=(1,),
                    meta={"tokens": B * S, "n_params": n_params,
                          "n_active": n_active, "fwd_bwd": False})

    if spec["kind"] == "decode":
        def decode_step(params, cache, tokens, lengths):
            return tfm.serve_step(params, cache, tokens, lengths, cfg)

        args = (params_shape, cache_shape,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32))
        in_sh = (pshard, cshard, batch_sh, len_sh)
        return Cell(arch, shape_name, "decode", decode_step, args, in_sh,
                    donate_argnums=(1,),
                    meta={"tokens": B, "n_params": n_params,
                          "n_active": n_active, "fwd_bwd": False,
                          "kv_bytes": int(np.prod(
                              cache_shape["k"].shape)) * 2 * 2})

    raise ValueError(spec["kind"])


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh) -> Cell:
    from ..models.gnn import build as gnn_build
    return gnn_build.build_cell(arch, shape_name, spec, mesh, Cell)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh) -> Cell:
    from ..models import recsys as rs
    return rs.build_cell(arch, shape_name, spec, mesh, Cell)


def _db_cell(arch: str, shape_name: str, spec: dict, mesh: Mesh) -> Cell:
    """The paper's GCDA operators (§5.4) at production scale — bonus cells
    proving the engine's analytical layer itself shards onto the meshes."""
    from jax.experimental.shard_map import shard_map
    dp = shr.dp_axes(mesh)
    f32 = jnp.float32
    kind = spec["kind"]

    if kind == "gcda_regression":
        n, d = spec["rows"], spec["features"]

        def step(X, y, w):
            def local(Xl, yl, wl):
                z = Xl @ wl
                p = jax.nn.sigmoid(z)
                g = jax.lax.psum(Xl.T @ (p - yl), dp) / n
                loss = jax.lax.psum(
                    jnp.sum(jax.nn.softplus(z) - yl * z), dp) / n
                return wl - 0.5 * g, loss

            return shard_map(local, mesh=mesh,
                             in_specs=(P(dp, None), P(dp), P()),
                             out_specs=(P(), P()))(X, y, w)

        args = (jax.ShapeDtypeStruct((n, d), f32),
                jax.ShapeDtypeStruct((n,), f32),
                jax.ShapeDtypeStruct((d,), f32))
        in_sh = (NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp)),
                 NamedSharding(mesh, P()))
        meta = {"rows": n, "features": d, "fwd_bwd": True}
        return Cell(arch, shape_name, "gcda_regression", step, args, in_sh,
                    meta=meta)

    if kind == "gcda_similarity":
        n, d = spec["rows"], spec["features"]

        def sim(X, Y):
            from ..kernels.cosine_sim.ref import cosine_sim_ref
            return cosine_sim_ref(X, Y).astype(jnp.bfloat16)

        args = (jax.ShapeDtypeStruct((n, d), f32),
                jax.ShapeDtypeStruct((n, d), f32))
        in_sh = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P("model", None)))
        return Cell(arch, shape_name, "gcda_similarity", sim, args, in_sh,
                    meta={"rows": n, "features": d, "fwd_bwd": False})

    if kind == "gcda_multiply":
        m, k, n = spec["m"], spec["k"], spec["n"]

        def mul(X, Y):
            return (X @ Y).astype(jnp.bfloat16)

        args = (jax.ShapeDtypeStruct((m, k), f32),
                jax.ShapeDtypeStruct((k, n), f32))
        in_sh = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P(None, "model")))
        return Cell(arch, shape_name, "gcda_multiply", mul, args, in_sh,
                    meta={"m": m, "k": k, "n": n, "fwd_bwd": False})

    raise ValueError(kind)


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    mod = configs_pkg.get(arch)
    spec = mod.SHAPES[shape_name]
    if spec.get("skip"):
        raise ValueError(f"cell {arch}/{shape_name} is skipped: {spec['skip']}")
    if mod.FAMILY == "lm":
        return _lm_cell(arch, shape_name, spec, mesh)
    if mod.FAMILY == "gnn":
        return _gnn_cell(arch, shape_name, spec, mesh)
    if mod.FAMILY == "recsys":
        return _recsys_cell(arch, shape_name, spec, mesh)
    if mod.FAMILY == "db":
        return _db_cell(arch, shape_name, spec, mesh)
    raise ValueError(mod.FAMILY)
